"""CI perf-regression gate over BENCH_kernel.json.

Compares a freshly produced benchmark JSON against the committed baseline
(benchmarks/baselines/BENCH_kernel.baseline.json) and FAILS (exit 1) when:

  * any traffic/efficiency ratio regresses more than --tolerance (default
    10%) below its baseline value — keys named `ratio` or `*_ratio*`, plus
    nested {"ratio": ...} traffic dicts;
  * any access count GROWS — keys named `accesses`, `ledger_accesses`,
    `banked_accesses` or `waves`: the planner/dispatcher access model is
    exact, so any growth is a real cost regression, not noise;
  * the jitted-dispatch count of a warm macro/region (`dispatches`) GROWS —
    the whole-schedule compiler's guarantee is ONE dispatch per schedule,
    and the dispatch count is the deterministic walltime proxy;
  * a gated baseline key disappeared from the current run (a silently
    dropped benchmark section must not pass the gate).

Wall-times and machine-dependent metrics are deliberately NOT gated; the
gated quantities are analytic (byte models, schedule lengths, tile counts)
and therefore deterministic across hosts.

Usage:
    python benchmarks/check_regression.py [BENCH_kernel.json]
        [--baseline benchmarks/baselines/BENCH_kernel.baseline.json]
        [--tolerance 0.10]
"""
from __future__ import annotations

import argparse
import json
import sys

#: key names gated as never-grow counters (exact, deterministic)
COUNTER_KEYS = ("accesses", "ledger_accesses", "banked_accesses", "waves",
                "dispatches")


def _is_ratio_key(key: str) -> bool:
    return "ratio" in key


def compare(baseline, current, tolerance: float, path: str = ""):
    """Yield (path, kind, baseline, current) problem tuples."""
    if isinstance(baseline, dict):
        if not isinstance(current, dict):
            yield (path, "missing", baseline, current)
            return
        for key, bval in baseline.items():
            sub = f"{path}.{key}" if path else key
            if key in current:
                yield from compare(bval, current[key], tolerance, sub)
            elif _gated(key, bval):
                yield (sub, "missing", bval, None)
        return
    key = path.rsplit(".", 1)[-1]
    if not isinstance(baseline, (int, float)) or isinstance(baseline, bool):
        return
    if not isinstance(current, (int, float)):
        yield (path, "missing", baseline, current)
        return
    if _is_ratio_key(key) and current < baseline * (1.0 - tolerance):
        yield (path, "ratio-regressed", baseline, current)
    if key in COUNTER_KEYS and current > baseline:
        yield (path, "count-grew", baseline, current)


def _gated(key: str, value) -> bool:
    """Does this baseline subtree contain anything the gate checks?"""
    if isinstance(value, dict):
        return any(_gated(k, v) for k, v in value.items())
    return _is_ratio_key(key) or key in COUNTER_KEYS


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", nargs="?", default="BENCH_kernel.json",
                    help="benchmark JSON produced by kernel_bench.py --json")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/BENCH_kernel.baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed fractional ratio drop (default 0.10)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)

    problems = list(compare(baseline, current, args.tolerance))
    checked = sum(_count_gated(k, v) for k, v in baseline.items())
    if problems:
        print(f"PERF REGRESSION: {len(problems)} of {checked} gated metrics "
              f"failed vs {args.baseline}")
        for path, kind, bval, cval in problems:
            print(f"  {kind:16s} {path}: baseline={str(bval)[:80]} "
                  f"current={str(cval)[:80]}")
        return 1
    print(f"perf gate OK: {checked} gated metrics within tolerance "
          f"({args.tolerance:.0%} ratio drop, zero access growth)")
    return 0


def _count_gated(key: str, value) -> int:
    if isinstance(value, dict):
        return sum(_count_gated(k, v) for k, v in value.items())
    return int(_is_ratio_key(key) or key in COUNTER_KEYS)


if __name__ == "__main__":
    sys.exit(main())

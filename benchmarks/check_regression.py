"""CI perf-regression gate over benchmark JSON (kernel bench, serve bench).

Compares a freshly produced benchmark JSON against the committed baseline
(benchmarks/baselines/*.baseline.json, picked with --baseline) and FAILS
(exit 1) when:

  * any traffic/efficiency ratio regresses more than --tolerance (default
    10%) below its baseline value — keys named `ratio` or `*_ratio*`, plus
    nested {"ratio": ...} traffic dicts;
  * any access count GROWS — keys named `accesses`, `ledger_accesses`,
    `banked_accesses`, `waves`, the serve engine's `load_accesses` /
    `total_accesses` and their `*_per_token` forms: the planner/dispatcher
    charge model is exact and the serve bench's request schedule is
    deterministic (arrival interval 0), so any growth is a real cost
    regression, not noise;
  * the jitted-dispatch count of a warm macro/region (`dispatches`) GROWS —
    the whole-schedule compiler's guarantee is ONE dispatch per schedule,
    and the dispatch count is the deterministic walltime proxy;
  * a latency key (`p99_ms`) exceeds baseline * --latency-factor (default
    10x) — a deliberately loose, machine-tolerant smoke bound that only
    catches order-of-magnitude serving collapses;
  * a gated baseline key disappeared from the current run (a silently
    dropped benchmark section must not pass the gate).

Other wall-times and machine-dependent metrics are deliberately NOT gated;
the tightly gated quantities are analytic (byte models, schedule lengths,
tile counts) and therefore deterministic across hosts.

When `$GITHUB_STEP_SUMMARY` is set (GitHub Actions), the gate also appends
a markdown table of every gated metric — section, metric, baseline,
current, delta — so a red job names the exact metric in the job summary.
`--keys` restricts gating to the named top-level baseline sections (CI
runs the bench per section and gates each against its slice of the one
committed baseline).

Usage:
    python benchmarks/check_regression.py [BENCH_kernel.json]
        [--baseline benchmarks/baselines/BENCH_kernel.baseline.json]
        [--keys attention,lowering] [--tolerance 0.10]
        [--latency-factor 10.0]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

#: key names gated as never-grow counters (exact, deterministic)
COUNTER_KEYS = ("accesses", "ledger_accesses", "banked_accesses", "waves",
                "dispatches", "load_accesses", "total_accesses",
                "accesses_per_token", "load_accesses_per_token",
                "total_accesses_per_token", "searches",
                # fault/ECC health: ANY growth over the committed zero
                # baseline means data loss the SECDED planes could not
                # repair — never acceptable on a deterministic seed
                "fault_uncorrected", "ecc_uncorrected",
                # ECC traffic is charged separately from the gated load
                # counters; pin its access counts too
                "ecc_accesses", "pin_ecc_accesses", "verify_ecc_accesses")

#: wall-clock latency keys, gated only against baseline * --latency-factor
LATENCY_KEYS = ("p99_ms",)


def _is_ratio_key(key: str) -> bool:
    return "ratio" in key


def compare(baseline, current, tolerance: float, path: str = "",
            latency_factor: float = 10.0):
    """Yield (path, kind, baseline, current) problem tuples."""
    if isinstance(baseline, dict):
        if not isinstance(current, dict):
            yield (path, "missing", baseline, current)
            return
        for key, bval in baseline.items():
            sub = f"{path}.{key}" if path else key
            if key in current:
                yield from compare(bval, current[key], tolerance, sub,
                                   latency_factor)
            elif _gated(key, bval):
                yield (sub, "missing", bval, None)
        return
    key = path.rsplit(".", 1)[-1]
    if not isinstance(baseline, (int, float)) or isinstance(baseline, bool):
        return
    if not isinstance(current, (int, float)):
        yield (path, "missing", baseline, current)
        return
    if _is_ratio_key(key) and current < baseline * (1.0 - tolerance):
        yield (path, "ratio-regressed", baseline, current)
    if key in COUNTER_KEYS and current > baseline:
        yield (path, "count-grew", baseline, current)
    if key in LATENCY_KEYS and baseline > 0 \
            and current > baseline * latency_factor:
        yield (path, "latency-blew-up", baseline, current)


def _gated(key: str, value) -> bool:
    """Does this baseline subtree contain anything the gate checks?"""
    if isinstance(value, dict):
        return any(_gated(k, v) for k, v in value.items())
    return _is_ratio_key(key) or key in COUNTER_KEYS or key in LATENCY_KEYS


def _gated_rows(baseline, current, path=""):
    """(path, baseline, current) for every gated scalar in the baseline."""
    if isinstance(baseline, dict):
        rows = []
        for key, bval in baseline.items():
            sub = f"{path}.{key}" if path else key
            cval = current.get(key) if isinstance(current, dict) else None
            rows.extend(_gated_rows(bval, cval, sub))
        return rows
    key = path.rsplit(".", 1)[-1]
    if not isinstance(baseline, (int, float)) or isinstance(baseline, bool):
        return []
    if _is_ratio_key(key) or key in COUNTER_KEYS or key in LATENCY_KEYS:
        return [(path, baseline, current)]
    return []


def _write_step_summary(baseline, current, problems, baseline_path) -> None:
    """Append the (section, metric, baseline, current, delta) table to the
    GitHub Actions job summary. No-op outside Actions."""
    out = os.environ.get("GITHUB_STEP_SUMMARY")
    if not out:
        return
    bad = {p for p, _, _, _ in problems}
    lines = [f"### Perf gate vs `{baseline_path}`", "",
             "| section | metric | baseline | current | delta |",
             "|---|---|---:|---:|---:|"]
    for path, bval, cval in _gated_rows(baseline, current):
        section, _, metric = path.partition(".")
        mark = " ❌" if path in bad else ""
        if isinstance(cval, (int, float)) and not isinstance(cval, bool):
            cur, delta = f"{cval:g}", f"{cval - bval:+g}"
        else:
            cur, delta = "missing", ""
        lines.append(f"| {section} | {metric or section}{mark} | "
                     f"{bval:g} | {cur} | {delta} |")
    lines.append("")
    status = (f"**{len(problems)} gated metric(s) FAILED**" if problems
              else "all gated metrics within tolerance")
    lines.append(status)
    with open(out, "a") as f:
        f.write("\n".join(lines) + "\n\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", nargs="?", default="BENCH_kernel.json",
                    help="benchmark JSON produced by kernel_bench.py --json")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/BENCH_kernel.baseline.json")
    ap.add_argument("--keys", default="",
                    help="comma-separated top-level baseline keys to gate "
                         "(default: every key); unknown keys fail loudly")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed fractional ratio drop (default 0.10)")
    ap.add_argument("--latency-factor", type=float, default=10.0,
                    help="p99 latency smoke bound: fail above "
                         "baseline * factor (default 10.0)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)

    if args.keys:
        sel = [s.strip() for s in args.keys.split(",") if s.strip()]
        unknown = [s for s in sel if s not in baseline]
        if unknown:
            print(f"PERF GATE ERROR: --keys {unknown} not in "
                  f"{args.baseline} (a renamed/dropped section must not "
                  f"silently pass)")
            return 1
        baseline = {k: v for k, v in baseline.items() if k in sel}

    problems = list(compare(baseline, current, args.tolerance,
                            latency_factor=args.latency_factor))
    checked = sum(_count_gated(k, v) for k, v in baseline.items())
    _write_step_summary(baseline, current, problems, args.baseline)
    if problems:
        print(f"PERF REGRESSION: {len(problems)} of {checked} gated metrics "
              f"failed vs {args.baseline}")
        for path, kind, bval, cval in problems:
            print(f"  {kind:16s} {path}: baseline={str(bval)[:80]} "
                  f"current={str(cval)[:80]}")
        return 1
    print(f"perf gate OK: {checked} gated metrics within tolerance "
          f"({args.tolerance:.0%} ratio drop, zero access growth)")
    return 0


def _count_gated(key: str, value) -> int:
    if isinstance(value, dict):
        return sum(_count_gated(k, v) for k, v in value.items())
    return int(_is_ratio_key(key) or key in COUNTER_KEYS
               or key in LATENCY_KEYS)


if __name__ == "__main__":
    sys.exit(main())

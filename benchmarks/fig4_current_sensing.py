"""Paper Fig. 4: ADRA CiM vs near-memory baseline, current-based sensing.

(a) energy components per op; (b) energy decrease vs array size;
(c) speedup vs array size. Anchors @1024^2: 1.94x, -41.18% E, -69.04% EDP.
"""
from repro.core import energy


def rows():
    out = []
    r1024 = energy.current_sensing(1024)
    for comp, val in r1024.read.breakdown.items():
        out.append(("fig4a_read_component", comp, energy.to_fj(val), ""))
    for comp, val in r1024.cim.breakdown.items():
        out.append(("fig4a_cim_component", comp, energy.to_fj(val), ""))
    for size, r in energy.sweep("current").items():
        out.append(("fig4b_energy_decrease_pct", size, r.energy_decrease_pct,
                    energy.anchor_note("current", "energy_decrease_pct",
                                       at_1024=True)))
        out.append(("fig4c_speedup", size, r.speedup,
                    energy.anchor_note("current", "speedup", at_1024=True)))
        out.append(("fig4_edp_decrease_pct", size, r.edp_decrease_pct,
                    energy.anchor_note("current", "edp_decrease_pct",
                                       at_1024=True)))
    return out


def main():
    for name, key, val, note in rows():
        print(f"{name},{key},{val:.4f},{note}")


if __name__ == "__main__":
    main()

"""Paper Fig. 5: precharged (scheme 1) vs charge-per-op (scheme 2) voltage
sensing. (a) energy vs CiM op frequency — crossover at 7.53 MHz;
(b) energy vs CiM parallelism P — crossover at ~42%."""

from repro.core import energy


def rows():
    out = []
    for f_mhz in (1, 2, 4, 7.53, 10, 20, 50):
        e = energy.scheme_energies_vs_frequency(f_mhz * 1e6)
        out.append(("fig5a_energy_vs_freq", f"{f_mhz}MHz",
                    e["scheme1"], e["scheme2"]))
    out.append(("fig5a_crossover_mhz", "-", energy.frequency_crossover_hz() / 1e6,
                energy.anchor_note("crossover", "frequency_mhz")))
    for p in (0.1, 0.25, 0.42, 0.5, 0.75, 1.0):
        e = energy.scheme_energies_vs_parallelism(p)
        out.append(("fig5b_energy_vs_parallelism", f"P={p}",
                    e["scheme1"], e["scheme2"]))
    out.append(("fig5b_crossover_P", "-", energy.parallelism_crossover(),
                energy.anchor_note("crossover", "parallelism")))
    return out


def main():
    for row in rows():
        print(",".join(str(x) for x in row))


if __name__ == "__main__":
    main()

"""Paper Fig. 6: ADRA vs baseline under precharged-RBL voltage sensing
(scheme 1). Paper: 1.57-1.73x speedup, +20-23% energy, 23.26-28.81% EDP
decrease; CiM bitline discharges 6*Delta vs 2*Delta for a read (3x energy)."""
from repro.core import energy


def rows():
    out = []
    r = energy.voltage_scheme1(1024)
    for comp, val in r.read.breakdown.items():
        out.append(("fig6a_read_component", comp, energy.to_fj(val), ""))
    for comp, val in r.cim.breakdown.items():
        out.append(("fig6a_cim_component", comp, energy.to_fj(val), ""))
    out.append(("fig6a_bitline_ratio_cim_over_read", 1024,
                r.cim.breakdown["bitline"] / r.read.breakdown["bitline"],
                energy.anchor_note("scheme1", "bitline_ratio_cim_over_read",
                                   suffix="x (6 Delta vs 2 Delta)")))
    for size, r in energy.sweep("scheme1").items():
        out.append(("fig6b_energy_decrease_pct", size, r.energy_decrease_pct,
                    energy.anchor_note("scheme1", "energy_decrease_pct",
                                       suffix=" (CiM costs more)")))
        out.append(("fig6c_speedup", size, r.speedup,
                    energy.anchor_note("scheme1", "speedup")))
        out.append(("fig6_edp_decrease_pct", size, r.edp_decrease_pct,
                    energy.anchor_note("scheme1", "edp_decrease_pct")))
    return out


def main():
    for name, key, val, note in rows():
        print(f"{name},{key},{val:.4f},{note}")


if __name__ == "__main__":
    main()

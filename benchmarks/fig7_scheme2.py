"""Paper Fig. 7: ADRA vs baseline under charge-per-op voltage sensing
(scheme 2). Paper: 1.945-1.983x speedup, 35.5-45.8% less energy,
66.83-72.6% EDP decrease."""
from repro.core import energy


def rows():
    out = []
    r = energy.voltage_scheme2(1024)
    for comp, val in r.read.breakdown.items():
        out.append(("fig7a_read_component", comp, energy.to_fj(val), ""))
    for comp, val in r.cim.breakdown.items():
        out.append(("fig7a_cim_component", comp, energy.to_fj(val), ""))
    for size, r in energy.sweep("scheme2").items():
        out.append(("fig7b_energy_decrease_pct", size, r.energy_decrease_pct,
                    energy.anchor_note("scheme2", "energy_decrease_pct")))
        out.append(("fig7c_speedup", size, r.speedup,
                    energy.anchor_note("scheme2", "speedup")))
        out.append(("fig7_edp_decrease_pct", size, r.edp_decrease_pct,
                    energy.anchor_note("scheme2", "edp_decrease_pct")))
    return out


def main():
    for name, key, val, note in rows():
        print(f"{name},{key},{val:.4f},{note}")


if __name__ == "__main__":
    main()

"""CiM engine + macro-op benchmark: fused passes and planned schedules vs
near-memory baselines — the TPU translation of the paper's one-vs-two memory
access argument, generalized to the full op surface and to multi-access
macro ops (multiply, int8 matmul).

Sections:
  engine — ONE fused pass (Boolean fn + sub + compare) vs per-function
    baseline passes: modeled and MEASURED HBM traffic, wall time, and the
    ledger's projected ADRA-array energy.
  macro — the planner's multiply / matmul schedules: access counts (asserted
    equal to the ledger's), fused (intermediates stay in-array) vs unfused
    (operands re-streamed per scheduled access) traffic, steady-state
    walltimes (block_until_ready, measured AFTER the compile call), and the
    whole-schedule execution guarantee: a warm macro is exactly ONE jitted
    dispatch (`dispatches` in cache_stats — the deterministic walltime proxy
    check_regression.py gates).
  bank_sweep — the banked array substrate: the same fused op placed on 1 to
    64 banks; words/access stays fixed by the geometry while the serialized
    wave count (and with it the contention-adjusted EDP) drops with bank
    count. Also asserts the compiled-schedule cache serves repeats.
  lowering — the jaxpr->CiM compiler on a quantized MLP: region/access
    counts of the lowered hybrid program (asserted equal to the executed
    ledger AND to the jaxpr-sourced offload estimate) and the lowered-MLP
    traffic ratio vs the near-memory per-access baseline.
  attention — batched dot_general lowering end to end: the quantized SDPA
    core (QK^T + AV as planned batched schedules, softmax a host island)
    bit-exact vs its host twin with accesses == plan == offload report and
    exactly 2 warm dispatches; resident-KV reuse > 0; blockwise attention
    replaying ONE compiled program pair across kv blocks; and a full
    decode step's dispatch count asserted exact — O(layers), not O(eqns).

`--json [PATH]` additionally writes the metrics as BENCH_kernel.json for CI
artifact tracking of the perf trajectory per PR; `benchmarks/
check_regression.py` gates CI on the committed baseline of that file.
`--sections` runs a named subset (CI runs one step per section so a gate
failure names the section); `--twice` runs every selected section a second
time and asserts the warm pass is all schedule-cache hits with an
unchanged per-pass dispatch count (zero retrace end to end).
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import cim
from repro.cim import ArraySpec, PlanePack, dispatch, planner

#: the fused request: Boolean fn + subtraction + comparison, one access
FUSED_OPS = ("xor", "sub", "lt", "eq")
#: the per-function baseline: one full access per function
BASELINE_PASSES = (("xor",), ("sub",), ("lt", "eq"))


def _block(out):
    jax.tree.map(lambda x: x.block_until_ready(), jax.tree.leaves(out))
    return out


def _time(fn, n=5):
    _block(fn())                         # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        _block(fn())
    return (time.perf_counter() - t0) / n * 1e6


def _steady_ms(fn, n=5):
    """Steady-state walltime in ms: warm up (trace + compile happen on the
    first call), then time `n` fully-blocked repeat calls. This is the
    number the old benchmark got wrong by timing the first (trace-
    inclusive) call only."""
    return _time(fn, n) / 1e3


def _one_call_dispatches(fn):
    """Jitted-program invocations of one warm call of `fn`."""
    _block(fn())                         # ensure warm
    before = dispatch.cache_stats()["dispatches"]
    _block(fn())
    return dispatch.cache_stats()["dispatches"] - before


def engine_section(metrics):
    n_bits, n_words = 16, 1 << 20
    rng = np.random.RandomState(0)
    a = jnp.array(rng.randint(-2**15, 2**15, n_words), jnp.int32)
    b = jnp.array(rng.randint(-2**15, 2**15, n_words), jnp.int32)
    pa, pb = PlanePack.pack(a, n_bits), PlanePack.pack(b, n_bits)

    # traffic: the roofline argument, modeled and measured from real buffers
    t = cim.traffic_model_bytes(n_bits, pa.planes.shape[1], ops=FUSED_OPS,
                                baseline_passes=BASELINE_PASSES)
    print(f"kernel_traffic_fused_bytes,{n_words},{t['fused']:.0f},xor+sub+cmp one pass")
    print(f"kernel_traffic_baseline_bytes,{n_words},{t['baseline']:.0f},one pass per function")
    print(f"kernel_traffic_model_ratio,{n_words},{t['ratio']:.3f},paper: k accesses vs 1")
    m = cim.measured_traffic_bytes(pa, pb, FUSED_OPS,
                                   baseline_passes=BASELINE_PASSES,
                                   backend="jnp-boolean")
    print(f"kernel_traffic_measured_ratio,{n_words},{m['ratio']:.3f},"
          f"actual buffer bytes, >1.5 required")
    assert m["ratio"] > 1.5, m
    metrics["engine"] = {
        "n_words": n_words,
        "traffic_model": t,
        "traffic_measured": m,
    }

    # wall time of fused vs unfused on the portable backend (host sanity,
    # not TPU perf; interpret-mode Pallas is not a performance proxy)
    fused = jax.jit(lambda: cim.execute(pa, pb, FUSED_OPS,
                                        backend="jnp-boolean"))
    unfused = jax.jit(lambda: cim.execute_unfused(
        pa, pb, BASELINE_PASSES, backend="jnp-boolean"))
    us_f = _time(fused)
    us_u = _time(unfused)
    print(f"kernel_fused_us,{n_words},{us_f:.1f},jnp-boolean backend on host")
    print(f"kernel_unfused_us,{n_words},{us_u:.1f},per-function passes")
    metrics["engine"]["fused_us"] = us_f
    metrics["engine"]["unfused_us"] = us_u

    # projected ADRA-array energy via the engine ledger (paper model)
    led = cim.ledger()
    led.reset()
    cim.execute(pa, pb, FUSED_OPS, backend="jnp-boolean")
    fused_proj = led.projected(scheme="current")
    led.reset()
    cim.execute_unfused(pa, pb, BASELINE_PASSES, backend="jnp-boolean")
    base_proj = led.projected(scheme="current")
    ratio = base_proj["cim_energy"] / fused_proj["cim_energy"]
    print(f"kernel_ledger_access_energy_ratio,{n_words},{ratio:.2f},"
          f"unfused charges {ratio:.0f}x the accesses")
    print(f"kernel_projected_adra_energy_saved_fj,{n_words},"
          f"{fused_proj['energy_saved_fj']:.0f},current sensing @1024^2")
    print(f"kernel_projected_edp_decrease_pct,{n_words},"
          f"{fused_proj['edp_decrease_pct']:.2f},")
    metrics["engine"]["ledger_access_energy_ratio"] = ratio
    metrics["engine"]["projected_energy_saved_fj"] = fused_proj["energy_saved_fj"]
    metrics["engine"]["projected_edp_decrease_pct"] = fused_proj["edp_decrease_pct"]


def macro_section(metrics):
    """The planner's schedules: access counts + fused-vs-unfused traffic."""
    rng = np.random.RandomState(1)
    led = cim.ledger()

    # -- multiply: 8x8 shift-and-add over 2^16 words -----------------------
    n_bits, n_words = 8, 1 << 16
    a = jnp.array(rng.randint(-128, 128, n_words), jnp.int32)
    b = jnp.array(rng.randint(-128, 128, n_words), jnp.int32)
    pa, pb = PlanePack.pack(a, n_bits), PlanePack.pack(b, n_bits)
    sched = planner.plan_multiply(n_bits, n_bits)
    led.reset()
    prod = cim.multiply(pa, pb, backend="jnp-boolean")
    mul_ledger_accesses = led.accesses           # one call's charge
    assert mul_ledger_accesses == sched.accesses, \
        (mul_ledger_accesses, sched.accesses)
    np.testing.assert_array_equal(np.array(prod.unpack()),
                                  np.array(a) * np.array(b))
    t = planner.schedule_traffic_bytes(sched, n_bits, pa.planes.shape[1])
    # the whole 2n-1 access schedule is ONE compiled program: a warm call
    # is exactly one jitted dispatch (the deterministic walltime proxy)
    mul_dispatches = _one_call_dispatches(
        lambda: cim.multiply(pa, pb, backend="jnp-boolean"))
    assert mul_dispatches == 1, mul_dispatches
    ms_mul = _steady_ms(lambda: cim.multiply(pa, pb, backend="jnp-boolean"))
    print(f"macro_multiply_accesses,{n_words},{sched.accesses},"
          f"ledger-verified shift-and-add schedule")
    print(f"macro_multiply_traffic_fused_bytes,{n_words},{t['fused']:.0f},"
          f"operands once, intermediates in-array")
    print(f"macro_multiply_traffic_unfused_bytes,{n_words},{t['baseline']:.0f},"
          f"operands re-streamed per access")
    print(f"macro_multiply_traffic_ratio,{n_words},{t['ratio']:.3f},"
          f">1.5 required")
    print(f"macro_multiply_walltime_ms,{n_words},{ms_mul:.2f},"
          f"steady-state, block_until_ready")
    print(f"macro_multiply_dispatches,{n_words},{mul_dispatches},"
          f"one compiled program per schedule")
    assert t["ratio"] > 1.5, t
    metrics["macro_multiply"] = {
        "n_words": n_words,
        "accesses": sched.accesses,
        "ledger_accesses": mul_ledger_accesses,
        "traffic": t,
        "walltime_ms": ms_mul,
        "dispatches": mul_dispatches,
    }

    # -- int8 matmul: planned contraction, access count vs ledger ----------
    m_, k_, n_ = 16, 32, 8
    A = jnp.array(rng.randint(-128, 128, (m_, k_)), jnp.int32)
    B = jnp.array(rng.randint(-128, 128, (k_, n_)), jnp.int32)
    msched = planner.plan_matmul(k_, n_, n_bits=8)
    led.reset()
    t0 = time.perf_counter()
    C = cim.matmul(A, B, n_bits=8, backend="jnp-boolean")
    _block(C)
    cold_ms = (time.perf_counter() - t0) * 1e3
    mm_ledger_accesses = led.accesses            # one call's charge
    assert mm_ledger_accesses == msched.accesses, \
        (mm_ledger_accesses, msched.accesses)
    np.testing.assert_array_equal(
        np.array(C), np.array(A, np.int64) @ np.array(B, np.int64))
    # the contraction's whole (2n-1)+log2(K_pad) schedule is one compiled
    # program; steady state is one dispatch per call, zero retrace
    mm_dispatches = _one_call_dispatches(
        lambda: cim.matmul(A, B, n_bits=8, backend="jnp-boolean"))
    assert mm_dispatches == 1, mm_dispatches
    ms = _steady_ms(lambda: cim.matmul(A, B, n_bits=8, backend="jnp-boolean"))
    mt = planner.schedule_traffic_bytes(
        msched, 2 * 8, (m_ * k_ * n_ + 31) // 32, working_bits=msched.out_bits)
    print(f"macro_matmul_accesses,{m_}x{k_}x{n_},{msched.accesses},"
          f"(2n-1)+log2(K_pad): independent of M and N")
    print(f"macro_matmul_traffic_ratio,{m_}x{k_}x{n_},{mt['ratio']:.3f},"
          f"fused schedule vs per-access re-streaming")
    print(f"macro_matmul_walltime_ms,{m_}x{k_}x{n_},{ms:.2f},"
          f"steady-state, block_until_ready (compile-inclusive "
          f"first call: {cold_ms:.0f} ms)")
    print(f"macro_matmul_dispatches,{m_}x{k_}x{n_},{mm_dispatches},"
          f"one jitted dispatch per schedule")
    metrics["macro_matmul"] = {
        "shape": [m_, k_, n_],
        "accesses": msched.accesses,
        "ledger_accesses": mm_ledger_accesses,
        "traffic": mt,
        "walltime_ms": ms,
        "compile_ms": cold_ms,
        "dispatches": mm_dispatches,
    }

    # projected array energy for the macro ops just charged
    proj = led.projected(scheme="current")
    print(f"macro_projected_edp_decrease_pct,{m_}x{k_}x{n_},"
          f"{proj['edp_decrease_pct']:.2f},")
    metrics["macro_matmul"]["projected_edp_decrease_pct"] = proj["edp_decrease_pct"]


def bank_sweep_section(metrics):
    """The banked substrate: fixed workload, bank count 1 -> 64.

    Geometry holds tile size constant (one 4096-word bank activation), so
    words/access is flat across the sweep; what banks buy is CONCURRENCY —
    the serialized wave count drops ~1/banks and the contention-adjusted
    EDP projection improves with it. Assertions pin the cache hit path and
    the monotone wave shrink so regressions fail loudly.
    """
    n_bits, n_words = 16, 1 << 18
    rng = np.random.RandomState(3)
    a = jnp.array(rng.randint(-2**15, 2**15, n_words), jnp.int32)
    b = jnp.array(rng.randint(-2**15, 2**15, n_words), jnp.int32)
    pa, pb = PlanePack.pack(a, n_bits), PlanePack.pack(b, n_bits)
    led = cim.ledger()

    sweep = {}
    prev_waves = None
    for banks in (1, 2, 4, 8, 16, 32, 64):
        spec = ArraySpec(banks=banks, subarrays=1, bitline_words=4096)
        led.reset()
        dispatch.execute_tiled(pa, pb, FUSED_OPS, spec=spec,
                               backend="jnp-boolean")
        rep = led.bank_report(spec)
        words_per_access = n_words / led.accesses
        print(f"bank_sweep_waves,{banks},{rep['waves']:.0f},"
              f"serialized activations on the busiest bank")
        print(f"bank_sweep_words_per_access,{banks},{words_per_access:.0f},"
              f"fixed by tile geometry")
        print(f"bank_sweep_cim_edp,{banks},{rep['cim_edp']:.0f},"
              f"contention-adjusted (energy x serialized latency)")
        print(f"bank_sweep_edp_decrease_pct,{banks},"
              f"{rep['edp_decrease_pct']:.2f},vs near-memory on same banks")
        assert prev_waves is None or rep["waves"] <= prev_waves, \
            (banks, rep["waves"], prev_waves)
        prev_waves = rep["waves"]
        sweep[str(banks)] = {
            "accesses": led.accesses,
            "waves": rep["waves"],
            "words_per_access": words_per_access,
            "utilization": rep["utilization"],
            "cim_edp": rep["cim_edp"],
            "edp_decrease_pct": rep["edp_decrease_pct"],
        }

    # the compiled-schedule cache: the sweep re-dispatched is all hits
    # (same ops / n_bits / tile shape / backend for every bank count)
    before = dispatch.cache_stats()
    for banks in (1, 2, 4, 8, 16, 32, 64):
        spec = ArraySpec(banks=banks, subarrays=1, bitline_words=4096)
        dispatch.execute_tiled(pa, pb, FUSED_OPS, spec=spec,
                               backend="jnp-boolean")
    after = dispatch.cache_stats()
    hits = after["hits"] - before["hits"]
    misses = after["misses"] - before["misses"]
    print(f"bank_sweep_cache_hits,{hits},{misses},"
          f"repeat schedules skip retracing")
    assert hits == 7 and misses == 0, (before, after)
    metrics["bank_sweep"] = {
        "n_words": n_words,
        "banks": sweep,
        "cache_repeat_hits": hits,
        "cache_repeat_misses": misses,
    }


def lowering_section(metrics):
    """The lowering compiler end to end: a quantized swiglu MLP compiled to
    the hybrid CiM/host program. Gates: the executed ledger must equal the
    compiled plan AND the offload estimate (the estimator/executor
    contract), and the fused-schedule traffic ratio vs re-streaming every
    access near-memory must stay >1.5."""
    from repro.core.offload import analyze_trace
    from repro.models import layers

    d_model, d_ff, batch, n_bits = 16, 32, 4, 8
    key = jax.random.PRNGKey(0)
    p = layers.mlp_init(key, d_model, d_ff, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, d_model),
                          jnp.float32)

    lf = layers._lowered_mlp("swiglu", n_bits, "jnp-boolean", None, None)
    comp = lf.trace(p, x)
    led = cim.ledger()
    led.reset()
    out = lf(p, x)
    np.testing.assert_array_equal(
        np.array(out), np.array(layers._mlp_quantized(p, x, "swiglu",
                                                      n_bits)))
    mlp_ledger_accesses = led.accesses           # one call's charge
    assert mlp_ledger_accesses == comp.accesses, \
        (mlp_ledger_accesses, comp.accesses)
    rep = analyze_trace(comp.trace)
    assert rep.adra_accesses == mlp_ledger_accesses, \
        (rep.adra_accesses, mlp_ledger_accesses)

    # each fused region is ONE compiled program: a warm MLP call costs
    # exactly len(regions) jitted dispatches, nothing per access
    mlp_dispatches = _one_call_dispatches(lambda: lf(p, x))
    assert mlp_dispatches == len(comp.regions), \
        (mlp_dispatches, len(comp.regions))
    mlp_ms = _steady_ms(lambda: lf(p, x))

    # lowered traffic: fused region schedules (operands stream once, every
    # intermediate stays in-array) vs the near-memory baseline re-streaming
    # operands for each scheduled access
    fused = baseline = 0.0
    for region in comp.regions:
        for op in region.ops:
            if op.schedule is None or op.accesses == 0:
                continue
            t = planner.schedule_traffic_bytes(
                op.schedule, op.n_bits, -(-op.words // 32))
            fused += t["fused"]
            baseline += t["baseline"]
    ratio = baseline / fused
    shape = f"{batch}x{d_model}x{d_ff}"
    print(f"lowering_mlp_regions,{shape},{len(comp.regions)},"
          f"one fused region per quantized matmul")
    print(f"lowering_mlp_accesses,{shape},{comp.accesses},"
          f"ledger- and offload-verified hybrid program")
    print(f"lowering_mlp_traffic_ratio,{shape},{ratio:.3f},"
          f"fused regions vs near-memory re-streaming, >1.5 required")
    print(f"lowering_mlp_walltime_ms,{shape},{mlp_ms:.2f},"
          f"steady-state, block_until_ready")
    print(f"lowering_mlp_dispatches,{shape},{mlp_dispatches},"
          f"one jitted dispatch per fused region")
    assert ratio > 1.5, ratio
    metrics["lowering"] = {
        "mlp": {
            "shape": [batch, d_model, d_ff],
            "regions": len(comp.regions),
            "eligible_eqns": comp.eligible_eqns,
            "accesses": comp.accesses,
            "ledger_accesses": mlp_ledger_accesses,
            "traffic": {"fused": fused, "baseline": baseline,
                        "ratio": ratio},
            "walltime_ms": mlp_ms,
            "dispatches": mlp_dispatches,
        },
    }


def attention_section(metrics):
    """Batched dot_general lowering end to end (see module docstring).

    Every assertion here is the acceptance contract of the attention
    lowering: bit-exact parity with the plain-JAX quantized twin, the
    executed ledger equal to both the compiled plan and the jaxpr-sourced
    offload estimate (which must classify the contractions as
    `batched_dot` with both KV sides resident-savable), warm dispatch
    counts exact, and resident KV reuse observed."""
    from repro.configs.base import ArchConfig
    from repro.core.offload import analyze_trace
    from repro.models import attention as attn_mod
    from repro.models import build, layers
    from repro.models.blockwise_attention import (
        blockwise_attention_cim, blockwise_attention_quantized)
    from repro.train import make_decode_step

    led = cim.ledger()
    rng = np.random.RandomState(7)
    b, tq, hq, hkv, d, tk, n_bits = 2, 1, 4, 2, 8, 16, 8
    q = jnp.array(rng.randn(b, tq, hq, d), jnp.float32)
    k = jnp.array(rng.randn(b, tk, hkv, d), jnp.float32)
    v = jnp.array(rng.randn(b, tk, hkv, d), jnp.float32)
    mask = jnp.ones((b, 1, tk), bool)
    scale = 1.0 / d ** 0.5
    shape = f"{b}x{hq}x{tk}x{d}"

    # -- lowered SDPA: parity + plan == ledger == offload ------------------
    host = attn_mod._sdpa_quantized(q, k, v, mask, scale, n_bits)
    qs = q.astype(jnp.float32) * scale
    lf = attn_mod._lowered_sdpa(n_bits, "jnp-boolean", None, None, False)
    comp = lf.trace(qs, k, v, mask)
    led.reset()
    out = lf(qs, k, v, mask).astype(q.dtype)
    np.testing.assert_array_equal(np.array(out), np.array(host))
    sdpa_ledger = led.accesses               # one call's charge
    assert sdpa_ledger == comp.accesses, (sdpa_ledger, comp.accesses)
    rep = analyze_trace(comp.trace)
    assert rep.adra_accesses == sdpa_ledger, (rep.adra_accesses, sdpa_ledger)
    assert rep.op_histogram.get("batched_dot") == 2, rep.op_histogram
    assert rep.resident_savable_accesses == 2, rep   # the K^T and V sides
    sdpa_disp = _one_call_dispatches(lambda: lf(qs, k, v, mask))
    assert sdpa_disp == len(comp.regions) == 2, (sdpa_disp, comp.regions)
    print(f"attention_sdpa_accesses,{shape},{sdpa_ledger},"
          f"plan == ledger == offload (QK^T + AV)")
    print(f"attention_sdpa_dispatches,{shape},{sdpa_disp},"
          f"two fused regions, softmax a host island")

    # -- resident KV: pinned K^T/V planes, reuse on the second call --------
    st0 = dispatch.cache_stats()
    r1 = attn_mod.sdpa_cim(q, k, v, mask, scale, n_bits=n_bits,
                           backend="jnp-boolean", resident=True)
    r2 = attn_mod.sdpa_cim(q, k, v, mask, scale, n_bits=n_bits,
                           backend="jnp-boolean", resident=True)
    st1 = dispatch.cache_stats()
    np.testing.assert_array_equal(np.array(r1), np.array(host))
    np.testing.assert_array_equal(np.array(r2), np.array(host))
    kv_reuses = st1.get("resident_hits", 0) - st0.get("resident_hits", 0)
    assert kv_reuses > 0, (st0, st1)
    print(f"attention_resident_kv_reuses,{shape},{kv_reuses},"
          f"same k/v arrays: entry packs skipped, >0 required")

    # -- blockwise: one compiled program pair replayed across kv blocks ----
    tq2, tk2, bk = 4, 32, 8
    q2 = jnp.array(rng.randn(b, tq2, hq, d), jnp.float32)
    k2 = jnp.array(rng.randn(b, tk2, hkv, d), jnp.float32)
    v2 = jnp.array(rng.randn(b, tk2, hkv, d), jnp.float32)
    nk = tk2 // bk
    href = blockwise_attention_quantized(q2, k2, v2, True, None, 0, bk,
                                         n_bits)
    s0 = dispatch.cache_stats()
    c1 = blockwise_attention_cim(q2, k2, v2, True, None, 0, bk, n_bits,
                                 backend="jnp-boolean")
    s1 = dispatch.cache_stats()
    np.testing.assert_array_equal(np.array(c1), np.array(href))
    bw_programs = s1["misses"] - s0["misses"]
    assert bw_programs <= 2, bw_programs     # QK-shape + AV-shape, shared
    c2 = blockwise_attention_cim(q2, k2, v2, True, None, 0, bk, n_bits,
                                 backend="jnp-boolean")
    s2 = dispatch.cache_stats()
    np.testing.assert_array_equal(np.array(c2), np.array(href))
    bw_disp = s2["dispatches"] - s1["dispatches"]
    assert s2["misses"] == s1["misses"], (s1, s2)
    assert bw_disp == 2 * nk, (bw_disp, nk)
    bshape = f"{b}x{hq}x{tk2}x{d}bk{bk}"
    print(f"attention_blockwise_dispatches,{bshape},{bw_disp},"
          f"2 per kv block, {bw_programs} fresh programs this pass")

    # -- a full decode step: dispatch count O(layers), asserted exact ------
    cfg = ArchConfig(name="bench-decode", family="dense", n_layers=2,
                     d_model=16, n_heads=4, n_kv_heads=2, head_dim=8,
                     d_ff=32, vocab_size=64, dtype="float32",
                     tensor_parallel=False, cim_mlp_bits=n_bits,
                     cim_attention_bits=n_bits, cim_unroll_groups=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(2))
    caches = model.init_caches(2, 8)
    dec = make_decode_step(model)
    step = {"tokens": jnp.array([[1], [2]], jnp.int32),
            "positions": jnp.array([3, 5], jnp.int32)}
    pm = layers.mlp_init(jax.random.PRNGKey(3), cfg.d_model, cfg.d_ff,
                         cfg.gating, jnp.float32)
    xm = jnp.zeros((2, 1, cfg.d_model), jnp.float32)
    mlp_regions = len(layers._lowered_mlp(cfg.gating, n_bits, None, None,
                                          None).trace(pm, xm).regions)
    dec_disp = _one_call_dispatches(lambda: dec(params, caches, step))
    expected = cfg.n_layers * (2 + mlp_regions)
    assert dec_disp == expected, (dec_disp, expected)
    led.reset()
    dec(params, caches, step)
    dec_accesses = led.accesses
    print(f"attention_decode_step_dispatches,{cfg.n_layers}layers,"
          f"{dec_disp},exact: layers x (2 attn + {mlp_regions} mlp) regions")
    print(f"attention_decode_step_accesses,{cfg.n_layers}layers,"
          f"{dec_accesses},every integer contraction a planned schedule")

    metrics["attention"] = {
        "sdpa": {
            "shape": [b, tq, hq, hkv, d, tk],
            "accesses": comp.accesses,
            "ledger_accesses": sdpa_ledger,
            "offload_accesses": rep.adra_accesses,
            "batched_dot_ops": rep.op_histogram.get("batched_dot", 0),
            "resident_savable_accesses": rep.resident_savable_accesses,
            "regions": len(comp.regions),
            "dispatches": sdpa_disp,
        },
        "resident_kv": {"reuses": kv_reuses},
        "blockwise": {
            "shape": [b, tq2, hq, hkv, d, tk2],
            "block_k": bk,
            "n_blocks": nk,
            "dispatches": bw_disp,
        },
        "decode_step": {
            "n_layers": cfg.n_layers,
            "mlp_regions_per_layer": mlp_regions,
            "dispatches": dec_disp,
            "accesses": dec_accesses,
        },
    }


def autotune_section(metrics):
    """The geometry autotuner end to end: cost-model-pruned search over an
    explicit candidate grid, measurement-confirmed winner, and the warm
    winners-cache path. Gates: the tuned geometry can never regress the
    default (walltime AND projected EDP ratios >= 1.0 by construction —
    the default is always measured and losing predictions are pruned), and
    a warm key costs ZERO re-searches. The winners table is written as
    BENCH_autotune_winners.json unconditionally (CI artifact)."""
    from repro.cim.autotune import Autotuner, Candidate

    def fn(a, b):
        t = (a + b) * b
        return t ^ a

    n_words = 4096
    rng = np.random.RandomState(11)
    a = jnp.array(rng.randint(-2**15, 2**15, n_words), jnp.int16)
    b = jnp.array(rng.randint(-2**15, 2**15, n_words), jnp.int16)
    candidates = (
        Candidate(banks=2, subarrays=2, bitline_words=1024),
        Candidate(banks=8, subarrays=4, bitline_words=256),
        Candidate(banks=4, subarrays=4, bitline_words=1024,
                  scheme="scheme2"),
    )

    # a FRESH tuner per section invocation keeps the --twice contract: both
    # passes run the identical cold-search + warm-hit sequence, so the warm
    # bench pass replays the same schedule-cache keys and dispatch count
    tuner = Autotuner()
    res = tuner.tune(fn, (a, b), candidates=candidates,
                     backend="jnp-boolean", steady_n=3)
    assert not res.from_cache and tuner.searches == 1, res
    wall_ratio = res.tuned_vs_default_walltime_ratio
    edp_ratio = res.tuned_vs_default_edp_ratio
    assert wall_ratio >= 1.0, res       # default is always in the measured set
    assert edp_ratio >= 1.0, res        # losing predictions are pruned

    # warm path: the same workload keys into the winners table — zero
    # re-searches, zero measurements
    warm = tuner.tune(fn, (a, b), candidates=candidates,
                      backend="jnp-boolean", steady_n=3)
    assert warm.from_cache and warm.winner == res.winner, warm
    assert tuner.searches == 1, tuner.searches

    winners_path = "BENCH_autotune_winners.json"
    tuner.save(winners_path)

    w = res.winner
    wtag = f"{w.banks}x{w.subarrays}x{w.bitline_words}/{w.scheme}"
    print(f"autotune_candidates,{n_words},{1 + len(candidates)},"
          f"default + explicit grid")
    print(f"autotune_measured_geometries,{n_words},{len(res.measured_ms)},"
          f"cost-model pruned, one rep per execution geometry")
    print(f"autotune_winner,{n_words},{wtag},"
          f"banks x subarrays x bitline_words / scheme")
    print(f"autotune_tuned_vs_default_walltime_ratio,{n_words},"
          f"{wall_ratio:.3f},>=1.0 by construction (default always measured)")
    print(f"autotune_tuned_vs_default_edp_ratio,{n_words},{edp_ratio:.3f},"
          f">=1.0 by construction (losing predictions pruned)")
    print(f"autotune_searches,{n_words},{tuner.searches},"
          f"warm repeat key cost zero re-searches")
    print(f"autotune_winners_json,,{winners_path},CI artifact")
    metrics["autotune"] = {
        "n_words": n_words,
        "candidates": 1 + len(candidates),
        "measured_geometries": len(res.measured_ms),
        "winner": {"banks": w.banks, "subarrays": w.subarrays,
                   "bitline_words": w.bitline_words, "rows": w.rows,
                   "scheme": w.scheme},
        "default_ms": res.default_ms,
        "tuned_ms": res.tuned_ms,
        "tuned_vs_default_walltime_ratio": wall_ratio,
        "tuned_vs_default_edp_ratio": edp_ratio,
        "searches": tuner.searches,
        "warm_from_cache": warm.from_cache,
    }


def ecc_section(metrics):
    """SECDED protection of resident operands: parity-plane row overhead
    vs an unprotected pin (5/8 at int8 — pinned by the baseline), the
    ledger's separated ECC charges (plain `charge_load` is asserted
    UNCHANGED so gated access counts stay valid), and a seeded single-bit
    fault campaign where every injected flip is corrected on `get()` with
    the logical values intact — `uncorrected` must stay ZERO (the
    never-grow counter check_regression gates). Fresh local ResidentSets,
    ledger deltas, and try/finally fault teardown keep the --twice
    contract: both passes replay identically with zero engine dispatches."""
    from repro.cim import faults
    from repro.cim.accounting import LEDGER
    from repro.cim.array import ResidentSet
    from repro.cim.cost import ecc_overhead
    from repro.cim.planepack import ecc_plane_count

    spec = ArraySpec(banks=4, subarrays=1, rows=256, bitline_words=32)
    n_bits, n_words = 8, 128
    rng = np.random.RandomState(3)
    x = jnp.array(rng.randint(-128, 128, n_words), jnp.int8)
    pack = PlanePack.pack(x, n_bits)
    n_parity = ecc_plane_count(n_bits)

    plain = ResidentSet(spec)
    prot = ResidentSet(spec, ecc=True)
    acc0, w320 = LEDGER.load_accesses, LEDGER.load_words32
    ecc0, eccw0 = LEDGER.ecc_accesses, LEDGER.ecc_words32
    plain.pin(("w",), pack)
    load_acc = LEDGER.load_accesses - acc0
    load_w32 = LEDGER.load_words32 - w320
    prot.pin(("w",), pack)
    # the protected pin pays the IDENTICAL plain load + a separate ECC charge
    assert load_acc > 0, LEDGER
    assert LEDGER.load_accesses - acc0 == 2 * load_acc, LEDGER
    pin_ecc_acc = LEDGER.ecc_accesses - ecc0
    pin_ecc_w32 = LEDGER.ecc_words32 - eccw0
    plain_rows = sum(plain.rows_per_bank().values())
    prot_rows = sum(prot.rows_per_bank().values())
    row_ratio = prot_rows / plain_rows - 1.0
    assert abs(row_ratio - ecc_overhead(n_bits)) < 1e-9, (row_ratio, n_bits)

    n_verifies = 16
    with faults.faults(faults.FaultConfig(seed=23, resident_ber=1e-3)) as fm:
        for _ in range(n_verifies):
            entry = prot.get(("w",))
            assert entry is not None, "uncorrectable under single-bit BER"
        assert np.array_equal(np.asarray(entry.pack.unpack()),
                              np.asarray(x, np.int32)), "values corrupted"
    assert fm.injected > 0 and fm.corrected == fm.injected, fm.stats()
    assert fm.uncorrected == 0, fm.stats()
    verify_ecc_acc = LEDGER.ecc_accesses - ecc0 - pin_ecc_acc
    plain.clear()
    prot.clear()

    print(f"ecc_parity_planes,{n_bits},{n_parity},"
          f"SECDED planes per {n_bits} data planes")
    print(f"ecc_row_overhead_ratio,{n_words},{row_ratio:.4f},"
          f"parity rows / data rows (cost.ecc_overhead)")
    print(f"ecc_pin_charge_words32,{n_words},{pin_ecc_w32:.1f},"
          f"ledger ECC words32 for one pin; plain load charge unchanged")
    print(f"ecc_verify_accesses,{n_verifies},{verify_ecc_acc},"
          f"parity reads per warm get")
    print(f"ecc_injected,{n_verifies},{fm.injected},"
          f"seeded single-bit flips over {n_verifies} verifies")
    print(f"ecc_corrected,{n_verifies},{fm.corrected},"
          f"must equal injected")
    print(f"ecc_uncorrected,{n_verifies},{fm.uncorrected},"
          f"must stay zero (never-grow gate)")
    metrics["ecc"] = {
        "n_bits": n_bits,
        "n_words": n_words,
        "parity_planes": n_parity,
        "row_overhead_ratio": row_ratio,
        "cost_overhead_ratio": ecc_overhead(n_bits),
        "load_accesses": load_acc,
        "load_words32": load_w32,
        "pin_ecc_accesses": pin_ecc_acc,
        "pin_ecc_words32": pin_ecc_w32,
        "verify_ecc_accesses": verify_ecc_acc,
        "verifies": n_verifies,
        "fault_injected": fm.injected,
        "fault_corrected": fm.corrected,
        "fault_uncorrected": fm.uncorrected,
        "ecc_uncorrected": fm.uncorrected,
    }


#: canonical section order; the `kernel` alias groups the substrate
#: sections so CI can run one step per gate-relevant unit
SECTIONS = (("engine", engine_section), ("macro", macro_section),
            ("bank_sweep", bank_sweep_section),
            ("lowering", lowering_section),
            ("attention", attention_section),
            ("autotune", autotune_section),
            ("ecc", ecc_section))
SECTION_ALIASES = {"all": ("engine", "macro", "bank_sweep", "lowering",
                           "attention", "autotune", "ecc"),
                   "kernel": ("engine", "macro", "bank_sweep")}


def _resolve_sections(arg: str):
    picked = []
    for name in (s.strip() for s in arg.split(",") if s.strip()):
        for resolved in SECTION_ALIASES.get(name, (name,)):
            if resolved not in dict(SECTIONS):
                raise SystemExit(f"unknown bench section {name!r}; pick "
                                 f"from {[n for n, _ in SECTIONS]} or "
                                 f"aliases {sorted(SECTION_ALIASES)}")
            if resolved not in picked:
                picked.append(resolved)
    return [(n, fn) for n, fn in SECTIONS if n in picked]


def main(argv=()):
    # argv defaults to () so programmatic callers (benchmarks.run) never
    # inherit the host process's CLI; __main__ passes sys.argv explicitly
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_kernel.json",
                    default=None, metavar="PATH",
                    help="also write metrics to PATH (default BENCH_kernel.json)")
    ap.add_argument("--sections", default="all",
                    help="comma-separated sections to run: "
                         "engine,macro,bank_sweep,lowering,attention, or "
                         "the aliases all / kernel (=engine+macro+"
                         "bank_sweep)")
    ap.add_argument("--twice", action="store_true",
                    help="run every section a second time and assert the "
                         "warm pass is all schedule-cache hits with an "
                         "unchanged per-pass dispatch count")
    args = ap.parse_args(list(argv))
    selected = _resolve_sections(args.sections)

    def run_sections(metrics):
        for _, fn in selected:
            fn(metrics)

    s0 = dispatch.cache_stats()
    metrics = {}
    run_sections(metrics)

    if args.twice:
        s1 = dispatch.cache_stats()
        run_sections({})
        s2 = dispatch.cache_stats()
        warm_misses = s2["misses"] - s1["misses"]
        cold_dispatches = s1["dispatches"] - s0["dispatches"]
        warm_dispatches = s2["dispatches"] - s1["dispatches"]
        print(f"bench_warm_pass_cache,{s2['hits'] - s1['hits']},"
              f"{warm_misses},second pass must be all hits")
        print(f"bench_warm_pass_dispatches,{cold_dispatches},"
              f"{warm_dispatches},per-pass dispatch count must not change")
        assert warm_misses == 0, (s1, s2)
        assert warm_dispatches == cold_dispatches, (s1, s2)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(metrics, f, indent=2, sort_keys=True)
        print(f"bench_json_written,,{args.json},access counts + traffic ratios")
    return metrics


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])

"""CiM engine benchmark: ONE fused pass vs per-function baseline passes —
the TPU translation of the paper's one-vs-two memory access argument,
generalized to the full op surface.

The fused engine computes a Boolean function + subtraction + comparison from
a single streamed pass over both plane stacks; the near-memory baseline
re-reads the operands once per function. Reports (a) the modeled and the
MEASURED (actual buffer bytes) HBM traffic ratio, (b) wall-time of fused vs
unfused execution on this host's portable backend, and (c) the projected
ADRA-array energy for the same op counts from the calibrated paper model,
via the engine's accounting ledger.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import cim
from repro.cim import PlanePack

#: the fused request: Boolean fn + subtraction + comparison, one access
FUSED_OPS = ("xor", "sub", "lt", "eq")
#: the per-function baseline: one full access per function
BASELINE_PASSES = (("xor",), ("sub",), ("lt", "eq"))


def _time(fn, n=5):
    jax.tree.map(lambda x: x.block_until_ready(),
                 jax.tree.leaves(fn()))  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn()
        jax.tree.map(lambda x: x.block_until_ready(), jax.tree.leaves(out))
    return (time.perf_counter() - t0) / n * 1e6


def main():
    n_bits, n_words = 16, 1 << 20
    rng = np.random.RandomState(0)
    a = jnp.array(rng.randint(-2**15, 2**15, n_words), jnp.int32)
    b = jnp.array(rng.randint(-2**15, 2**15, n_words), jnp.int32)
    pa, pb = PlanePack.pack(a, n_bits), PlanePack.pack(b, n_bits)

    # traffic: the roofline argument, modeled and measured from real buffers
    t = cim.traffic_model_bytes(n_bits, pa.planes.shape[1], ops=FUSED_OPS,
                                baseline_passes=BASELINE_PASSES)
    print(f"kernel_traffic_fused_bytes,{n_words},{t['fused']:.0f},xor+sub+cmp one pass")
    print(f"kernel_traffic_baseline_bytes,{n_words},{t['baseline']:.0f},one pass per function")
    print(f"kernel_traffic_model_ratio,{n_words},{t['ratio']:.3f},paper: k accesses vs 1")
    m = cim.measured_traffic_bytes(pa, pb, FUSED_OPS,
                                   baseline_passes=BASELINE_PASSES,
                                   backend="jnp-boolean")
    print(f"kernel_traffic_measured_ratio,{n_words},{m['ratio']:.3f},"
          f"actual buffer bytes, >1.5 required")
    assert m["ratio"] > 1.5, m

    # wall time of fused vs unfused on the portable backend (host sanity,
    # not TPU perf; interpret-mode Pallas is not a performance proxy)
    fused = jax.jit(lambda: cim.execute(pa, pb, FUSED_OPS,
                                        backend="jnp-boolean"))
    unfused = jax.jit(lambda: cim.execute_unfused(
        pa, pb, BASELINE_PASSES, backend="jnp-boolean"))
    us_f = _time(fused)
    us_u = _time(unfused)
    print(f"kernel_fused_us,{n_words},{us_f:.1f},jnp-boolean backend on host")
    print(f"kernel_unfused_us,{n_words},{us_u:.1f},per-function passes")

    # projected ADRA-array energy via the engine ledger (paper model)
    led = cim.ledger()
    led.reset()
    cim.execute(pa, pb, FUSED_OPS, backend="jnp-boolean")
    fused_proj = led.projected(scheme="current")
    led.reset()
    cim.execute_unfused(pa, pb, BASELINE_PASSES, backend="jnp-boolean")
    base_proj = led.projected(scheme="current")
    ratio = base_proj["cim_energy"] / fused_proj["cim_energy"]
    print(f"kernel_ledger_access_energy_ratio,{n_words},{ratio:.2f},"
          f"unfused charges {ratio:.0f}x the accesses")
    print(f"kernel_projected_adra_energy_saved_fj,{n_words},"
          f"{fused_proj['energy_saved_fj']:.0f},current sensing @1024^2")
    print(f"kernel_projected_edp_decrease_pct,{n_words},"
          f"{fused_proj['edp_decrease_pct']:.2f},")


if __name__ == "__main__":
    main()

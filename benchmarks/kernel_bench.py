"""ADRA bit-plane kernel benchmark: fused single-pass vs per-function
baseline passes — the TPU translation of the paper's one-vs-two memory
access argument.

Reports (a) the HBM traffic model for TPU-scale tensors, (b) measured
wall-time of the jnp oracle paths on THIS host (CPU; interpret-mode Pallas
is not a performance proxy), and (c) the projected ADRA-array EDP for the
same op counts from the calibrated paper model.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy
from repro.core.bitplane import pack_bitplanes
from repro.kernels import ref
from repro.kernels.adra_bitplane import traffic_model_bytes


def _time(fn, *args, n=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else None
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / n * 1e6


def main():
    n_bits, n_words = 16, 1 << 20
    rng = np.random.RandomState(0)
    a = jnp.array(rng.randint(-2**15, 2**15, n_words), jnp.int32)
    b = jnp.array(rng.randint(-2**15, 2**15, n_words), jnp.int32)
    ap, bp = pack_bitplanes(a, n_bits), pack_bitplanes(b, n_bits)

    # traffic model (the roofline argument)
    t = traffic_model_bytes(n_bits, ap.shape[1])
    print(f"kernel_traffic_fused_bytes,{n_words},{t['fused']:.0f},")
    print(f"kernel_traffic_baseline_bytes,{n_words},{t['baseline']:.0f},")
    print(f"kernel_traffic_ratio,{n_words},{t['ratio']:.3f},paper: ~2 accesses vs 1")

    # oracle-path wall time on this host (sanity, not TPU perf)
    fused = jax.jit(lambda x, y: ref.adra_bitplane_ref(x, y, 1))
    us = _time(fused, ap, bp)
    print(f"kernel_oracle_fused_us,{n_words},{us:.1f},jnp path on CPU host")

    # projected ADRA-array energy for the same op count (paper model)
    ops32 = n_words * n_bits / 32
    r = energy.current_sensing(1024)
    saved = (r.baseline.energy - r.cim.energy) * ops32
    print(f"kernel_projected_adra_energy_saved_fj,{n_words},{energy.to_fj(saved):.0f},"
          f"current sensing @1024^2")
    print(f"kernel_projected_edp_decrease_pct,{n_words},{r.edp_decrease_pct:.2f},")


if __name__ == "__main__":
    main()

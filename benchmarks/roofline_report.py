"""Roofline report: renders the dry-run JSON artifacts into the
EXPERIMENTS.md table (all (arch x shape x mesh) cells)."""
import glob
import json
import os

OUT_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_cells(mesh_filter=None):
    cells = []
    for f in sorted(glob.glob(os.path.join(OUT_DIR, "*.json"))):
        d = json.load(open(f))
        if mesh_filter and mesh_filter not in f:
            continue
        cells.append(d)
    return cells


def main():
    cells = load_cells()
    if not cells:
        print("no dry-run artifacts found; run: python -m repro.launch.sweep")
        return
    # the device column is the shared DeviceSpec the dry-run's roofline
    # terms were computed under (repro.cim.cost.DeviceSpec provenance in
    # the artifact); artifacts from before the provenance field fall back
    # to the default device's name
    try:
        from repro.cim.cost import DEFAULT_DEVICE
        fallback_device = DEFAULT_DEVICE.name
    except ImportError:            # run without PYTHONPATH=src
        fallback_device = "tpu-v5e"

    print("arch,shape,mesh,device,status,t_compute_s,t_memory_s,"
          "t_collective_s,bottleneck,model_flops,useful_ratio,"
          "roofline_fraction")
    for d in cells:
        dev = (d.get("roofline") or {}).get("device") \
            or (d.get("device") or {}).get("name") or fallback_device
        if "skipped" in d:
            print(f"{d['arch']},{d['shape']},{d.get('mesh','-')},{dev},"
                  f"skipped(N/A),,,,,,,")
            continue
        if d.get("status") != "ok":
            print(f"{d['arch']},{d['shape']},{d.get('mesh','-')},{dev},"
                  f"ERROR,,,,,,,")
            continue
        r = d["roofline"]
        print(f"{d['arch']},{d['shape']},{d['mesh']},{dev},ok,"
              f"{r['t_compute_s']:.3e},{r['t_memory_s']:.3e},"
              f"{r['t_collective_s']:.3e},{r['bottleneck']},"
              f"{r['model_flops']:.3e},{r['useful_flops_ratio']:.3f},"
              f"{r['roofline_fraction']:.4f}")


if __name__ == "__main__":
    main()

"""Benchmark harness: one module per paper table/figure + the TPU kernel
traffic bench + the roofline report. Prints ``name,key,value,note`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only fig4|fig5|fig6|fig7|kernel|roofline]
"""
import argparse

from . import (
    fig4_current_sensing,
    fig5_voltage_tradeoffs,
    fig6_scheme1,
    fig7_scheme2,
    kernel_bench,
    roofline_report,
)

SECTIONS = {
    "fig4": fig4_current_sensing.main,
    "fig5": fig5_voltage_tradeoffs.main,
    "fig6": fig6_scheme1.main,
    "fig7": fig7_scheme2.main,
    "kernel": kernel_bench.main,
    "roofline": roofline_report.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(SECTIONS), default=None)
    args = ap.parse_args()
    chosen = [args.only] if args.only else list(SECTIONS)
    for name in chosen:
        print(f"# --- {name} " + "-" * 50)
        SECTIONS[name]()


if __name__ == '__main__':
    main()

"""Walk through the ADRA paper end to end on the simulator.

Reproduces, in order: the many-to-one failure of symmetric CiM, the four
distinct I_SL levels under asymmetric biasing, sense margins, the 2-bit
single-access read, the full compute-module subtraction/comparison, and the
energy/EDP headline numbers for all three sensing schemes.

  PYTHONPATH=src python examples/adra_cim_demo.py

Every CiM section prints its walltime plus the compiled-schedule cache /
dispatch deltas, so the whole-schedule execution speedup (one jitted XLA
dispatch per macro or fused region, warm calls all cache hits) is visible
directly in the demo output.
"""
import contextlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    adra_access,
    cim_compare,
    cim_sub,
    current_sensing,
    frequency_crossover_hz,
    parallelism_crossover,
    voltage_scheme1,
    voltage_scheme2,
)
from repro.core.array import AdraArrayConfig, level_currents
from repro.core.sensing import (
    current_sense_margins,
    symmetric_sense_is_ambiguous,
    voltage_sense_margins,
)
from repro.cim import dispatch as cim_dispatch


@contextlib.contextmanager
def section(title):
    """Time a demo section and report its dispatch/cache activity."""
    print(f"\n{title}")
    before = cim_dispatch.cache_stats()
    t0 = time.perf_counter()
    yield
    ms = (time.perf_counter() - t0) * 1e3
    after = cim_dispatch.cache_stats()
    print(f"   -- {ms:.1f} ms | dispatches "
          f"+{after['dispatches'] - before['dispatches']}, schedule cache "
          f"+{after['hits'] - before['hits']} hits / "
          f"+{after['misses'] - before['misses']} misses")


cfg = AdraArrayConfig()

print("1) symmetric multi-WL assertion (prior work) is many-to-one:")
sym = np.array(jax.device_get(level_currents(cfg, asymmetric=False))) * 1e6
print(f"   I_SL(00,10,01,11) = {np.round(sym, 3)} uA  "
      f"-> (1,0) vs (0,1) ambiguous: {symmetric_sense_is_ambiguous(cfg)}")

print("\n2) ADRA asymmetric biasing (V_GREAD1=0.83V, V_GREAD2=1.0V) is one-to-one:")
lv = np.array(jax.device_get(level_currents(cfg, asymmetric=True))) * 1e6
print(f"   I_SL(00,10,01,11) = {np.round(lv, 2)} uA")
cm = np.array(jax.device_get(current_sense_margins(cfg))) * 1e6
vm = np.array(jax.device_get(voltage_sense_margins(cfg))) * 1e3
print(f"   current margins {np.round(cm, 1)} uA (paper: >1 uA), "
      f"voltage margins {np.round(vm, 0)} mV (paper: >50 mV)")

print("\n3) single-access 2-bit read (3 SAs + OAI gate recover A and B):")
a = jnp.array([[0, 1, 0, 1]])
b = jnp.array([[0, 0, 1, 1]])
acc = adra_access(a, b, mode="analog")
print(f"   stored A={np.array(a[0])} B={np.array(b[0])}")
print(f"   sensed OR={np.array(acc.or_[0])} AND={np.array(acc.and_[0])} "
      f"B={np.array(acc.b[0])} -> A={np.array(acc.a[0])}")

print("\n4) in-memory subtraction & comparison (non-commutative!):")
x = jnp.array([37, -90, 64], jnp.int32)
y = jnp.array([90, -37, 64], jnp.int32)
sub = cim_sub(x, y, n_bits=8, mode="analog")
cmp_ = cim_compare(x, y, n_bits=8, mode="analog")
print(f"   x={np.array(x)}, y={np.array(y)}")
print(f"   x-y={np.array(sub.value)}, lt={np.array(cmp_.lt)}, eq={np.array(cmp_.eq)}")

from repro import cim
from repro.cim import PlanePack

with section("5) unified CiM engine: same op surface, any backend, one access:"):
    pa, pb = PlanePack.pack(x, 8), PlanePack.pack(y, 8)
    for backend in ("jnp-boolean", "pallas-interpret", "analog-oracle"):
        out = cim.execute(pa, pb, ("xor", "sub", "lt"), backend=backend)
        print(f"   [{backend:16s}] xor={np.array(out['xor'].unpack())} "
              f"sub={np.array(out['sub'].unpack())} lt={np.array(out['lt'].unpack())}")
    led = cim.ledger()
    print(f"   ledger: {led.accesses} accesses charged, "
          f"projected EDP -{led.projected()['edp_decrease_pct']:.1f}%")

from repro.cim import planner

with section("6) macro-op planner: multi-access arithmetic as access "
             "schedules, each compiled to ONE jitted dispatch:"):
    mul_plan = planner.plan_multiply(8, 8)
    print(f"   multiply 8x8 plan: {mul_plan.accesses} accesses "
          f"{[s.ops[0] for s in mul_plan.steps]}")
    led.reset()
    prod = cim.multiply(PlanePack.pack(x, 8), PlanePack.pack(y, 8),
                        backend="jnp-boolean")
    print(f"   x*y={np.array(prod.unpack())}  (ledger charged {led.accesses} "
          f"accesses = plan length)")
    t = planner.schedule_traffic_bytes(mul_plan, 8, prod.planes.shape[1])
    print(f"   fused schedule traffic {t['fused']:.0f} B vs unfused "
          f"{t['baseline']:.0f} B -> {t['ratio']:.1f}x (intermediates stay in-array)")
    A = jnp.array([[1, -2, 3], [4, 5, -6]], jnp.int32)
    B = jnp.array([[7, -8], [9, 10], [-11, 12]], jnp.int32)
    mm_plan = planner.plan_matmul(3, 2, n_bits=8)
    led.reset()
    C = cim.matmul(A, B, n_bits=8, backend="jnp-boolean")
    print(f"   int8 matmul [2,3]x[3,2] -> {np.array(C).tolist()} in "
          f"{led.accesses} accesses (plan {mm_plan.accesses}; "
          f"independent of M and N)")

from repro.cim import ArraySpec, lower
from repro.models import layers

with section("7) jaxpr->CiM lowering compiler: unmodified JAX -> hybrid "
             "execution, one dispatch per fused region:"):
    key = jax.random.PRNGKey(0)
    p = layers.mlp_init(key, 8, 16, "swiglu", jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (2, 8), jnp.float32)
    spec = ArraySpec(banks=4, subarrays=1, rows=128, bitline_words=32)
    mlp_lowered = layers._lowered_mlp("swiglu", 8, "jnp-boolean", spec, None)
    comp = mlp_lowered.trace(p, xs)
    for line in comp.describe().splitlines():
        print("   " + line)
    led.reset()
    y_low = mlp_lowered(p, xs)
    y_ref = layers._mlp_quantized(p, xs, "swiglu", 8)
    print(f"   bit-exact vs un-lowered mlp: "
          f"{bool(jnp.all(y_low == y_ref))}  (ledger charged {led.accesses} "
          f"banked activations)")
    rep = led.bank_report(spec)
    print(f"   bank report: {rep['activations']:.0f} activations over "
          f"{rep['banks']:.0f} banks, {rep['waves']:.0f} waves, "
          f"utilization {rep['utilization']:.2f}, "
          f"EDP -{rep['edp_decrease_pct']:.1f}% vs near-memory")

    x16 = jnp.array(x, jnp.int16)
    y16 = jnp.array(y, jnp.int16)
    fused_chain = lower(lambda a, b: jnp.where((a + b) - 3 < a, a, b),
                        backend="jnp-boolean")
    chain_comp = fused_chain.trace(x16, y16)
    fused_chain(x16, y16)
    print(f"   fused chain {chain_comp.regions[0].schedule.segments} -> "
          f"{chain_comp.accesses} accesses, select is free periphery")

with section("8) whole-schedule compiled execution: warm macros are one "
             "XLA dispatch, zero retrace:"):
    rng = np.random.RandomState(7)
    Am = jnp.array(rng.randint(-128, 128, (16, 32)), jnp.int32)
    Bm = jnp.array(rng.randint(-128, 128, (32, 8)), jnp.int32)

    def timed_matmul():
        t0 = time.perf_counter()
        out = cim.matmul(Am, Bm, n_bits=8, backend="jnp-boolean")
        out.block_until_ready()
        return (time.perf_counter() - t0) * 1e3

    cold = timed_matmul()                 # traces + compiles the schedule
    warm = min(timed_matmul() for _ in range(3))
    cs = cim_dispatch.cache_stats()
    print(f"   int8 matmul [16,32]x[32,8]: {cold:.1f} ms cold "
          f"(trace + XLA compile) -> {warm:.2f} ms warm "
          f"({cold / max(warm, 1e-9):.0f}x), one dispatch per call")
    print(f"   schedule cache: {cs['hits']} hits / {cs['misses']} misses / "
          f"{cs['evictions']} evictions, {cs['dispatches']} dispatches total")

print("\n9) energy/latency model (calibrated to the paper's SPICE anchors):")
for name, r in [("current sensing", current_sensing(1024)),
                ("voltage scheme 1", voltage_scheme1(1024)),
                ("voltage scheme 2", voltage_scheme2(1024))]:
    print(f"   {name:17s}: {r.speedup:.2f}x speedup, "
          f"{r.energy_decrease_pct:+.1f}% energy, EDP -{r.edp_decrease_pct:.1f}%")
print(f"   scheme1/2 crossovers: {frequency_crossover_hz()/1e6:.2f} MHz "
      f"(paper 7.53), P={parallelism_crossover():.3f} (paper ~0.42)")

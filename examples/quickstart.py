"""Quickstart: the ADRA CiM primitive + a tiny LM training run.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import cim
from repro.cim import PlanePack
from repro.configs import get_config
from repro.core import edp_summary
from repro.models import build
from repro.optim import AdamWConfig
from repro.train import init_state, make_train_step


def adra_primitives():
    print("== ADRA single-access in-memory arithmetic (unified CiM engine) ==")
    print(f"backend: {cim.default_backend_name()} "
          f"(registered: {', '.join(cim.available_backends())})")
    a = jnp.array([12, -7, 100, 3], jnp.int32)
    b = jnp.array([5, -7, 120, -3], jnp.int32)
    print("a      :", a)
    print("b      :", b)
    print("a - b  :", cim.sub(a, b, n_bits=8), " (single memory access)")
    print("a + b  :", cim.add(a, b, n_bits=8))
    c = cim.compare(a, b, n_bits=8)
    print("a <=> b: lt", c.lt, " eq", c.eq, " gt", c.gt)
    print("a XOR b:", cim.boolean(a & 0xF, b & 0xF, "xor", n_bits=4))

    # the fused request: one access yields a Boolean fn + arithmetic + compare
    out = cim.execute(PlanePack.pack(a, 8), PlanePack.pack(b, 8),
                      ("nand", "sub", "lt", "eq"))
    print("one access -> nand", out["nand"].unpack(),
          " sub", out["sub"].unpack(), " lt", out["lt"].unpack())
    # chained packed-plane pipeline: (a - b) - b without ever unpacking
    d1 = cim.execute(PlanePack.pack(a, 8), PlanePack.pack(b, 8), ("sub",))["sub"]
    d2 = cim.execute(d1, PlanePack.pack(b, 8).extend_to(d1.n_bits),
                     ("sub",))["sub"]
    print("(a-b)-b :", d2.unpack(), " (stayed packed between ops)")

    # macro ops: the planner lowers multi-access arithmetic to explicit
    # schedules of single accesses; the ledger charges exactly the plan
    print("a * b  :", cim.multiply(PlanePack.pack(a, 8),
                                   PlanePack.pack(b, 8)).unpack(),
          f" ({cim.plan_multiply(8, 8).accesses} accesses, shift-and-add)")
    print("relu(a):", cim.relu(PlanePack.pack(a, 8)).unpack(),
          " (1 access: gt predicate + peripheral select)")
    A = jnp.array([[1, 2], [3, 4]], jnp.int32)
    B = jnp.array([[5, -6], [7, 8]], jnp.int32)
    print("A @ B  :", cim.matmul(A, B, n_bits=8).tolist(),
          f" ({cim.plan_matmul(2, 2, n_bits=8).accesses} accesses,"
          " independent of M and N)")
    print("\npaper-model EDP decrease per sensing scheme:")
    for scheme, row in edp_summary().items():
        print(f"  {scheme:8s}: speedup {row['speedup']:.2f}x, "
              f"energy {row['energy_decrease_pct']:+.1f}%, "
              f"EDP -{row['edp_decrease_pct']:.1f}%")


def tiny_training():
    print("\n== 20 training steps of a reduced llama3.2 on CPU ==")
    cfg = get_config("llama3.2-1b").reduced()
    model = build(cfg)
    opt = AdamWConfig(lr=3e-3)
    state = init_state(model, jax.random.PRNGKey(0), opt)
    step = jax.jit(make_train_step(model, opt))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size),
        "targets": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, cfg.vocab_size),
    }
    for i in range(20):
        state, m = step(state, batch)
        if i % 5 == 0 or i == 19:
            print(f"  step {i:2d}  loss {float(m['loss']):.4f}")


if __name__ == "__main__":
    adra_primitives()
    tiny_training()

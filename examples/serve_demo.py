"""Batched serving with the ADRA quantized-comparison sampler.

Runs prefill + decode on a reduced gemma-2b, sampling each token two ways —
float argmax and the ADRA in-memory comparison tree — and checks they agree.

  PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build
from repro.train import adra_sample, greedy_sample, make_decode_step, make_prefill_step

cfg = get_config("gemma-2b").reduced()
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))

B, P, G = 4, 16, 12
prefill = jax.jit(make_prefill_step(model, max_len=P + G))
decode = jax.jit(make_decode_step(model))

prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab_size)
caches, logits = prefill(params, {"tokens": prompts})

agree = 0
tok = greedy_sample(logits)
generated = [tok]
t0 = time.monotonic()
for t in range(P, P + G - 1):
    caches, logits = decode(params, caches,
                            {"tokens": tok[:, None],
                             "positions": jnp.full((B,), t, jnp.int32)})
    tok_f = greedy_sample(logits)
    tok_a = adra_sample(logits, n_bits=8)
    agree += int(jnp.sum(tok_f == tok_a))
    tok = tok_f
    generated.append(tok)
dt = time.monotonic() - t0

gen = np.array(jnp.stack(generated, 1))
print(f"generated {gen.shape[1]} tokens x {B} sequences in {dt:.2f}s")
print(f"ADRA sampler vs float argmax agreement: {agree}/{B * (G - 1)}")
print("sequences:\n", gen)

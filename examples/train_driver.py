"""End-to-end training driver example: a ~100M-parameter llama-family model
for a few hundred steps under the fault-tolerant Supervisor (async
checkpointing, NaN sentinel, restart-exact data).

  PYTHONPATH=src python examples/train_driver.py            # quick (reduced)
  PYTHONPATH=src python examples/train_driver.py --full100m # ~100M, 200 steps

This is a thin veneer over the production launcher:
  python -m repro.launch.train --arch llama3.2-1b --preset 100m --steps 200
"""
import sys

from repro.launch import train as train_launcher

if __name__ == "__main__":
    if "--full100m" in sys.argv:
        argv = ["--arch", "llama3.2-1b", "--preset", "100m",
                "--steps", "200", "--batch", "8", "--seq", "256",
                "--ckpt-every", "50"]
    else:
        argv = ["--arch", "llama3.2-1b", "--preset", "reduced",
                "--steps", "60", "--batch", "8", "--seq", "128",
                "--ckpt-every", "20"]
    sys.argv = [sys.argv[0]] + argv
    train_launcher.main()

"""Sharded checkpointing: per-host npz shards + manifest, atomic step commit,
async save, and cross-mesh resharding restore (elastic scaling).

Layout:
  <dir>/step_000000123/
      manifest.json          tree structure, shapes, dtypes, mesh, status
      host_<k>.npz           this host's addressable shard data
  <dir>/LATEST               committed step pointer (written last => atomic)

On restore the target mesh/sharding may differ from the save-time one
(node failure -> smaller mesh; scale-up -> larger): arrays are reassembled
from shards and re-placed with the NEW sharding. Restore correctness across
meshes is covered by tests/test_checkpoint.py.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

SEP = "::"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save

    def save(self, step: int, state: Any, blocking: bool = True) -> None:
        if self._thread is not None:
            self._thread.join()  # one in-flight save at a time
            self._thread = None
        flat = _flatten(state)          # device_get happens on the caller
        if blocking:
            self._write(step, flat)
        else:
            t = threading.Thread(target=self._write, args=(step, flat), daemon=True)
            t.start()
            self._thread = t

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: Dict[str, np.ndarray]) -> None:
        d = os.path.join(self.dir, f"step_{step:09d}")
        tmp = d + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"host_{jax.process_index()}.npz"), **flat)
        manifest = {
            "step": step,
            "keys": sorted(flat.keys()),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "n_hosts": jax.process_count(),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.isdir(d):
            shutil.rmtree(d)
        os.rename(tmp, d)
        with open(os.path.join(self.dir, "LATEST.tmp"), "w") as f:
            f.write(str(step))
        os.replace(os.path.join(self.dir, "LATEST.tmp"),
                   os.path.join(self.dir, "LATEST"))
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"), ignore_errors=True)

    # --------------------------------------------------------------- restore

    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return int(f.read().strip())

    def all_steps(self):
        out = []
        for n in os.listdir(self.dir):
            if n.startswith("step_") and not n.endswith(".tmp"):
                out.append(int(n.split("_")[1]))
        return sorted(out)

    def restore(self, step: int, target_state: Any, shardings: Any = None) -> Any:
        """Restore into the structure of `target_state`; if `shardings` is
        given (a NamedSharding tree), arrays are placed with it — this is the
        elastic path: the saved mesh need not equal the target mesh."""
        d = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data: Dict[str, np.ndarray] = {}
        for k in range(manifest["n_hosts"]):
            f = os.path.join(d, f"host_{k}.npz")
            if os.path.exists(f):
                with np.load(f) as z:
                    data.update({n: z[n] for n in z.files})

        paths, treedef = jax.tree_util.tree_flatten_with_path(target_state)
        shard_leaves = (jax.tree.leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
            if shardings is not None else [None] * len(paths))
        leaves = []
        for (path, leaf), shd in zip(paths, shard_leaves):
            key = SEP.join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            arr = data[key]
            if shd is not None:
                leaves.append(jax.device_put(arr, shd))
            else:
                leaves.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)

"""repro.cim — the unified ADRA computing-in-memory engine.

One asymmetric dual-row access yields {OR, AND, B} (and A via the OAI21
gate); the engine turns that into the FULL op surface — add, sub, compare,
carry, and all 16 two-input Boolean functions — from one streamed pass, on
any registered backend:

  opset       — the op catalogue + plane-level Boolean composition rules
  planepack   — PlanePack pytree: packed uint32 planes + static metadata,
                so chained ops never round-trip through pack/unpack
  fused_kernel— the generalized single-pass Pallas TPU kernel
  backends    — registry: pallas-tpu / pallas-interpret / jnp-boolean /
                analog-oracle, one dispatch point for all call sites
  engine      — execute / execute_unfused + integer-level add, sub,
                compare, boolean wrappers + HBM traffic model/measurement
  planner     — macro-op planner: multi-access computations lowered to
                explicit access Schedules (the cost model IS the plan)
  macro       — schedule executors: multiply, abs/relu/min/max, popcount,
                tree reduce_sum, int8 dot/matmul — all in the packed
                domain, each compiled to ONE jitted XLA dispatch per
                schedule (run_schedule_program) with ledger charges
                replayed from the plan
  accounting  — per-op energy ledger wired through repro.core.energy,
                extended with per-(device, bank) activation slots and a
                contention-adjusted EDP projection
  array       — banked physical geometry: ArraySpec (banks x subarrays x
                rows x bitline words) and TilePlan placement
  dispatch    — tiling dispatcher: bank-sized tiles vmapped over the fused
                kernel, bounded-LRU compiled-schedule cache (hit/miss/
                eviction counters), and a shard_map path over the
                launch/mesh meshes
  trace       — jaxpr -> CiM IR: eqn-level eligibility classification
                shared by the offload estimator and the executor
  cost        — spec-driven cost model: DeviceSpec host roofline vs CiM
                energy/latency/EDP per eqn, and the offload policy that
                decides (per eqn, with fusion-boundary re-evaluation)
                whether lowering pays at all
  autotune    — geometry/bits autotuner: cost-model-pruned, walltime-
                confirmed search over tile shape x banks x scheme, winners
                in a bounded LRU persistable to JSON
  lower       — the lowering compiler: fuse eligible eqn runs into region
                Schedules, execute them through ChainExecutor, run the
                rest on the host — offload estimates become execution

Layering: repro.core holds the physics (device model, sensing, gate-level
modules, calibrated energy model) and remains the semantic oracle; repro.cim
is the execution engine every caller dispatches through.
"""
from . import (  # noqa: F401
    accounting,
    array,
    autotune,
    backends,
    cost,
    dispatch,
    engine,
    faults,
    lower as lower_mod,
    macro,
    opset,
    planner,
    trace as trace_mod,
)
from .accounting import LEDGER, Ledger, ledger, project_savings  # noqa: F401
from .array import (  # noqa: F401
    DEFAULT_SPEC,
    ArraySpec,
    ResidentSet,
    TilePlan,
    clear_resident,
    current_spec,
    resident_set,
    resident_stats,
    set_current_spec,
    set_resident_ecc,
)
from .faults import (  # noqa: F401
    FaultConfig,
    FaultModel,
    UncorrectableFaultError,
    fault_seed,
    fault_stats,
)
from .autotune import Autotuner, Candidate, TuneResult  # noqa: F401
from .cost import (  # noqa: F401
    DEFAULT_DEVICE,
    DEFAULT_POLICY,
    POLICIES,
    DeviceSpec,
    EqnVerdict,
    OffloadPlan,
    cim_wins_table,
    plan_offload,
)
from .dispatch import (  # noqa: F401
    BoundedLRU,
    cache_stats,
    clear_schedule_cache,
    execute_sharded,
    execute_tiled,
    set_schedule_cache_capacity,
)
from .backends import (  # noqa: F401
    available_backends,
    default_backend_name,
    get_backend,
    on_tpu,
    register_backend,
    set_default_backend,
)
from .engine import (  # noqa: F401
    CmpOut,
    add,
    boolean,
    compare,
    execute,
    execute_unfused,
    measured_traffic_bytes,
    sub,
    traffic_model_bytes,
)
from .fused_kernel import DEFAULT_BLOCK_W, fused_planes_op  # noqa: F401
from .lower import (  # noqa: F401
    LoweredComputation,
    LoweredFunction,
    lower,
)
from .trace import Trace, TracedOp, trace  # noqa: F401
from .macro import (  # noqa: F401
    ChainExecutor,
    CompiledSchedule,
    ScheduleCursor,
    abs_,
    dot,
    matmul,
    matmul_rhs_pack,
    maximum,
    minimum,
    multiply,
    popcount,
    reduce_sum,
    relu,
    run_schedule_program,
    select,
)
from .opset import (  # noqa: F401
    ALL_OPS,
    ARITH_OPS,
    BOOLEAN_OPS,
    PREDICATE_OPS,
    CimOpError,
)
from .planepack import PlanePack, mask_to_ints  # noqa: F401
from .planner import (  # noqa: F401
    Schedule,
    Step,
    concat_schedules,
    plan_abs,
    plan_elementwise,
    plan_neg,
    plan_dot,
    plan_matmul,
    plan_maximum,
    plan_minimum,
    plan_multiply,
    plan_popcount,
    plan_reduce_sum,
    plan_relu,
    schedule_traffic_bytes,
)

"""Per-op energy accounting for the CiM engine, wired through repro.core.energy.

Every engine execution charges a ledger with the number of ADRA memory
accesses and 32-bit-word-equivalent operations it represents; the ledger then
projects array-level energy/latency/EDP through the calibrated paper model
(any sensing scheme). The fused engine charges ONE access per op-set — the
paper's single-access claim — while the unfused baseline charges one access
per pass, so the ledger difference IS the paper's headline saving.

Charging happens at Python trace time: under jit, a call site is charged once
per compilation, not once per device execution. That is the right granularity
for the model-level projections here (per-op costs are multiplied out by the
word counts); benchmarks that need per-invocation counts run unjitted.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from repro.core import energy


@dataclasses.dataclass
class Ledger:
    """Counts of ADRA accesses executed through the engine."""

    accesses: int = 0
    words32: float = 0.0          # 32-bit-word-equivalent ops charged
    per_op: Dict[str, int] = dataclasses.field(default_factory=dict)
    enabled: bool = True

    def charge(self, ops: Tuple[str, ...], n_bits: int, n_words: int,
               accesses: int = 1) -> None:
        if not self.enabled:
            return
        self.accesses += accesses
        self.words32 += n_words * n_bits / 32.0 * accesses
        for op in ops:
            self.per_op[op] = self.per_op.get(op, 0) + 1

    def reset(self) -> None:
        self.accesses = 0
        self.words32 = 0.0
        self.per_op.clear()

    def projected(self, scheme: str = "current", rows: int = 1024) -> Dict[str, float]:
        """Array-level projection of the charged work through the paper model."""
        return project_savings(self.words32, scheme=scheme, rows=rows)


#: process-wide ledger the engine charges into
LEDGER = Ledger()


def ledger() -> Ledger:
    return LEDGER


_SCHEMES = {
    "current": energy.current_sensing,
    "scheme1": energy.voltage_scheme1,
    "scheme2": energy.voltage_scheme2,
}


def project_savings(words32: float, scheme: str = "current",
                    rows: int = 1024) -> Dict[str, float]:
    """Energy/latency/EDP of `words32` word-ops: ADRA CiM vs the two-access
    near-memory baseline, in both internal units and physical estimates."""
    res = _SCHEMES[scheme](rows)
    return {
        "words32": words32,
        "cim_energy": res.cim.energy * words32,
        "baseline_energy": res.baseline.energy * words32,
        "energy_saved": (res.baseline.energy - res.cim.energy) * words32,
        "energy_saved_fj": energy.to_fj(
            (res.baseline.energy - res.cim.energy) * words32),
        "speedup": res.speedup,
        "edp_decrease_pct": res.edp_decrease_pct,
    }

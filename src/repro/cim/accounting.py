"""Per-op energy accounting for the CiM engine, wired through repro.core.energy.

Every engine execution charges a ledger with the number of ADRA memory
accesses and 32-bit-word-equivalent operations it represents; the ledger then
projects array-level energy/latency/EDP through the calibrated paper model
(any sensing scheme). The fused engine charges ONE access per op-set — the
paper's single-access claim — while the unfused baseline charges one access
per pass, so the ledger difference IS the paper's headline saving.

The banked substrate (repro.cim.array / repro.cim.dispatch) extends the
model to physical geometry: `charge_banked` attributes one activation per
tile to its (device, bank) slot, tracks activated-but-idle words (the last
tile's empty bitline columns) and inter-bank reduction traffic, and
`bank_report` turns those into a contention-adjusted EDP projection —
energy follows ACTIVATED words (idle columns still burn bitline energy),
latency follows the busiest bank's wave count (banks run concurrently,
waves serialize).

Charging happens at Python call time, never inside a compiled program. The
whole-schedule execution path (repro.cim.macro.run_schedule_program) makes
that explicit: tracing a schedule records its charges into a `PlannedCharges`
object — charge-from-plan, which PR 2-4's cursor guarantee proves equals the
execution — and every invocation of the compiled program replays that record
into the ledger. A call site compiled into a larger jit is charged once per
outer trace (once per compiled shape), eager call sites once per invocation;
both exactly as before the schedules were compiled.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core import energy

#: modeled interconnect cost of moving one 32-bit word between banks during
#: a cross-tile reduction step (internal units — fractions of one standard
#: 1024-row read energy / latency; a NoC hop is cheap next to an activation)
E_HOP_WORD32 = 0.05
T_HOP_WORD32 = 0.01


@dataclasses.dataclass
class Ledger:
    """Counts of ADRA accesses executed through the engine.

    bank_accesses      : activations per (device, bank) slot; the unbanked
                         engine path charges slot (0, 0).
    activated_words32  : 32-bit-word slots ACTIVATED (incl. the idle columns
                         of partially-filled tiles) — >= words32.
    inter_bank_words32 : words crossing banks in reduction steps.
    load_accesses      : operand-load (row-write) accesses: a STREAMED
                         operand must be driven into the array rows before
                         an access can compute over it — one load per
                         operand entry pack (per tile when placed). Resident
                         operands skip this charge; that skip is the paper's
                         stored-operand assumption made measurable.
    load_words32       : word-equivalents written by operand loads.
    resident_reuses    : resident-operand reuses (entry pack skipped).
    resident_words32   : word-equivalents those reuses did NOT re-write.
    ecc_accesses       : parity-plane accesses (extra row writes at pin
                         time, parity reads per verify/scrub) — the
                         protection overhead, kept out of total_accesses so
                         compute/load bills are comparable with ECC off.
    ecc_words32        : word-equivalents those parity planes moved.
    fault_injected     : bits flipped into live data by the fault overlay.
    fault_detected     : bits an ECC verify saw (corrected + uncorrected).
    fault_corrected    : bits SECDED repaired in place.
    fault_uncorrected  : bits detected but NOT repairable — the entry was
                         invalidated and rebuilt; a nonzero steady-state
                         value is data loss and is gated never-grow in CI.
    """

    accesses: int = 0
    words32: float = 0.0          # 32-bit-word-equivalent ops charged
    per_op: Dict[str, int] = dataclasses.field(default_factory=dict)
    bank_accesses: Dict[Tuple[int, int], int] = dataclasses.field(
        default_factory=dict)
    activated_words32: float = 0.0
    inter_bank_words32: float = 0.0
    load_accesses: int = 0
    load_words32: float = 0.0
    resident_reuses: int = 0
    resident_words32: float = 0.0
    ecc_accesses: int = 0
    ecc_words32: float = 0.0
    fault_injected: int = 0
    fault_detected: int = 0
    fault_corrected: int = 0
    fault_uncorrected: int = 0
    enabled: bool = True

    @property
    def total_accesses(self) -> int:
        """Compute accesses + streamed operand-load accesses — the number a
        resident-operand execution strictly shrinks vs the repack path
        (compute accesses alone are identical by construction)."""
        return self.accesses + self.load_accesses

    def charge(self, ops: Tuple[str, ...], n_bits: int, n_words: int,
               accesses: int = 1) -> None:
        if not self.enabled:
            return
        self.accesses += accesses
        self.words32 += n_words * n_bits / 32.0 * accesses
        self.activated_words32 += n_words * n_bits / 32.0 * accesses
        self.bank_accesses[(0, 0)] = \
            self.bank_accesses.get((0, 0), 0) + accesses
        for op in ops:
            self.per_op[op] = self.per_op.get(op, 0) + 1

    def charge_banked(self, ops: Tuple[str, ...], n_bits: int, n_words: int,
                      plan, n_devices: int = 1) -> None:
        """One logical op executed as `plan.n_tiles` bank activations.

        The word-work (words32) is charged once — tiling does not multiply
        the useful work — while activations land on their (device, bank)
        slots and the last tile's idle columns count as activated words.
        """
        if not self.enabled:
            return
        self.accesses += plan.n_tiles
        self.words32 += n_words * n_bits / 32.0
        self.activated_words32 += \
            plan.n_tiles * plan.tile_words * n_bits / 32.0
        for slot, n in plan.bank_counts(n_devices).items():
            self.bank_accesses[slot] = self.bank_accesses.get(slot, 0) + n
        for op in ops:
            self.per_op[op] = self.per_op.get(op, 0) + 1

    def charge_reduction(self, words32: float) -> None:
        """Inter-bank traffic of a cross-tile reduction step."""
        if not self.enabled:
            return
        self.inter_bank_words32 += words32

    def charge_load(self, n_bits: int, n_words: int,
                    n_tiles: int = 1) -> None:
        """Row-writes driving one STREAMED operand entry pack into the
        array — one load access per tile it lands on. Pins charge this
        exactly once; streamed operands pay it every call."""
        if not self.enabled:
            return
        self.load_accesses += n_tiles
        self.load_words32 += n_words * n_bits / 32.0

    def charge_resident_reuse(self, n_bits: int, n_words: int) -> None:
        """One resident-operand reuse: the entry pack (and its load
        accesses) was skipped because the operand already lives in rows."""
        if not self.enabled:
            return
        self.resident_reuses += 1
        self.resident_words32 += n_words * n_bits / 32.0

    def charge_ecc(self, n_parity_bits: int, n_words: int,
                   n_tiles: int = 1) -> None:
        """Parity-plane traffic of ECC protection: the extra rows written
        at pin time and the parity reads of each verify/scrub pass."""
        if not self.enabled:
            return
        self.ecc_accesses += n_tiles
        self.ecc_words32 += n_words * n_parity_bits / 32.0

    def charge_fault(self, injected: int = 0, detected: int = 0,
                     corrected: int = 0, uncorrected: int = 0) -> None:
        """Fault-campaign outcome bits (see repro.cim.faults)."""
        if not self.enabled:
            return
        self.fault_injected += injected
        self.fault_detected += detected
        self.fault_corrected += corrected
        self.fault_uncorrected += uncorrected

    def reset(self) -> None:
        """Restore every counter to its dataclass default.

        Introspective on purpose: a hand-written field list silently stops
        clearing newly added counters (per-op breakdowns, the per-bank slots
        here) the day someone forgets to extend it — covered by
        tests/test_cim_array.py::test_ledger_reset_clears_every_field.
        """
        for f in dataclasses.fields(self):
            if f.name == "enabled":
                continue
            if f.default is not dataclasses.MISSING:
                setattr(self, f.name, f.default)
            else:
                setattr(self, f.name, f.default_factory())

    def per_device(self) -> Dict[int, int]:
        """Activations per device (sum of that device's bank slots)."""
        out: Dict[int, int] = {}
        for (dev, _bank), n in self.bank_accesses.items():
            out[dev] = out.get(dev, 0) + n
        return out

    def projected(self, scheme: str = "current", rows: int = 1024) -> Dict[str, float]:
        """Array-level projection of the charged work through the paper model."""
        return project_savings(self.words32, scheme=scheme, rows=rows)

    def bank_report(self, spec, scheme: str = "current",
                    rows: int = 1024) -> Dict[str, float]:
        """Contention-adjusted EDP projection for the charged bank traffic.

        Energy side: every ACTIVATED word burns the per-word CiM energy
        (idle columns of a partial tile included), plus E_HOP_WORD32 per
        inter-bank reduction word. Latency side: banks across all devices
        run concurrently, so the critical path is the busiest slot's wave
        count; reduction hops serialize behind the interconnect. The
        baseline is the same word-work through the two-access near-memory
        path on the same geometry.
        """
        res = _SCHEMES[scheme](rows)
        total = sum(self.bank_accesses.values()) or 1
        waves = max(self.bank_accesses.values(), default=1)
        devices = 1 + max((d for d, _ in self.bank_accesses), default=0)
        slots = spec.banks * devices
        ideal_waves = -(-total // slots)
        per_access_words = self.activated_words32 / total

        e_cim = res.cim.energy * self.activated_words32 \
            + E_HOP_WORD32 * self.inter_bank_words32
        t_cim = res.cim.latency * waves \
            + T_HOP_WORD32 * self.inter_bank_words32 / max(1, slots)
        e_base = res.baseline.energy * self.activated_words32
        t_base = res.baseline.latency * waves
        base_edp = e_base * t_base
        return {
            "banks": float(spec.banks),
            "devices": float(devices),
            "activations": float(total),
            "waves": float(waves),
            "ideal_waves": float(ideal_waves),
            "contention_factor": waves / max(1, ideal_waves),
            "utilization": self.words32 / max(1e-12, self.activated_words32),
            "words_per_access": per_access_words,
            "inter_bank_words32": self.inter_bank_words32,
            "cim_energy": e_cim,
            "cim_latency": t_cim,
            "cim_edp": e_cim * t_cim,
            "baseline_edp": base_edp,
            # 0.0 on an empty/reset ledger (no charged work -> no saving)
            "edp_decrease_pct": (100.0 * (1.0 - (e_cim * t_cim) / base_edp)
                                 if base_edp else 0.0),
        }


#: process-wide ledger the engine charges into
LEDGER = Ledger()


@dataclasses.dataclass(frozen=True)
class PlannedCharges:
    """The ledger record of ONE schedule execution, computed from the plan.

    Compiling a schedule into a single XLA program removes the per-access
    Python call sites the ledger used to be charged from; this object is
    their replacement. While the step program is being traced, each planned
    access appends one entry — ("access", ops, n_bits, n_words) for the
    unbanked engine, ("banked", ops, n_bits, n_words, plan, n_devices) for
    the tiling dispatcher, ("reduction", words32) for inter-bank reduction
    traffic, ("load", n_bits, n_words, n_tiles) for streamed operand
    row-writes and ("resident", n_bits, n_words) for resident-operand
    reuses — and `replay()` applies the whole record to the ledger on every
    invocation of the compiled program. Because the ScheduleCursor refuses
    any access its plan does not contain, the record provably matches both
    the plan and the execution: accesses == schedule.accesses still holds by
    construction, now at zero per-access Python cost.
    """

    entries: Tuple[Tuple, ...]

    @property
    def accesses(self) -> int:
        """Array accesses one replay charges (logical, not per-tile)."""
        return sum(1 for e in self.entries if e[0] in ("access", "banked"))

    def replay(self, ledger: Optional["Ledger"] = None) -> None:
        led = LEDGER if ledger is None else ledger
        for entry in self.entries:
            kind = entry[0]
            if kind == "access":
                _, ops, n_bits, n_words = entry
                led.charge(ops, n_bits, n_words)
            elif kind == "banked":
                _, ops, n_bits, n_words, plan, n_devices = entry
                led.charge_banked(ops, n_bits, n_words, plan,
                                  n_devices=n_devices)
            elif kind == "reduction":
                led.charge_reduction(entry[1])
            elif kind == "load":
                _, n_bits, n_words, n_tiles = entry
                led.charge_load(n_bits, n_words, n_tiles=n_tiles)
            elif kind == "resident":
                _, n_bits, n_words = entry
                led.charge_resident_reuse(n_bits, n_words)
            else:                              # pragma: no cover
                raise ValueError(f"unknown charge entry {kind!r}")


def ledger() -> Ledger:
    return LEDGER


_SCHEMES = {
    "current": energy.current_sensing,
    "scheme1": energy.voltage_scheme1,
    "scheme2": energy.voltage_scheme2,
}


def project_savings(words32: float, scheme: str = "current",
                    rows: int = 1024) -> Dict[str, float]:
    """Energy/latency/EDP of `words32` word-ops: ADRA CiM vs the two-access
    near-memory baseline, in both internal units and physical estimates."""
    res = _SCHEMES[scheme](rows)
    return {
        "words32": words32,
        "cim_energy": res.cim.energy * words32,
        "baseline_energy": res.baseline.energy * words32,
        "energy_saved": (res.baseline.energy - res.cim.energy) * words32,
        "energy_saved_fj": energy.to_fj(
            (res.baseline.energy - res.cim.energy) * words32),
        "speedup": res.speedup,
        "edp_decrease_pct": res.edp_decrease_pct,
    }

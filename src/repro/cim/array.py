"""Banked CiM array substrate: physical geometry, tile placement, residency.

The engine (repro.cim.engine) treats the memory as one infinitely wide
array; real ADRA arrays are banks of subarrays of rows x bitlines. This
module is the geometry layer between the two: an `ArraySpec` describes the
physical array, and its `plan()` method turns any operand word count into a
`TilePlan` — which words go to which bank activation — that the tiling
dispatcher (repro.cim.dispatch) executes and the accounting ledger charges.

Layout convention (the engine's transposed bit-serial form): inside a
subarray each bitline column holds ONE word and row p holds bit-plane p, so
one dual-row activation computes over `bitline_words` words in parallel and
the operand/result plane stacks occupy rows. A bank activation drives all
of its subarrays at once (shared wordline drivers), so one bank serves
`subarrays * bitline_words` words per access; banks operate concurrently,
and tiles beyond `banks` per round serialize into waves — the contention
the per-bank ledger model charges.

The RESIDENT region: FeFET rows are nonvolatile, so an operand written once
(a weight plane stack, a paged KV block) can stay in its rows across calls —
the paper's stored-operand assumption. A `ResidentSet` tracks those pinned
plane stacks per bank under the row budget: every pin charges the ledger ONE
operand load (per tile), every reuse charges zero, and rows claimed by
residents shrink what `check_fits` allows a streaming access (the combined
check names the resident occupancy in its error). Pins are LRU-evicted under
pressure; `reserve()` entries (KV pages) are not evictable and fail loudly
instead. Counters aggregate process-wide into `dispatch.cache_stats()`.

Defaults are calibrated to the paper's 1024-row FeFET array
(1024 x 1024 subarray => 1024 words per subarray activation).
"""
from __future__ import annotations

import dataclasses
import weakref
from collections import OrderedDict
from typing import Any, Dict, Optional, Sequence, Tuple

from . import opset


@dataclasses.dataclass(frozen=True)
class ArraySpec:
    """Physical geometry of a banked ADRA CiM array.

    banks          : independently activatable banks (concurrent).
    subarrays      : subarrays per bank, activated together per access.
    rows           : wordlines per subarray — bounds the plane budget of one
                     access (two operand stacks + every requested output).
    bitline_words  : words served per subarray activation (one word per
                     bitline column in the transposed bit-serial layout);
                     must be a multiple of 32 so tiles align with the packed
                     uint32 lanes of PlanePack.
    disabled_banks : banks taken out of service (whole-bank failures).
                     Placement round-robins over the ENABLED banks only;
                     the default () keeps degraded and healthy specs
                     distinct hashable values, so every spec-keyed cache
                     (compiled programs, resident-set registry, lowered
                     callables) naturally separates the two.
    """

    banks: int = 4
    subarrays: int = 4
    rows: int = 1024
    bitline_words: int = 1024
    disabled_banks: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.banks < 1 or self.subarrays < 1 or self.rows < 1:
            raise opset.CimOpError(f"degenerate ArraySpec: {self}")
        if self.bitline_words < 32 or self.bitline_words % 32:
            raise opset.CimOpError(
                f"bitline_words must be a positive multiple of 32 (packed "
                f"uint32 lanes), got {self.bitline_words}")
        dead = tuple(sorted(set(int(b) for b in self.disabled_banks)))
        if any(b < 0 or b >= self.banks for b in dead):
            raise opset.CimOpError(
                f"disabled_banks {dead} outside [0, {self.banks})")
        if len(dead) >= self.banks:
            raise opset.CimOpError(
                f"every bank of {self} disabled: nothing left to remap to")
        object.__setattr__(self, "disabled_banks", dead)

    @property
    def enabled_banks(self) -> Tuple[int, ...]:
        """Live bank ids, in order — what placement round-robins over."""
        if not self.disabled_banks:
            return tuple(range(self.banks))
        dead = set(self.disabled_banks)
        return tuple(b for b in range(self.banks) if b not in dead)

    @property
    def n_enabled(self) -> int:
        return self.banks - len(self.disabled_banks)

    def disable_bank(self, bank: int) -> "ArraySpec":
        """The degraded spec with `bank` also dead (raises via __post_init__
        when that would leave no live banks)."""
        return dataclasses.replace(
            self, disabled_banks=self.disabled_banks + (int(bank),))

    @property
    def tile_words(self) -> int:
        """Words one bank activation serves = the tiling granule."""
        return self.subarrays * self.bitline_words

    @property
    def parallel_words(self) -> int:
        """Words the whole array serves per wave (all LIVE banks active)."""
        return self.n_enabled * self.tile_words

    def check_fits(self, n_bits: int, ops: Sequence[str],
                   resident_rows: int = 0) -> None:
        """One access must fit its operand + result planes in the rows of a
        subarray: 2 operand stacks of n_bits plus every requested output —
        MINUS whatever rows the resident region has pinned (the combined
        streaming + residency budget of one bank)."""
        need = 2 * n_bits + sum(opset.out_rows(op, n_bits) for op in ops)
        if need + resident_rows > self.rows:
            occupancy = (f" with {resident_rows} rows held by resident "
                         f"operands" if resident_rows else "")
            raise opset.CimOpError(
                f"access needs {need} rows (2x{n_bits} operand planes + "
                f"outputs {tuple(ops)}){occupancy} but subarrays have "
                f"{self.rows}")

    def plan(self, n_words: int) -> "TilePlan":
        if n_words < 1:
            raise opset.CimOpError(f"cannot place {n_words} words")
        n_tiles = -(-n_words // self.tile_words)
        return TilePlan(n_words=n_words, tile_words=self.tile_words,
                        n_tiles=n_tiles, banks=self.banks,
                        enabled=(self.enabled_banks
                                 if self.disabled_banks else ()))


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Placement of an operand pair onto a banked array: tile t covers words
    [t * tile_words, (t+1) * tile_words) and runs on the t-th live bank in
    round-robin order during wave `t // n_live` — the layout that balances
    banks best for contiguous operands. `enabled` names the live banks of a
    DEGRADED array (dead banks are skipped, waves stretch accordingly); the
    default () means all `banks` are live, so healthy plans hash and compare
    exactly as before. Static and hashable: it is part of the
    compiled-schedule cache key."""

    n_words: int
    tile_words: int
    n_tiles: int
    banks: int
    enabled: Tuple[int, ...] = ()

    @property
    def live_banks(self) -> Tuple[int, ...]:
        return self.enabled if self.enabled else tuple(range(self.banks))

    @property
    def n_live(self) -> int:
        return len(self.enabled) if self.enabled else self.banks

    @property
    def lanes_per_tile(self) -> int:
        return self.tile_words // 32

    @property
    def waves(self) -> int:
        """Sequential activations on the busiest bank (the critical path)."""
        return -(-self.n_tiles // self.n_live)

    @property
    def pad_words(self) -> int:
        """Idle bitline columns of the last tile (activated but operand-less)."""
        return self.n_tiles * self.tile_words - self.n_words

    def bank_of(self, tile: int) -> int:
        """Physical bank of tile `tile` — never a disabled bank."""
        live = self.live_banks
        return live[tile % len(live)]

    def bank_counts(self, n_devices: int = 1) -> Dict[Tuple[int, int], int]:
        """Activations per (device, bank) — what the ledger charges.

        Closed-form: device d owns the contiguous tile block [d*per_dev,
        min((d+1)*per_dev, n_tiles)) and live bank slot s takes every tile
        ≡ s mod n_live inside it, so each slot is a count of a residue
        class in a range — O(devices * banks), never O(n_tiles)
        (model-scale operands place hundreds of thousands of tiles per
        schedule step). Keys are PHYSICAL bank ids; disabled banks never
        appear."""
        live = self.live_banks
        n_live = len(live)

        def upto(x: int, s: int) -> int:
            # tiles t in [0, x) with t % n_live == s  (0 <= s < n_live)
            return (x - s + n_live - 1) // n_live

        per_dev = -(-self.n_tiles // n_devices)
        counts: Dict[Tuple[int, int], int] = {}
        for d in range(n_devices):
            lo = min(d * per_dev, self.n_tiles)
            hi = min(lo + per_dev, self.n_tiles)
            for s, b in enumerate(live):
                n = upto(hi, s) - upto(lo, s)
                if n:
                    counts[(d, b)] = n
        return counts


#: the paper's array, four banks of four subarrays
DEFAULT_SPEC = ArraySpec()


# ---------------------------------------------------------------------------
# the resident region: operands pinned in bank rows across calls
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ResidentEntry:
    """One pinned occupant of the resident region.

    pack         : the pinned PlanePack (None for a `reserve()` row claim,
                   e.g. a paged KV block whose values live outside the
                   packed domain but whose rows are spoken for).
    rows_by_bank : rows this entry holds in each bank — n_bits plane rows
                   per tile placed there (tiles on the same bank stack),
                   plus the SECDED parity rows when the set runs with ECC.
    fingerprint  : identity of the source buffers; a mismatched `get()`
                   drops the entry (stale pin) instead of returning it.
    evictable    : LRU-evictable under pin pressure; reservations are not.
    ecc_parity   : uint32[r+1, W] SECDED parity planes of the pinned pack
                   (None when the set runs unprotected).
    scrubbed_s   : fault-model clock of the last verify/scrub — what the
                   retention-decay model integrates flips over.
    """

    key: Tuple
    pack: Any
    rows_by_bank: Dict[int, int]
    words32: float = 0.0
    fingerprint: Tuple = ()
    evictable: bool = True
    aux: Any = None
    hits: int = 0
    ecc_parity: Any = None
    scrubbed_s: float = 0.0


class ResidentSet:
    """Row-budget-checked resident region of one banked array.

    `pin(key, pack)` writes a plane stack into rows once — charging the
    ledger the operand-load accesses a streaming execution would pay per
    call — and keeps it addressable across calls; `get(key)` is the warm
    path (zero load charges, `resident_reuses` counted by the caller's
    schedule). Pins are LRU-ordered and evicted when a new pin does not fit
    the per-bank row budget (`rows - reserve_rows`); `reserve()` claims
    rows without a pack (paged KV blocks) and is never evicted silently.
    """

    def __init__(self, spec: Optional[ArraySpec] = None,
                 reserve_rows: int = 0, ecc: bool = False):
        self.spec = spec or DEFAULT_SPEC
        if reserve_rows < 0 or reserve_rows >= self.spec.rows:
            raise opset.CimOpError(
                f"reserve_rows must be in [0, {self.spec.rows}), "
                f"got {reserve_rows}")
        self.reserve_rows = reserve_rows
        self.ecc = bool(ecc)
        self._entries: "OrderedDict[Tuple, ResidentEntry]" = OrderedDict()
        self.pins = 0
        self.reserves = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0
        self.ecc_corrected = 0
        self.ecc_uncorrected = 0
        self.ecc_verifies = 0
        _ALL_SETS.add(self)

    # -- occupancy ----------------------------------------------------------
    def rows_per_bank(self) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for e in self._entries.values():
            for b, r in e.rows_by_bank.items():
                out[b] = out.get(b, 0) + r
        return out

    @property
    def resident_rows(self) -> int:
        """Rows held in the busiest bank — what a streaming access loses."""
        return max(self.rows_per_bank().values(), default=0)

    def _rows_for(self, n_bits: int, n_words: int) -> Dict[int, int]:
        """Per-bank rows of an n_bits pack of n_words: n_bits plane rows
        per tile on the tile's round-robin bank (same-bank tiles stack)."""
        plan = self.spec.plan(n_words)
        return {b: n_bits * n for (_d, b), n in plan.bank_counts(1).items()}

    def fits(self, rows_by_bank: Dict[int, int]) -> bool:
        occ = self.rows_per_bank()
        budget = self.spec.rows - self.reserve_rows
        return all(occ.get(b, 0) + r <= budget
                   for b, r in rows_by_bank.items())

    # -- lifecycle ----------------------------------------------------------
    def peek(self, key: Tuple,
             fingerprint: Optional[Tuple] = None) -> bool:
        """Presence+fingerprint test WITHOUT counters or LRU movement — the
        warm-pass probe (a real `get` follows for entries actually used)."""
        entry = self._entries.get(key)
        return entry is not None and (
            fingerprint is None or entry.fingerprint == tuple(fingerprint))

    def get(self, key: Tuple,
            fingerprint: Optional[Tuple] = None) -> Optional[ResidentEntry]:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            _STATS["resident_misses"] += 1
            return None
        if fingerprint is not None and entry.fingerprint != fingerprint:
            # the source buffers changed identity: the pinned rows are stale
            del self._entries[key]
            self.invalidations += 1
            _STATS["resident_invalidations"] += 1
            self.misses += 1
            _STATS["resident_misses"] += 1
            return None
        if entry.ecc_parity is not None and not self._verify(entry):
            # uncorrectable: the rows are data loss; the entry was dropped
            # (invalidation) so the caller rebuilds from the source
            self.misses += 1
            _STATS["resident_misses"] += 1
            return None
        entry.hits += 1
        self.hits += 1
        _STATS["resident_hits"] += 1
        self._entries.move_to_end(key)
        return entry

    def pin(self, key: Tuple, pack, fingerprint: Tuple = (),
            aux: Any = None) -> ResidentEntry:
        """Pack `pack` into resident rows (evicting LRU pins to fit) and
        charge the one-time operand load the pin replaces per call. With
        `ecc` on, the SECDED parity planes are encoded here, stored as
        extra rows of the same banks, and their row writes charged as ECC
        overhead (`Ledger.charge_ecc`)."""
        from .accounting import LEDGER

        if key in self._entries:
            del self._entries[key]        # re-pin: release the stale rows
        parity = None
        n_ecc = 0
        if self.ecc:
            from . import faults as faults_mod
            from .planepack import ecc_encode, ecc_plane_count
            import numpy as _np
            parity = ecc_encode(_np.asarray(pack.planes))
            n_ecc = ecc_plane_count(pack.n_bits)
        rows = self._rows_for(pack.n_bits + n_ecc, pack.n_words)
        self._make_room(key, rows)
        words32 = pack.n_words * pack.n_bits / 32.0
        fm = None
        if self.ecc:
            fm = faults_mod.active()
        entry = ResidentEntry(key=key, pack=pack, rows_by_bank=rows,
                              words32=words32, fingerprint=tuple(fingerprint),
                              evictable=True, aux=aux, ecc_parity=parity,
                              scrubbed_s=(fm.clock() if fm is not None
                                          else 0.0))
        self._entries[key] = entry
        self.pins += 1
        _STATS["resident_pins"] += 1
        n_tiles = self.spec.plan(pack.n_words).n_tiles
        LEDGER.charge_load(pack.n_bits, pack.n_words, n_tiles=n_tiles)
        if n_ecc:
            LEDGER.charge_ecc(n_ecc, pack.n_words, n_tiles=n_tiles)
        return entry

    # -- ECC verify / scrub --------------------------------------------------

    def _verify(self, entry: ResidentEntry, decay_s: float = 0.0) -> bool:
        """One ECC pass over a protected entry: inject whatever the active
        fault model says the rows took (per-get resident BER, plus
        `decay_s` seconds of retention decay on the scrub path), then
        SECDED-verify and repair. Returns False — after invalidating the
        entry — when the damage was uncorrectable."""
        import dataclasses as _dc

        import jax.numpy as _jnp
        import numpy as _np

        from . import faults as faults_mod
        from .accounting import LEDGER
        from .planepack import ecc_check_correct

        fm = faults_mod.active()
        planes = _np.asarray(entry.pack.planes)
        parity = entry.ecc_parity
        if fm is not None:
            planes, _ = fm.corrupt_resident(planes)
            if decay_s > 0.0:
                flips = fm.decay_bits(
                    decay_s, planes.size * 32 + parity.size * 32)
                if flips:
                    planes = _np.array(planes, copy=True)
                    flat = planes.reshape(-1)
                    idx = fm.rng.integers(0, planes.size * 32, size=flips)
                    for i in _np.asarray(idx):
                        flat[i // 32] ^= _np.uint32(1) << _np.uint32(i % 32)
                    fm.injected += flips
                    faults_mod._STATS["fault_injected"] += flips
                    LEDGER.charge_fault(injected=int(flips))
            entry.scrubbed_s = fm.clock()
        fixed, fixed_par, corrected, uncorrected = \
            ecc_check_correct(planes, parity)
        self.ecc_verifies += 1
        _STATS["ecc_verifies"] += 1
        from .planepack import ecc_plane_count
        LEDGER.charge_ecc(ecc_plane_count(entry.pack.n_bits),
                          entry.pack.n_words,
                          n_tiles=self.spec.plan(entry.pack.n_words).n_tiles)
        if corrected:
            self.ecc_corrected += corrected
            _STATS["ecc_corrected"] += corrected
        if uncorrected:
            self.ecc_uncorrected += uncorrected
            _STATS["ecc_uncorrected"] += uncorrected
        if fm is not None:
            fm.record_verify(corrected, uncorrected)
        if uncorrected:
            self._entries.pop(entry.key, None)
            self.invalidations += 1
            _STATS["resident_invalidations"] += 1
            if fm is not None and fm.config.raise_on_uncorrectable:
                raise faults_mod.UncorrectableFaultError(
                    f"resident entry {entry.key!r}: {uncorrected} "
                    f"uncorrectable bit(s); entry invalidated — re-pin "
                    f"and retry")
            return False
        if corrected or fm is not None:
            entry.pack = _dc.replace(entry.pack,
                                     planes=_jnp.asarray(fixed))
            entry.ecc_parity = fixed_par
        return True

    def scrub(self) -> Dict[str, int]:
        """Walk every protected pin, integrate retention decay since its
        last verify, and repair what SECDED can (uncorrectable entries are
        invalidated so the next `get` misses and rebuilds). The periodic
        background pass a serving process runs between steps."""
        from . import faults as faults_mod

        fm = faults_mod.active()
        now = fm.clock() if fm is not None else 0.0
        corrected0 = self.ecc_corrected
        uncorrected0 = self.ecc_uncorrected
        scanned = 0
        dropped = 0
        for entry in list(self._entries.values()):
            if entry.ecc_parity is None:
                continue
            scanned += 1
            decay_s = max(0.0, now - entry.scrubbed_s) if fm is not None \
                else 0.0
            if not self._verify(entry, decay_s=decay_s):
                dropped += 1
        _STATS["ecc_scrubs"] += 1
        return {"scanned": scanned, "dropped": dropped,
                "corrected": self.ecc_corrected - corrected0,
                "uncorrected": self.ecc_uncorrected - uncorrected0}

    def reserve(self, key: Tuple, n_rows: int, bank: int = 0,
                words32: float = 0.0,
                fingerprint: Tuple = ()) -> ResidentEntry:
        """Claim `n_rows` on one bank without a pack (a paged KV block's
        rows). Not evictable: a failed fit raises instead of silently
        dropping someone else's state."""
        if key in self._entries:
            del self._entries[key]
        rows = {int(bank) % self.spec.banks: int(n_rows)}
        self._make_room(key, rows)
        entry = ResidentEntry(key=key, pack=None, rows_by_bank=rows,
                              words32=words32, fingerprint=tuple(fingerprint),
                              evictable=False)
        self._entries[key] = entry
        self.reserves += 1
        _STATS["resident_reserves"] += 1
        return entry

    def _make_room(self, key: Tuple, rows_by_bank: Dict[int, int]) -> None:
        budget = self.spec.rows - self.reserve_rows
        if any(r > budget for r in rows_by_bank.values()):
            raise opset.CimOpError(
                f"resident entry {key!r} needs {max(rows_by_bank.values())} "
                f"rows on one bank but the resident budget is {budget} "
                f"(rows {self.spec.rows} - reserve {self.reserve_rows})")
        while not self.fits(rows_by_bank):
            victim = next((k for k, e in self._entries.items()
                           if e.evictable), None)
            if victim is None:
                occ = self.rows_per_bank()
                raise opset.CimOpError(
                    f"resident entry {key!r} does not fit: occupancy "
                    f"{occ} of {budget} rows/bank is all reservations")
            del self._entries[victim]
            self.evictions += 1
            _STATS["resident_evictions"] += 1

    def release(self, key: Tuple) -> bool:
        return self._entries.pop(key, None) is not None

    def clear(self) -> None:
        self._entries.clear()

    def __len__(self) -> int:
        return len(self._entries)

    def stats(self) -> Dict[str, int]:
        return {"entries": len(self._entries), "pins": self.pins,
                "reserves": self.reserves,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "invalidations": self.invalidations,
                "ecc_verifies": self.ecc_verifies,
                "ecc_corrected": self.ecc_corrected,
                "ecc_uncorrected": self.ecc_uncorrected,
                "resident_rows": self.resident_rows}


#: every live ResidentSet (weak: test-local sets vanish with their tests)
_ALL_SETS: "weakref.WeakSet[ResidentSet]" = weakref.WeakSet()

#: process-wide counters surfaced through dispatch.cache_stats()
_STATS: Dict[str, int] = {}


def _reset_stats() -> None:
    _STATS.update(resident_pins=0, resident_reserves=0, resident_hits=0,
                  resident_misses=0, resident_evictions=0,
                  resident_invalidations=0,
                  ecc_verifies=0, ecc_corrected=0, ecc_uncorrected=0,
                  ecc_scrubs=0)


_reset_stats()

#: process-wide resident set per geometry (the one `resident_rows_for`
#: consults and the serving stack shares between weight pins and KV pages)
_RESIDENT_SETS: Dict[ArraySpec, ResidentSet] = {}

#: whether registry ResidentSets are created ECC-protected (serving turns
#: this on before building its lowered state; default off keeps the
#: committed ledger/bench baselines exact)
_DEFAULT_ECC: bool = False

#: process-wide spec override: the failover lever. Layers that default to
#: spec=None resolve through `current_spec()`, so flipping this to a
#: degraded ArraySpec re-routes every subsequent lowering/pin/dispatch
#: through the degraded geometry — fresh spec-keyed caches and all.
_CURRENT_SPEC: Optional[ArraySpec] = None


def set_resident_ecc(on: bool) -> bool:
    """Make future registry ResidentSets ECC-protected (or not); returns
    the previous setting. Existing sets keep their mode — call
    `clear_resident()` first to rebuild them protected."""
    global _DEFAULT_ECC
    prev = _DEFAULT_ECC
    _DEFAULT_ECC = bool(on)
    return prev


def resident_ecc_default() -> bool:
    return _DEFAULT_ECC


def set_current_spec(spec: Optional[ArraySpec]) -> Optional[ArraySpec]:
    """Install the process-wide spec override (None restores DEFAULT_SPEC
    resolution); returns the previous override."""
    global _CURRENT_SPEC
    prev = _CURRENT_SPEC
    _CURRENT_SPEC = spec
    return prev


def current_spec() -> ArraySpec:
    """What `spec=None` means right now: the failover override if one is
    installed, else the paper's DEFAULT_SPEC."""
    return _CURRENT_SPEC if _CURRENT_SPEC is not None else DEFAULT_SPEC


def spec_override() -> Optional[ArraySpec]:
    """The raw failover override (None when the process is healthy).
    Call sites whose `spec=None` historically meant UNBANKED lowering
    (models.layers) consult this — they must not pick up DEFAULT_SPEC."""
    return _CURRENT_SPEC


def resident_set(spec: Optional[ArraySpec] = None) -> ResidentSet:
    """The process-wide ResidentSet for `spec` (`current_spec()` when None).

    Registry sets keep a quarter of the rows as reserve: headroom the
    combined `check_fits` budget guarantees streamed access planes — pins
    can never squeeze an access out of its own subarray."""
    spec = spec or current_spec()
    rs = _RESIDENT_SETS.get(spec)
    if rs is None:
        rs = _RESIDENT_SETS[spec] = ResidentSet(
            spec, reserve_rows=spec.rows // 4, ecc=_DEFAULT_ECC)
    return rs


def resident_rows_for(spec: Optional[ArraySpec]) -> int:
    """Busiest-bank resident occupancy of the registry set for `spec` —
    what the dispatcher folds into the combined check_fits budget."""
    rs = _RESIDENT_SETS.get(spec or current_spec())
    return rs.resident_rows if rs is not None else 0


def resident_stats() -> Dict[str, int]:
    """Aggregated pin/hit/eviction counters across every ResidentSet."""
    out = dict(_STATS)
    out["resident_entries"] = sum(len(s) for s in _ALL_SETS)
    out["resident_rows"] = max((s.resident_rows for s in _ALL_SETS),
                               default=0)
    return out


def clear_resident() -> None:
    """Drop every registry ResidentSet and zero the aggregate counters."""
    for rs in list(_ALL_SETS):
        rs.clear()
    _RESIDENT_SETS.clear()
    _reset_stats()

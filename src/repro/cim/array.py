"""Banked CiM array substrate: physical geometry + tile placement.

The engine (repro.cim.engine) treats the memory as one infinitely wide
array; real ADRA arrays are banks of subarrays of rows x bitlines. This
module is the geometry layer between the two: an `ArraySpec` describes the
physical array, and its `plan()` method turns any operand word count into a
`TilePlan` — which words go to which bank activation — that the tiling
dispatcher (repro.cim.dispatch) executes and the accounting ledger charges.

Layout convention (the engine's transposed bit-serial form): inside a
subarray each bitline column holds ONE word and row p holds bit-plane p, so
one dual-row activation computes over `bitline_words` words in parallel and
the operand/result plane stacks occupy rows. A bank activation drives all
of its subarrays at once (shared wordline drivers), so one bank serves
`subarrays * bitline_words` words per access; banks operate concurrently,
and tiles beyond `banks` per round serialize into waves — the contention
the per-bank ledger model charges.

Defaults are calibrated to the paper's 1024-row FeFET array
(1024 x 1024 subarray => 1024 words per subarray activation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

from . import opset


@dataclasses.dataclass(frozen=True)
class ArraySpec:
    """Physical geometry of a banked ADRA CiM array.

    banks          : independently activatable banks (concurrent).
    subarrays      : subarrays per bank, activated together per access.
    rows           : wordlines per subarray — bounds the plane budget of one
                     access (two operand stacks + every requested output).
    bitline_words  : words served per subarray activation (one word per
                     bitline column in the transposed bit-serial layout);
                     must be a multiple of 32 so tiles align with the packed
                     uint32 lanes of PlanePack.
    """

    banks: int = 4
    subarrays: int = 4
    rows: int = 1024
    bitline_words: int = 1024

    def __post_init__(self):
        if self.banks < 1 or self.subarrays < 1 or self.rows < 1:
            raise opset.CimOpError(f"degenerate ArraySpec: {self}")
        if self.bitline_words < 32 or self.bitline_words % 32:
            raise opset.CimOpError(
                f"bitline_words must be a positive multiple of 32 (packed "
                f"uint32 lanes), got {self.bitline_words}")

    @property
    def tile_words(self) -> int:
        """Words one bank activation serves = the tiling granule."""
        return self.subarrays * self.bitline_words

    @property
    def parallel_words(self) -> int:
        """Words the whole array serves per wave (all banks active)."""
        return self.banks * self.tile_words

    def check_fits(self, n_bits: int, ops: Sequence[str]) -> None:
        """One access must fit its operand + result planes in the rows of a
        subarray: 2 operand stacks of n_bits plus every requested output."""
        need = 2 * n_bits + sum(opset.out_rows(op, n_bits) for op in ops)
        if need > self.rows:
            raise opset.CimOpError(
                f"access needs {need} rows (2x{n_bits} operand planes + "
                f"outputs {tuple(ops)}) but subarrays have {self.rows}")

    def plan(self, n_words: int) -> "TilePlan":
        if n_words < 1:
            raise opset.CimOpError(f"cannot place {n_words} words")
        n_tiles = -(-n_words // self.tile_words)
        return TilePlan(n_words=n_words, tile_words=self.tile_words,
                        n_tiles=n_tiles, banks=self.banks)


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Placement of an operand pair onto a banked array: tile t covers words
    [t * tile_words, (t+1) * tile_words) and runs on bank `t % banks` during
    wave `(t // banks)` — round-robin, the layout that balances banks best
    for contiguous operands. Static and hashable: it is part of the
    compiled-schedule cache key."""

    n_words: int
    tile_words: int
    n_tiles: int
    banks: int

    @property
    def lanes_per_tile(self) -> int:
        return self.tile_words // 32

    @property
    def waves(self) -> int:
        """Sequential activations on the busiest bank (the critical path)."""
        return -(-self.n_tiles // self.banks)

    @property
    def pad_words(self) -> int:
        """Idle bitline columns of the last tile (activated but operand-less)."""
        return self.n_tiles * self.tile_words - self.n_words

    def bank_of(self, tile: int) -> int:
        return tile % self.banks

    def bank_counts(self, n_devices: int = 1) -> Dict[Tuple[int, int], int]:
        """Activations per (device, bank) — what the ledger charges.

        Closed-form: device d owns the contiguous tile block [d*per_dev,
        min((d+1)*per_dev, n_tiles)) and bank b takes every tile ≡ b mod
        banks inside it, so each slot is a count of a residue class in a
        range — O(devices * banks), never O(n_tiles) (model-scale operands
        place hundreds of thousands of tiles per schedule step)."""
        def upto(x: int, b: int) -> int:
            # tiles t in [0, x) with t % banks == b  (0 <= b < banks)
            return (x - b + self.banks - 1) // self.banks

        per_dev = -(-self.n_tiles // n_devices)
        counts: Dict[Tuple[int, int], int] = {}
        for d in range(n_devices):
            lo = min(d * per_dev, self.n_tiles)
            hi = min(lo + per_dev, self.n_tiles)
            for b in range(self.banks):
                n = upto(hi, b) - upto(lo, b)
                if n:
                    counts[(d, b)] = n
        return counts


#: the paper's array, four banks of four subarrays
DEFAULT_SPEC = ArraySpec()

"""Geometry/bits autotuner: search the array configuration per region.

For one lowered workload, `Autotuner.tune` searches tile shape
(subarrays x bitline words) x bank count x sensing scheme [x n_bits via a
`build` callback], PRUNED by the cost model (repro.cim.cost) and
CONFIRMED by steady-state walltime measurement (block-until-ready timing,
the kernel_bench convention):

  1. predict — every candidate's total CiM EDP under `policy="always"`
     (all eligible eqns counted, so geometries compare on the full
     lowering); candidates predicted WORSE than the default geometry are
     never measured. The default itself is always kept, so the tuned
     winner can never regress it.
  2. measure — one representative per distinct execution geometry (the
     sensing scheme changes energy accounting, not execution, so the
     scheme dimension is resolved purely by prediction); winner is the
     lowest measured walltime, ties broken by predicted EDP.

Winners live in a bounded LRU (`repro.cim.dispatch.BoundedLRU` — the same
policy as the compiled-schedule program table) keyed like the dispatch
cache: the STRUCTURAL region keys of the default-geometry lowering x the
`DeviceSpec` identity. A warm key returns its winner with ZERO
re-searches (`Autotuner.searches` counts real searches), and the table
round-trips to JSON so CI and serve can warm-start.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from . import cost as cost_mod
from .array import ArraySpec
from .dispatch import BoundedLRU

# ---------------------------------------------------------------------------
# search space
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the search space. `n_bits` only takes effect through a
    `build` callback (quantization width changes the traced function);
    without one it is ignored."""

    banks: int = 4
    subarrays: int = 4
    bitline_words: int = 1024
    rows: int = 1024
    scheme: str = "current"
    n_bits: Optional[int] = None

    def spec(self) -> ArraySpec:
        return ArraySpec(banks=self.banks, subarrays=self.subarrays,
                         rows=self.rows, bitline_words=self.bitline_words)

    def geom_key(self, with_bits: bool) -> Tuple:
        """Execution identity: candidates sharing it run bit-identically
        (the sensing scheme is an accounting overlay)."""
        key = (self.banks, self.subarrays, self.bitline_words, self.rows)
        return key + (self.n_bits,) if with_bits else key


#: the hand-picked spec the rest of the repo defaults to
DEFAULT_CANDIDATE = Candidate()

#: a modest default grid (callers with a budget pass their own)
DEFAULT_CANDIDATES: Tuple[Candidate, ...] = tuple(
    Candidate(banks=b, subarrays=s, bitline_words=w, scheme=sc)
    for b in (2, 4, 8)
    for s, w in ((2, 1024), (4, 256), (4, 1024))
    for sc in ("current", "scheme2"))


# ---------------------------------------------------------------------------
# steady-state timing (the kernel_bench block-until-ready convention)
# ---------------------------------------------------------------------------


def _block(x) -> None:
    import jax

    jax.tree_util.tree_map(
        lambda l: l.block_until_ready()
        if hasattr(l, "block_until_ready") else l, x)


def steady_ms(fn: Callable[[], object], n: int = 5) -> float:
    """Mean wall ms per call after a compile/warmup call, every call
    blocked until ready."""
    _block(fn())
    t0 = time.perf_counter()
    for _ in range(n):
        _block(fn())
    return (time.perf_counter() - t0) * 1e3 / max(1, n)


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TuneResult:
    key: str                       # winners-table key (region keys x device)
    winner: Candidate
    from_cache: bool               # True: warm hit, nothing searched
    predicted_edp: Dict[str, float]    # candidate repr -> projected CiM EDP
    measured_ms: Dict[str, float]      # measured representatives only
    default_ms: Optional[float] = None
    tuned_ms: Optional[float] = None

    @property
    def tuned_vs_default_walltime_ratio(self) -> float:
        """>= 1.0 by construction: the default geometry is always in the
        measured set, and the winner is the measured minimum."""
        if not self.tuned_ms or not self.default_ms:
            return 1.0
        return self.default_ms / self.tuned_ms

    @property
    def tuned_vs_default_edp_ratio(self) -> float:
        """>= 1.0 by construction: losing predictions are pruned."""
        d = self.predicted_edp.get(repr(DEFAULT_CANDIDATE))
        w = self.predicted_edp.get(repr(self.winner))
        if not d or not w:
            return 1.0
        return d / w


class Autotuner:
    """Cost-model-pruned, measurement-confirmed geometry search with a
    bounded winners cache (see module docstring)."""

    def __init__(self, device: Optional[cost_mod.DeviceSpec] = None,
                 capacity: int = 64):
        self.device = device or cost_mod.DEFAULT_DEVICE
        self.winners: BoundedLRU = BoundedLRU(capacity)
        self.searches = 0

    # -- projection --------------------------------------------------------
    def predicted_edp(self, tr, cand: Candidate) -> float:
        """Projected total CiM EDP of `tr` on `cand`'s geometry/scheme,
        all eligible eqns counted (policy='always')."""
        plan = cost_mod.plan_offload(tr, spec=cand.spec(),
                                     scheme=cand.scheme, rows=cand.rows,
                                     device=self.device, policy="always")
        return sum(v.cim_edp for v in plan.verdicts)

    # -- cache key ---------------------------------------------------------
    def _key(self, tr, backend: Optional[str]) -> str:
        """Structural region keys of the DEFAULT-geometry lowering x the
        DeviceSpec — the dispatch schedule cache's keying discipline, so
        structurally identical workloads (repeated layers) share one
        winner."""
        # NOTE: the package __init__ rebinds the name `lower` to the
        # function, so pull the class straight from the submodule
        from .lower import LoweredComputation

        comp = LoweredComputation(
            tr, backend=backend, spec=DEFAULT_CANDIDATE.spec(),
            policy="always")
        region_keys = tuple(r.key for r in comp.regions)
        return repr((region_keys, self.device.key))

    # -- search ------------------------------------------------------------
    def tune(self, fn, args: Sequence, *,
             candidates: Optional[Sequence[Candidate]] = None,
             build: Optional[Callable[[Candidate], Tuple]] = None,
             backend: Optional[str] = None, measure: bool = True,
             steady_n: int = 5) -> TuneResult:
        """Search geometries for `fn(*args)`.

        `build(candidate) -> (fn, args)` lets candidates vary the traced
        function itself (the n_bits dimension: requantized weights); when
        omitted every candidate runs the same `fn`. Lowering for
        measurement uses `policy="always"` so geometries compare on
        identical work."""
        from .lower import lower as lower_fn
        from .trace import trace as trace_fn

        tr = trace_fn(fn, *args)
        key = self._key(tr, backend)
        cached = self.winners.get(key)
        if cached is not None:
            return TuneResult(key=key, winner=cached, from_cache=True,
                              predicted_edp={}, measured_ms={})

        self.searches += 1
        cands: List[Candidate] = [DEFAULT_CANDIDATE]
        for c in (candidates if candidates is not None
                  else DEFAULT_CANDIDATES):
            if c not in cands:
                cands.append(c)

        def traced(c: Candidate):
            if build is None:
                return tr, fn, args
            fn_c, args_c = build(c)
            return trace_fn(fn_c, *args_c), fn_c, args_c

        predicted: Dict[Candidate, float] = {}
        for c in cands:
            tr_c, _, _ = traced(c)
            predicted[c] = self.predicted_edp(tr_c, c)

        # prune: never measure a geometry projected worse than the default
        keep = [c for c in cands if predicted[c] <= predicted[cands[0]]]

        by_geom: Dict[Tuple, Candidate] = {}
        for c in keep:
            g = c.geom_key(with_bits=build is not None)
            if g not in by_geom or predicted[c] < predicted[by_geom[g]]:
                by_geom[g] = c

        measured: Dict[Candidate, float] = {}
        if measure:
            for c in by_geom.values():
                _, fn_c, args_c = traced(c)
                lowered = lower_fn(fn_c, backend=backend,
                                   spec=c.spec(), policy="always")
                measured[c] = steady_ms(lambda: lowered(*args_c),
                                        n=steady_n)
            winner = min(measured, key=lambda c: (measured[c],
                                                  predicted[c]))
            default_geom = cands[0].geom_key(with_bits=build is not None)
            default_ms = measured[by_geom[default_geom]]
            tuned_ms = measured[winner]
        else:
            winner = min(keep, key=lambda c: predicted[c])
            default_ms = tuned_ms = None

        self.winners.put(key, winner)
        return TuneResult(
            key=key, winner=winner, from_cache=False,
            predicted_edp={repr(c): predicted[c] for c in cands},
            measured_ms={repr(c): measured[c] for c in measured},
            default_ms=default_ms, tuned_ms=tuned_ms)

    # -- persistence -------------------------------------------------------
    def save(self, path: str) -> None:
        """Winners table -> JSON (CI artifact / serve warm-start)."""
        data = {
            "device": self.device.to_dict(),
            "searches": self.searches,
            "winners": [{"key": k, "winner": dataclasses.asdict(c)}
                        for k, c in self.winners.items()],
        }
        with open(path, "w") as f:
            json.dump(data, f, indent=2)

    def load(self, path: str) -> int:
        """Warm the winners table from `save`'s JSON; returns the number
        of entries loaded. A table saved under a DIFFERENT DeviceSpec is
        refused (its keys could never hit anyway)."""
        with open(path) as f:
            data = json.load(f)
        if data.get("device", {}).get("name") != self.device.name:
            raise ValueError(
                f"winners file {path} was tuned for device "
                f"{data.get('device', {}).get('name')!r}, not "
                f"{self.device.name!r}")
        n = 0
        for entry in data.get("winners", []):
            self.winners.put(entry["key"], Candidate(**entry["winner"]))
            n += 1
        return n

"""CiM backend registry: one dispatch point for every ADRA execution model.

A backend is a callable over packed bit-planes:

    fn(a_planes uint32[n, W], b_planes uint32[n, W], ops: tuple[str, ...])
        -> tuple[jax.Array, ...]   # one output per op, opset shape rules

Registered backends:

  pallas-tpu       — the fused single-pass Pallas kernel, compiled (TPU)
  pallas-interpret — same kernel through the Pallas interpreter (CPU tests)
  jnp-boolean      — pure-jnp plane math, ideal SAs (fast portable path and
                     the dry-run lowering fallback)
  analog-oracle    — per-bit senseline currents from the calibrated FeFET
                     device model, thresholded against the SA references
                     (repro.core.adra mode="analog"): the slow path that IS
                     the paper, used to validate every other backend

This replaces the ad-hoc `_on_tpu()` checks that used to be scattered through
kernels/ops.py: resolution order is explicit argument > REPRO_CIM_BACKEND
env var > set_default_backend() > platform default.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import opset
from .fused_kernel import fused_planes_op

Planes = jax.Array
BackendFn = Callable[[Planes, Planes, Tuple[str, ...]], Tuple[jax.Array, ...]]


@dataclasses.dataclass(frozen=True)
class Backend:
    name: str
    fn: BackendFn
    description: str

    def __call__(self, a_planes, b_planes, ops):
        return self.fn(a_planes, b_planes, ops)


_REGISTRY: Dict[str, Backend] = {}
_DEFAULT_OVERRIDE: Optional[str] = None


def register_backend(name: str, fn: BackendFn, description: str = "") -> Backend:
    bk = Backend(name=name, fn=fn, description=description)
    _REGISTRY[name] = bk
    return bk


def available_backends() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def set_default_backend(name: Optional[str]) -> None:
    """Process-wide default (None restores platform-based resolution)."""
    global _DEFAULT_OVERRIDE
    if name is not None and name not in _REGISTRY:
        raise KeyError(f"unknown backend {name!r}; have {available_backends()}")
    _DEFAULT_OVERRIDE = name


def default_backend_name() -> str:
    env = os.environ.get("REPRO_CIM_BACKEND")
    if env:
        return env
    if _DEFAULT_OVERRIDE:
        return _DEFAULT_OVERRIDE
    return "pallas-tpu" if on_tpu() else "jnp-boolean"


def get_backend(name: Optional[str] = None) -> Backend:
    name = name or default_backend_name()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown CiM backend {name!r}; have {available_backends()}") from None


# ---------------------------------------------------------------------------
# pallas-tpu / pallas-interpret
# ---------------------------------------------------------------------------


def _pallas_backend(a_planes, b_planes, ops, *, interpret: bool):
    return fused_planes_op(a_planes, b_planes, tuple(ops), interpret=interpret)


# ---------------------------------------------------------------------------
# jnp-boolean: the kernel's dataflow in pure jnp (ideal SAs)
# ---------------------------------------------------------------------------


def _jnp_boolean_backend(a_planes, b_planes, ops):
    ops = opset.validate_ops(ops)
    n_bits, w = a_planes.shape
    need_add = opset.needs_add_chain(ops)
    need_sub = opset.needs_sub_chain(ops)
    out: Dict[str, list] = {fn: [] for fn in ops if fn in opset.BOOLEAN_OPS}
    add_planes, sub_planes = [], []

    zeros = jnp.zeros((w,), jnp.uint32)
    carry_a, carry_s, nz = zeros, ~zeros, zeros
    for i in range(n_bits):
        a, b = a_planes[i], b_planes[i]
        or_, and_ = a | b, a & b
        a_rec = opset.oai21_recover_a_planes(or_, and_, b)
        for fn in out:
            out[fn].append(opset.boolean_plane(fn, or_, and_, b, a_rec))
        xor = or_ & ~and_
        if need_add:
            add_planes.append(xor ^ carry_a)
            carry_a = and_ | (carry_a & xor)
        if need_sub:
            xnor = ~xor
            s = xnor ^ carry_s
            sub_planes.append(s)
            carry_s = (or_ & ~b) | (carry_s & xnor)
            nz = nz | s

    a_msb, b_msb = a_planes[n_bits - 1], b_planes[n_bits - 1]
    results: Dict[str, jax.Array] = {}
    if need_add:
        xor = a_msb ^ b_msb
        add_planes.append(xor ^ carry_a)
        results["add"] = jnp.stack(add_planes)
        results["carry_add"] = ((a_msb & b_msb) | (carry_a & xor))[None, :]
    if need_sub:
        nb = ~b_msb
        xnor = a_msb ^ nb
        s_ext = xnor ^ carry_s
        sub_planes.append(s_ext)
        nz = nz | s_ext
        results["sub"] = jnp.stack(sub_planes)
        results["carry_sub"] = ((a_msb & nb) | (carry_s & xnor))[None, :]
        results["lt"] = s_ext[None, :]
        results["eq"] = (~nz)[None, :]
        results["gt"] = (~s_ext & nz)[None, :]
    for fn, planes in out.items():
        results[fn] = jnp.stack(planes)
    return tuple(results[op] for op in ops)


# ---------------------------------------------------------------------------
# analog-oracle: the device-model path from repro.core.adra, per bit
# ---------------------------------------------------------------------------


def _planes_to_bits(planes: jax.Array) -> jax.Array:
    """uint32[rows, W] -> int32[W*32, rows] 0/1 bit matrix (word-major)."""
    rows, w = planes.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (planes[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(rows, w * 32).T.astype(jnp.int32)


def _bits_to_planes(bits: jax.Array) -> jax.Array:
    """int32[W*32, rows] 0/1 -> uint32[rows, W] packed planes."""
    n, rows = bits.shape
    assert n % 32 == 0, n
    weights = (1 << jnp.arange(32, dtype=jnp.uint32)).astype(jnp.uint32)
    chunks = bits.T.reshape(rows, n // 32, 32).astype(jnp.uint32)
    return jnp.sum(chunks * weights, axis=-1)


def _analog_oracle_backend(a_planes, b_planes, ops):
    """Unpack to bits, run the sensed analog dataflow, repack. Slow by design
    (evaluates the FeFET device model per bit); use small widths."""
    from repro.core.adra import adra_access
    from repro.core.compute_module import compare_from_sub, ripple_chain

    ops = opset.validate_ops(ops)
    a_bits = _planes_to_bits(a_planes)      # [N, n_bits]
    b_bits = _planes_to_bits(b_planes)
    acc = adra_access(a_bits, b_bits, mode="analog")

    results: Dict[str, jax.Array] = {}
    if opset.needs_add_chain(ops):
        sum_bits, c_out = ripple_chain(acc.or_, acc.and_, acc.b, select=0)
        results["add"] = _bits_to_planes(sum_bits)
        results["carry_add"] = _bits_to_planes(c_out[:, None])
    if opset.needs_sub_chain(ops):
        sum_bits, c_out = ripple_chain(acc.or_, acc.and_, acc.b, select=1)
        results["sub"] = _bits_to_planes(sum_bits)
        results["carry_sub"] = _bits_to_planes(c_out[:, None])
        c = compare_from_sub(sum_bits)
        results["lt"] = _bits_to_planes(c.lt[:, None])
        results["eq"] = _bits_to_planes(c.eq[:, None])
        results["gt"] = _bits_to_planes(c.gt[:, None])
    for fn in ops:
        if fn in opset.BOOLEAN_OPS:
            plane_bits = opset.boolean_plane(
                fn,
                acc.or_.astype(jnp.uint32), acc.and_.astype(jnp.uint32),
                acc.b.astype(jnp.uint32), acc.a.astype(jnp.uint32)) & 1
            results[fn] = _bits_to_planes(plane_bits.astype(jnp.int32))
    return tuple(results[op] for op in ops)


# ---------------------------------------------------------------------------
# registration
# ---------------------------------------------------------------------------

import functools as _functools

register_backend(
    "pallas-tpu", _functools.partial(_pallas_backend, interpret=False),
    "fused single-pass Pallas kernel, compiled")
register_backend(
    "pallas-interpret", _functools.partial(_pallas_backend, interpret=True),
    "fused Pallas kernel through the interpreter (portable tests)")
register_backend(
    "jnp-boolean", _jnp_boolean_backend,
    "pure-jnp plane math with ideal SAs")
register_backend(
    "analog-oracle", _analog_oracle_backend,
    "calibrated FeFET device model + sensed SAs (the paper, per bit)")

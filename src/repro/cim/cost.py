"""Spec-driven cost model: should this eqn run in the array at all?

One projection, three consumers. For every classified eqn in a
`repro.cim.trace.Trace` this module projects

  * a CiM cost — energy/latency/EDP in the paper's internal units, built
    from the SAME quantities the ledger charges (per-access activated
    words, `TilePlan` waves, streamed-load row writes, inter-bank
    reduction words), so projection and execution share one accounting;
  * a near-memory baseline cost — the paper's two-access read-modify-write
    on the same data, paying only the USEFUL words (the baseline needs no
    bank padding or wave serialization);
  * a host roofline cost — time from a `DeviceSpec` (peak FLOP/s, HBM B/s
    — the constants `launch/roofline.py` hard-codes for a v5e chip,
    loadable from CSV so a non-v5e target is one spec row away) and a
    simple pJ/flop + pJ/byte energy model.

`plan_offload` turns the per-eqn verdicts into an offload decision for
the lowering compiler (`repro.cim.lower`) and the estimator
(`repro.core.offload`) — both call it, so the report's demotion list IS
the executor's demotion list.

Offload policies
----------------
  "always"  — lower every eligible eqn (the pre-cost-model behavior;
              bit-exact with it, including dispatch counts).
  "edp"     — DEFAULT ("cost" is an alias). Lower an eqn only when its
              projected CiM EDP beats the near-memory baseline on the
              same operands. Unbanked placements always win under current
              sensing (both sides scale with the word count), so this
              policy only demotes pad-dominated banked placements —
              utilization below ~0.6 of a tile — and loss-making voltage
              schemes.
  "latency" — lower only when projected CiM wall time beats the host
              roofline time from the `DeviceSpec`. Physical-units policy:
              demotes shapes too small to amortize array access latency
              against a ~200 TFLOP/s host.
  "never"   — demote everything (debugging / A-B measurement).

Region fusion re-evaluates at fusion boundaries: a LOSING eqn sandwiched
between winners may still fuse when hosting it would force the region to
unpack its packed operands and repack the host result — the pack/unpack
toll (one array read + one row write per crossing 32-bit word) is modeled
explicitly, and the eqn keeps its `lowers=False` verdict with
`fused=True` so reports show the trade.
"""
from __future__ import annotations

import csv
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import energy

from . import accounting
from .array import ArraySpec

# ---------------------------------------------------------------------------
# DeviceSpec: the host side of the comparison
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """Host-chip roofline constants (one row of a device CSV).

    `peak_flops` / `hbm_bw` / `ici_bw` are the v5e numbers that
    `launch/roofline.py` historically hard-coded; `pj_per_flop` /
    `pj_per_byte` extend the roofline with a first-order energy model so
    the "edp" comparison has a host energy to talk about.
    """

    name: str = "tpu-v5e"
    peak_flops: float = 197e12     # bf16 FLOP/s per chip
    hbm_bw: float = 819e9          # HBM bytes/s per chip
    ici_bw: float = 50e9           # ICI bytes/s per link
    pj_per_flop: float = 0.5       # host compute energy per scalar op
    pj_per_byte: float = 20.0      # host DRAM energy per byte moved

    def to_dict(self) -> Dict[str, float]:
        d = dataclasses.asdict(self)
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "DeviceSpec":
        fields = {f.name for f in dataclasses.fields(cls)}
        kwargs = {k: (v if k == "name" else float(v))
                  for k, v in d.items() if k in fields}
        unknown = set(d) - fields
        if unknown:
            raise ValueError(f"unknown DeviceSpec fields {sorted(unknown)}")
        return cls(**kwargs)

    @classmethod
    def from_csv(cls, path: str, name: Optional[str] = None) -> "DeviceSpec":
        """Load a device row from a CSV with a header row naming the
        dataclass fields. With `name`, pick that row; otherwise the first."""
        with open(path, newline="") as f:
            rows = list(csv.DictReader(f))
        if not rows:
            raise ValueError(f"no device rows in {path}")
        if name is None:
            return cls.from_dict(rows[0])
        for row in rows:
            if row.get("name") == name:
                return cls.from_dict(row)
        raise ValueError(f"device {name!r} not in {path} "
                         f"(have {[r.get('name') for r in rows]})")

    @property
    def key(self) -> Tuple:
        """Hashable identity for cache keys (autotune winners)."""
        return tuple(dataclasses.astuple(self))


DEFAULT_DEVICE = DeviceSpec()

# ---------------------------------------------------------------------------
# offload policies
# ---------------------------------------------------------------------------

POLICIES = ("always", "edp", "latency", "never")
DEFAULT_POLICY = "edp"
_POLICY_ALIASES = {"cost": "edp"}


def normalize_policy(policy: Optional[str]) -> str:
    p = DEFAULT_POLICY if policy is None else _POLICY_ALIASES.get(policy,
                                                                  policy)
    if p not in POLICIES:
        raise ValueError(f"unknown offload policy {policy!r} "
                         f"(expected one of {POLICIES} or 'cost')")
    return p


# ---------------------------------------------------------------------------
# per-eqn accounting shared with repro.core.offload.analyze_trace
# ---------------------------------------------------------------------------

#: streamed-operand entry packs per op kind (binary ops: 2, reductions: 1)
STREAM_LOADS = {"reduce_sum": 1, "population_count": 1}


def eqn_words32(op) -> float:
    """32-bit-word operations one execution of this eqn performs — the
    estimator's convention (mul/dot work at the 2n-bit product width on
    every planned access)."""
    if not op.eligible or op.accesses == 0:
        return 0.0
    bits = op.n_bits
    if op.kind == "single":
        return op.words * bits / 32.0
    if op.name in ("mul", "dot_general"):
        return op.accesses * op.words * (2 * bits) / 32.0
    return op.accesses * op.words * bits / 32.0    # reduce_sum / popcount


def eqn_stream_loads(op) -> int:
    """Fresh operand entry packs if nothing is memoized (upper bound —
    region fusion and residency remove loads, never add them)."""
    if not op.eligible or op.accesses == 0:
        return 0
    return STREAM_LOADS.get(op.name, 2)


def eqn_load_words32(op) -> float:
    """Row-write words driving those streamed packs into the array."""
    return eqn_stream_loads(op) * op.words * op.n_bits / 32.0


# ---------------------------------------------------------------------------
# per-eqn verdict
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EqnVerdict:
    """The cost model's projection and decision for ONE eligible eqn.

    Energy/latency fields are in the paper's internal units (multiples of
    the standard-read energy/latency at 1024 rows); `*_s` / `*_j` fields
    are physical. `margin` is the fractional win under `policy` (> 0: CiM
    wins; -0.25: CiM costs 25% more than the alternative)."""

    index: int                     # position in trace.ops
    name: str
    kind: str
    n_bits: int
    words: int
    accesses: int
    banked_accesses: int           # accesses * n_tiles (== ledger, banked)
    waves: int                     # accesses * plan.waves (critical path)
    words32: float                 # useful 32-bit-word ops
    activated_words32: float       # incl. pad columns of partial tiles
    load_words32: float            # streamed entry-pack row writes
    inter_bank_words32: float      # cross-tile reduction traffic
    cim_energy: float              # internal units, as bank_report charges
    cim_latency: float
    base_energy: float             # near-memory two-access baseline
    base_latency: float
    host_time_s: float             # DeviceSpec roofline
    host_energy_j: float
    policy: str
    lowers: bool                   # the decision under `policy`
    fused: bool = False            # losing eqn kept fused (sandwich toll)
    margin: float = 0.0
    reason: str = ""

    @property
    def cim_edp(self) -> float:
        return self.cim_energy * self.cim_latency

    @property
    def base_edp(self) -> float:
        return self.base_energy * self.base_latency

    @property
    def cim_time_s(self) -> float:
        return self.cim_latency * energy.T0_NS * 1e-9

    @property
    def cim_energy_j(self) -> float:
        return self.cim_energy * energy.E0_FJ * 1e-15

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["cim_edp"] = self.cim_edp
        d["base_edp"] = self.base_edp
        return d


def ecc_overhead(n_bits: int) -> float:
    """Fractional row/load overhead of SECDED on an n_bits resident pack:
    parity planes per data plane (5/8 at int8 — see planepack)."""
    from .planepack import ecc_plane_count

    return ecc_plane_count(n_bits) / max(1, n_bits)


def project_eqn(op, index: int, spec: Optional[ArraySpec], res,
                device: DeviceSpec, policy: str,
                ecc_overhead_ratio: float = 0.0) -> EqnVerdict:
    """Project one eligible eqn's CiM / baseline / host costs and decide
    whether it lowers under `policy`. `res` is an `energy.SchemeResult`.
    `ecc_overhead_ratio` (> 0 when resident operands run ECC-protected)
    scales the streamed-load row writes: every protected load also writes
    its parity planes, so the CiM side pays the protection the host side
    never needs — the cost model weighs ECC against host fallback."""
    from .trace import aval_of, host_flops, host_io_bits

    words32 = eqn_words32(op)
    load_w32 = eqn_load_words32(op) * (1.0 + max(0.0, ecc_overhead_ratio))

    if spec is not None and op.words >= 1 and op.accesses > 0:
        plan = spec.plan(op.words)
        n_tiles = plan.n_tiles
        waves = op.accesses * plan.waves
        banked_accesses = op.accesses * n_tiles
        # activated words include the idle pad columns of partial tiles —
        # exactly the ratio charge_banked bills over the useful words
        pad_scale = n_tiles * plan.tile_words / max(1, op.words)
        activated = words32 * pad_scale
        load_accesses_scale = n_tiles
    else:
        n_tiles = 1
        waves = op.accesses
        banked_accesses = op.accesses
        activated = words32
        load_accesses_scale = 1
    del load_accesses_scale    # loads charge per tile but words dominate

    inter32 = 0.0
    if n_tiles > 1 and op.name in ("reduce_sum", "dot_general"):
        out = aval_of(op.outvars[0])
        out_words = 1
        for d in out.shape:
            out_words *= int(d)
        inter32 = (n_tiles - 1) * out_words * max(op.n_bits, 32) / 32.0

    # -- CiM side: the ledger's bank_report formulas per eqn ---------------
    e_cim = (res.cim.energy * activated
             + res.read.energy * load_w32
             + accounting.E_HOP_WORD32 * inter32)
    slots = spec.banks if spec is not None else 1
    t_cim = (res.cim.latency * max(1, waves)
             + accounting.T_HOP_WORD32 * inter32 / max(1, slots))

    # -- near-memory baseline: same wave structure as bank_report's t_base,
    # but paying only the USEFUL words — a near-memory unit reads packed
    # operands and needs no bank-pad columns, so pad-dominated placements
    # lose here while full tiles keep the paper's per-word margin
    e_base = res.baseline.energy * words32
    t_base = res.baseline.latency * max(1, waves)

    # -- host roofline from the DeviceSpec ---------------------------------
    flops = host_flops(op)
    host_bytes = -(-host_io_bits(op) // 8)
    host_time = max(flops / device.peak_flops, host_bytes / device.hbm_bw)
    host_energy = (flops * device.pj_per_flop
                   + host_bytes * device.pj_per_byte) * 1e-12

    cim_time_s = t_cim * energy.T0_NS * 1e-9
    if op.accesses == 0:
        lowers, margin, reason = True, 0.0, "free"
    elif policy == "always":
        lowers, margin, reason = True, 0.0, "forced"
    elif policy == "never":
        lowers, margin, reason = False, 0.0, "forced"
    elif policy == "latency":
        lowers = cim_time_s <= host_time
        margin = 1.0 - cim_time_s / host_time if host_time > 0 else -1.0
        reason = "cim faster than host roofline" if lowers \
            else "host roofline faster"
    else:                                   # "edp"
        cim_edp = e_cim * t_cim
        base_edp = e_base * t_base
        lowers = cim_edp <= base_edp
        margin = 1.0 - cim_edp / base_edp if base_edp > 0 else 0.0
        reason = "cim edp beats near-memory baseline" if lowers \
            else "pad/load overhead loses to baseline"

    return EqnVerdict(
        index=index, name=op.name, kind=op.kind, n_bits=op.n_bits,
        words=op.words, accesses=op.accesses,
        banked_accesses=banked_accesses, waves=waves,
        words32=words32, activated_words32=activated,
        load_words32=load_w32, inter_bank_words32=inter32,
        cim_energy=e_cim, cim_latency=t_cim,
        base_energy=e_base, base_latency=t_base,
        host_time_s=host_time, host_energy_j=host_energy,
        policy=policy, lowers=lowers, margin=margin, reason=reason)


# ---------------------------------------------------------------------------
# the offload plan: verdicts + demotions, shared by estimator and executor
# ---------------------------------------------------------------------------

#: process-wide decision counters (serve report / diagnostics)
PLAN_STATS = {"plans": 0, "eqns_lowered": 0, "eqns_demoted": 0,
              "demoted_accesses": 0, "fused_despite_loss": 0}


def reset_plan_stats() -> None:
    for k in PLAN_STATS:
        PLAN_STATS[k] = 0


@dataclasses.dataclass(frozen=True)
class OffloadPlan:
    """plan_offload's output: one verdict per eligible eqn plus the set of
    eqn indices demoted to host execution."""

    policy: str
    scheme: str
    rows: int
    device: DeviceSpec
    verdicts: Tuple[EqnVerdict, ...]
    demoted: frozenset

    def verdict_for(self, index: int) -> Optional[EqnVerdict]:
        for v in self.verdicts:
            if v.index == index:
                return v
        return None

    @property
    def demoted_eqns(self) -> int:
        return len(self.demoted)

    @property
    def demoted_accesses(self) -> int:
        return sum(v.accesses for v in self.verdicts
                   if v.index in self.demoted)

    @property
    def fused_losses(self) -> int:
        return sum(1 for v in self.verdicts if v.fused)


def _crossing_words32(tr, seg: Sequence[int], pos: int) -> float:
    """Packed words that would cross a host detour at seg[pos]: vars
    produced by eqns before the split and consumed by eqns after it
    (within the fused run) — each pays one array read out and one row
    write back if the sandwiched eqn is hosted."""
    from .trace import aval_of, dtype_bits

    produced = set()
    for i in seg[:pos]:
        produced.update(id(v) for v in tr.ops[i].outvars)
    crossing = {}
    for j in seg[pos + 1:]:
        for v in tr.ops[j].invars:
            if id(v) in produced and id(v) not in crossing:
                crossing[id(v)] = v
    w32 = 0.0
    for v in crossing.values():
        aval = aval_of(v)
        nel = 1
        for d in aval.shape:
            nel *= int(d)
        try:
            bits = dtype_bits(aval.dtype)
        except Exception:
            bits = aval.dtype.itemsize * 8
        w32 += nel * bits / 32.0
    return w32


def _keeps_fused(tr, seg: Sequence[int], pos: int, v: EqnVerdict, res,
                 device: DeviceSpec, policy: str) -> bool:
    """Is fusing this losing eqn cheaper than the host detour it avoids?

    The detour pays the pack/unpack toll: every crossing word32 is read
    out of the array and written back (2 x standard-read energy), and the
    region serializes behind 2 extra array passes."""
    toll_w32 = _crossing_words32(tr, seg, pos)
    if toll_w32 <= 0:
        return False
    if policy == "latency":
        toll_s = 2.0 * toll_w32 * 4.0 / device.hbm_bw
        return v.cim_time_s <= v.host_time_s + toll_s
    detour_e = v.base_energy + 2.0 * res.read.energy * toll_w32
    detour_t = v.base_latency + 2.0 * res.read.latency
    return v.cim_edp <= detour_e * detour_t


def plan_offload(tr, spec: Optional[ArraySpec] = None,
                 scheme: str = "current", rows: int = 1024,
                 device: Optional[DeviceSpec] = None,
                 policy: Optional[str] = None) -> OffloadPlan:
    """Decide, per eligible eqn of `tr`, whether it lowers to the array.

    Demotion works on maximal runs of consecutive eligible eqns (the
    regions the lowering compiler would fuse): losing eqns at a run's
    EDGES are demoted outright; an INTERIOR loser is kept fused when the
    pack/unpack toll of hosting it exceeds its loss (`fused=True` on its
    verdict), else the run splits around it and the halves re-evaluate."""
    policy = normalize_policy(policy)
    device = device or DEFAULT_DEVICE
    res = accounting._SCHEMES[scheme](rows)

    from . import array as array_mod

    verdicts: Dict[int, EqnVerdict] = {}
    for i, op in enumerate(tr.ops):
        if op.eligible:
            ratio = ecc_overhead(op.n_bits) \
                if array_mod.resident_ecc_default() else 0.0
            verdicts[i] = project_eqn(op, i, spec, res, device, policy,
                                      ecc_overhead_ratio=ratio)

    demoted: set = set()
    if policy == "never":
        demoted = set(verdicts)
    elif policy != "always":
        runs: List[List[int]] = []
        for i, op in enumerate(tr.ops):
            if not op.eligible:
                continue
            if runs and runs[-1][-1] == i - 1:
                runs[-1].append(i)
            else:
                runs.append([i])

        def wins(i: int) -> bool:
            return verdicts[i].lowers

        fused: set = set()
        stack = list(runs)
        while stack:
            seg = stack.pop()
            while seg and not wins(seg[0]):
                demoted.add(seg.pop(0))
            while seg and not wins(seg[-1]):
                demoted.add(seg.pop())
            split_at = None
            for pos in range(1, len(seg) - 1):
                i = seg[pos]
                if wins(i):
                    continue
                if _keeps_fused(tr, seg, pos, verdicts[i], res, device,
                                policy):
                    continue
                split_at = pos
                break
            if split_at is None:
                fused.update(i for i in seg[1:-1] if not wins(i))
                continue
            demoted.add(seg[split_at])
            stack.append(seg[:split_at])
            stack.append(seg[split_at + 1:])
        for i in fused:
            verdicts[i] = dataclasses.replace(verdicts[i], fused=True)

    plan = OffloadPlan(policy=policy, scheme=scheme, rows=rows,
                       device=device,
                       verdicts=tuple(verdicts[i] for i in sorted(verdicts)),
                       demoted=frozenset(demoted))
    PLAN_STATS["plans"] += 1
    PLAN_STATS["eqns_lowered"] += len(plan.verdicts) - len(plan.demoted)
    PLAN_STATS["eqns_demoted"] += len(plan.demoted)
    PLAN_STATS["demoted_accesses"] += plan.demoted_accesses
    PLAN_STATS["fused_despite_loss"] += plan.fused_losses
    return plan


# ---------------------------------------------------------------------------
# "when does CiM win?" — representative shapes for docs/diagnostics
# ---------------------------------------------------------------------------


def cim_wins_rows(device: Optional[DeviceSpec] = None,
                  scheme: str = "current", rows: int = 1024) -> List[Dict]:
    """The README table: three representative shapes through the cost
    model — an unbanked elementwise op (wins), a banked well-utilized
    matmul tile (wins), and a pad-dominated banked sliver (loses)."""
    import jax.numpy as jnp
    import numpy as np

    from .trace import trace

    device = device or DEFAULT_DEVICE
    cases = [
        ("int16 add, 4096 words, unbanked",
         lambda a, b: a + b,
         (np.zeros(4096, np.int16), np.ones(4096, np.int16)),
         None),
        ("int8 matmul 16x64 @ 64x64, banked 4x(4x256)",
         lambda a, b: jnp.dot(a, b, preferred_element_type=jnp.int32),
         (np.ones((16, 64), np.int8), np.ones((64, 64), np.int8)),
         ArraySpec(banks=4, subarrays=4, rows=rows, bitline_words=256)),
        ("int16 add, 4 words on 32-word tiles (12% utilized)",
         lambda a, b: a + b,
         (np.zeros(4, np.int16), np.ones(4, np.int16)),
         ArraySpec(banks=2, subarrays=1, rows=rows, bitline_words=32)),
    ]
    out = []
    for label, fn, args, spec in cases:
        plan = plan_offload(trace(fn, *args), spec=spec, scheme=scheme,
                            rows=rows, device=device, policy="edp")
        v = max(plan.verdicts, key=lambda x: x.accesses)
        out.append({
            "shape": label,
            "cim_edp": v.cim_edp,
            "baseline_edp": v.base_edp,
            "edp_margin_pct": 100.0 * v.margin,
            "host_time_ns": v.host_time_s * 1e9,
            "cim_time_ns": v.cim_time_s * 1e9,
            "lowers": v.lowers,
        })
    return out


def cim_wins_table(device: Optional[DeviceSpec] = None,
                   scheme: str = "current", rows: int = 1024) -> str:
    """`cim_wins_rows` rendered as the README's markdown table."""
    lines = ["| shape | CiM EDP | baseline EDP | EDP margin | verdict |",
             "|---|---:|---:|---:|---|"]
    for r in cim_wins_rows(device, scheme, rows):
        lines.append(
            f"| {r['shape']} | {r['cim_edp']:.1f} | {r['baseline_edp']:.1f} "
            f"| {r['edp_margin_pct']:+.1f}% | "
            f"{'lower' if r['lowers'] else 'host'} |")
    return "\n".join(lines)


if __name__ == "__main__":          # pragma: no cover
    print(cim_wins_table())

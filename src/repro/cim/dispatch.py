"""Tiling dispatcher: run any PlanePack op request on a banked array.

`execute_tiled` splits an operand pair into bank-sized tiles (ArraySpec /
TilePlan from repro.cim.array), vmaps the fused backend over the tile axis,
and stitches the outputs back together — bit-exact with the untiled engine,
because elementwise CiM ops touch each word independently and tiles cut the
packed lane axis on uint32 boundaries.

Two substrate services live here as well:

  * a compiled-schedule cache: a bounded LRU of jitted programs keyed by
    schedule structure. It holds both the per-step tiled programs built
    here (key: ops, n_bits, tile shape, backend, placement) and the
    WHOLE-schedule step programs built by repro.cim.macro — one jitted XLA
    dispatch covering every access of a macro or fused region. `cache_stats()`
    exposes hit/miss/eviction counters plus `dispatches`, the number of
    jitted-program invocations — the deterministic walltime proxy the
    benchmarks gate on (a warm macro matmul is exactly ONE dispatch).
  * a `jax.shard_map` path over the production/smoke meshes of
    repro.launch.mesh: pass `mesh=` and tiles are block-distributed over the
    mesh's "data" axis, each device executing (and its ledger slice being
    charged for) only its own bank activations — multi-device execution with
    no other caller changes.

The ledger is charged per (device, bank) activation (see
repro.cim.accounting), which is what makes the contention-adjusted EDP
projection and the per-device ledger sum-check possible.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import engine, opset
from . import array as array_mod
from .accounting import LEDGER
from .array import DEFAULT_SPEC, ArraySpec, TilePlan
from .backends import Backend, get_backend
from .planepack import PlanePack


def _shard_map(body, mesh, in_specs, out_specs):
    """Version-portable shard_map (jax>=0.6: jax.shard_map/check_vma;
    older: jax.experimental.shard_map/check_rep)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


# ---------------------------------------------------------------------------
# compiled-schedule cache (bounded LRU)
# ---------------------------------------------------------------------------

import os as _os
from collections import OrderedDict

#: default capacity; override per process with set_schedule_cache_capacity()
#: or the REPRO_CIM_CACHE_CAPACITY env var. Serving workloads with varied
#: tile shapes would otherwise grow the program table without bound.
_DEFAULT_CAPACITY = 256


class BoundedLRU:
    """Move-to-front bounded mapping with hit/miss/eviction counters — the
    schedule-program table's caching policy, factored out so other
    structural-key caches (the autotuner's winners table) share one
    implementation. An insert past capacity evicts the coldest entry;
    correctness must never depend on residency."""

    def __init__(self, capacity: int = _DEFAULT_CAPACITY):
        if capacity < 1:
            raise opset.CimOpError(
                f"cache capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._data: "OrderedDict[object, object]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key, default=None):
        """Look up, counting a hit (and refreshing recency) or a miss.
        Callers that miss MUST build and `put` under the same key."""
        if key in self._data:
            self.hits += 1
            self._data.move_to_end(key)
            return self._data[key]
        self.misses += 1
        return default

    def put(self, key, value) -> None:
        self._data[key] = value
        self._data.move_to_end(key)
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)
            self.evictions += 1

    def set_capacity(self, capacity: int) -> None:
        if capacity < 1:
            raise opset.CimOpError(
                f"cache capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)
            self.evictions += 1

    def clear(self) -> None:
        self._data.clear()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def items(self):
        return self._data.items()

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._data), "evictions": self.evictions,
                "capacity": self.capacity}

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key) -> bool:
        return key in self._data


_PROGRAMS: "OrderedDict[tuple, object]" = OrderedDict()


def _env_capacity() -> int:
    """REPRO_CIM_CACHE_CAPACITY, validated like set_schedule_cache_capacity
    (malformed or < 1 values fall back to the default instead of silently
    disabling the cache or crashing the import)."""
    raw = _os.environ.get("REPRO_CIM_CACHE_CAPACITY")
    if raw is None:
        return _DEFAULT_CAPACITY
    try:
        cap = int(raw)
    except ValueError:
        return _DEFAULT_CAPACITY
    return cap if cap >= 1 else _DEFAULT_CAPACITY


_CAPACITY = _env_capacity()
_HITS = 0
_MISSES = 0
_EVICTIONS = 0
_DISPATCHES = 0


def cache_stats() -> Dict[str, int]:
    """Counters of the compiled-schedule cache: hits/misses/evictions of
    the program table plus `dispatches`, the total number of jitted-program
    invocations (whole-schedule step programs and per-step tiled programs
    alike). A warm macro or fused region costs exactly one dispatch.
    Resident-region counters (resident_pins/hits/misses/evictions/
    invalidations, aggregated across every ResidentSet) ride along so one
    call answers both "did the program cache stay warm" and "did the
    operands stay pinned"."""
    stats = {"hits": _HITS, "misses": _MISSES, "entries": len(_PROGRAMS),
             "evictions": _EVICTIONS, "capacity": _CAPACITY,
             "dispatches": _DISPATCHES}
    stats.update(array_mod.resident_stats())
    from . import faults as faults_mod

    stats.update(faults_mod.fault_stats())
    return stats


def clear_schedule_cache() -> None:
    global _HITS, _MISSES, _EVICTIONS, _DISPATCHES
    _PROGRAMS.clear()
    _HITS = 0
    _MISSES = 0
    _EVICTIONS = 0
    _DISPATCHES = 0


def count_dispatch(n: int = 1) -> None:
    """Record `n` jitted-program invocations (see cache_stats)."""
    global _DISPATCHES
    _DISPATCHES += n


def program_cache_get(key):
    """Look up a compiled program, counting a hit (and refreshing LRU
    recency) or a miss. Callers that miss MUST build and `program_cache_put`
    under the same key."""
    global _HITS, _MISSES
    prog = _PROGRAMS.get(key)
    if prog is not None:
        _HITS += 1
        _PROGRAMS.move_to_end(key)
        return prog
    _MISSES += 1
    return None


def program_cache_put(key, prog) -> None:
    _PROGRAMS[key] = prog
    _evict_to_capacity()


def set_schedule_cache_capacity(capacity: int) -> None:
    """Bound the compiled-schedule cache to `capacity` entries (>= 1);
    least-recently-used programs are evicted once the bound is exceeded."""
    global _CAPACITY
    if capacity < 1:
        raise opset.CimOpError(f"cache capacity must be >= 1, got {capacity}")
    _CAPACITY = int(capacity)
    _evict_to_capacity()


def _evict_to_capacity() -> None:
    global _EVICTIONS
    while len(_PROGRAMS) > _CAPACITY:
        _PROGRAMS.popitem(last=False)
        _EVICTIONS += 1


def _cached_program(ops: Tuple[str, ...], n_bits: int, tile_shape: tuple,
                    bk: Backend, mesh, axis: Optional[str]):
    """The jitted tiled program for one schedule key.

    Without the cache every call would close over a fresh lambda and retrace
    under jit; with it, a repeated (ops, n_bits, tile_shape, backend[,mesh])
    schedule reuses the compiled executable. The table is a bounded LRU:
    a hit refreshes recency, an insert past capacity evicts the coldest
    program (it recompiles on next use — correctness never depends on
    residency)."""
    # the mesh object itself (hashable) is the key component: two meshes of
    # identical shape over DIFFERENT devices must not share a program
    key = (ops, n_bits, tile_shape, bk.name,
           None if mesh is None else (mesh, axis))
    prog = program_cache_get(key)
    if prog is not None:
        return prog

    prog = jax.jit(_tiled_body(ops, bk, mesh, axis))
    program_cache_put(key, prog)
    return prog


def _tiled_body(ops: Tuple[str, ...], bk: Backend, mesh, axis):
    """The (unjitted) tiled computation: vmap the fused backend over the
    tile axis, shard_mapped over `axis` when a mesh is given. Shared by the
    eager per-step program above and the traced whole-schedule path below
    (where the enclosing step program provides the jit)."""

    def tiled(ta, tb):
        return jax.vmap(lambda ap, bp: bk.fn(ap, bp, ops))(ta, tb)

    if mesh is None:
        return tiled
    from jax.sharding import PartitionSpec as P

    spec3 = P(axis, None, None)
    return _shard_map(tiled, mesh, in_specs=(spec3, spec3),
                      out_specs=tuple(spec3 for _ in ops))


# ---------------------------------------------------------------------------
# tile / untile (packed lane axis, uint32 boundaries)
# ---------------------------------------------------------------------------


def _tile(planes: jax.Array, plan: TilePlan, n_tiles: int) -> jax.Array:
    """uint32[n_bits, W] -> uint32[n_tiles, n_bits, lanes_per_tile]."""
    n_bits, w = planes.shape
    pad = n_tiles * plan.lanes_per_tile - w
    if pad:
        planes = jnp.pad(planes, ((0, 0), (0, pad)))
    return planes.reshape(n_bits, n_tiles, plan.lanes_per_tile) \
                 .transpose(1, 0, 2)


def _untile(raw: jax.Array, w: int) -> jax.Array:
    """uint32[n_tiles, rows, lanes] -> uint32[rows, W] (pad lanes dropped)."""
    n_tiles, rows, lanes = raw.shape
    return raw.transpose(1, 0, 2).reshape(rows, n_tiles * lanes)[:, :w]


# ---------------------------------------------------------------------------
# the dispatcher
# ---------------------------------------------------------------------------


def _prepare_tiles(a: PlanePack, b: PlanePack, ops: Sequence[str],
                   spec: Optional[ArraySpec], mesh, axis: str):
    """Shared front half of the tiled paths: operand alignment, geometry
    checks, tile placement and the padded tile stacks."""
    a, b, ops = engine.prepare_operands(a, b, ops)
    spec = spec or DEFAULT_SPEC
    # combined budget: access planes must fit alongside whatever the
    # process-wide resident region for this geometry has pinned in rows
    spec.check_fits(a.n_bits, ops,
                    resident_rows=array_mod.resident_rows_for(spec))
    plan = spec.plan(a.n_words)

    n_devices = 1
    exec_tiles = plan.n_tiles
    if mesh is not None:
        if axis not in mesh.axis_names:
            raise opset.CimOpError(
                f"mesh has axes {mesh.axis_names}, no {axis!r}")
        n_devices = int(mesh.shape[axis])
        # block placement: pad the tile axis so every device owns the same
        # number of tiles; pad tiles hold no operands and are not charged
        exec_tiles = -(-plan.n_tiles // n_devices) * n_devices

    ta = _tile(a.planes, plan, exec_tiles)
    tb = _tile(b.planes, plan, exec_tiles)
    return a, b, ops, plan, n_devices, ta, tb


def _fault_overlay(a: PlanePack, b: PlanePack, plan: TilePlan,
                   ta, tb, exec_tiles: int):
    """Transient-fault injection on the STREAMED operands of one eager
    tiled access (BER flips + stuck-at rows of the active FaultModel).
    Faults are injected only on concrete values — inside a trace the
    operands pass through untouched (a flip baked into a compiled program
    would replay forever, which is not a fault model)."""
    from . import faults as faults_mod

    fm = faults_mod.active()
    if fm is None or (fm.config.ber <= 0.0 and not fm.config.stuck):
        return a, b, ta, tb
    if isinstance(a.planes, jax.core.Tracer) \
            or isinstance(b.planes, jax.core.Tracer):
        return a, b, ta, tb
    import dataclasses as _dc

    import numpy as np

    pa, na = fm.corrupt_streamed(np.asarray(a.planes), plan)
    pb, nb = fm.corrupt_streamed(np.asarray(b.planes), plan)
    if na:
        a = _dc.replace(a, planes=jnp.asarray(pa))
        ta = _tile(a.planes, plan, exec_tiles)
    if nb:
        b = _dc.replace(b, planes=jnp.asarray(pb))
        tb = _tile(b.planes, plan, exec_tiles)
    return a, b, ta, tb


def _wrap_tiled(a: PlanePack, ops: Tuple[str, ...],
                raws) -> engine.Outputs:
    w = a.planes.shape[1]
    return {op: engine._wrap(op, _untile(raw, w), a.n_bits, a.shape)
            for op, raw in zip(ops, raws)}


def execute_tiled(a: PlanePack, b: PlanePack, ops: Sequence[str],
                  spec: Optional[ArraySpec] = None,
                  backend: Optional[str] = None,
                  mesh=None, axis: str = "data") -> engine.Outputs:
    """One logical ADRA access on a banked array: bank-sized tiles, vmapped
    (and, with `mesh`, shard_mapped over its `axis`) over the fused backend.

    Bit-exact with engine.execute; the difference is physical: the ledger is
    charged one activation per tile, attributed to (device, bank), and the
    last tile's idle columns are charged as activated-but-idle words.
    """
    a, b, ops, plan, n_devices, ta, tb = _prepare_tiles(
        a, b, ops, spec, mesh, axis)
    a, b, ta, tb = _fault_overlay(a, b, plan, ta, tb,
                                  exec_tiles=ta.shape[0])
    bk = get_backend(backend)
    prog = _cached_program(ops, a.n_bits, tuple(ta.shape[1:]), bk,
                           mesh, axis if mesh is not None else None)
    raws = prog(ta, tb)
    count_dispatch()      # invoke first, account after (as CompiledSchedule)

    LEDGER.charge_banked(ops, a.n_bits, a.n_words, plan,
                         n_devices=n_devices)
    return _wrap_tiled(a, ops, raws)


def execute_tiled_traced(a: PlanePack, b: PlanePack, ops: Sequence[str],
                         spec: Optional[ArraySpec] = None,
                         backend: Optional[str] = None,
                         mesh=None, axis: str = "data",
                         charges: Optional[list] = None) -> engine.Outputs:
    """The side-effect-free inner form of `execute_tiled`: the same tiled
    (and shard_mapped) computation applied INLINE — no inner jit, no ledger
    mutation — so a whole-schedule step program can trace banked accesses
    into one XLA dispatch. With `charges`, appends the charge-from-plan
    record `execute_tiled` would have applied."""
    a, b, ops, plan, n_devices, ta, tb = _prepare_tiles(
        a, b, ops, spec, mesh, axis)
    bk = get_backend(backend)
    raws = _tiled_body(ops, bk, mesh, axis if mesh is not None else None)(
        ta, tb)
    if charges is not None:
        charges.append(("banked", ops, a.n_bits, a.n_words, plan, n_devices))
    return _wrap_tiled(a, ops, raws)


def execute_sharded(a: PlanePack, b: PlanePack, ops: Sequence[str], mesh,
                    spec: Optional[ArraySpec] = None,
                    backend: Optional[str] = None,
                    axis: str = "data") -> engine.Outputs:
    """`execute_tiled` with a mandatory mesh (the multi-device entry point —
    make_smoke_mesh / make_production_mesh from repro.launch.mesh)."""
    return execute_tiled(a, b, ops, spec=spec, backend=backend,
                         mesh=mesh, axis=axis)

"""The unified CiM engine: one dispatch point for every ADRA operation.

`execute` runs any subset of the op catalogue (opset.ALL_OPS) over two
PlanePacks in ONE simulated memory access on the selected backend, returning
PlanePacks — so chained ops stay in the packed bit-plane domain with zero
intermediate pack/unpack. `execute_unfused` is the near-memory baseline (one
access per pass) the paper argues against; benchmarks compare the two.

Integer-level convenience wrappers (add / sub / compare / boolean) pack,
execute, and unpack for call sites that live in ordinary integer arrays.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Sequence, Tuple

import jax

from . import opset
from .accounting import LEDGER
from .backends import get_backend
from .planepack import PlanePack

Outputs = Dict[str, PlanePack]


def _wrap(op: str, raw: jax.Array, n_bits: int,
          shape: Tuple[int, ...]) -> PlanePack:
    rows = opset.out_rows(op, n_bits)
    assert raw.shape[0] == rows, (op, raw.shape, rows)
    return PlanePack(planes=raw, n_bits=rows, signed=opset.out_signed(op),
                     shape=shape)


def prepare_operands(a: PlanePack, b: PlanePack, ops: Sequence[str]
                     ) -> Tuple[PlanePack, PlanePack, Tuple[str, ...]]:
    """Validate an op request and align its operands in the packed domain.

    Shared by the single-array `execute` below and the banked tiling
    dispatcher (repro.cim.dispatch), so both paths see identical widening."""
    ops = opset.validate_ops(tuple(ops))
    if a.shape != b.shape:
        raise opset.CimOpError(f"operand shapes differ: {a.shape} vs {b.shape}")
    a, b = a.align(b)
    if (opset.needs_add_chain(ops) or opset.needs_sub_chain(ops)) \
            and not (a.signed and b.signed):
        # the ripple chains interpret operands as two's complement (the
        # overflow module sign-extends the MSB plane); widen by one plane —
        # zero for unsigned, sign replica for signed — so unsigned magnitudes
        # with the top bit set cannot be misread as negative
        n = a.n_bits + 1
        a, b = a.extend_to(n), b.extend_to(n)
    return a, b, ops


def execute_traced(a: PlanePack, b: PlanePack, ops: Sequence[str],
                   backend: Optional[str] = None,
                   charges: Optional[list] = None) -> Outputs:
    """The side-effect-free inner form of `execute`: pure computation, no
    ledger mutation, so a whole schedule of these can be traced into ONE
    jitted XLA program (repro.cim.macro.run_schedule_program).

    With `charges`, the access this call represents is appended as a
    charge-from-plan record — ("access", ops, n_bits, n_words) at the
    post-alignment width, exactly what `execute` would have charged — for
    the compiled program to replay per invocation (accounting.PlannedCharges).
    """
    a, b, ops = prepare_operands(a, b, ops)
    bk = get_backend(backend)
    raws = bk(a.planes, b.planes, ops)
    if charges is not None:
        charges.append(("access", ops, a.n_bits, a.n_words))
    return {op: _wrap(op, raw, a.n_bits, a.shape)
            for op, raw in zip(ops, raws)}


def execute(a: PlanePack, b: PlanePack, ops: Sequence[str],
            backend: Optional[str] = None) -> Outputs:
    """One ADRA access: every requested op from a single streamed pass.

    Operands of different widths are sign/zero-extended in the packed domain
    first. Returns {op: PlanePack}; predicates come back as 1-plane unsigned
    packs (unpack() gives 0/1 per word).
    """
    a, b = _fault_overlay(a, b)
    charges: list = []
    out = execute_traced(a, b, ops, backend=backend, charges=charges)
    for _, c_ops, n_bits, n_words in charges:
        LEDGER.charge(c_ops, n_bits, n_words, accesses=1)
    return out


def _fault_overlay(a: PlanePack, b: PlanePack
                   ) -> Tuple[PlanePack, PlanePack]:
    """Transient BER injection on the streamed operands of one eager
    access (the untiled path has no bank placement, so stuck-at rows do
    not apply here). Concrete values only — tracers pass untouched."""
    from . import faults as faults_mod

    fm = faults_mod.active()
    if fm is None or fm.config.ber <= 0.0:
        return a, b
    if isinstance(a.planes, jax.core.Tracer) \
            or isinstance(b.planes, jax.core.Tracer):
        return a, b
    import dataclasses as _dc

    import jax.numpy as jnp
    import numpy as np

    pa, na = fm.corrupt_streamed(np.asarray(a.planes))
    pb, nb = fm.corrupt_streamed(np.asarray(b.planes))
    if na:
        a = _dc.replace(a, planes=jnp.asarray(pa))
    if nb:
        b = _dc.replace(b, planes=jnp.asarray(pb))
    return a, b


def execute_unfused(a: PlanePack, b: PlanePack,
                    passes: Sequence[Sequence[str]],
                    backend: Optional[str] = None) -> Outputs:
    """Near-memory baseline: one FULL access per pass, operands re-streamed
    each time (the paper's two-access execution, generalized to k passes)."""
    out: Outputs = {}
    for ops in passes:
        out.update(execute(a, b, ops, backend=backend))
    return out


# ---------------------------------------------------------------------------
# integer-level wrappers
# ---------------------------------------------------------------------------


class CmpOut(NamedTuple):
    lt: jax.Array
    eq: jax.Array
    gt: jax.Array


def add(x: jax.Array, y: jax.Array, n_bits: int = 32,
        backend: Optional[str] = None) -> jax.Array:
    """x + y via one ADRA access. The engine emits the full (n+1)-plane
    result; unpack() materializes it as int32, so values are exact for
    n_bits < 32 and wrap modulo 2^32 at n_bits = 32 (int32 semantics).
    Callers needing the wider planes should use execute() directly."""
    out = execute(PlanePack.pack(x, n_bits), PlanePack.pack(y, n_bits),
                  ("add",), backend=backend)
    return out["add"].unpack()


def sub(x: jax.Array, y: jax.Array, n_bits: int = 32,
        backend: Optional[str] = None) -> jax.Array:
    """x - y via one ADRA access (the paper's non-commutative headline)."""
    out = execute(PlanePack.pack(x, n_bits), PlanePack.pack(y, n_bits),
                  ("sub",), backend=backend)
    return out["sub"].unpack()


def compare(x: jax.Array, y: jax.Array, n_bits: int = 32,
            backend: Optional[str] = None) -> CmpOut:
    """Single-access comparison: lt/eq/gt 0/1 arrays of the operand shape."""
    out = execute(PlanePack.pack(x, n_bits), PlanePack.pack(y, n_bits),
                  ("lt", "eq", "gt"), backend=backend)
    return CmpOut(lt=out["lt"].unpack(), eq=out["eq"].unpack(),
                  gt=out["gt"].unpack())


def boolean(x: jax.Array, y: jax.Array, fn: str, n_bits: int = 32,
            backend: Optional[str] = None) -> jax.Array:
    """Any of the 16 two-input Boolean functions, one access."""
    if fn not in opset.BOOLEAN_OPS:
        raise opset.CimOpError(
            f"unknown Boolean function {fn!r}; valid: {opset.BOOLEAN_OPS}")
    out = execute(PlanePack.pack(x, n_bits), PlanePack.pack(y, n_bits),
                  (fn,), backend=backend)
    return out[fn].unpack()


# ---------------------------------------------------------------------------
# HBM traffic: the roofline argument, modeled and measured
# ---------------------------------------------------------------------------


def traffic_model_bytes(n_bits: int, n_words32: int,
                        ops: Sequence[str] = ("sub", "carry_sub", "lt", "eq"),
                        baseline_passes: Optional[Sequence[Sequence[str]]] = None,
                        ) -> Dict[str, float]:
    """HBM bytes of one fused pass vs per-pass baseline re-reads.

    The memory-roofline analogue of the paper's one-vs-two access argument:
    the baseline re-streams both operand stacks for every pass."""
    ops = opset.validate_ops(tuple(ops))
    if baseline_passes is None:
        baseline_passes = tuple((op,) for op in ops)
    plane_bytes = 4 * n_words32
    ops_in = 2 * n_bits * plane_bytes
    out_bytes = {op: opset.out_rows(op, n_bits) * plane_bytes for op in ops}
    fused = ops_in + sum(out_bytes.values())
    baseline = sum(ops_in + sum(out_bytes[o] for o in p)
                   for p in baseline_passes)
    return {"fused": float(fused), "baseline": float(baseline),
            "ratio": baseline / fused}


def measured_traffic_bytes(a: PlanePack, b: PlanePack, ops: Sequence[str],
                           baseline_passes: Optional[Sequence[Sequence[str]]] = None,
                           backend: Optional[str] = None) -> Dict[str, float]:
    """Like traffic_model_bytes, but measured from the buffers the backend
    program ACTUALLY streams: operand + result bytes per pass, read off the
    abstractly-evaluated backend call (no execution, no ledger charge)."""
    ops = opset.validate_ops(tuple(ops))
    if baseline_passes is None:
        baseline_passes = tuple((op,) for op in ops)
    a, b = a.align(b)
    in_bytes = a.planes.nbytes + b.planes.nbytes
    bk = get_backend(backend)

    def pass_bytes(pass_ops):
        outs = jax.eval_shape(
            lambda ap, bp: bk(ap, bp, tuple(pass_ops)), a.planes, b.planes)
        out_bytes = 0
        for o in jax.tree_util.tree_leaves(outs):
            n = 1
            for d in o.shape:
                n *= int(d)
            out_bytes += n * o.dtype.itemsize
        return in_bytes + out_bytes

    fused = pass_bytes(ops)
    baseline = sum(pass_bytes(p) for p in baseline_passes)
    return {"fused": float(fused), "baseline": float(baseline),
            "ratio": baseline / fused}

"""Seeded deterministic fault injection for the CiM substrate.

FeFET arrays fail in characteristic ways: transient sensing upsets (a bit
flips during one access), retention decay (pinned nonvolatile rows leak
charge over seconds), stuck-at rows (a wordline driver welded to 0/1) and
whole-bank failures (a shared driver or sense-amp block dies). This module
models all four as an OVERLAY the rest of the stack opts into:

  * `install(FaultModel)` arms a process-wide model; `active()` is what the
    eager execution paths (`engine.execute`, `dispatch.execute_tiled`) and
    the resident region (`ResidentSet.get` / `scrub`) consult. With nothing
    installed every hook is a None-check — zero cost, zero behavior change.
  * Transient faults are injected ONLY at eager Python call time, never
    inside a traced program: a flip baked into a compiled XLA program would
    replay identically on every invocation, which is not a fault model.
    Resident-plane faults always qualify (pins are concrete by
    construction), which is where ECC protection lives.
  * Everything is deterministic: one `numpy` PCG64 generator seeded from
    `FaultConfig.seed` (default: the `REPRO_CIM_FAULT_SEED` env var),
    advanced monotonically per injection site. The same seed and the same
    call sequence produce the same faults — chaos tests are replayable.

Counters (injected / detected / corrected / uncorrected) are charged into
the accounting Ledger (`charge_fault`) AND aggregated process-wide here, so
`dispatch.cache_stats()` answers "did the run take faults and did ECC hold"
next to its cache/residency counters.

The same seed convention covers the training side: `host_failure_hook`
builds the `fault_hook` callables `runtime.supervisor.Supervisor` restarts
on (raising `SimulatedHostFailure`), so serving chaos tests and training
chaos tests share one `REPRO_CIM_FAULT_SEED`.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from . import opset
from .accounting import LEDGER

#: env vars of the shared fault-seed convention (serving + training chaos)
ENV_SEED = "REPRO_CIM_FAULT_SEED"
ENV_BER = "REPRO_CIM_FAULT_BER"
ENV_RESIDENT_BER = "REPRO_CIM_FAULT_RESIDENT_BER"
ENV_RETENTION = "REPRO_CIM_FAULT_RETENTION"


class UncorrectableFaultError(opset.CimOpError):
    """An ECC verify found more errors than SECDED can repair and the
    installed FaultModel asked for fail-stop semantics. The stale entry has
    already been invalidated; re-running the step re-pins from the source
    (the serve engine's repair loop does exactly that)."""


def fault_seed(default: int = 0) -> int:
    """The process fault seed: REPRO_CIM_FAULT_SEED, else `default`."""
    raw = os.environ.get(ENV_SEED)
    if raw is None:
        return default
    try:
        return int(raw)
    except ValueError:
        return default


def _env_float(name: str, default: float = 0.0) -> float:
    raw = os.environ.get(name)
    if raw is None:
        return default
    try:
        return float(raw)
    except ValueError:
        return default


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Knobs of one deterministic fault campaign.

    ber           : per-bit flip probability on each STREAMED operand of an
                    eager access (engine.execute / dispatch.execute_tiled) —
                    unprotected: silent data corruption, counted `injected`.
    resident_ber  : per-bit flip probability applied to a pinned entry's
                    plane stack on every resident `get` — the ECC-protected
                    surface (verify runs right after injection).
    retention_per_s : expected plane-bit flips per second pinned, applied by
                    the periodic scrub pass (decay of nonvolatile rows).
    stuck          : ((bank, plane, value), ...) stuck-at rows forced on
                    streamed tiled accesses of the named bank.
    kill_bank_at  : (decode_step, bank) — `on_step(step)` marks `bank` dead
                    once `step` is reached (the serve chaos harness's
                    mid-run bank kill).
    raise_on_uncorrectable : fail-stop ECC semantics — `ResidentSet.get`
                    raises UncorrectableFaultError instead of silently
                    invalidate-and-miss (the serve repair loop installs
                    this to count explicit repairs).
    uncorrectable_at_verify : verify indices (0-based, process order) hit
                    with a forced double-flip in one column — deterministic
                    trigger for the invalidate/repair paths.
    """

    seed: int = 0
    ber: float = 0.0
    resident_ber: float = 0.0
    retention_per_s: float = 0.0
    stuck: Tuple[Tuple[int, int, int], ...] = ()
    kill_bank_at: Optional[Tuple[int, int]] = None
    raise_on_uncorrectable: bool = False
    uncorrectable_at_verify: Tuple[int, ...] = ()

    @classmethod
    def from_env(cls, **overrides) -> "FaultConfig":
        base = dict(seed=fault_seed(), ber=_env_float(ENV_BER),
                    resident_ber=_env_float(ENV_RESIDENT_BER),
                    retention_per_s=_env_float(ENV_RETENTION))
        base.update(overrides)
        return cls(**base)


class FaultModel:
    """One seeded fault campaign: deterministic injection + counters."""

    def __init__(self, config: Optional[FaultConfig] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.config = config or FaultConfig()
        self.clock = clock
        self.rng = np.random.Generator(np.random.PCG64(self.config.seed))
        self.dead_banks: Tuple[int, ...] = ()
        self.injected = 0          # bits flipped into live data
        self.detected = 0          # bits ECC saw (corrected + uncorrected)
        self.corrected = 0
        self.uncorrected = 0
        self.verifies = 0          # ECC verify passes executed
        self.bank_kills = 0

    # -- bank lifecycle ------------------------------------------------------

    def kill_bank(self, bank: int) -> None:
        if bank not in self.dead_banks:
            self.dead_banks = self.dead_banks + (int(bank),)
            self.bank_kills += 1

    def on_step(self, step: int) -> None:
        """Advance scheduled faults to `step` (the serve loop's clock)."""
        ka = self.config.kill_bank_at
        if ka is not None and step >= ka[0]:
            self.kill_bank(ka[1])

    # -- plane corruption ----------------------------------------------------

    def _flip_planes(self, planes: np.ndarray, ber: float) -> Tuple[
            np.ndarray, int]:
        """Flip ~Binomial(total_bits, ber) uniformly-placed bits."""
        total_bits = planes.size * 32
        n = int(self.rng.binomial(total_bits, ber)) if ber > 0 else 0
        if n == 0:
            return planes, 0
        out = np.array(planes, dtype=np.uint32, copy=True)
        idx = self.rng.integers(0, total_bits, size=n)
        flat = out.reshape(-1)
        for i in np.asarray(idx):
            flat[i // 32] ^= np.uint32(1) << np.uint32(i % 32)
        return out, n

    def corrupt_streamed(self, planes, plan=None) -> Tuple[np.ndarray, int]:
        """Transient faults on one streamed operand of an eager access:
        BER flips plus stuck-at rows of the banks `plan` places tiles on.
        Returns (possibly new) planes and the number of bits injected."""
        arr = np.asarray(planes, dtype=np.uint32)
        arr, n = self._flip_planes(arr, self.config.ber)
        if self.config.stuck and plan is not None:
            arr = np.array(arr, dtype=np.uint32, copy=True)
            lanes = plan.lanes_per_tile
            for bank, plane, value in self.config.stuck:
                if plane >= arr.shape[0]:
                    continue
                for t in range(plan.n_tiles):
                    if plan.bank_of(t) != bank:
                        continue
                    lo = t * lanes
                    hi = min((t + 1) * lanes, arr.shape[1])
                    if lo >= arr.shape[1]:
                        break
                    before = arr[plane, lo:hi].copy()
                    arr[plane, lo:hi] = np.uint32(0xFFFFFFFF if value else 0)
                    n += _bit_delta(before, arr[plane, lo:hi])
        if n:
            self.injected += n
            _STATS["fault_injected"] += n
            LEDGER.charge_fault(injected=n)
        return arr, n

    def corrupt_resident(self, planes) -> Tuple[np.ndarray, int]:
        """Per-`get` decay on a pinned entry's planes (ECC territory)."""
        arr = np.asarray(planes, dtype=np.uint32)
        arr, n = self._flip_planes(arr, self.config.resident_ber)
        if self.verifies in self.config.uncorrectable_at_verify \
                and arr.shape[0] >= 2:
            # forced double error in one column: same lane bit, two planes
            arr = np.array(arr, dtype=np.uint32, copy=True)
            arr[0, 0] ^= np.uint32(1)
            arr[1, 0] ^= np.uint32(1)
            n += 2
        if n:
            self.injected += n
            _STATS["fault_injected"] += n
            LEDGER.charge_fault(injected=n)
        return arr, n

    def decay_bits(self, seconds: float, total_bits: int) -> int:
        """Retention-decay flips accumulated over `seconds` pinned."""
        lam = self.config.retention_per_s * max(0.0, seconds)
        if lam <= 0.0:
            return 0
        return min(int(self.rng.poisson(lam)), total_bits)

    # -- ECC outcome accounting ---------------------------------------------

    def record_verify(self, corrected: int, uncorrected: int) -> None:
        self.verifies += 1
        _STATS["fault_verifies"] += 1
        if corrected:
            self.corrected += corrected
            self.detected += corrected
            _STATS["fault_corrected"] += corrected
            _STATS["fault_detected"] += corrected
        if uncorrected:
            self.uncorrected += uncorrected
            self.detected += uncorrected
            _STATS["fault_uncorrected"] += uncorrected
            _STATS["fault_detected"] += uncorrected
        LEDGER.charge_fault(detected=corrected + uncorrected,
                            corrected=corrected, uncorrected=uncorrected)

    def stats(self) -> Dict[str, int]:
        return {"injected": self.injected, "detected": self.detected,
                "corrected": self.corrected,
                "uncorrected": self.uncorrected,
                "verifies": self.verifies, "bank_kills": self.bank_kills,
                "dead_banks": list(self.dead_banks)}


def _bit_delta(before: np.ndarray, after: np.ndarray) -> int:
    return int(np.unpackbits((before ^ after).view(np.uint8)).sum())


# ---------------------------------------------------------------------------
# the process-wide overlay
# ---------------------------------------------------------------------------

_ACTIVE: Optional[FaultModel] = None

#: process-wide counters surfaced through dispatch.cache_stats()
_STATS: Dict[str, int] = {}


def _reset_stats() -> None:
    _STATS.update(fault_injected=0, fault_detected=0, fault_corrected=0,
                  fault_uncorrected=0, fault_verifies=0)


_reset_stats()


def install(model: FaultModel) -> FaultModel:
    """Arm `model` as the process fault overlay (replacing any other)."""
    global _ACTIVE
    _ACTIVE = model
    return model


def uninstall() -> None:
    global _ACTIVE
    _ACTIVE = None


def active() -> Optional[FaultModel]:
    return _ACTIVE


def fault_stats() -> Dict[str, int]:
    """Aggregated process-wide injection/ECC counters (cache_stats rides)."""
    return dict(_STATS)


def reset_fault_stats() -> None:
    _reset_stats()


class faults:
    """Context manager: install a FaultModel for a with-block.

        with faults(FaultConfig(seed=7, resident_ber=1e-3)) as fm:
            ...
    """

    def __init__(self, config_or_model, clock=time.monotonic):
        self.model = config_or_model if isinstance(config_or_model,
                                                   FaultModel) \
            else FaultModel(config_or_model, clock=clock)
        self._prev: Optional[FaultModel] = None

    def __enter__(self) -> FaultModel:
        global _ACTIVE
        self._prev = _ACTIVE
        _ACTIVE = self.model
        return self.model

    def __exit__(self, *exc) -> None:
        global _ACTIVE
        _ACTIVE = self._prev
        return None


# ---------------------------------------------------------------------------
# the training side of the shared seed convention
# ---------------------------------------------------------------------------


def host_failure_hook(fail_steps: Tuple[int, ...] = (),
                      p_fail: float = 0.0,
                      seed: Optional[int] = None
                      ) -> Callable[[int], None]:
    """A `Supervisor(fault_hook=...)` callable under the shared convention.

    Raises SimulatedHostFailure at every step in `fail_steps`, plus with
    probability `p_fail` per step — decided by a generator seeded from
    (seed or REPRO_CIM_FAULT_SEED, step), so a given (seed, step) either
    always fails or never does: restarts replay deterministically, which is
    what makes the supervisor's restart-exact guarantee testable."""
    from repro.runtime.supervisor import SimulatedHostFailure

    base = fault_seed() if seed is None else int(seed)
    fail = frozenset(int(s) for s in fail_steps)
    fired = set()

    def hook(step: int) -> None:
        if step in fail and step not in fired:
            fired.add(step)
            raise SimulatedHostFailure(
                f"injected host failure at step {step} (seed {base})")
        if p_fail > 0.0 and step not in fired:
            g = np.random.Generator(np.random.PCG64((base, int(step))))
            if g.random() < p_fail:
                fired.add(step)
                raise SimulatedHostFailure(
                    f"injected host failure at step {step} (seed {base})")

    return hook

"""Generalized Pallas TPU kernel: ANY subset of the CiM op catalogue from ONE
streamed pass over both bit-plane stacks.

This is the TPU analogue of the paper's full peripheral: the three sense
amplifiers + OAI21 gate expose {OR, AND, B, A} per bit from a single memory
access, and the dual-output compute modules ripple BOTH the addition and the
subtraction chains in the same cycle. Here the plane stacks stream HBM->VMEM
exactly once, and every requested output — add/sub plane stacks, carry-outs,
lt/eq/gt bitmaps, any of the 16 Boolean function plane stacks — is emitted
from that one pass with pure VPU bitwise ops.

The near-memory baseline (what the paper beats) is one pass PER function,
re-reading the operands each time; the engine exposes it for benchmarks via
`repro.cim.engine.execute_unfused`.

Layout:  a_planes, b_planes : uint32[n_bits, n_words32]
Grid:    1-D over lane blocks; the whole bit dim stays resident in VMEM
         (a 33-plane f32-width stack at block_w=512 is ~66 KiB per ref,
         well inside the ~16 MiB VMEM budget; MXU-free, pure VPU).

The op request is STATIC: each distinct subset specializes its own kernel, so
unrequested outputs cost neither VMEM nor HBM writeback.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import opset

DEFAULT_BLOCK_W = 512  # lane-dim block (multiple of 128 for VPU alignment)


def _fused_kernel(a_ref, b_ref, *out_refs, ops: Tuple[str, ...]):
    """One word block: single streamed pass, all requested outputs.

    a_ref/b_ref: uint32[n_bits, bw]; out_refs ordered as `ops`
    (arith: [n_bits+1, bw]; predicates: [1, bw]; boolean fns: [n_bits, bw]).
    """
    n_bits = a_ref.shape[0]
    bw = a_ref.shape[1]
    out = dict(zip(ops, out_refs))
    need_add = opset.needs_add_chain(ops)
    need_sub = opset.needs_sub_chain(ops)
    bool_fns = tuple(o for o in ops if o in opset.BOOLEAN_OPS)

    zeros = jnp.zeros((bw,), jnp.uint32)
    ones = ~zeros

    def module(i, state):
        carry_a, carry_s, nz = state
        a = a_ref[i, :]
        b = b_ref[i, :]
        # the single-access signal set (3 SAs + OAI21), plane-wise
        or_ = a | b
        and_ = a & b
        a_rec = opset.oai21_recover_a_planes(or_, and_, b)
        for fn in bool_fns:
            out[fn][i, :] = opset.boolean_plane(fn, or_, and_, b, a_rec)
        xor = or_ & ~and_                       # half-sum (addition)
        if need_add:
            s = xor ^ carry_a
            if "add" in out:
                out["add"][i, :] = s
            carry_a = and_ | (carry_a & xor)    # generate | propagate
        if need_sub:
            xnor = ~xor                         # half-sum with B inverted
            a_nb = or_ & ~b                     # generate term A * NOT(B)
            s = xnor ^ carry_s
            if "sub" in out:
                out["sub"][i, :] = s
            carry_s = a_nb | (carry_s & xnor)
            nz = nz | s                         # OR tree for the zero detect
        return carry_a, carry_s, nz

    # C_IN(0): 0 for addition, 1 for subtraction (A - B = A + ~B + 1)
    carry_a, carry_s, nz = jax.lax.fori_loop(
        0, n_bits, module, (zeros, ones, zeros))

    # (n+1)-th compute module: sign-extended inputs (paper Sec. III-B)
    a_msb = a_ref[n_bits - 1, :]
    b_msb = b_ref[n_bits - 1, :]
    if need_add:
        xor = a_msb ^ b_msb
        s_ext = xor ^ carry_a
        if "add" in out:
            out["add"][n_bits, :] = s_ext
        if "carry_add" in out:
            out["carry_add"][0, :] = (a_msb & b_msb) | (carry_a & xor)
    if need_sub:
        nb = ~b_msb
        xnor = a_msb ^ nb
        s_ext = xnor ^ carry_s
        nz = nz | s_ext
        if "sub" in out:
            out["sub"][n_bits, :] = s_ext
        if "carry_sub" in out:
            out["carry_sub"][0, :] = (a_msb & nb) | (carry_s & xnor)
        if "lt" in out:
            out["lt"][0, :] = s_ext             # sign of the (n+1)-bit A-B
        if "eq" in out:
            out["eq"][0, :] = ~nz               # AND tree over ~SUM bits
        if "gt" in out:
            out["gt"][0, :] = ~s_ext & nz       # not lt, not eq


@functools.partial(jax.jit, static_argnames=("ops", "block_w", "interpret"))
def fused_planes_op(
    a_planes: jax.Array,
    b_planes: jax.Array,
    ops: Tuple[str, ...],
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = False,
) -> Tuple[jax.Array, ...]:
    """Run the fused kernel; returns one array per requested op, in order."""
    ops = opset.validate_ops(ops)
    n_bits, w = a_planes.shape
    assert b_planes.shape == (n_bits, w), (a_planes.shape, b_planes.shape)
    pad = (-w) % block_w
    if pad:
        a_planes = jnp.pad(a_planes, ((0, 0), (0, pad)))
        b_planes = jnp.pad(b_planes, ((0, 0), (0, pad)))
    wp = a_planes.shape[1]

    grid = (wp // block_w,)
    rows = [opset.out_rows(op, n_bits) for op in ops]
    out_shapes = tuple(
        jax.ShapeDtypeStruct((r, wp), jnp.uint32) for r in rows)
    plane_spec = pl.BlockSpec((n_bits, block_w), lambda i: (0, i))
    out_specs = tuple(
        pl.BlockSpec((r, block_w), lambda i: (0, i)) for r in rows)

    outs = pl.pallas_call(
        functools.partial(_fused_kernel, ops=ops),
        grid=grid,
        in_specs=[plane_spec, plane_spec],
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(a_planes, b_planes)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    return tuple(o[:, :w] for o in outs)

"""The jaxpr -> CiM lowering compiler: offload ESTIMATES become EXECUTION.

`lower(fn)` turns an unmodified JAX function into a hybrid callable:

  1. `repro.cim.trace` stages the function and classifies every eqn
     (single-access / multi-access / free peripheral / host).
  2. Maximal runs of eligible eqns become fused REGIONS. Each region's
     per-eqn schedules are concatenated (planner.concat_schedules) into ONE
     region Schedule, compiled by macro.run_schedule_program into ONE
     jitted XLA program: every access of every fused eqn, all the
     packed-domain peripherals between them, the entry packs and the exit
     unpacks execute as a single dispatch. Chained eligible ops share the
     program's cursor (a ChainExecutor over it) and their intermediates
     stay in the PlanePack packed domain with ZERO pack/unpack between
     them. Region programs live in the dispatch layer's bounded-LRU cache
     under a STRUCTURAL key (canonicalized dataflow + operand signatures),
     so repeated regions hit end-to-end with zero retrace; ledger charges
     replay from the trace-time PlannedCharges record. Region inputs that
     are dead after the region (intermediates, never the caller's arrays)
     are donated to the program on accelerator platforms, letting XLA reuse
     their buffers for the accumulator chain.
  3. Everything else executes on the host, eqn by eqn, exactly as
     `jax.core.eval_jaxpr` would.

The hybrid callable is bit-exact with the original function: every CiM op
result is truncated/extended to its eqn's output dtype in the packed domain
(free peripheral wiring), so int8 wrap-around, unsigned arithmetic and bool
predicates all match jnp semantics — asserted across the full eligible op
surface by tests/test_cim_lower.py.

Cost model contract: the region schedules ARE the cost. An unbanked run
charges the ledger exactly `sum(region.schedule.accesses)` accesses — the
same number `repro.core.offload.analyze(fn, *args)` (source="jaxpr")
reports, because both read the same trace. With an ArraySpec, every access
tiles over banks through repro.cim.dispatch and the ledger charges per
(device, bank) activations instead.

The one declared exception to zero-repack: a `dot_general` consumes
MATERIALIZED integer operands (the broadcast [M, K_pad, N] layout has to be
built, exactly as in repro.cim.macro.matmul), so a packed in-region operand
feeding a contraction is unpacked first. Elementwise chains never repack.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import cost as cost_mod
from . import macro, planner
from . import array as array_mod
from . import trace as trace_mod
from .array import ArraySpec
from .opset import CimOpError
from .planepack import PlanePack
from .trace import CMP_PRIMS, ConstVal, TracedOp, aval_of, dtype_bits, dtype_signed

_FULL32 = np.uint32(0xFFFFFFFF)


# ---------------------------------------------------------------------------
# packed-domain helpers (all zero-access peripheral wiring)
# ---------------------------------------------------------------------------

_PAD_MASKS: Dict[Tuple[int, int], np.ndarray] = {}


def _pad_mask(n_words: int, lanes: int) -> np.ndarray:
    m = _PAD_MASKS.get((n_words, lanes))
    if m is None:
        m = np.zeros(lanes, np.uint32)
        full, rem = divmod(n_words, 32)
        m[:full] = _FULL32
        if rem:
            m[full] = (np.uint32(1) << np.uint32(rem)) - np.uint32(1)
        _PAD_MASKS[(n_words, lanes)] = m
    return m


def _mask_pad(pack: PlanePack) -> PlanePack:
    """Zero the bit positions past the last logical word. Every region
    result is masked so packs feeding shifts/reductions keep the zero-pad
    invariant (an `eq` bitmap, say, reads 1 on pad words)."""
    lanes = pack.planes.shape[1]
    if pack.n_words >= lanes * 32:
        return pack
    mask = jnp.asarray(_pad_mask(pack.n_words, lanes))
    return dataclasses.replace(pack, planes=pack.planes & mask[None, :])


def _to_width(pack: PlanePack, bits: int, signed: bool) -> PlanePack:
    if pack.n_bits > bits:
        pack = pack.truncate_to(bits)
    elif pack.n_bits < bits:
        pack = pack.extend_to(bits)      # fill follows the pack's signedness
    return pack.as_signed(signed)


def _finish(pack: PlanePack, aval) -> PlanePack:
    """Land an eqn result on its output aval: width/signedness per dtype
    (two's-complement wrap, exactly jnp's cast semantics), logical shape,
    pad bits cleared."""
    pack = _to_width(pack, dtype_bits(aval.dtype), dtype_signed(aval.dtype))
    pack = dataclasses.replace(pack, shape=tuple(aval.shape))
    return _mask_pad(pack)


def _complement(pack: PlanePack) -> PlanePack:
    """Bitwise NOT of every plane — the SA output complement, free wiring."""
    return dataclasses.replace(pack,
                               planes=pack.planes ^ jnp.uint32(0xFFFFFFFF))


def _broadcast_pack(pack: PlanePack, shape: Tuple[int, ...]) -> PlanePack:
    """Scalar pack -> `shape`: the row buffer fanning one word out."""
    if pack.n_words != 1:
        raise CimOpError(f"can only broadcast scalar packs, got {pack.shape}")
    n = 1
    for d in shape:
        n *= int(d)
    return pack.take_words(np.zeros(n, np.int64), tuple(shape))


# ---------------------------------------------------------------------------
# regions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ResidentAtom:
    """One region input pinnable in the resident region.

    ai      : index into the region's in_atoms (== operand leaf position).
    kind    : "matmul_rhs" — every in-region consumer is a dot_general with
              this atom as its rhs, so the pinned stack is the expanded
              [M, K_pad, N] entry pack (macro.matmul_rhs_pack) and warm
              calls skip the rhs expansion AND pack entirely;
              "batched_matmul_rhs" — the batched analogue: the consumers
              are canonical batched dots and the pinned stack is the
              [B_flat * M, K_pad, N] expansion
              (macro.batched_matmul_rhs_pack) — attention's K^T / V sides;
              "pack" — the atom's plain entry pack is pinned and seeded
              into the region's pack env.
    n_words : logical words of the pinned pack (fit checks + charges).
    m       : *matmul_rhs only — the per-batch lhs row count baked into
              the pack.
    """

    ai: int
    kind: str
    n_bits: int
    signed: bool
    n_words: int
    m: int = 0
    #: matmul_rhs only — region op indices of the zero-access pass-through
    #: chain (convert/reshape) between the atom and the dot's rhs: replayed
    #: on the host when pinning, SKIPPED in the resident region body
    chain_eqns: Tuple[int, ...] = ()


@dataclasses.dataclass
class Region:
    """A maximal run of eligible eqns fused into one Schedule.

    `in_atoms` are the region program's inputs (external Vars + closed-over
    ConstVals, in first-use order; scalar Literals are baked into the
    trace). `donatable` indexes the in_atoms that are dead after the region
    — safe for jit buffer donation. `key` is the structural cache key:
    dataflow with canonicalized var numbering plus operand signatures, so
    two structurally identical regions share one compiled program.

    `resident` (set by residency planning) names the in_atoms whose entry
    packs are pinned across calls; `schedule_resident` is the same step
    plan with those operand sides named resident — a DIFFERENT Schedule
    value, so resident and streamed executions of the same region occupy
    different program-cache slots by construction."""

    name: str
    ops: List[TracedOp]
    schedule: planner.Schedule
    unpack_vars: Tuple[Any, ...] = ()   # outvars a host consumer needs
    in_atoms: Tuple[Any, ...] = ()
    donatable: Tuple[int, ...] = ()
    key: Tuple = ()
    index: int = 0
    resident: Tuple[ResidentAtom, ...] = ()
    schedule_resident: Optional[planner.Schedule] = None
    donatable_resident: Tuple[int, ...] = ()

    @property
    def accesses(self) -> int:
        return self.schedule.accesses


def _region_in_atoms(region: Region) -> Tuple[Any, ...]:
    """External operands of a region, in first-use order: Vars produced
    outside it plus ConstVals (deduped; Literals stay baked in)."""
    produced = {v for op in region.ops for v in op.outvars
                if not isinstance(v, jax.core.DropVar)}
    atoms: List[Any] = []
    seen: set = set()
    for op in region.ops:
        for a in op.invars:
            if isinstance(a, jax.core.Var):
                if a not in produced and a not in seen:
                    seen.add(a)
                    atoms.append(a)
            elif isinstance(a, ConstVal):
                if id(a) not in seen:
                    seen.add(id(a))
                    atoms.append(a)
    return tuple(atoms)


#: shared cache-key signature discipline (ONE definition, see macro.aval_sig)
_aval_sig = macro.aval_sig


def _region_key(region: Region) -> Tuple:
    """Structural identity of a region's traced computation.

    Vars (and ConstVals — their VALUES are program inputs, not baked
    constants) are numbered by first appearance, Literal values are hashed
    by content; together with op names and operand/result signatures this
    determines the region body's trace exactly, so structurally identical
    regions may share one compiled program."""
    ids: Dict[int, int] = {}

    def ref(v) -> int:
        return ids.setdefault(id(v), len(ids))

    parts: List[Tuple] = [
        ("in",) + tuple((ref(a), _aval_sig(aval_of(a)))
                        for a in region.in_atoms)]
    for op in region.ops:
        ins = []
        for a in op.invars:
            if isinstance(a, jax.core.Literal):
                ins.append(("lit", np.asarray(a.val).tobytes(),
                            _aval_sig(a.aval)))
            else:
                ins.append(("v", ref(a), _aval_sig(aval_of(a))))
        outs = tuple(("drop",) if isinstance(v, jax.core.DropVar)
                     else ("v", ref(v), _aval_sig(v.aval))
                     for v in op.outvars)
        parts.append((op.name, tuple(ins), outs))
    parts.append(("out",) + tuple(ref(v) for v in region.unpack_vars))
    return tuple(parts)


#: consumers whose getp() call always uses the operand's OWN aval shape
#: (unary source-shape reads) — safe for a penv-seeded resident pack
_SRC_SHAPE_OPS = ("reduce_sum", "convert_element_type", "reshape",
                  "broadcast_in_dim")


def _classify_resident(region: Region, ai: int, atom) -> \
        Optional[ResidentAtom]:
    """How (and whether) one derived region input can be pinned.

    "matmul_rhs" when the atom — possibly through a chain of zero-access
    unary pass-throughs (convert/reshape) with no other consumers — is
    consumed only by dot_generals taking it as rhs with one consistent
    (M, n_bits, signedness): the expanded broadcast pack is then pinnable,
    the chain eqns are replayed on the host once at pin time and skipped in
    the resident body, and the warm path skips the whole rhs build.
    Otherwise "pack" when every consumer reads the atom at its own aval
    shape (or through geti's unpack) — the plain entry pack seeds the
    region's pack env. None when the consumption pattern would need a
    per-call repack anyway (e.g. non-scalar broadcast into a wider
    elementwise shape)."""
    aval = aval_of(atom)
    consumers = [op for op in region.ops
                 if any(a is atom for a in op.invars)]
    if not consumers:                      # pragma: no cover
        return None
    # forward walk: frontier is the value the dots would consume
    frontier = atom
    chain_eqns: List[int] = []
    mk = None
    rhs_only = True
    while True:
        cons = [(ei, op) for ei, op in enumerate(region.ops)
                if any(a is frontier for a in op.invars)]
        if not cons:
            rhs_only = False
            break
        if all(op.name == "dot_general" and op.invars[1] is frontier
               and op.invars[0] is not frontier for _, op in cons):
            for _, op in cons:
                lhs_aval = aval_of(op.invars[0])
                nb = len(op.params["dimension_numbers"][1][0])
                sig = (nb, tuple(int(d) for d in lhs_aval.shape[:-1]),
                       op.n_bits, dtype_signed(lhs_aval.dtype))
                if mk is None:
                    mk = sig
                elif mk != sig:
                    rhs_only = False
                    break
            break
        ei, op = cons[0]
        if len(cons) != 1 \
                or op.name not in ("convert_element_type", "reshape") \
                or op.invars[0] is not frontier \
                or isinstance(op.outvars[0], jax.core.DropVar) \
                or op.outvars[0] in region.unpack_vars:
            rhs_only = False
            break
        chain_eqns.append(ei)
        frontier = op.outvars[0]
    f_aval = aval_of(frontier)
    if rhs_only and mk is not None and len(f_aval.shape) == mk[0] + 2:
        nb, lead, n_bits, signed = mk
        # `lead` is the lhs's [*B, M]; the pinned stack holds one expanded
        # [K_pad, N] block per (batch, m) row, so the flattened row count is
        # prod(lead) and the per-batch M (what the pack builder broadcasts
        # the rhs over) is its last entry
        rows = 1
        for d in lead:
            rows *= d
        m = lead[-1]
        k, n = int(f_aval.shape[-2]), int(f_aval.shape[-1])
        k_pad = 1 << planner._log2_ceil(k)
        return ResidentAtom(ai=ai,
                            kind="batched_matmul_rhs" if nb else "matmul_rhs",
                            n_bits=n_bits, signed=signed,
                            n_words=rows * k_pad * n, m=m,
                            chain_eqns=tuple(chain_eqns))
    n_words = 1
    for d in aval.shape:
        n_words *= int(d)
    for op in consumers:
        if op.name == "dot_general" or (op.name in _SRC_SHAPE_OPS
                                        and op.invars[0] is atom):
            continue
        out_shape = tuple(aval_of(op.outvars[0]).shape)
        if out_shape != tuple(aval.shape) and n_words != 1:
            return None    # would repack at the broadcast shape per call
    return ResidentAtom(ai=ai, kind="pack",
                        n_bits=dtype_bits(aval.dtype),
                        signed=dtype_signed(aval.dtype), n_words=n_words)


def _read_host(env: Dict[Any, Any], atom):
    if isinstance(atom, jax.core.Literal):
        return jnp.asarray(atom.val, dtype=atom.aval.dtype)
    if isinstance(atom, ConstVal):
        return atom.val
    return env[atom]


class LoweredComputation:
    """One staged-and-planned lowering of a function at fixed avals.

    `execute(*args)` runs the hybrid program; `describe()` prints the
    region structure and fused schedules; `accesses` is the exact unbanked
    ledger charge of one execution.
    """

    def __init__(self, tr: trace_mod.Trace,
                 backend: Optional[str] = None,
                 spec: Optional[ArraySpec] = None, mesh=None,
                 resident_leaf_idx: Tuple[int, ...] = (),
                 resident_set=None, policy: Optional[str] = None,
                 device=None):
        self.trace = tr
        self.backend = backend
        self.spec = spec
        self.mesh = mesh
        self.resident_leaf_idx = tuple(resident_leaf_idx)
        # resident_set=None -> the registry set for `spec`: resolved fresh
        # on every execute (clear_resident/set_resident_ecc/failover swap
        # the registry object; stale captures would pin unprotected), and
        # once here for the construction-time residency budget planning
        self._registry_rs = resident_set is None
        if resident_set is None and self.resident_leaf_idx:
            resident_set = array_mod.resident_set(spec)
        self.resident_set = resident_set
        # the cost model decides, per eligible eqn, whether lowering pays
        # under `policy` (repro.cim.cost); demoted eqns run on host
        self.offload_plan = cost_mod.plan_offload(
            tr, spec=spec, device=device, policy=policy)
        self.policy = self.offload_plan.policy
        self.items: List[Tuple[str, Any]] = []
        self.regions: List[Region] = []
        self._warm_skip: frozenset = frozenset()
        self._build()
        self._plan_residency()

    # -- structure ----------------------------------------------------------
    def _build(self) -> None:
        items: List[Tuple[str, Any]] = []
        buf: List[TracedOp] = []

        def flush():
            if not buf:
                return
            scheds = [o.schedule for o in buf if o.schedule is not None]
            if not scheds or sum(s.accesses for s in scheds) == 0:
                # a run of purely-free eqns does no array work: host it
                items.extend(("host", o) for o in buf)
            else:
                # the schedule's macro name is deliberately NOT positional:
                # it is part of the program-cache key, and structurally
                # identical regions (e.g. repeated layers) must share one
                # compiled program — Region.name keeps the position for
                # display
                region = Region(name=f"region{len(self.regions)}",
                                ops=list(buf),
                                schedule=planner.concat_schedules(
                                    scheds, macro="region"),
                                index=len(self.regions))
                self.regions.append(region)
                items.append(("region", region))
            buf.clear()

        demoted = self.offload_plan.demoted
        for i, op in enumerate(self.trace.ops):
            if op.eligible and i not in demoted:
                buf.append(op)
            else:
                flush()
                items.append(("host", op))
        flush()
        self.items = items

        # which region outputs must materialize for host consumers / outputs
        out_roots = {v for v in self.trace.closed.jaxpr.outvars
                     if isinstance(v, jax.core.Var)}
        consumed_after: List[set] = [set() for _ in items]
        acc: set = set(out_roots)
        for i in range(len(items) - 1, -1, -1):
            consumed_after[i] = set(acc)
            kind, payload = items[i]
            ops = payload.ops if kind == "region" else [payload]
            for op in ops:
                acc.update(v for v in op.invars
                           if isinstance(v, jax.core.Var))
        caller_owned = set(self.trace.closed.jaxpr.invars) \
            | set(self.trace.closed.jaxpr.constvars)
        # an _alias eqn (pjit-inlining passthrough) binds its outvar to the
        # SAME jax.Array as its source — caller arguments and still-live
        # vars included — so any var touching an alias is unsafe to donate
        alias_tainted: set = set()
        for op in self.trace.ops:
            if op.name == "_alias":
                alias_tainted.update(
                    v for v in op.invars if isinstance(v, jax.core.Var))
                alias_tainted.update(
                    v for v in op.outvars
                    if not isinstance(v, jax.core.DropVar))
        for i, (kind, payload) in enumerate(items):
            if kind == "region":
                payload.unpack_vars = tuple(
                    v for op in payload.ops for v in op.outvars
                    if v in consumed_after[i])
                payload.in_atoms = _region_in_atoms(payload)
                # inputs dead after this region (and neither the caller's
                # own buffers nor alias-shared ones) may be donated to the
                # compiled region program
                payload.donatable = tuple(
                    j for j, a in enumerate(payload.in_atoms)
                    if isinstance(a, jax.core.Var)
                    and a not in caller_owned
                    and a not in alias_tainted
                    and a not in consumed_after[i])
                payload.key = _region_key(payload)

    # -- residency planning -------------------------------------------------
    def _plan_residency(self) -> None:
        """Decide, statically, which region inputs can live in array rows.

        A region input is resident-eligible when its value is DERIVED purely
        from the resident arguments (seeded at the jaxpr invars, propagated
        through eqns whose every Var input is itself derived — closed-over
        constants and literals are call-invariant and never block), its
        in-region consumption pattern admits a pinnable entry pack, and that
        pack's rows fit the empty resident budget of the ResidentSet's
        geometry (an oversize atom silently stays streamed — never an
        error). The warm-skip set then marks host eqns that exist ONLY to
        produce resident-derived values: with every pin warm they are pure
        dead weight and the hybrid executor skips them."""
        rs = self.resident_set
        if rs is None or not self.resident_leaf_idx:
            return
        jaxpr = self.trace.closed.jaxpr
        derived = {jaxpr.invars[i] for i in self.resident_leaf_idx}
        for op in self.trace.ops:
            vars_in = [a for a in op.invars if isinstance(a, jax.core.Var)]
            if all(v in derived for v in vars_in):
                derived.update(v for v in op.outvars
                               if not isinstance(v, jax.core.DropVar))
        budget = rs.spec.rows - rs.reserve_rows
        for region in self.regions:
            resident: List[ResidentAtom] = []
            for ai, atom in enumerate(region.in_atoms):
                if not isinstance(atom, jax.core.Var) or atom not in derived:
                    continue
                ra = _classify_resident(region, ai, atom)
                if ra is None:
                    continue
                rows = rs._rows_for(ra.n_bits, ra.n_words)
                if max(rows.values(), default=0) > budget:
                    continue
                resident.append(ra)
            if resident:
                region.resident = tuple(resident)
                names = tuple(f"in{ra.ai}" for ra in resident)
                region.schedule_resident = region.schedule \
                    .with_operands(*names).with_resident(*names)
                rset = {ra.ai for ra in resident}
                region.donatable_resident = tuple(
                    j for j in region.donatable if j not in rset)
        if not any(r.resident for r in self.regions):
            return
        needed = {v for v in jaxpr.outvars if isinstance(v, jax.core.Var)}
        skip = set()
        for i in range(len(self.items) - 1, -1, -1):
            kind, payload = self.items[i]
            if kind == "region":
                rset = {ra.ai for ra in payload.resident}
                needed.update(
                    a for j, a in enumerate(payload.in_atoms)
                    if isinstance(a, jax.core.Var) and j not in rset)
            else:
                outs = [v for v in payload.outvars
                        if not isinstance(v, jax.core.DropVar)]
                if not any(v in needed for v in outs):
                    skip.add(i)
                else:
                    needed.update(v for v in payload.invars
                                  if isinstance(v, jax.core.Var))
        self._warm_skip = frozenset(skip)

    def _build_resident_pack(self, region: Region, ra: ResidentAtom,
                             value) -> PlanePack:
        """The concrete plane stack a ResidentSet pins for one atom —
        bitwise identical to what the region body would build per call."""
        arr = jnp.asarray(value)
        if ra.kind in ("matmul_rhs", "batched_matmul_rhs"):
            # replay the skipped pass-through chain on the host: these are
            # the eqns between the region input and the dot's rhs
            for ei in ra.chain_eqns:
                op = region.ops[ei]
                oav = aval_of(op.outvars[0])
                if op.name == "convert_element_type":
                    arr = arr.astype(oav.dtype)
                else:
                    arr = arr.reshape(tuple(oav.shape))
            if ra.kind == "batched_matmul_rhs":
                return macro.batched_matmul_rhs_pack(arr, ra.m, ra.n_bits,
                                                     signed=ra.signed)
            return macro.matmul_rhs_pack(arr, ra.m, ra.n_bits,
                                         signed=ra.signed)
        if arr.dtype == jnp.bool_:
            arr = arr.astype(jnp.int32)
        return PlanePack.pack(arr, ra.n_bits, signed=ra.signed)

    # -- execution ----------------------------------------------------------
    def execute(self, *args):
        leaves = jax.tree_util.tree_leaves(args)
        invars = self.trace.closed.jaxpr.invars
        if len(leaves) != len(invars):
            raise CimOpError(
                f"lowered function takes {len(invars)} array leaves, "
                f"got {len(leaves)}")
        env: Dict[Any, Any] = dict(zip(invars, leaves))
        # a closed-over constant can BE an output (or leak past the invar
        # substitution); seed the env so those reads resolve
        env.update(zip(self.trace.closed.jaxpr.constvars,
                       self.trace.closed.consts))

        # residency: active only with concrete resident leaves — under an
        # outer jit the leaves are Tracers, whose identity is per-trace and
        # whose planes must not be captured in a pin, so the call falls
        # back to the plain streamed path (charged once per outer trace,
        # exactly as before)
        rs = self.resident_set
        if self._registry_rs and self.resident_leaf_idx:
            # registry-backed: re-resolve each call so ECC toggles,
            # clear_resident() and failover spec swaps take effect on the
            # next execution instead of pinning into a stale set
            rs = array_mod.resident_set(self.spec)
        resident_on = (rs is not None and self.resident_leaf_idx
                       and any(r.resident for r in self.regions)
                       and not any(isinstance(leaves[i], jax.core.Tracer)
                                   for i in self.resident_leaf_idx))
        fp = None
        keep = None
        warm = False
        if resident_on:
            # the fingerprint is PART of the key: one LoweredComputation is
            # shared by every caller with these avals (e.g. identical layers
            # of a stack), and each caller's weights deserve their own pin.
            # The entry keeps strong refs (aux) to the fingerprinted arrays
            # and this computation, so a recycled id() can never alias.
            fp = tuple(id(leaves[i]) for i in self.resident_leaf_idx)
            keep = tuple(leaves[i] for i in self.resident_leaf_idx) + (self,)
            warm = all(
                rs.peek(("lowered", id(self), r.index, ra.ai) + fp, fp)
                for r in self.regions for ra in r.resident)

        for i, (kind, payload) in enumerate(self.items):
            if kind == "host":
                if warm and i in self._warm_skip:
                    continue
                self._run_host(payload, env)
                continue
            rmap = None
            if resident_on and payload.resident:
                rmap = {}
                for ra in payload.resident:
                    key = ("lowered", id(self), payload.index, ra.ai) + fp
                    entry = rs.get(key, fingerprint=fp)
                    if entry is None:
                        value = _read_host(env, payload.in_atoms[ra.ai])
                        entry = rs.pin(
                            key,
                            self._build_resident_pack(payload, ra, value),
                            fingerprint=fp, aux=keep)
                    rmap[ra.ai] = entry.pack
            self._run_region(payload, env, resident_map=rmap)
        outs = [_read_host(env, v) for v in self.trace.closed.jaxpr.outvars]
        out_tree = jax.tree_util.tree_structure(self.trace.out_shape)
        return jax.tree_util.tree_unflatten(out_tree, outs)

    __call__ = execute

    def _run_host(self, op: TracedOp, env: Dict[Any, Any]) -> None:
        if op.name == "_alias":
            env[op.outvars[0]] = _read_host(env, op.invars[0])
            return
        subfuns, bind_params = op.prim.get_bind_params(op.params)
        in_vals = [_read_host(env, v) for v in op.invars]
        vals = op.prim.bind(*subfuns, *in_vals, **bind_params)
        if not op.prim.multiple_results:
            vals = [vals]
        for var, val in zip(op.outvars, vals):
            if not isinstance(var, jax.core.DropVar):
                env[var] = val

    def _run_region(self, region: Region, env: Dict[Any, Any],
                    resident_map: Optional[Dict[int, PlanePack]] = None
                    ) -> None:
        """Execute a fused region as ONE jitted XLA program: gather the
        region's input leaves from the host env, invoke (or compile) the
        cached step program, land the unpacked outputs back in the env.

        With `resident_map` (atom index -> pinned PlanePack) the resident
        atoms enter the program AS plane stacks — their raw values are
        never read, their entry packs never rebuilt — under the resident
        schedule and a resident-marked body key, so streamed and resident
        executions of one region never share a compiled program."""
        leaves = tuple(
            resident_map[j] if resident_map and j in resident_map
            else _read_host(env, a)
            for j, a in enumerate(region.in_atoms))
        if resident_map:
            schedule = region.schedule_resident
            body_key = ("region", region.key,
                        ("resident",) + region.resident)
            donatable = region.donatable_resident
            body = self._region_body(region, frozenset(resident_map))
        else:
            schedule = region.schedule
            body_key = ("region", region.key)
            donatable = region.donatable
            body = self._region_body(region)
        # donation only pays (and only passes silently) on accelerators;
        # CPU jit ignores donations with a warning, so skip it there
        donate = donatable \
            if jax.default_backend() in ("gpu", "tpu") else ()
        outs = macro.run_schedule_program(
            schedule, body, leaves,
            body_key=body_key, backend=self.backend,
            spec=self.spec, mesh=self.mesh, donate=donate)
        for var, val in zip(region.unpack_vars, outs):
            env[var] = val

    def _region_body(self, region: Region,
                     resident_ais: frozenset = frozenset()):
        """The traceable region computation `run_schedule_program` compiles:
        the per-eqn execution loop over the program's shared cursor."""
        resident_kinds = {ra.ai: ra for ra in region.resident
                          if ra.ai in resident_ais}
        # eqns replayed into the pinned pack at pin time: dead in the body
        skip_eqns = frozenset(ei for ra in resident_kinds.values()
                              for ei in ra.chain_eqns)

        def body(cur, *leaves):
            chain = macro.ChainExecutor.from_cursor(cur)
            var_env: Dict[Any, Any] = {}
            const_env: Dict[int, Any] = {}
            resident_matmul: Dict[Any, PlanePack] = {}
            penv: Dict[Any, PlanePack] = {}
            for j, (atom, leaf) in enumerate(zip(region.in_atoms, leaves)):
                ra = resident_kinds.get(j)
                if ra is not None:
                    if ra.kind in ("matmul_rhs", "batched_matmul_rhs"):
                        # keyed at the END of the pass-through chain — the
                        # var the dot handler actually consumes; the reuse
                        # charge lands inside _matmul_with
                        fvar = region.ops[ra.chain_eqns[-1]].outvars[0] \
                            if ra.chain_eqns else atom
                        resident_matmul[fvar] = leaf
                    else:
                        penv[atom] = leaf     # pre-seeded entry pack
                        cur.charge_resident(leaf.n_bits, leaf.n_words)
                elif isinstance(atom, ConstVal):
                    const_env[id(atom)] = leaf
                else:
                    var_env[atom] = leaf

            def read(atom):
                if isinstance(atom, jax.core.Literal):
                    return jnp.asarray(atom.val, dtype=atom.aval.dtype)
                if isinstance(atom, ConstVal):
                    return const_env[id(atom)]
                return var_env[atom]

            def getp(atom, shape) -> PlanePack:
                """Operand as a PlanePack of logical `shape` (region entry
                pack for external values — each packed ONCE per region —
                with scalar fanout staying in the packed domain)."""
                if isinstance(atom, jax.core.Var) and atom in penv:
                    p = penv[atom]
                    if p.shape != tuple(shape):
                        p = _broadcast_pack(p, tuple(shape))
                    return p
                aval = aval_of(atom)
                arr = jnp.asarray(read(atom))
                if arr.dtype == jnp.bool_:
                    arr = arr.astype(jnp.int32)
                if tuple(arr.shape) != tuple(shape):
                    arr = jnp.broadcast_to(arr, tuple(shape))
                p = PlanePack.pack(arr, dtype_bits(aval.dtype),
                                   signed=dtype_signed(aval.dtype))
                # a freshly built entry pack is a STREAMED operand load:
                # its planes are driven into rows before the first access
                # (resident atoms never reach here — they are pre-seeded)
                cur.charge_load(p.n_bits, p.n_words)
                if isinstance(atom, jax.core.Var) and \
                        tuple(shape) == tuple(aval.shape):
                    penv[atom] = p    # entry pack: reused by later consumers
                return p

            def geti(atom) -> jax.Array:
                """Operand as an integer array (the dot_general layout
                rebuild — the one declared in-region materialization)."""
                if isinstance(atom, jax.core.Var) and atom in penv:
                    aval = aval_of(atom)
                    return penv[atom].unpack().astype(aval.dtype)
                return jnp.asarray(read(atom))

            for ei, op in enumerate(region.ops):
                if ei in skip_eqns:
                    continue
                out_aval = aval_of(op.outvars[0])
                shape = tuple(out_aval.shape)
                name = op.name
                if name in ("add", "sub", "and", "or", "xor"):
                    pa = getp(op.invars[0], shape)
                    pb = getp(op.invars[1], shape)
                    res = chain.execute(pa, pb, (name,))[name]
                elif name in CMP_PRIMS:
                    base, complement = CMP_PRIMS[name]
                    pa = getp(op.invars[0], shape)
                    pb = getp(op.invars[1], shape)
                    res = chain.execute(pa, pb, (base,))[base]
                    if complement:
                        res = _complement(res)
                elif name == "min":
                    res = chain.minimum(getp(op.invars[0], shape),
                                        getp(op.invars[1], shape))
                elif name == "max":
                    res = chain.maximum(getp(op.invars[0], shape),
                                        getp(op.invars[1], shape))
                elif name == "neg":
                    res = chain.neg(getp(op.invars[0], shape))
                elif name == "abs":
                    res = chain.abs_(getp(op.invars[0], shape))
                elif name == "mul":
                    res = chain.multiply(getp(op.invars[0], shape),
                                         getp(op.invars[1], shape))
                elif name == "population_count":
                    res = chain.popcount(getp(op.invars[0], shape))
                elif name == "reduce_sum":
                    src_shape = tuple(aval_of(op.invars[0]).shape)
                    res = chain.reduce_sum(getp(op.invars[0], src_shape))
                elif name == "dot_general":
                    rb = resident_matmul.get(op.invars[1]) \
                        if isinstance(op.invars[1], jax.core.Var) else None
                    nb = len(op.params["dimension_numbers"][1][0])
                    mm = chain.batched_matmul if nb else chain.matmul
                    res = mm(geti(op.invars[0]),
                             None if rb is not None
                             else geti(op.invars[1]), op.n_bits,
                             signed=dtype_signed(
                                 aval_of(op.invars[0]).dtype),
                             b_pack=rb)
                elif name == "convert_element_type":
                    src_shape = tuple(aval_of(op.invars[0]).shape)
                    res = getp(op.invars[0], src_shape)
                elif name == "reshape":
                    src_shape = tuple(aval_of(op.invars[0]).shape)
                    res = getp(op.invars[0], src_shape)
                elif name == "not":
                    res = _complement(getp(op.invars[0], shape))
                elif name == "select_n":
                    pred = getp(op.invars[0], shape)
                    x = getp(op.invars[1], shape)
                    y = getp(op.invars[2], shape)
                    res = macro.select(pred, y, x)  # pred ? cases[1] : cases[0]
                elif name == "broadcast_in_dim":
                    src_shape = tuple(aval_of(op.invars[0]).shape)
                    res = _broadcast_pack(getp(op.invars[0], src_shape),
                                          shape)
                else:                             # pragma: no cover
                    raise CimOpError(f"region executor missing op {name!r}")
                if not isinstance(op.outvars[0], jax.core.DropVar):
                    penv[op.outvars[0]] = _finish(res, out_aval)

            return tuple(penv[var].unpack().astype(aval_of(var).dtype)
                         for var in region.unpack_vars)

        return body

    # -- reporting ----------------------------------------------------------
    @property
    def accesses(self) -> int:
        """Planned (== executed, unbanked) ADRA accesses per call."""
        return sum(r.accesses for r in self.regions)

    @property
    def eligible_eqns(self) -> int:
        return sum(len(r.ops) for r in self.regions)

    @property
    def host_eqns(self) -> int:
        return sum(1 for kind, _ in self.items if kind == "host")

    def describe(self) -> str:
        plan = self.offload_plan
        lines = [f"lowered: {len(self.regions)} CiM region(s), "
                 f"{self.host_eqns} host eqn(s), "
                 f"{self.accesses} planned accesses "
                 f"[policy={plan.policy}, {plan.demoted_eqns} demoted, "
                 f"{plan.fused_losses} kept fused despite loss]"]
        for v in plan.verdicts:
            if v.index in plan.demoted:
                lines.append(f"  demoted eqn#{v.index} {v.name} "
                             f"({v.accesses} accesses): {v.reason} "
                             f"(margin {100 * v.margin:+.1f}%)")
        for r in self.regions:
            segs = ", ".join(f"{name}:{n}" for name, n in
                             (r.schedule.segments or ()))
            lines.append(f"  {r.name}: {len(r.ops)} eqns fused -> "
                         f"{r.accesses} accesses [{segs}]")
        return "\n".join(lines)


#: per-function bound on cached signature traces — a long-lived server fed
#: ever-varying shapes must not grow a LoweredFunction without limit (the
#: same growth class the dispatch schedule cache bounds one layer down)
SIGNATURE_CACHE_CAPACITY = 128


class LoweredFunction:
    """`lower(fn)`: traces lazily per argument signature (like jit) and
    executes the hybrid CiM/host program. The signature cache is a bounded
    LRU (SIGNATURE_CACHE_CAPACITY); an evicted signature simply retraces."""

    def __init__(self, fn, backend: Optional[str] = None,
                 spec: Optional[ArraySpec] = None, mesh=None,
                 resident_argnums: Tuple[int, ...] = (),
                 resident_set=None, policy: Optional[str] = None,
                 device=None):
        self.fn = fn
        self.backend = backend
        self.spec = spec
        self.mesh = mesh
        self.resident_argnums = tuple(resident_argnums)
        self.resident_set = resident_set
        self.policy = cost_mod.normalize_policy(policy)
        self.device = device
        # resident_set=None means "the registry set for `spec`", resolved
        # PER EXECUTION by LoweredComputation — never captured here: the
        # registry set is replaced by clear_resident()/set_resident_ecc()/
        # failover, and a captured reference would keep pinning into a
        # stale (e.g. unprotected) set for the life of the layer cache
        self._cache: "OrderedDict[Any, LoweredComputation]" = OrderedDict()

    def _resident_leaf_idx(self, args) -> Tuple[int, ...]:
        """Flat leaf indices of the resident argnums (the positions
        `execute` fingerprints and the residency planner seeds from)."""
        if not self.resident_argnums:
            return ()
        spans = []
        start = 0
        for a in args:
            n = len(jax.tree_util.tree_leaves(a))
            spans.append((start, start + n))
            start += n
        idx: List[int] = []
        for an in self.resident_argnums:
            if an < len(spans):
                idx.extend(range(*spans[an]))
        return tuple(idx)

    def trace(self, *args) -> LoweredComputation:
        leaves, treedef = jax.tree_util.tree_flatten(args)
        key = (treedef, tuple(
            (jnp.shape(x), str(jnp.result_type(x))) for x in leaves))
        comp = self._cache.get(key)
        if comp is None:
            comp = LoweredComputation(
                trace_mod.trace(self.fn, *args), backend=self.backend,
                spec=self.spec, mesh=self.mesh,
                resident_leaf_idx=self._resident_leaf_idx(args),
                resident_set=self.resident_set, policy=self.policy,
                device=self.device)
            self._cache[key] = comp
            while len(self._cache) > SIGNATURE_CACHE_CAPACITY:
                self._cache.popitem(last=False)
        else:
            self._cache.move_to_end(key)
        return comp

    def __call__(self, *args):
        return self.trace(*args).execute(*args)


def lower(fn, backend: Optional[str] = None,
          spec: Optional[ArraySpec] = None, mesh=None,
          resident_argnums: Tuple[int, ...] = (),
          resident_set=None, policy: Optional[str] = None,
          device=None) -> LoweredFunction:
    """Compile `fn` into a hybrid CiM/host callable (see module docstring).

    backend : CiM backend name for the fused regions (registry default
              when None).
    spec    : optional banked ArraySpec — region accesses tile over banks
              through the dispatch layer and the ledger charges per
              (device, bank) activations.
    mesh    : optional device mesh forwarded to the tiling dispatcher.
    resident_argnums : argument positions whose (pure) derivatives may be
              pinned in the resident region: region inputs derived solely
              from these arguments skip their per-call entry pack once
              pinned, and host eqns that only feed pinned values are
              skipped on warm passes. Identity-fingerprinted — pass the
              SAME weight arrays each call to stay warm.
    resident_set : the ResidentSet to pin into (the process-wide registry
              set for `spec` when omitted).
    policy  : offload policy (repro.cim.cost): "edp" (default, alias
              "cost") lowers an eqn only when its projected CiM EDP beats
              the near-memory baseline; "latency" compares against the
              DeviceSpec host roofline; "always" reproduces the
              pre-cost-model behavior bit-exactly; "never" demotes all.
    device  : DeviceSpec for the host side of the comparison
              (cost.DEFAULT_DEVICE — a v5e chip — when None).
    """
    return LoweredFunction(fn, backend=backend, spec=spec, mesh=mesh,
                           resident_argnums=resident_argnums,
                           resident_set=resident_set, policy=policy,
                           device=device)

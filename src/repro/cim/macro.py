"""Macro-op executors: multi-access CiM arithmetic over the single-access
engine.

Every macro here executes a `planner.Schedule` through a cursor that allows
exactly the planned accesses (same order, same op-sets) and nothing else —
each cursor step is one `engine.execute` call, so the accounting ledger is
charged precisely `schedule.accesses` times per macro invocation. Operands,
partial products, accumulators and tree levels all stay in the PlanePack
packed domain; the only integer codec entries are the caller's own pack()
at entry and unpack() at exit.

Macros:

  multiply   — shift-and-add; signed multipliers subtract the MSB partial
               product (single-access sub, the paper's headline op)
  abs_/relu  — sub-chain predicate + zero-cost peripheral select
  minimum/maximum — lt/gt predicate + select, one access each
  popcount   — pairwise plane tree, n-1 add accesses
  reduce_sum — log-stride tree reduction with row-buffer shifts
  dot/matmul — int x int -> wide-int contraction: one multiply over a
               broadcast [M, K_pad, N] layout + a stride-N reduction; the
               access count depends only on the bit width and K (word
               parallelism), never on M or N
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import dispatch, engine, opset, planner
from .accounting import LEDGER
from .array import ArraySpec
from .opset import CimOpError
from .planepack import PlanePack


class ScheduleCursor:
    """Executes a Schedule one access at a time, refusing to deviate.

    This is the accounting guarantee: a macro CANNOT issue an access its
    plan does not contain, so ledger accesses == schedule.accesses holds by
    construction, not by convention. With an ArraySpec the cursor routes
    every access through the banked tiling dispatcher instead of the
    infinite-array engine — each planned step then costs `plan.n_tiles`
    bank activations and the guarantee becomes ledger accesses ==
    schedule.placed_accesses. A mesh additionally spreads the tiles over
    its "data" axis via shard_map.
    """

    def __init__(self, schedule: planner.Schedule,
                 backend: Optional[str] = None,
                 spec: Optional[ArraySpec] = None,
                 mesh=None):
        self.schedule = schedule
        self.backend = backend
        self.spec = spec
        self.mesh = mesh
        self._i = 0

    def step(self) -> planner.Step:
        if self._i >= len(self.schedule.steps):
            raise CimOpError(
                f"{self.schedule.macro}: executor exceeded its planned "
                f"{self.schedule.accesses} accesses")
        return self.schedule.steps[self._i]

    def execute(self, a: PlanePack, b: PlanePack,
                ops: Sequence[str]) -> engine.Outputs:
        step = self.step()
        if tuple(ops) != step.ops:
            raise CimOpError(
                f"{self.schedule.macro}: access {self._i} executes {ops!r} "
                f"but the plan says {step.ops!r}")
        self._i += 1
        if self.spec is None:
            return engine.execute(a, b, step.ops, backend=self.backend)
        return dispatch.execute_tiled(a, b, step.ops, spec=self.spec,
                                      backend=self.backend, mesh=self.mesh)

    def remaining(self) -> Tuple[planner.Step, ...]:
        return self.schedule.steps[self._i:]

    def finish(self) -> None:
        if self._i != len(self.schedule.steps):
            raise CimOpError(
                f"{self.schedule.macro}: executed {self._i} of "
                f"{self.schedule.accesses} planned accesses")



def _cursor(sched: planner.Schedule, n_words: int,
            backend: Optional[str], spec: Optional[ArraySpec],
            mesh) -> ScheduleCursor:
    """Place a schedule on the banked geometry (when given) and open its
    cursor — the single spot where placement meets execution."""
    if spec is not None:
        sched = sched.placed(spec, n_words)
    return ScheduleCursor(sched, backend, spec=spec, mesh=mesh)


# ---------------------------------------------------------------------------
# peripheral select (zero accesses)
# ---------------------------------------------------------------------------


def select(pred: PlanePack, x: PlanePack, y: PlanePack) -> PlanePack:
    """Per-word mux: pred ? x : y, as predicated writeback in the periphery.

    The predicate is a 1-plane bitmap (an engine lt/eq/gt output); selection
    gates which operand's planes reach the row buffer — no array access.
    """
    if pred.planes.shape[0] != 1:
        raise CimOpError("select predicate must be a 1-plane bitmap")
    if x.signed != y.signed:
        n = max(x.n_bits, y.n_bits) + 1   # room so both read as signed
        x, y = x.extend_to(n).as_signed(True), y.extend_to(n).as_signed(True)
    x, y = x.align(y)
    mask = pred.planes[0]
    planes = (x.planes & mask) | (y.planes & ~mask)
    return PlanePack(planes=planes, n_bits=x.n_bits,
                     signed=x.signed, shape=x.shape)


def _plane_mask(bitmap: jax.Array, n_bits: int, like: PlanePack) -> PlanePack:
    """One multiplier-bit bitmap replicated across n_bits planes (the row
    driver asserting the same enable on every plane — free wiring)."""
    planes = jnp.broadcast_to(bitmap[None], (n_bits,) + bitmap.shape)
    return PlanePack(planes=planes, n_bits=n_bits, signed=True,
                     shape=like.shape)


# ---------------------------------------------------------------------------
# multiply
# ---------------------------------------------------------------------------


def _multiply_with(cur: ScheduleCursor, a: PlanePack,
                   b: PlanePack) -> PlanePack:
    """Shift-and-add over a cursor (shared by multiply and matmul)."""
    w = a.n_bits + b.n_bits
    a_ext = a.extend_to(w).as_signed(True)
    acc: Optional[PlanePack] = None
    for i in range(b.n_bits):
        last_signed = b.signed and i == b.n_bits - 1
        pp = cur.execute(a_ext, _plane_mask(b.planes[i], w, a), ("and",))
        # AND of a sign-extended word against a replicated enable bit is a
        # valid two's-complement word (a_ext or 0); shift = weight 2^i,
        # truncation keeps the arithmetic modulo 2^w
        shifted = pp["and"].as_signed(True).truncate_to(w - i).shift_up(i)
        if acc is None:
            if last_signed:            # 1-bit signed multiplier: b in {0,-1}
                zero = PlanePack.zeros_like(shifted)
                acc = cur.execute(zero, shifted, ("sub",))["sub"]
            else:
                acc = shifted
        else:
            op = "sub" if last_signed else "add"
            acc = cur.execute(acc, shifted, (op,))[op]
        acc = acc.truncate_to(w)
    return acc.as_signed(a.signed or b.signed)


def multiply(a: PlanePack, b: PlanePack,
             backend: Optional[str] = None,
             spec: Optional[ArraySpec] = None, mesh=None) -> PlanePack:
    """Exact product, (n_a + n_b)-plane result, 2*n_b - 1 accesses (times
    the tile count when placed on a banked `spec`)."""
    if a.shape != b.shape:
        raise CimOpError(f"operand shapes differ: {a.shape} vs {b.shape}")
    sched = planner.plan_multiply(a.n_bits, b.n_bits, signed_b=b.signed)
    cur = _cursor(sched, a.n_words, backend, spec, mesh)
    out = _multiply_with(cur, a, b)
    cur.finish()
    return out


# ---------------------------------------------------------------------------
# select-based macros: abs / relu / min / max
# ---------------------------------------------------------------------------


def _abs_with(cur: ScheduleCursor, a: PlanePack) -> PlanePack:
    zero = PlanePack.zeros_like(a)
    out = cur.execute(zero, a, ("sub", "lt"))
    return select(out["lt"], a, out["sub"])


def _relu_with(cur: ScheduleCursor, a: PlanePack) -> PlanePack:
    zero = PlanePack.zeros_like(a)
    out = cur.execute(a, zero, ("gt",))
    return select(out["gt"], a, zero)


def _minimum_with(cur: ScheduleCursor, a: PlanePack,
                  b: PlanePack) -> PlanePack:
    out = cur.execute(a, b, ("lt",))
    return select(out["lt"], a, b)


def _maximum_with(cur: ScheduleCursor, a: PlanePack,
                  b: PlanePack) -> PlanePack:
    out = cur.execute(a, b, ("gt",))
    return select(out["gt"], a, b)


def abs_(a: PlanePack, backend: Optional[str] = None,
         spec: Optional[ArraySpec] = None, mesh=None) -> PlanePack:
    """|a| in one access: (0 - a, 0 < a) together, then select a vs -a.
    Result is (n+1)-plane so abs(INT_MIN) is exact."""
    cur = _cursor(planner.plan_abs(a.n_bits), a.n_words, backend, spec,
                  mesh)
    out = _abs_with(cur, a)
    cur.finish()
    return out


def relu(a: PlanePack, backend: Optional[str] = None,
         spec: Optional[ArraySpec] = None, mesh=None) -> PlanePack:
    """max(a, 0) in one access: the a > 0 predicate gates the writeback."""
    cur = _cursor(planner.plan_relu(a.n_bits), a.n_words, backend, spec,
                  mesh)
    out = _relu_with(cur, a)
    cur.finish()
    return out


def minimum(a: PlanePack, b: PlanePack,
            backend: Optional[str] = None,
            spec: Optional[ArraySpec] = None, mesh=None) -> PlanePack:
    cur = _cursor(planner.plan_minimum(max(a.n_bits, b.n_bits)),
                  a.n_words, backend, spec, mesh)
    out = _minimum_with(cur, a, b)
    cur.finish()
    return out


def maximum(a: PlanePack, b: PlanePack,
            backend: Optional[str] = None,
            spec: Optional[ArraySpec] = None, mesh=None) -> PlanePack:
    cur = _cursor(planner.plan_maximum(max(a.n_bits, b.n_bits)),
                  a.n_words, backend, spec, mesh)
    out = _maximum_with(cur, a, b)
    cur.finish()
    return out


# ---------------------------------------------------------------------------
# popcount / reductions
# ---------------------------------------------------------------------------


def _popcount_with(cur: ScheduleCursor, a: PlanePack) -> PlanePack:
    level = [PlanePack(planes=a.planes[i:i + 1], n_bits=1, signed=False,
                       shape=a.shape)
             for i in range(a.n_bits)]
    while len(level) > 1:
        nxt = [cur.execute(level[j], level[j + 1], ("add",))["add"]
               for j in range(0, len(level) - 1, 2)]
        if len(level) % 2:
            nxt.append(level[-1])
        level = nxt
    return level[0]


def popcount(a: PlanePack, backend: Optional[str] = None,
             spec: Optional[ArraySpec] = None, mesh=None) -> PlanePack:
    """Set bits of each word's n-bit two's-complement pattern: pairwise
    plane tree, n - 1 add accesses."""
    cur = _cursor(planner.plan_popcount(a.n_bits), a.n_words, backend,
                  spec, mesh)
    out = _popcount_with(cur, a)
    cur.finish()
    return out


def _reduce_with(cur: ScheduleCursor, acc: PlanePack,
                 n_steps: Optional[int] = None) -> PlanePack:
    """Log-stride reduction: each planned step shifts the row buffer by its
    stride and adds, so element 0 of each segment accumulates the segment
    sum; exactness relies on the pack's zero padding past the last word.

    `n_steps` bounds the walk to the next n_steps planned steps — required
    when the cursor belongs to a fused region schedule that continues past
    this reduction; None consumes everything remaining (the standalone
    reduce/matmul cursors, whose plans end with the reduction).

    On a banked cursor the shift moves words BETWEEN tiles whenever the
    stride reaches across a tile boundary — that movement is the inter-bank
    reduction traffic the ledger charges (fraction of words crossing scales
    with stride/tile_words, capped at all of them)."""
    if not acc.signed:
        acc = acc.extend_to(acc.n_bits + 1).as_signed(True)
    steps = cur.remaining()
    if n_steps is not None:
        steps = steps[:n_steps]
    for step in steps:
        if cur.spec is not None and step.stride:
            plan = cur.spec.plan(acc.n_words)
            if plan.n_tiles > 1:
                frac = min(1.0, step.stride / plan.tile_words)
                LEDGER.charge_reduction(
                    acc.n_words * frac * acc.n_bits / 32.0)
        shifted = acc.shift_elements(step.stride)
        acc = cur.execute(acc, shifted, ("add",))["add"]
    return acc


def reduce_sum(a: PlanePack, backend: Optional[str] = None,
               spec: Optional[ArraySpec] = None, mesh=None) -> PlanePack:
    """Sum of ALL logical elements, ceil(log2(n_words)) accesses; returns a
    scalar-shaped pack (element 0 of the tree)."""
    sched = planner.plan_reduce_sum(a.n_words, stride=1, n_bits=a.n_bits)
    cur = _cursor(sched, a.n_words, backend, spec, mesh)
    acc = _reduce_with(cur, a)
    cur.finish()
    return PlanePack(planes=acc.planes, n_bits=acc.n_bits,
                     signed=acc.signed, shape=())


# ---------------------------------------------------------------------------
# quantized dot / matmul
# ---------------------------------------------------------------------------


def _matmul_with(cur: ScheduleCursor, a: jax.Array, b: jax.Array,
                 n_bits: int, signed: bool = True) -> PlanePack:
    """The matmul dataflow over an open cursor: broadcast [M, K_pad, N]
    operand layout, ONE shift-and-add multiply, log2(K_pad) stride-N tree
    reduction, result gathered to an [M, N] pack. Shared by the standalone
    `matmul` wrapper and the lowering compiler's fused-region executor
    (which passes a region cursor mid-schedule)."""
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise CimOpError(f"matmul needs [M,K] x [K,N], got {a.shape} {b.shape}")
    m, k = a.shape
    n = b.shape[1]
    k_pad = 1 << planner._log2_ceil(k)
    a_exp = jnp.zeros((m, k_pad, n), jnp.int32).at[:, :k, :].set(
        jnp.broadcast_to(a[:, :, None], (m, k, n)).astype(jnp.int32))
    b_exp = jnp.zeros((m, k_pad, n), jnp.int32).at[:, :k, :].set(
        jnp.broadcast_to(b[None, :, :], (m, k, n)).astype(jnp.int32))

    prod = _multiply_with(cur, PlanePack.pack(a_exp, n_bits, signed=signed),
                          PlanePack.pack(b_exp, n_bits, signed=signed))
    acc = _reduce_with(cur, prod, n_steps=planner._log2_ceil(k_pad))

    # k = 0 slice of each row: flat(m, 0, n) = m * K_pad * N + n
    idx = (np.arange(m)[:, None] * (k_pad * n) + np.arange(n)[None, :])
    return acc.take_words(idx.reshape(-1), (m, n))


def matmul(a: jax.Array, b: jax.Array, n_bits: int = 8,
           backend: Optional[str] = None,
           spec: Optional[ArraySpec] = None, mesh=None) -> jax.Array:
    """Exact intN x intN -> int32 matmul through the CiM array.

    a : int [M, K], b : int [K, N], entries representable in n_bits signed.
    Lowered to ONE shift-and-add multiply over the broadcast [M, K_pad, N]
    operand layout plus a log2(K_pad) stride-N tree reduction — the whole
    contraction is (2*n_bits - 1) + ceil(log2 K) accesses regardless of M
    and N. Word-level parallelism is the CiM scaling argument; the operand
    broadcast is the (honest) cost of it.
    """
    a = jnp.asarray(a)
    b = jnp.asarray(b)
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise CimOpError(f"matmul needs [M,K] x [K,N], got {a.shape} {b.shape}")
    m, k = a.shape
    n = b.shape[1]
    k_pad = 1 << planner._log2_ceil(k)
    sched = planner.plan_matmul(k, n, n_bits=n_bits, signed=True)
    cur = _cursor(sched, m * k_pad * n, backend, spec, mesh)
    out = _matmul_with(cur, a, b, n_bits)
    cur.finish()
    return out.unpack()


# ---------------------------------------------------------------------------
# chain executor: one cursor for a fused multi-eqn region
# ---------------------------------------------------------------------------


class ChainExecutor:
    """Executes a fused region Schedule (planner.concat_schedules) through
    ONE shared cursor: each constituent op issues its planned accesses in
    order against the same cursor, so a whole multi-eqn region inherits the
    per-macro accounting guarantee — ledger accesses == region plan length,
    with every intermediate staying in the PlanePack packed domain.

    This is the execution half of the lowering compiler's region fusion
    (repro.cim.lower): lower() concatenates per-eqn schedules at trace
    time; the hybrid callable opens a ChainExecutor per region at run time.
    """

    def __init__(self, schedule: planner.Schedule,
                 backend: Optional[str] = None,
                 spec: Optional[ArraySpec] = None, mesh=None):
        self.cursor = ScheduleCursor(schedule, backend, spec=spec, mesh=mesh)

    # -- single-access ops (one planned step each) --------------------------
    def execute(self, a: PlanePack, b: PlanePack,
                ops: Sequence[str]) -> engine.Outputs:
        return self.cursor.execute(a, b, ops)

    def minimum(self, a: PlanePack, b: PlanePack) -> PlanePack:
        return _minimum_with(self.cursor, a, b)

    def maximum(self, a: PlanePack, b: PlanePack) -> PlanePack:
        return _maximum_with(self.cursor, a, b)

    def abs_(self, a: PlanePack) -> PlanePack:
        return _abs_with(self.cursor, a)

    def neg(self, a: PlanePack) -> PlanePack:
        zero = PlanePack.zeros_like(a)
        return self.cursor.execute(zero, a, ("sub",))["sub"]

    # -- multi-access macros (their planned segment of the region) ----------
    def multiply(self, a: PlanePack, b: PlanePack) -> PlanePack:
        return _multiply_with(self.cursor, a, b)

    def popcount(self, a: PlanePack) -> PlanePack:
        return _popcount_with(self.cursor, a)

    def reduce_sum(self, a: PlanePack) -> PlanePack:
        acc = _reduce_with(self.cursor, a,
                           n_steps=planner._log2_ceil(max(1, a.n_words)))
        return PlanePack(planes=acc.planes, n_bits=acc.n_bits,
                         signed=acc.signed, shape=())

    def matmul(self, a: jax.Array, b: jax.Array, n_bits: int,
               signed: bool = True) -> PlanePack:
        return _matmul_with(self.cursor, a, b, n_bits, signed=signed)

    def finish(self) -> None:
        self.cursor.finish()


def dot(a: jax.Array, b: jax.Array, n_bits: int = 8,
        backend: Optional[str] = None,
        spec: Optional[ArraySpec] = None, mesh=None) -> jax.Array:
    """Exact intN x intN -> int32 dot product of two [K] vectors."""
    a = jnp.asarray(a).reshape(1, -1)
    b = jnp.asarray(b).reshape(-1, 1)
    return matmul(a, b, n_bits=n_bits, backend=backend,
                  spec=spec, mesh=mesh)[0, 0]


# ---------------------------------------------------------------------------
# integer-level convenience wrappers (pack at entry, unpack at exit)
# ---------------------------------------------------------------------------


def multiply_ints(x: jax.Array, y: jax.Array, n_bits: int = 16,
                  signed: bool = True,
                  backend: Optional[str] = None,
                  spec: Optional[ArraySpec] = None) -> jax.Array:
    p = multiply(PlanePack.pack(x, n_bits, signed=signed),
                 PlanePack.pack(y, n_bits, signed=signed), backend=backend,
                 spec=spec)
    return p.unpack()


def relu_ints(x: jax.Array, n_bits: int = 16,
              backend: Optional[str] = None,
              spec: Optional[ArraySpec] = None) -> jax.Array:
    return relu(PlanePack.pack(x, n_bits), backend=backend,
                spec=spec).unpack()


def abs_ints(x: jax.Array, n_bits: int = 16,
             backend: Optional[str] = None,
             spec: Optional[ArraySpec] = None) -> jax.Array:
    return abs_(PlanePack.pack(x, n_bits), backend=backend,
                spec=spec).unpack()


def minimum_ints(x: jax.Array, y: jax.Array, n_bits: int = 16,
                 backend: Optional[str] = None,
                 spec: Optional[ArraySpec] = None) -> jax.Array:
    return minimum(PlanePack.pack(x, n_bits), PlanePack.pack(y, n_bits),
                   backend=backend, spec=spec).unpack()


def maximum_ints(x: jax.Array, y: jax.Array, n_bits: int = 16,
                 backend: Optional[str] = None,
                 spec: Optional[ArraySpec] = None) -> jax.Array:
    return maximum(PlanePack.pack(x, n_bits), PlanePack.pack(y, n_bits),
                   backend=backend, spec=spec).unpack()


def popcount_ints(x: jax.Array, n_bits: int = 16,
                  backend: Optional[str] = None,
                  spec: Optional[ArraySpec] = None) -> jax.Array:
    return popcount(PlanePack.pack(x, n_bits), backend=backend,
                    spec=spec).unpack()


def reduce_sum_ints(x: jax.Array, n_bits: int = 16, signed: bool = True,
                    backend: Optional[str] = None,
                    spec: Optional[ArraySpec] = None) -> jax.Array:
    return reduce_sum(PlanePack.pack(x, n_bits, signed=signed),
                      backend=backend, spec=spec).unpack()

"""Macro-op executors: multi-access CiM arithmetic over the single-access
engine, compiled to ONE jitted XLA program per schedule.

Every macro here executes a `planner.Schedule` through a cursor that allows
exactly the planned accesses (same order, same op-sets) and nothing else.
The cursor has two modes:

  * eager (charges=None): each step is one `engine.execute` /
    `dispatch.execute_tiled` call charging the ledger directly — tens of
    host round trips per macro, kept for direct cursor users and tests.
  * traced (charges=list): each step is the side-effect-free
    `execute_traced` form and appends its ledger charge to a
    charge-from-plan record instead of mutating anything.

`run_schedule_program` uses the traced mode to compile a whole schedule —
every access plus all the packed-domain peripherals between them (plane
shifts, truncations, selects, row-buffer strides) — into a single `jax.jit`
program, cached in the dispatch layer's bounded LRU keyed on schedule
structure. A warm macro is ONE XLA dispatch; the recorded PlannedCharges
replay into the ledger per invocation, so `ledger accesses ==
schedule.accesses` still holds by construction. ADRA step sequences are
width-heterogeneous (bit growth between accesses: partial products widen,
tree levels deepen), so the step program is an unrolled trace rather than a
`lax.scan` — XLA pipelines the unrolled chain and aliases the accumulator
buffers internally; scan would require shape-stable carries no ADRA
schedule has.

Operands, partial products, accumulators and tree levels all stay in the
PlanePack packed domain; the only integer codec entries are the caller's
own pack() at entry and unpack() at exit.

Macros:

  multiply   — shift-and-add; signed multipliers subtract the MSB partial
               product (single-access sub, the paper's headline op)
  abs_/relu  — sub-chain predicate + zero-cost peripheral select
  minimum/maximum — lt/gt predicate + select, one access each
  popcount   — pairwise plane tree, n-1 add accesses
  reduce_sum — log-stride tree reduction with row-buffer shifts
  dot/matmul — int x int -> wide-int contraction: one multiply over a
               broadcast [M, K_pad, N] layout + a stride-N reduction; the
               access count depends only on the bit width and K (word
               parallelism), never on M or N
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import dispatch, engine, opset, planner
from .accounting import LEDGER, PlannedCharges
from .array import ArraySpec
from .backends import get_backend
from .opset import CimOpError
from .planepack import PlanePack


class ScheduleCursor:
    """Executes a Schedule one access at a time, refusing to deviate.

    This is the accounting guarantee: a macro CANNOT issue an access its
    plan does not contain, so ledger accesses == schedule.accesses holds by
    construction, not by convention. With an ArraySpec the cursor routes
    every access through the banked tiling dispatcher instead of the
    infinite-array engine — each planned step then costs `plan.n_tiles`
    bank activations and the guarantee becomes ledger accesses ==
    schedule.placed_accesses. A mesh additionally spreads the tiles over
    its "data" axis via shard_map.

    With `charges` (a list) the cursor is in TRACED mode: accesses run
    through the side-effect-free `execute_traced` forms, the ledger is
    never touched, and every planned charge is appended to `charges` — the
    charge-from-plan record `run_schedule_program` replays per invocation
    of the compiled step program.
    """

    def __init__(self, schedule: planner.Schedule,
                 backend: Optional[str] = None,
                 spec: Optional[ArraySpec] = None,
                 mesh=None, charges: Optional[list] = None):
        self.schedule = schedule
        self.backend = backend
        self.spec = spec
        self.mesh = mesh
        self.charges = charges
        self._i = 0

    def step(self) -> planner.Step:
        if self._i >= len(self.schedule.steps):
            raise CimOpError(
                f"{self.schedule.macro}: executor exceeded its planned "
                f"{self.schedule.accesses} accesses")
        return self.schedule.steps[self._i]

    def execute(self, a: PlanePack, b: PlanePack,
                ops: Sequence[str]) -> engine.Outputs:
        step = self.step()
        if tuple(ops) != step.ops:
            raise CimOpError(
                f"{self.schedule.macro}: access {self._i} executes {ops!r} "
                f"but the plan says {step.ops!r}")
        self._i += 1
        if self.charges is not None:
            if self.spec is None:
                return engine.execute_traced(a, b, step.ops,
                                             backend=self.backend,
                                             charges=self.charges)
            return dispatch.execute_tiled_traced(
                a, b, step.ops, spec=self.spec, backend=self.backend,
                mesh=self.mesh, charges=self.charges)
        if self.spec is None:
            return engine.execute(a, b, step.ops, backend=self.backend)
        return dispatch.execute_tiled(a, b, step.ops, spec=self.spec,
                                      backend=self.backend, mesh=self.mesh)

    def charge_reduction(self, words32: float) -> None:
        """Inter-bank reduction traffic: charged directly in eager mode,
        recorded into the charge-from-plan record in traced mode."""
        if self.charges is not None:
            self.charges.append(("reduction", float(words32)))
        else:
            LEDGER.charge_reduction(words32)

    def charge_load(self, n_bits: int, n_words: int) -> None:
        """Operand-load row-writes for one STREAMED entry pack built inside
        this schedule (one load access per tile it lands on). Resident
        operands never reach this — they charge `charge_resident` instead."""
        n_tiles = self.spec.plan(n_words).n_tiles if self.spec else 1
        if self.charges is not None:
            self.charges.append(("load", n_bits, n_words, n_tiles))
        else:
            LEDGER.charge_load(n_bits, n_words, n_tiles=n_tiles)

    def charge_resident(self, n_bits: int, n_words: int) -> None:
        """One resident-operand reuse: entry pack (and its loads) skipped."""
        if self.charges is not None:
            self.charges.append(("resident", n_bits, n_words))
        else:
            LEDGER.charge_resident_reuse(n_bits, n_words)

    def remaining(self) -> Tuple[planner.Step, ...]:
        return self.schedule.steps[self._i:]

    def finish(self) -> None:
        if self._i != len(self.schedule.steps):
            raise CimOpError(
                f"{self.schedule.macro}: executed {self._i} of "
                f"{self.schedule.accesses} planned accesses")


# ---------------------------------------------------------------------------
# whole-schedule step programs: one jitted XLA dispatch per macro/region
# ---------------------------------------------------------------------------


class CompiledSchedule:
    """A jitted whole-schedule program plus its charge-from-plan record.

    Calling it replays the recorded ledger charges (computed once, at trace
    time, from the cursor-checked plan) and invokes the compiled program —
    ONE XLA dispatch for the entire schedule."""

    __slots__ = ("fn", "charges")

    def __init__(self, fn, charges: PlannedCharges):
        self.fn = fn
        self.charges = charges

    def __call__(self, *leaves):
        # invoke first, account after: a failed invocation must not leave
        # the ledger charged (or the dispatch counter bumped) for an
        # execution that never happened
        out = self.fn(*leaves)
        self.charges.replay()
        dispatch.count_dispatch()
        return out


def aval_sig(aval) -> Tuple:
    """Cache-key signature of one abstract value: shape, dtype and
    weak_type — anything jit would retrace on must be in OUR program-cache
    keys, or a cache hit could replay charges recorded from a different
    trace. The ONE definition of that discipline; the lowering compiler's
    region keys use it too."""
    return (tuple(aval.shape), str(aval.dtype),
            bool(getattr(aval, "weak_type", False)))


def _leaf_sig(x):
    """aval_sig of a concrete (or traced) input leaf."""
    try:
        return aval_sig(jax.core.get_aval(x))
    except Exception:
        return aval_sig(jnp.asarray(x))


def run_schedule_program(schedule: planner.Schedule, body, operands,
                         body_key=(), backend: Optional[str] = None,
                         spec: Optional[ArraySpec] = None, mesh=None,
                         donate: Tuple[int, ...] = ()):
    """Execute `body(cursor, *operands)` as ONE jitted XLA program.

    The whole schedule — every planned access plus the zero-cost
    packed-domain peripherals between them — is traced once into a single
    `jax.jit` program (unrolled: ADRA step sequences are width-
    heterogeneous, see module docstring) and cached in the dispatch layer's
    bounded LRU, keyed on the schedule structure, the body identity
    (`body_key`), operand signatures, backend, banked geometry and mesh. A
    repeated macro or fused region therefore hits end-to-end: zero retrace,
    one dispatch, and the PlannedCharges recorded at trace time replayed
    into the ledger — accesses == schedule.accesses, unbanked or banked,
    exactly as the eager cursor charged.

    `donate` names operand leaf positions whose buffers the program may
    reuse for its accumulator chain (jit donate_argnums); callers must only
    donate buffers that are dead after the call.

    Residency note: a cached program keeps its body closure (for a region:
    the Region and any closed-over ConstVal constants) alive until LRU
    eviction — that is what makes eviction-then-recompile possible. The
    bounded capacity (set_schedule_cache_capacity / REPRO_CIM_CACHE_CAPACITY)
    is the memory ceiling; long-lived servers that reload weights should
    size it accordingly.
    """
    bk_name = get_backend(backend).name
    leaves, treedef = jax.tree_util.tree_flatten(operands)
    key = ("step-program", schedule, tuple(body_key), treedef,
           tuple(_leaf_sig(x) for x in leaves), bk_name, spec, mesh,
           tuple(donate))
    prog = dispatch.program_cache_get(key)
    if prog is not None:
        return prog(*leaves)

    # operand-load charges are the BODY's responsibility (cur.charge_load /
    # charge_resident at the point a streamed entry pack is built), never
    # implied by an operand's type: a top-level PlanePack may already live
    # in rows, and eager-cursor execution must charge identically
    charges: list = []

    def fn(*flat):
        args = jax.tree_util.tree_unflatten(treedef, list(flat))
        cur = ScheduleCursor(schedule, bk_name, spec=spec, mesh=mesh,
                             charges=charges)
        out = body(cur, *args)
        cur.finish()
        return out

    jitted = jax.jit(fn, donate_argnums=tuple(donate))
    out = jitted(*leaves)       # first call traces: `charges` fills here
    planned = PlannedCharges(tuple(charges))
    if planned.accesses != schedule.accesses:   # pragma: no cover
        raise CimOpError(
            f"{schedule.macro}: traced {planned.accesses} accesses but the "
            f"plan has {schedule.accesses}")
    dispatch.program_cache_put(key, CompiledSchedule(jitted, planned))
    planned.replay()
    dispatch.count_dispatch()
    return out


def _place(sched: planner.Schedule, spec: Optional[ArraySpec],
           n_words: int) -> planner.Schedule:
    """Pin a schedule to the banked geometry (when given) — the single spot
    where placement meets compilation."""
    return sched.placed(spec, n_words) if spec is not None else sched


# ---------------------------------------------------------------------------
# peripheral select (zero accesses)
# ---------------------------------------------------------------------------


def select(pred: PlanePack, x: PlanePack, y: PlanePack) -> PlanePack:
    """Per-word mux: pred ? x : y, as predicated writeback in the periphery.

    The predicate is a 1-plane bitmap (an engine lt/eq/gt output); selection
    gates which operand's planes reach the row buffer — no array access.
    """
    if pred.planes.shape[0] != 1:
        raise CimOpError("select predicate must be a 1-plane bitmap")
    if x.signed != y.signed:
        n = max(x.n_bits, y.n_bits) + 1   # room so both read as signed
        x, y = x.extend_to(n).as_signed(True), y.extend_to(n).as_signed(True)
    x, y = x.align(y)
    mask = pred.planes[0]
    planes = (x.planes & mask) | (y.planes & ~mask)
    return PlanePack(planes=planes, n_bits=x.n_bits,
                     signed=x.signed, shape=x.shape)


def _plane_mask(bitmap: jax.Array, n_bits: int, like: PlanePack) -> PlanePack:
    """One multiplier-bit bitmap replicated across n_bits planes (the row
    driver asserting the same enable on every plane — free wiring)."""
    planes = jnp.broadcast_to(bitmap[None], (n_bits,) + bitmap.shape)
    return PlanePack(planes=planes, n_bits=n_bits, signed=True,
                     shape=like.shape)


# ---------------------------------------------------------------------------
# multiply
# ---------------------------------------------------------------------------


def _multiply_with(cur: ScheduleCursor, a: PlanePack,
                   b: PlanePack) -> PlanePack:
    """Shift-and-add over a cursor (shared by multiply and matmul)."""
    w = a.n_bits + b.n_bits
    a_ext = a.extend_to(w).as_signed(True)
    acc: Optional[PlanePack] = None
    for i in range(b.n_bits):
        last_signed = b.signed and i == b.n_bits - 1
        pp = cur.execute(a_ext, _plane_mask(b.planes[i], w, a), ("and",))
        # AND of a sign-extended word against a replicated enable bit is a
        # valid two's-complement word (a_ext or 0); shift = weight 2^i,
        # truncation keeps the arithmetic modulo 2^w
        shifted = pp["and"].as_signed(True).truncate_to(w - i).shift_up(i)
        if acc is None:
            if last_signed:            # 1-bit signed multiplier: b in {0,-1}
                zero = PlanePack.zeros_like(shifted)
                acc = cur.execute(zero, shifted, ("sub",))["sub"]
            else:
                acc = shifted
        else:
            op = "sub" if last_signed else "add"
            acc = cur.execute(acc, shifted, (op,))[op]
        acc = acc.truncate_to(w)
    return acc.as_signed(a.signed or b.signed)


def multiply(a: PlanePack, b: PlanePack,
             backend: Optional[str] = None,
             spec: Optional[ArraySpec] = None, mesh=None) -> PlanePack:
    """Exact product, (n_a + n_b)-plane result, 2*n_b - 1 accesses (times
    the tile count when placed on a banked `spec`) — compiled to one XLA
    dispatch."""
    if a.shape != b.shape:
        raise CimOpError(f"operand shapes differ: {a.shape} vs {b.shape}")
    sched = _place(planner.plan_multiply(a.n_bits, b.n_bits,
                                         signed_b=b.signed), spec, a.n_words)
    return run_schedule_program(sched, _multiply_with, (a, b),
                                body_key=("multiply",), backend=backend,
                                spec=spec, mesh=mesh)


# ---------------------------------------------------------------------------
# select-based macros: abs / relu / min / max
# ---------------------------------------------------------------------------


def _abs_with(cur: ScheduleCursor, a: PlanePack) -> PlanePack:
    zero = PlanePack.zeros_like(a)
    out = cur.execute(zero, a, ("sub", "lt"))
    return select(out["lt"], a, out["sub"])


def _relu_with(cur: ScheduleCursor, a: PlanePack) -> PlanePack:
    zero = PlanePack.zeros_like(a)
    out = cur.execute(a, zero, ("gt",))
    return select(out["gt"], a, zero)


def _minimum_with(cur: ScheduleCursor, a: PlanePack,
                  b: PlanePack) -> PlanePack:
    out = cur.execute(a, b, ("lt",))
    return select(out["lt"], a, b)


def _maximum_with(cur: ScheduleCursor, a: PlanePack,
                  b: PlanePack) -> PlanePack:
    out = cur.execute(a, b, ("gt",))
    return select(out["gt"], a, b)


def abs_(a: PlanePack, backend: Optional[str] = None,
         spec: Optional[ArraySpec] = None, mesh=None) -> PlanePack:
    """|a| in one access: (0 - a, 0 < a) together, then select a vs -a.
    Result is (n+1)-plane so abs(INT_MIN) is exact."""
    sched = _place(planner.plan_abs(a.n_bits), spec, a.n_words)
    return run_schedule_program(sched, _abs_with, (a,), body_key=("abs",),
                                backend=backend, spec=spec, mesh=mesh)


def relu(a: PlanePack, backend: Optional[str] = None,
         spec: Optional[ArraySpec] = None, mesh=None) -> PlanePack:
    """max(a, 0) in one access: the a > 0 predicate gates the writeback."""
    sched = _place(planner.plan_relu(a.n_bits), spec, a.n_words)
    return run_schedule_program(sched, _relu_with, (a,), body_key=("relu",),
                                backend=backend, spec=spec, mesh=mesh)


def minimum(a: PlanePack, b: PlanePack,
            backend: Optional[str] = None,
            spec: Optional[ArraySpec] = None, mesh=None) -> PlanePack:
    sched = _place(planner.plan_minimum(max(a.n_bits, b.n_bits)), spec,
                   a.n_words)
    return run_schedule_program(sched, _minimum_with, (a, b),
                                body_key=("minimum",), backend=backend,
                                spec=spec, mesh=mesh)


def maximum(a: PlanePack, b: PlanePack,
            backend: Optional[str] = None,
            spec: Optional[ArraySpec] = None, mesh=None) -> PlanePack:
    sched = _place(planner.plan_maximum(max(a.n_bits, b.n_bits)), spec,
                   a.n_words)
    return run_schedule_program(sched, _maximum_with, (a, b),
                                body_key=("maximum",), backend=backend,
                                spec=spec, mesh=mesh)


# ---------------------------------------------------------------------------
# popcount / reductions
# ---------------------------------------------------------------------------


def _popcount_with(cur: ScheduleCursor, a: PlanePack) -> PlanePack:
    level = [PlanePack(planes=a.planes[i:i + 1], n_bits=1, signed=False,
                       shape=a.shape)
             for i in range(a.n_bits)]
    while len(level) > 1:
        nxt = [cur.execute(level[j], level[j + 1], ("add",))["add"]
               for j in range(0, len(level) - 1, 2)]
        if len(level) % 2:
            nxt.append(level[-1])
        level = nxt
    return level[0]


def popcount(a: PlanePack, backend: Optional[str] = None,
             spec: Optional[ArraySpec] = None, mesh=None) -> PlanePack:
    """Set bits of each word's n-bit two's-complement pattern: pairwise
    plane tree, n - 1 add accesses."""
    sched = _place(planner.plan_popcount(a.n_bits), spec, a.n_words)
    return run_schedule_program(sched, _popcount_with, (a,),
                                body_key=("popcount",), backend=backend,
                                spec=spec, mesh=mesh)


def _reduce_with(cur: ScheduleCursor, acc: PlanePack,
                 n_steps: Optional[int] = None) -> PlanePack:
    """Log-stride reduction: each planned step shifts the row buffer by its
    stride and adds, so element 0 of each segment accumulates the segment
    sum; exactness relies on the pack's zero padding past the last word.

    `n_steps` bounds the walk to the next n_steps planned steps — required
    when the cursor belongs to a fused region schedule that continues past
    this reduction; None consumes everything remaining (the standalone
    reduce/matmul cursors, whose plans end with the reduction).

    On a banked cursor the shift moves words BETWEEN tiles whenever the
    stride reaches across a tile boundary — that movement is the inter-bank
    reduction traffic the ledger charges (fraction of words crossing scales
    with stride/tile_words, capped at all of them)."""
    if not acc.signed:
        acc = acc.extend_to(acc.n_bits + 1).as_signed(True)
    steps = cur.remaining()
    if n_steps is not None:
        steps = steps[:n_steps]
    for step in steps:
        if cur.spec is not None and step.stride:
            plan = cur.spec.plan(acc.n_words)
            if plan.n_tiles > 1:
                frac = min(1.0, step.stride / plan.tile_words)
                cur.charge_reduction(
                    acc.n_words * frac * acc.n_bits / 32.0)
        shifted = acc.shift_elements(step.stride)
        acc = cur.execute(acc, shifted, ("add",))["add"]
    return acc


def _reduce_sum_body(cur: ScheduleCursor, a: PlanePack) -> PlanePack:
    acc = _reduce_with(cur, a)
    return PlanePack(planes=acc.planes, n_bits=acc.n_bits,
                     signed=acc.signed, shape=())


def reduce_sum(a: PlanePack, backend: Optional[str] = None,
               spec: Optional[ArraySpec] = None, mesh=None) -> PlanePack:
    """Sum of ALL logical elements, ceil(log2(n_words)) accesses; returns a
    scalar-shaped pack (element 0 of the tree)."""
    sched = _place(planner.plan_reduce_sum(a.n_words, stride=1,
                                           n_bits=a.n_bits), spec, a.n_words)
    return run_schedule_program(sched, _reduce_sum_body, (a,),
                                body_key=("reduce_sum",), backend=backend,
                                spec=spec, mesh=mesh)


# ---------------------------------------------------------------------------
# quantized dot / matmul
# ---------------------------------------------------------------------------


def matmul_rhs_pack(b: jax.Array, m: int, n_bits: int,
                    signed: bool = True) -> PlanePack:
    """The expanded [M, K_pad, N] rhs entry pack of a matmul — the plane
    stack a ResidentSet pins so warm calls skip building (and loading) it.
    Built OUTSIDE any trace: the result is a concrete pack whose planes can
    live in array rows across calls."""
    b = jnp.asarray(b)
    if b.ndim != 2:
        raise CimOpError(f"matmul rhs must be [K, N], got {b.shape}")
    k, n = b.shape
    k_pad = 1 << planner._log2_ceil(k)
    b_exp = jnp.zeros((m, k_pad, n), jnp.int32).at[:, :k, :].set(
        jnp.broadcast_to(b[None, :, :], (m, k, n)).astype(jnp.int32))
    return PlanePack.pack(b_exp, n_bits, signed=signed)


def batched_matmul_rhs_pack(b: jax.Array, m: int, n_bits: int,
                            signed: bool = True) -> PlanePack:
    """The expanded [B_flat * M, K_pad, N] rhs entry pack of a batched
    matmul ([*B, K, N] rhs broadcast over the lhs's M rows within each
    batch element) — the plane stack a ResidentSet pins for an attention
    K^T / V side so warm decode streams only the query past resident rows.
    Built OUTSIDE any trace, like `matmul_rhs_pack`."""
    b = jnp.asarray(b)
    if b.ndim < 3:
        raise CimOpError(f"batched matmul rhs must be [*B, K, N], "
                         f"got {b.shape}")
    k, n = int(b.shape[-2]), int(b.shape[-1])
    bf = 1
    for d in b.shape[:-2]:
        bf *= int(d)
    k_pad = 1 << planner._log2_ceil(k)
    b3 = b.reshape(bf, k, n)
    b_exp = jnp.zeros((bf * m, k_pad, n), jnp.int32).at[:, :k, :].set(
        jnp.broadcast_to(b3[:, None, :, :], (bf, m, k, n))
        .astype(jnp.int32).reshape(bf * m, k, n))
    return PlanePack.pack(b_exp, n_bits, signed=signed)


def _batched_matmul_with(cur: ScheduleCursor, a: jax.Array, b,
                         n_bits: int, signed: bool = True,
                         b_pack: Optional[PlanePack] = None) -> PlanePack:
    """The batched matmul dataflow over an open cursor: the batch dims
    flatten onto the word axis, the expanded operands are
    [B_flat * M, K_pad, N], and the step sequence — one shift-and-add
    multiply plus a log2(K_pad) stride-N tree reduction — is the 2-D
    `_matmul_with` dataflow verbatim with M' = B_flat * M. Correctness of
    the shared reduction follows from the 2-D argument: each (b, m) block
    is a contiguous K_pad * N word segment whose k = 0 slice alone is
    gathered at exit; cross-block garbage lands on discarded k > 0 slots.

    With `b_pack` (a pinned `batched_matmul_rhs_pack`) the rhs side is
    RESIDENT: its per-batch expansion and entry pack are skipped and the
    ledger charges one zero-load reuse — decode's KV sides stay in rows
    while only the streamed lhs (the query) pays loads."""
    a = jnp.asarray(a)
    if a.ndim < 3:
        raise CimOpError(f"batched matmul needs [*B, M, K] lhs, "
                         f"got {a.shape}")
    m, k = int(a.shape[-2]), int(a.shape[-1])
    bdims = tuple(int(d) for d in a.shape[:-2])
    bf = 1
    for d in bdims:
        bf *= d
    a2 = a.reshape(bf * m, k)
    if b_pack is not None:
        mm, k_pad, n = b_pack.shape
        if mm != bf * m or k > k_pad:
            raise CimOpError(
                f"resident rhs pack {b_pack.shape} does not match lhs "
                f"{a.shape} (expanded for {bf}x{m} rows, K_pad={k_pad})")
        pb = b_pack
    else:
        b = jnp.asarray(b)
        if b.ndim != a.ndim or tuple(int(d) for d in b.shape[:-2]) != bdims \
                or int(b.shape[-2]) != k:
            raise CimOpError(
                f"batched matmul needs [*B,M,K] x [*B,K,N], "
                f"got {a.shape} {b.shape}")
        n = int(b.shape[-1])
        k_pad = 1 << planner._log2_ceil(k)
        b3 = b.reshape(bf, k, n)
        b_exp = jnp.zeros((bf * m, k_pad, n), jnp.int32).at[:, :k, :].set(
            jnp.broadcast_to(b3[:, None, :, :], (bf, m, k, n))
            .astype(jnp.int32).reshape(bf * m, k, n))
        pb = PlanePack.pack(b_exp, n_bits, signed=signed)
        cur.charge_load(n_bits, pb.n_words)
    a_exp = jnp.zeros((bf * m, k_pad, n), jnp.int32).at[:, :k, :].set(
        jnp.broadcast_to(a2[:, :, None], (bf * m, k, n)).astype(jnp.int32))
    pa = PlanePack.pack(a_exp, n_bits, signed=signed)
    cur.charge_load(n_bits, pa.n_words)
    if b_pack is not None:
        cur.charge_resident(n_bits, pb.n_words)

    prod = _multiply_with(cur, pa, pb)
    acc = _reduce_with(cur, prod, n_steps=planner._log2_ceil(k_pad))

    idx = (np.arange(bf * m)[:, None] * (k_pad * n) + np.arange(n)[None, :])
    return acc.take_words(idx.reshape(-1), bdims + (m, n))


def _matmul_with(cur: ScheduleCursor, a: jax.Array, b,
                 n_bits: int, signed: bool = True,
                 b_pack: Optional[PlanePack] = None) -> PlanePack:
    """The matmul dataflow over an open cursor: broadcast [M, K_pad, N]
    operand layout, ONE shift-and-add multiply, log2(K_pad) stride-N tree
    reduction, result gathered to an [M, N] pack. Shared by the standalone
    `matmul` wrapper and the lowering compiler's fused-region executor
    (which passes a region cursor mid-schedule).

    With `b_pack` (a pinned `matmul_rhs_pack`) the rhs side is RESIDENT:
    its expansion and entry pack are skipped entirely — the streamed lhs
    pays its load, the rhs charges one zero-load resident reuse — which is
    the paper's stored-operand execution made literal."""
    a = jnp.asarray(a)
    if b_pack is not None:
        if a.ndim != 2:
            raise CimOpError(f"matmul needs [M,K] lhs, got {a.shape}")
        m, k = a.shape
        mm, k_pad, n = b_pack.shape
        if mm != m or k > k_pad:
            raise CimOpError(
                f"resident rhs pack {b_pack.shape} does not match lhs "
                f"{a.shape} (expanded for M={mm}, K_pad={k_pad})")
        pb = b_pack
    else:
        b = jnp.asarray(b)
        if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
            raise CimOpError(
                f"matmul needs [M,K] x [K,N], got {a.shape} {b.shape}")
        m, k = a.shape
        n = b.shape[1]
        k_pad = 1 << planner._log2_ceil(k)
        b_exp = jnp.zeros((m, k_pad, n), jnp.int32).at[:, :k, :].set(
            jnp.broadcast_to(b[None, :, :], (m, k, n)).astype(jnp.int32))
        pb = PlanePack.pack(b_exp, n_bits, signed=signed)
        cur.charge_load(n_bits, pb.n_words)
    a_exp = jnp.zeros((m, k_pad, n), jnp.int32).at[:, :k, :].set(
        jnp.broadcast_to(a[:, :, None], (m, k, n)).astype(jnp.int32))
    pa = PlanePack.pack(a_exp, n_bits, signed=signed)
    cur.charge_load(n_bits, pa.n_words)
    if b_pack is not None:
        cur.charge_resident(n_bits, pb.n_words)

    prod = _multiply_with(cur, pa, pb)
    acc = _reduce_with(cur, prod, n_steps=planner._log2_ceil(k_pad))

    # k = 0 slice of each row: flat(m, 0, n) = m * K_pad * N + n
    idx = (np.arange(m)[:, None] * (k_pad * n) + np.arange(n)[None, :])
    return acc.take_words(idx.reshape(-1), (m, n))


def matmul(a: jax.Array, b: Optional[jax.Array] = None, n_bits: int = 8,
           backend: Optional[str] = None,
           spec: Optional[ArraySpec] = None, mesh=None,
           b_pack: Optional[PlanePack] = None) -> jax.Array:
    """Exact intN x intN -> int32 matmul through the CiM array.

    a : int [M, K], b : int [K, N], entries representable in n_bits signed.
    Lowered to ONE shift-and-add multiply over the broadcast [M, K_pad, N]
    operand layout plus a log2(K_pad) stride-N tree reduction — the whole
    contraction is (2*n_bits - 1) + ceil(log2 K) accesses regardless of M
    and N. Word-level parallelism is the CiM scaling argument; the operand
    broadcast is the (honest) cost of it.

    With `b_pack` (a pinned `matmul_rhs_pack`; `b` may then be None) the
    rhs is RESIDENT: the schedule names it so, the compiled program keys on
    that residency, and only the lhs pays operand-load charges.
    """
    a = jnp.asarray(a)
    if b_pack is not None:
        m2, k_pad, n = b_pack.shape
        sched = _place(planner.plan_matmul(k_pad, n, n_bits=n_bits,
                                           signed=True, resident_rhs=True),
                       spec, m2 * k_pad * n)

        def body_res(cur, a_, bp):
            return _matmul_with(cur, a_, None, n_bits, b_pack=bp).unpack()

        return run_schedule_program(sched, body_res, (a, b_pack),
                                    body_key=("matmul", n_bits, "resident"),
                                    backend=backend, spec=spec, mesh=mesh)
    b = jnp.asarray(b)
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise CimOpError(f"matmul needs [M,K] x [K,N], got {a.shape} {b.shape}")
    m, k = a.shape
    n = b.shape[1]
    k_pad = 1 << planner._log2_ceil(k)
    sched = _place(planner.plan_matmul(k, n, n_bits=n_bits, signed=True),
                   spec, m * k_pad * n)

    def body(cur, a_, b_):
        # the broadcast-layout build, the entry packs and the exit unpack
        # all live INSIDE the step program — the whole contraction is one
        # XLA dispatch end to end
        return _matmul_with(cur, a_, b_, n_bits).unpack()

    return run_schedule_program(sched, body, (a, b),
                                body_key=("matmul", n_bits),
                                backend=backend, spec=spec, mesh=mesh)


def batched_matmul(a: jax.Array, b: Optional[jax.Array] = None,
                   n_bits: int = 8, backend: Optional[str] = None,
                   spec: Optional[ArraySpec] = None, mesh=None,
                   b_pack: Optional[PlanePack] = None) -> jax.Array:
    """Exact batched intN x intN -> int32 contraction through the CiM array.

    a : int [*B, M, K], b : int [*B, K, N] — every batch element contracts
    in the SAME (2*n_bits - 1) + ceil(log2 K) accesses as a single 2-D
    matmul: the batch dims flatten onto the word/tile axis, so batching
    scales words (and tile placement) but never the per-tile access count.

    With `b_pack` (a pinned `batched_matmul_rhs_pack`; `b` may then be
    None) the rhs is RESIDENT and only the lhs pays operand loads — the
    decode-attention execution where K^T and V live in rows and the query
    streams past them.
    """
    a = jnp.asarray(a)
    if a.ndim < 3:
        raise CimOpError(f"batched matmul needs [*B, M, K] lhs, "
                         f"got {a.shape}")
    m, k = int(a.shape[-2]), int(a.shape[-1])
    bf = 1
    for d in a.shape[:-2]:
        bf *= int(d)
    if b_pack is not None:
        mm, k_pad, n = b_pack.shape
        sched = _place(planner.plan_batched_matmul(
            bf, k_pad, n, n_bits=n_bits, signed=True, resident_rhs=True),
            spec, mm * k_pad * n)

        def body_res(cur, a_, bp):
            return _batched_matmul_with(cur, a_, None, n_bits,
                                        b_pack=bp).unpack()

        return run_schedule_program(
            sched, body_res, (a, b_pack),
            body_key=("batched_matmul", n_bits, "resident"),
            backend=backend, spec=spec, mesh=mesh)
    b = jnp.asarray(b)
    if b.ndim != a.ndim or b.shape[:-2] != a.shape[:-2] \
            or int(b.shape[-2]) != k:
        raise CimOpError(
            f"batched matmul needs [*B,M,K] x [*B,K,N], got {a.shape} "
            f"{b.shape}")
    n = int(b.shape[-1])
    k_pad = 1 << planner._log2_ceil(k)
    sched = _place(planner.plan_batched_matmul(bf, k, n, n_bits=n_bits,
                                               signed=True),
                   spec, bf * m * k_pad * n)

    def body(cur, a_, b_):
        return _batched_matmul_with(cur, a_, b_, n_bits).unpack()

    return run_schedule_program(sched, body, (a, b),
                                body_key=("batched_matmul", n_bits),
                                backend=backend, spec=spec, mesh=mesh)


# ---------------------------------------------------------------------------
# chain executor: one cursor for a fused multi-eqn region
# ---------------------------------------------------------------------------


class ChainExecutor:
    """Executes a fused region Schedule (planner.concat_schedules) through
    ONE shared cursor: each constituent op issues its planned accesses in
    order against the same cursor, so a whole multi-eqn region inherits the
    per-macro accounting guarantee — ledger accesses == region plan length,
    with every intermediate staying in the PlanePack packed domain.

    This is the execution half of the lowering compiler's region fusion
    (repro.cim.lower): lower() concatenates per-eqn schedules at trace
    time; the hybrid callable compiles each region into one step program
    (run_schedule_program) whose body drives a ChainExecutor over the
    program's traced cursor (`from_cursor`).
    """

    def __init__(self, schedule: planner.Schedule,
                 backend: Optional[str] = None,
                 spec: Optional[ArraySpec] = None, mesh=None,
                 charges: Optional[list] = None):
        self.cursor = ScheduleCursor(schedule, backend, spec=spec, mesh=mesh,
                                     charges=charges)

    @classmethod
    def from_cursor(cls, cursor: ScheduleCursor) -> "ChainExecutor":
        """Wrap an already-open cursor (the step program's traced one)."""
        self = cls.__new__(cls)
        self.cursor = cursor
        return self

    # -- single-access ops (one planned step each) --------------------------
    def execute(self, a: PlanePack, b: PlanePack,
                ops: Sequence[str]) -> engine.Outputs:
        return self.cursor.execute(a, b, ops)

    def minimum(self, a: PlanePack, b: PlanePack) -> PlanePack:
        return _minimum_with(self.cursor, a, b)

    def maximum(self, a: PlanePack, b: PlanePack) -> PlanePack:
        return _maximum_with(self.cursor, a, b)

    def abs_(self, a: PlanePack) -> PlanePack:
        return _abs_with(self.cursor, a)

    def neg(self, a: PlanePack) -> PlanePack:
        zero = PlanePack.zeros_like(a)
        return self.cursor.execute(zero, a, ("sub",))["sub"]

    # -- multi-access macros (their planned segment of the region) ----------
    def multiply(self, a: PlanePack, b: PlanePack) -> PlanePack:
        return _multiply_with(self.cursor, a, b)

    def popcount(self, a: PlanePack) -> PlanePack:
        return _popcount_with(self.cursor, a)

    def reduce_sum(self, a: PlanePack) -> PlanePack:
        acc = _reduce_with(self.cursor, a,
                           n_steps=planner._log2_ceil(max(1, a.n_words)))
        return PlanePack(planes=acc.planes, n_bits=acc.n_bits,
                         signed=acc.signed, shape=())

    def matmul(self, a: jax.Array, b, n_bits: int,
               signed: bool = True,
               b_pack: Optional[PlanePack] = None) -> PlanePack:
        return _matmul_with(self.cursor, a, b, n_bits, signed=signed,
                            b_pack=b_pack)

    def batched_matmul(self, a: jax.Array, b, n_bits: int,
                       signed: bool = True,
                       b_pack: Optional[PlanePack] = None) -> PlanePack:
        return _batched_matmul_with(self.cursor, a, b, n_bits, signed=signed,
                                    b_pack=b_pack)

    def finish(self) -> None:
        self.cursor.finish()


def dot(a: jax.Array, b: jax.Array, n_bits: int = 8,
        backend: Optional[str] = None,
        spec: Optional[ArraySpec] = None, mesh=None) -> jax.Array:
    """Exact intN x intN -> int32 dot product of two [K] vectors."""
    a = jnp.asarray(a).reshape(1, -1)
    b = jnp.asarray(b).reshape(-1, 1)
    return matmul(a, b, n_bits=n_bits, backend=backend,
                  spec=spec, mesh=mesh)[0, 0]


# ---------------------------------------------------------------------------
# integer-level convenience wrappers (pack at entry, unpack at exit)
# ---------------------------------------------------------------------------


def multiply_ints(x: jax.Array, y: jax.Array, n_bits: int = 16,
                  signed: bool = True,
                  backend: Optional[str] = None,
                  spec: Optional[ArraySpec] = None) -> jax.Array:
    p = multiply(PlanePack.pack(x, n_bits, signed=signed),
                 PlanePack.pack(y, n_bits, signed=signed), backend=backend,
                 spec=spec)
    return p.unpack()


def relu_ints(x: jax.Array, n_bits: int = 16,
              backend: Optional[str] = None,
              spec: Optional[ArraySpec] = None) -> jax.Array:
    return relu(PlanePack.pack(x, n_bits), backend=backend,
                spec=spec).unpack()


def abs_ints(x: jax.Array, n_bits: int = 16,
             backend: Optional[str] = None,
             spec: Optional[ArraySpec] = None) -> jax.Array:
    return abs_(PlanePack.pack(x, n_bits), backend=backend,
                spec=spec).unpack()


def minimum_ints(x: jax.Array, y: jax.Array, n_bits: int = 16,
                 backend: Optional[str] = None,
                 spec: Optional[ArraySpec] = None) -> jax.Array:
    return minimum(PlanePack.pack(x, n_bits), PlanePack.pack(y, n_bits),
                   backend=backend, spec=spec).unpack()


def maximum_ints(x: jax.Array, y: jax.Array, n_bits: int = 16,
                 backend: Optional[str] = None,
                 spec: Optional[ArraySpec] = None) -> jax.Array:
    return maximum(PlanePack.pack(x, n_bits), PlanePack.pack(y, n_bits),
                   backend=backend, spec=spec).unpack()


def popcount_ints(x: jax.Array, n_bits: int = 16,
                  backend: Optional[str] = None,
                  spec: Optional[ArraySpec] = None) -> jax.Array:
    return popcount(PlanePack.pack(x, n_bits), backend=backend,
                    spec=spec).unpack()


def reduce_sum_ints(x: jax.Array, n_bits: int = 16, signed: bool = True,
                    backend: Optional[str] = None,
                    spec: Optional[ArraySpec] = None) -> jax.Array:
    return reduce_sum(PlanePack.pack(x, n_bits, signed=signed),
                      backend=backend, spec=spec).unpack()

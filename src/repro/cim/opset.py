"""The CiM engine's op catalogue: everything ONE ADRA access can emit.

One asymmetric dual-row activation yields the signal set {OR, AND, B} (and A
via the OAI21 gate). From that single access the peripheral logic derives, in
the same pass: the addition and subtraction plane stacks (dual-output module
design), the carry-outs, the lt/eq/gt comparison bitmaps, and any of the 16
two-input Boolean functions. Every backend implements exactly this catalogue
over packed uint32 bit-planes; the engine validates requests against it.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


class CimOpError(ValueError):
    """A malformed CiM op request (unknown op, empty/duplicate op-set, bad
    Boolean function name). Subclasses ValueError so pre-existing callers
    catching ValueError keep working; new callers can catch CiM failures
    specifically."""

#: the 16 two-input Boolean functions, minterm order (see repro.core.adra)
BOOLEAN_OPS: Tuple[str, ...] = (
    "false", "nor", "a_and_not_b", "not_b", "not_a_and_b", "not_a",
    "xor", "nand", "and", "xnor", "a", "a_or_not_b", "b", "not_a_or_b",
    "or", "true",
)

#: arithmetic plane stacks — (n_bits+1) planes incl. the overflow module
ARITH_OPS: Tuple[str, ...] = ("add", "sub")

#: per-word predicate bitmaps — one uint32 row
PREDICATE_OPS: Tuple[str, ...] = ("lt", "eq", "gt", "carry_add", "carry_sub")

ALL_OPS: Tuple[str, ...] = ARITH_OPS + PREDICATE_OPS + BOOLEAN_OPS

#: predicates derived from the subtraction ripple chain
_SUB_DERIVED = ("sub", "lt", "eq", "gt", "carry_sub")
_ADD_DERIVED = ("add", "carry_add")


def validate_ops(ops: Tuple[str, ...]) -> Tuple[str, ...]:
    ops = tuple(ops)
    if not ops:
        raise CimOpError("empty op request")
    for op in ops:
        if op not in ALL_OPS:
            raise CimOpError(f"unknown CiM op {op!r}; valid: {ALL_OPS}")
    if len(set(ops)) != len(ops):
        raise CimOpError(f"duplicate ops in request: {ops}")
    return ops


def needs_add_chain(ops) -> bool:
    return any(o in _ADD_DERIVED for o in ops)


def needs_sub_chain(ops) -> bool:
    return any(o in _SUB_DERIVED for o in ops)


def out_rows(op: str, n_bits: int) -> int:
    """Plane rows of one output: arith stacks carry the overflow plane."""
    if op in ARITH_OPS:
        return n_bits + 1
    if op in PREDICATE_OPS:
        return 1
    return n_bits


def out_signed(op: str) -> bool:
    return op in ARITH_OPS


def boolean_plane(fn: str, or_: jax.Array, and_: jax.Array,
                  b: jax.Array, a: jax.Array) -> jax.Array:
    """One Boolean-function plane from the single-access signal set.

    Composed exactly from {OR, AND, B, A} and complements — the signals the
    three SAs + OAI gate provide — in full-width uint32 bitwise form.
    """
    if fn == "false":
        return jnp.zeros_like(or_)
    if fn == "true":
        return ~jnp.zeros_like(or_)
    return {
        "nor": lambda: ~or_,
        "a_and_not_b": lambda: or_ & ~b,
        "not_b": lambda: ~b,
        "not_a_and_b": lambda: or_ & ~a,
        "not_a": lambda: ~a,
        "xor": lambda: or_ & ~and_,
        "nand": lambda: ~and_,
        "and": lambda: and_,
        "xnor": lambda: ~(or_ & ~and_),
        "a": lambda: a,
        "a_or_not_b": lambda: ~(or_ & ~a),   # a | ~b == ~(~a & b)
        "b": lambda: b,
        "not_a_or_b": lambda: ~(or_ & ~b),   # ~a | b == ~(a & ~b)
        "or": lambda: or_,
    }[fn]()


def oai21_recover_a_planes(or_: jax.Array, and_: jax.Array,
                           b: jax.Array) -> jax.Array:
    """A = NOT(NAND(A,B) * (B + NOR(A,B))) — the OAI21 gate, plane-wise."""
    return ~(~and_ & (b | ~or_))

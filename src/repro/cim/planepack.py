"""PlanePack: packed bit-plane pytree — the CiM engine's working format.

The ADRA array never leaves bit-serial form between operations: the output
planes of one op are the input planes of the next. PlanePack makes that true
on TPU too. It carries the packed uint32 plane stack (plane p = bit p of 32
words per lane element) plus the static metadata (n_bits, signedness, logical
shape) needed to re-assemble integers — so chained CiM ops stay packed across
calls instead of round-tripping through pack_bitplanes/unpack_bitplanes.

Registered as a JAX pytree: PlanePacks flow through jit/vmap/scan with the
plane stack as the single traced leaf and the metadata static.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.bitplane import pack_bitplanes, unpack_bitplanes


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PlanePack:
    """Packed bit-plane representation of an integer tensor.

    planes : uint32[n_bits, W] — plane p, lane word w, bit j holds bit p of
             logical element 32*w + j (LSB-first planes, two's complement).
    n_bits : word width (number of planes).
    signed : whether the MSB plane is a two's-complement sign plane.
    shape  : logical tensor shape (prod(shape) = number of valid words;
             the lane dim is padded to a multiple of 32).
    """

    planes: jax.Array
    n_bits: int
    signed: bool
    shape: Tuple[int, ...]

    # -- pytree protocol: planes traced, metadata static --------------------
    def tree_flatten(self):
        return (self.planes,), (self.n_bits, self.signed, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        n_bits, signed, shape = aux
        return cls(planes=children[0], n_bits=n_bits, signed=signed, shape=shape)

    # -- construction / materialization ------------------------------------
    @classmethod
    def pack(cls, x: jax.Array, n_bits: int, signed: bool = True) -> "PlanePack":
        """Integer tensor (any shape) -> PlanePack. The ONLY place a CiM
        pipeline pays the transpose-and-pack cost."""
        x = jnp.asarray(x)
        shape = tuple(x.shape)
        return cls(planes=pack_bitplanes(x, n_bits), n_bits=n_bits,
                   signed=signed, shape=shape)

    @property
    def n_words(self) -> int:
        n = 1
        for d in self.shape:
            n *= int(d)
        return n

    def unpack(self) -> jax.Array:
        """PlanePack -> int32 tensor of the logical shape (pipeline exit)."""
        vals = unpack_bitplanes(self.planes, self.n_words, signed=self.signed)
        return vals.reshape(self.shape)

    # -- packed-domain transforms (no pack/unpack round trip) ---------------
    def extend_to(self, n_bits: int) -> "PlanePack":
        """Widen to n_bits planes entirely in the packed domain: replicate the
        sign plane (signed) or append zero planes (unsigned). This is how a
        chained pipeline aligns an (n+1)-bit result with an n-bit operand."""
        if n_bits < self.n_bits:
            raise ValueError(f"cannot narrow {self.n_bits} -> {n_bits} planes")
        if n_bits == self.n_bits:
            return self
        extra = n_bits - self.n_bits
        if self.signed:
            fill = jnp.broadcast_to(self.planes[-1:],
                                    (extra,) + self.planes.shape[1:])
        else:
            fill = jnp.zeros((extra,) + self.planes.shape[1:], jnp.uint32)
        return PlanePack(planes=jnp.concatenate([self.planes, fill], axis=0),
                         n_bits=n_bits, signed=self.signed, shape=self.shape)

    def align(self, other: "PlanePack") -> Tuple["PlanePack", "PlanePack"]:
        """Widen both operands to the common width, packed-domain only."""
        n = max(self.n_bits, other.n_bits)
        return self.extend_to(n), other.extend_to(n)

    # -- peripheral wiring for the macro-op planner -------------------------
    # These model zero-access peripheral operations of the CiM array: plane
    # re-weighting (shift), writeback truncation, signedness reinterpretation,
    # and row-buffer data movement. None of them touch the integer codecs and
    # none of them charge the ledger — only engine accesses do.

    def as_signed(self, signed: bool = True) -> "PlanePack":
        """Reinterpret the same planes under a different signedness. Caller
        asserts the value is representable (e.g. an AND partial product of a
        sign-extended operand IS a valid two's-complement word)."""
        if signed == self.signed:
            return self
        return dataclasses.replace(self, signed=signed)

    def shift_up(self, k: int) -> "PlanePack":
        """Multiply by 2^k: insert k zero planes below the LSB (pure plane
        re-indexing — the shift-and-add multiplier's shifted operand)."""
        if k < 0:
            raise ValueError(f"negative plane shift {k}")
        if k == 0:
            return self
        zeros = jnp.zeros((k,) + self.planes.shape[1:], jnp.uint32)
        return dataclasses.replace(
            self, planes=jnp.concatenate([zeros, self.planes], axis=0),
            n_bits=self.n_bits + k)

    def truncate_to(self, n_bits: int) -> "PlanePack":
        """Keep the lowest n_bits planes: arithmetic modulo 2^n_bits (the
        writeback simply not storing the high planes)."""
        if n_bits > self.n_bits:
            raise ValueError(f"cannot truncate {self.n_bits} -> {n_bits} planes")
        if n_bits == self.n_bits:
            return self
        return dataclasses.replace(self, planes=self.planes[:n_bits],
                                   n_bits=n_bits)

    def shift_elements(self, k: int) -> "PlanePack":
        """Element j <- element j + k (zero fill past the end), per plane —
        the row-buffer shuffle a tree reduction steps with. Operates on the
        packed bitstream directly: element e lives at bit e of the
        32-words-per-lane stream, so this is a k-bit funnel shift."""
        if k < 0:
            raise ValueError(f"negative element shift {k}")
        word, bit = divmod(k, 32)
        p = self.planes
        n, w = p.shape
        if word >= w:
            return dataclasses.replace(self, planes=jnp.zeros_like(p))
        if word:
            p = jnp.concatenate(
                [p[:, word:], jnp.zeros((n, word), jnp.uint32)], axis=1)
        if bit:
            hi = jnp.concatenate(
                [p[:, 1:], jnp.zeros((n, 1), jnp.uint32)], axis=1)
            p = (p >> jnp.uint32(bit)) | (hi << jnp.uint32(32 - bit))
        return dataclasses.replace(self, planes=p)

    def take_words(self, flat_indices, shape: Tuple[int, ...]) -> "PlanePack":
        """Gather logical elements by flat index into a new pack of `shape`.

        Plane-level bit gather + lane repack (row-buffer permutation); never
        reassembles integers, so chained pipelines stay codec-free.
        """
        idx = jnp.asarray(flat_indices, jnp.uint32).reshape(-1)
        word = (idx // 32).astype(jnp.int32)
        bit = idx % 32
        bits = (self.planes[:, word] >> bit) & jnp.uint32(1)   # [n_bits, N]
        n = idx.shape[0]
        pad = (-n) % 32
        if pad:
            bits = jnp.pad(bits, ((0, 0), (0, pad)))
        weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
        planes = jnp.sum(bits.reshape(self.n_bits, -1, 32) * weights, axis=-1)
        return PlanePack(planes=planes, n_bits=self.n_bits,
                         signed=self.signed, shape=tuple(shape))

    @classmethod
    def zeros_like(cls, other: "PlanePack") -> "PlanePack":
        """An all-zero pack of the same geometry (free: the array's zero row)."""
        return dataclasses.replace(other, planes=jnp.zeros_like(other.planes))


# ---------------------------------------------------------------------------
# SECDED over plane columns: parity planes for the resident region
# ---------------------------------------------------------------------------
#
# In the transposed layout one logical element occupies a COLUMN: bit j of
# lane word w across the n_bits plane rows. A Hamming(SECDED) code across the
# plane index therefore protects every element independently, and because the
# planes are packed uint32 the whole codec is a handful of bitwise XORs over
# plane rows — one parity plane per Hamming check bit plus one overall-parity
# plane, stored as extra rows next to the data planes they protect.
#
# Guarantees per column (element): any single bit flip is corrected exactly,
# any double flip is detected (never miscorrected); three or more flips may
# alias a valid syndrome and miscorrect — the classic SECDED bound, asserted
# by tests/test_cim_faults.py.
#
# These helpers are numpy-eager on purpose: ECC verify/correct runs at
# Python call time on CONCRETE pinned planes (residency is disabled under
# tracers), never inside a compiled program.

import numpy as np


def _hamming_data_positions(m: int) -> list:
    """Hamming codeword positions of the m data planes: the first m
    positive integers that are not powers of two (powers of two are the
    check-bit positions)."""
    pos, p = [], 3
    while len(pos) < m:
        if p & (p - 1):
            pos.append(p)
        p += 1
    return pos


def ecc_plane_count(n_bits: int) -> int:
    """Parity planes protecting `n_bits` data planes: r Hamming check
    planes (2^r >= n_bits + r + 1) plus the overall-parity plane that
    upgrades single-error-correction to double-error-detection."""
    if n_bits < 1:
        raise ValueError(f"cannot protect {n_bits} planes")
    r = 0
    while (1 << r) < n_bits + r + 1:
        r += 1
    return r + 1


def ecc_encode(planes) -> np.ndarray:
    """uint32[m, W] data planes -> uint32[r+1, W] parity planes (r Hamming
    check planes, then the overall parity plane)."""
    data = np.asarray(planes, dtype=np.uint32)
    m, w = data.shape
    r = ecc_plane_count(m) - 1
    pos = _hamming_data_positions(m)
    parity = np.zeros((r + 1, w), np.uint32)
    for k in range(r):
        acc = np.zeros(w, np.uint32)
        for i, p in enumerate(pos):
            if (p >> k) & 1:
                acc ^= data[i]
        parity[k] = acc
    parity[r] = (np.bitwise_xor.reduce(data, axis=0)
                 ^ (np.bitwise_xor.reduce(parity[:r], axis=0)
                    if r else np.uint32(0)))
    return parity


def _popcount(mask: np.ndarray) -> int:
    return int(np.unpackbits(mask.view(np.uint8)).sum())


def ecc_check_correct(planes, parity) -> Tuple[np.ndarray, np.ndarray,
                                               int, int]:
    """Verify (and repair) a protected plane stack.

    Returns (data, parity, corrected, uncorrected): the corrected copies
    plus per-bit counts — `corrected` single-bit errors repaired in place
    (data, check or overall planes alike), `uncorrected` bits flagged as
    detected-but-uncorrectable (even total parity with a nonzero syndrome:
    a double error in one column). The caller must treat any nonzero
    `uncorrected` as data loss — invalidate and rebuild from the source.
    """
    data = np.array(planes, dtype=np.uint32, copy=True)
    par = np.array(parity, dtype=np.uint32, copy=True)
    m, w = data.shape
    r = par.shape[0] - 1
    pos = _hamming_data_positions(m)

    syn = np.zeros((r, w), np.uint32)
    for k in range(r):
        acc = par[k].copy()
        for i, p in enumerate(pos):
            if (p >> k) & 1:
                acc ^= data[i]
        syn[k] = acc
    overall = np.bitwise_xor.reduce(data, axis=0)
    for k in range(r + 1):
        overall = overall ^ par[k]
    any_syn = np.bitwise_or.reduce(syn, axis=0) if r \
        else np.zeros(w, np.uint32)

    def syndrome_is(p: int) -> np.ndarray:
        acc = np.full(w, 0xFFFFFFFF, np.uint32)
        for k in range(r):
            acc &= syn[k] if (p >> k) & 1 else ~syn[k]
        return acc

    corrected = 0
    fixed = np.zeros(w, np.uint32)
    for i, p in enumerate(pos):               # single error in a data plane
        fix = syndrome_is(p) & overall
        if fix.any():
            data[i] ^= fix
            corrected += _popcount(fix)
        fixed |= fix
    for k in range(r):                        # single error in a check plane
        fix = syndrome_is(1 << k) & overall
        if fix.any():
            par[k] ^= fix
            corrected += _popcount(fix)
        fixed |= fix
    fix = syndrome_is(0) & overall            # error in the overall plane
    if fix.any():
        par[r] ^= fix
        corrected += _popcount(fix)
    fixed |= fix

    # even parity + nonzero syndrome: double error (detected, not fixable);
    # odd parity pointing outside every valid position: 3+ flips, ditto
    uncorrectable = (any_syn & ~overall) | (overall & ~fixed)
    uncorrected = _popcount(uncorrectable)
    return data, par, corrected, uncorrected


def mask_to_ints(bitmap: jax.Array, shape: Tuple[int, ...]) -> jax.Array:
    """uint32[1, W] per-word predicate bitmap -> int32 0/1 tensor of shape."""
    n = 1
    for d in shape:
        n *= int(d)
    w = bitmap.shape[-1]
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (bitmap.reshape(w)[:, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(w * 32)[:n].astype(jnp.int32).reshape(shape)

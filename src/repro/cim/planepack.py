"""PlanePack: packed bit-plane pytree — the CiM engine's working format.

The ADRA array never leaves bit-serial form between operations: the output
planes of one op are the input planes of the next. PlanePack makes that true
on TPU too. It carries the packed uint32 plane stack (plane p = bit p of 32
words per lane element) plus the static metadata (n_bits, signedness, logical
shape) needed to re-assemble integers — so chained CiM ops stay packed across
calls instead of round-tripping through pack_bitplanes/unpack_bitplanes.

Registered as a JAX pytree: PlanePacks flow through jit/vmap/scan with the
plane stack as the single traced leaf and the metadata static.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.bitplane import pack_bitplanes, unpack_bitplanes


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PlanePack:
    """Packed bit-plane representation of an integer tensor.

    planes : uint32[n_bits, W] — plane p, lane word w, bit j holds bit p of
             logical element 32*w + j (LSB-first planes, two's complement).
    n_bits : word width (number of planes).
    signed : whether the MSB plane is a two's-complement sign plane.
    shape  : logical tensor shape (prod(shape) = number of valid words;
             the lane dim is padded to a multiple of 32).
    """

    planes: jax.Array
    n_bits: int
    signed: bool
    shape: Tuple[int, ...]

    # -- pytree protocol: planes traced, metadata static --------------------
    def tree_flatten(self):
        return (self.planes,), (self.n_bits, self.signed, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        n_bits, signed, shape = aux
        return cls(planes=children[0], n_bits=n_bits, signed=signed, shape=shape)

    # -- construction / materialization ------------------------------------
    @classmethod
    def pack(cls, x: jax.Array, n_bits: int, signed: bool = True) -> "PlanePack":
        """Integer tensor (any shape) -> PlanePack. The ONLY place a CiM
        pipeline pays the transpose-and-pack cost."""
        x = jnp.asarray(x)
        shape = tuple(x.shape)
        return cls(planes=pack_bitplanes(x, n_bits), n_bits=n_bits,
                   signed=signed, shape=shape)

    @property
    def n_words(self) -> int:
        n = 1
        for d in self.shape:
            n *= int(d)
        return n

    def unpack(self) -> jax.Array:
        """PlanePack -> int32 tensor of the logical shape (pipeline exit)."""
        vals = unpack_bitplanes(self.planes, self.n_words, signed=self.signed)
        return vals.reshape(self.shape)

    # -- packed-domain transforms (no pack/unpack round trip) ---------------
    def extend_to(self, n_bits: int) -> "PlanePack":
        """Widen to n_bits planes entirely in the packed domain: replicate the
        sign plane (signed) or append zero planes (unsigned). This is how a
        chained pipeline aligns an (n+1)-bit result with an n-bit operand."""
        if n_bits < self.n_bits:
            raise ValueError(f"cannot narrow {self.n_bits} -> {n_bits} planes")
        if n_bits == self.n_bits:
            return self
        extra = n_bits - self.n_bits
        if self.signed:
            fill = jnp.broadcast_to(self.planes[-1:],
                                    (extra,) + self.planes.shape[1:])
        else:
            fill = jnp.zeros((extra,) + self.planes.shape[1:], jnp.uint32)
        return PlanePack(planes=jnp.concatenate([self.planes, fill], axis=0),
                         n_bits=n_bits, signed=self.signed, shape=self.shape)

    def align(self, other: "PlanePack") -> Tuple["PlanePack", "PlanePack"]:
        """Widen both operands to the common width, packed-domain only."""
        n = max(self.n_bits, other.n_bits)
        return self.extend_to(n), other.extend_to(n)


def mask_to_ints(bitmap: jax.Array, shape: Tuple[int, ...]) -> jax.Array:
    """uint32[1, W] per-word predicate bitmap -> int32 0/1 tensor of shape."""
    n = 1
    for d in shape:
        n *= int(d)
    w = bitmap.shape[-1]
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (bitmap.reshape(w)[:, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(w * 32)[:n].astype(jnp.int32).reshape(shape)

"""Macro-op planner: lower multi-access CiM arithmetic to access schedules.

The single-access engine (repro.cim.engine) computes any op-set the paper's
one asymmetric dual-row activation can emit. Everything beyond that —
multiplication, reductions, quantized dot products — is a *composition* of
accesses. This module plans those compositions as explicit `Schedule`s: an
ordered tuple of `Step`s, each describing exactly one `engine.execute` call
(its op-set plus the zero-cost peripheral wiring around it: plane shifts for
shift-and-add, element strides for tree reductions).

The schedule IS the cost model. `Schedule.accesses == len(steps)` is the
number of ADRA array accesses the macro performs, and `repro.cim.macro`
executes schedules through a cursor that refuses to deviate from them — so
the ledger's access count provably equals the planned count, keeping EDP
projections faithful to the paper's access-count argument.

Between accesses everything stays in the PlanePack packed domain; the only
non-access operations a schedule implies are peripheral wiring (plane
re-indexing, writeback truncation, row-buffer shifts) which move no operand
through the array.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

from . import opset
from .array import ArraySpec, TilePlan


@dataclasses.dataclass(frozen=True)
class Step:
    """One planned ADRA access.

    ops    : the fused op-set of this access (one engine.execute call).
    role   : dataflow role — 'pp' (partial product), 'acc' (accumulate),
             'neg' (negate-from-zero), 'reduce' (tree-reduction add),
             'pred' (predicate for a peripheral select), 'pair' (popcount
             pairwise add).
    shift  : plane (weight) shift applied to this step's operand, in planes.
    stride : element stride of the row-buffer shift feeding this step.
    """

    ops: Tuple[str, ...]
    role: str
    shift: int = 0
    stride: int = 0


@dataclasses.dataclass(frozen=True)
class Schedule:
    """An ordered access plan for one macro op (or a fused region of ops).

    `placement` (set by `placed()`) pins the schedule to a banked array
    geometry: every step then executes as `placement.n_tiles` bank
    activations through the tiling dispatcher, and `placed_accesses` is the
    physical activation count the ledger will show.

    `segments` (set by `concat_schedules`) records the per-op boundaries of
    a fused region plan: an ordered tuple of (macro name, step count) pairs
    summing to len(steps) — the lowering compiler's provenance trail.

    `operands`/`resident` name the macro's operand sides and the subset
    already pinned in array rows: a resident side skips the entry pack (and
    its ledger load charges) when the schedule executes, and because
    Schedule is part of every compiled-program cache key, two executions of
    the same macro with different residency compile to different programs.
    """

    macro: str
    steps: Tuple[Step, ...]
    out_bits: int                 # width of the macro's result planes
    placement: Optional[TilePlan] = None
    segments: Optional[Tuple[Tuple[str, int], ...]] = None
    operands: Tuple[str, ...] = ()
    resident: Tuple[str, ...] = ()

    @property
    def accesses(self) -> int:
        return len(self.steps)

    @property
    def placed_accesses(self) -> int:
        """Bank activations when placed (accesses * tiles); logical accesses
        when not."""
        tiles = self.placement.n_tiles if self.placement else 1
        return len(self.steps) * tiles

    @property
    def placed_waves(self) -> int:
        """Serialized wave count when placed (accesses * waves per step —
        the critical path the cost model's latency term charges); logical
        accesses when not."""
        waves = self.placement.waves if self.placement else 1
        return len(self.steps) * waves

    def placed(self, spec: ArraySpec, n_words: int) -> "Schedule":
        """The same schedule carrying its tile placement on `spec`."""
        return dataclasses.replace(self, placement=spec.plan(n_words))

    def with_operands(self, *names: str) -> "Schedule":
        """The same schedule naming its operand sides (e.g. 'lhs', 'rhs')."""
        return dataclasses.replace(self, operands=tuple(names))

    def with_resident(self, *names: str) -> "Schedule":
        """The same schedule marking `names` as resident operand sides."""
        unknown = tuple(n for n in names if n not in self.operands)
        if unknown:
            raise opset.CimOpError(
                f"resident sides {unknown} not among operands "
                f"{self.operands} of macro {self.macro!r}")
        return dataclasses.replace(self, resident=tuple(names))

    def op_passes(self) -> Tuple[Tuple[str, ...], ...]:
        return tuple(s.ops for s in self.steps)

    def __add__(self, other: "Schedule") -> "Schedule":
        return Schedule(macro=f"{self.macro}+{other.macro}",
                        steps=self.steps + other.steps,
                        out_bits=max(self.out_bits, other.out_bits),
                        placement=self.placement or other.placement)


def _log2_ceil(n: int) -> int:
    r = 0
    while (1 << r) < n:
        r += 1
    return r


# ---------------------------------------------------------------------------
# plans
# ---------------------------------------------------------------------------


def plan_multiply(n_bits_a: int, n_bits_b: int,
                  signed_b: bool = True) -> Schedule:
    """Shift-and-add multiply: one AND access per multiplier bit (partial
    product against the sign-extended multiplicand), one add access per
    accumulation; the top bit of a signed multiplier carries weight
    -2^(n-1), so its partial product is *subtracted* — the engine's
    single-access sub makes that free of extra passes."""
    if n_bits_a < 1 or n_bits_b < 1:
        raise opset.CimOpError(
            f"multiply needs positive widths, got {n_bits_a}x{n_bits_b}")
    steps = []
    for i in range(n_bits_b):
        last_signed = signed_b and i == n_bits_b - 1
        steps.append(Step(("and",), role="pp", shift=i))
        if i == 0:
            if last_signed:            # 1-bit signed multiplier: b in {0,-1}
                steps.append(Step(("sub",), role="neg", shift=i))
        else:
            steps.append(Step(("sub" if last_signed else "add",),
                              role="acc", shift=i))
    return Schedule("multiply", tuple(steps), out_bits=n_bits_a + n_bits_b)


def plan_elementwise(ops: Tuple[str, ...], out_bits: int,
                     macro: Optional[str] = None) -> Schedule:
    """One single-access elementwise step: the engine computes every op in
    `ops` from the same dual-row activation (add/sub/compare/any Boolean
    function). This is the plan the lowering compiler emits for each
    ADRA-eligible single-access jaxpr eqn."""
    ops = opset.validate_ops(tuple(ops))
    return Schedule(macro or "+".join(ops), (Step(ops, role="ew"),),
                    out_bits=out_bits)


def plan_neg(n_bits: int) -> Schedule:
    """0 - a: one sub access against the array's zero row."""
    return Schedule("neg", (Step(("sub",), role="neg"),), out_bits=n_bits + 1)


def plan_abs(n_bits: int) -> Schedule:
    """abs via the sub-chain: ONE access computes 0 - a and the 0 < a
    predicate together; a peripheral select between a and -a finishes it."""
    return Schedule("abs", (Step(("sub", "lt"), role="pred"),),
                    out_bits=n_bits + 1)


def plan_relu(n_bits: int) -> Schedule:
    """relu: one access for the a > 0 predicate; peripheral select a vs 0."""
    return Schedule("relu", (Step(("gt",), role="pred"),), out_bits=n_bits)


def plan_minimum(n_bits: int) -> Schedule:
    return Schedule("minimum", (Step(("lt",), role="pred"),), out_bits=n_bits)


def plan_maximum(n_bits: int) -> Schedule:
    return Schedule("maximum", (Step(("gt",), role="pred"),), out_bits=n_bits)


def plan_popcount(n_bits: int) -> Schedule:
    """Pairwise tree over the n single-bit planes: n - 1 add accesses."""
    if n_bits < 1:
        raise opset.CimOpError(f"popcount needs positive width, got {n_bits}")
    steps, level = [], n_bits
    while level > 1:
        pairs = level // 2
        steps.extend(Step(("add",), role="pair") for _ in range(pairs))
        level = pairs + (level % 2)
    return Schedule("popcount", tuple(steps),
                    out_bits=_log2_ceil(n_bits + 1) + 1)


def plan_reduce_sum(n_elems: int, stride: int = 1,
                    n_bits: int = 32) -> Schedule:
    """Log-stride tree reduction: ceil(log2(n)) add accesses, each fed by a
    zero-fill row-buffer shift of stride * 2^r elements. Element 0 (of each
    stride-aligned segment) holds the sum afterwards."""
    if n_elems < 1:
        raise opset.CimOpError(f"reduce needs at least one element, {n_elems}")
    steps = tuple(Step(("add",), role="reduce", stride=stride << r)
                  for r in range(_log2_ceil(n_elems)))
    return Schedule("reduce_sum", steps,
                    out_bits=n_bits + _log2_ceil(n_elems))


def plan_matmul(k: int, n_cols: int, n_bits: int = 8,
                signed: bool = True, resident_rhs: bool = False) -> Schedule:
    """int x int -> wide-int matmul over a [M, K_pad, N] broadcast layout:
    ONE shift-and-add multiply over the whole expanded tensor (word
    parallelism makes the access count independent of M and N) followed by a
    log2(K_pad) stride-N tree reduction over the contraction axis.

    `resident_rhs` marks the rhs (weight) side as pinned in array rows: the
    step sequence is identical — residency changes operand loading, never
    the access count — but the schedule names the rhs resident so executors
    skip its entry pack and compiled programs key on residency."""
    if k < 1 or n_cols < 1:
        raise opset.CimOpError(f"matmul needs k, n >= 1, got {k}, {n_cols}")
    k_pad = 1 << _log2_ceil(k)
    mul = plan_multiply(n_bits, n_bits, signed_b=signed)
    red = plan_reduce_sum(k_pad, stride=n_cols, n_bits=mul.out_bits)
    sched = Schedule("matmul", mul.steps + red.steps, out_bits=red.out_bits,
                     operands=("lhs", "rhs"))
    return sched.with_resident("rhs") if resident_rhs else sched


def plan_dot(k: int, n_bits: int = 8, signed: bool = True) -> Schedule:
    sched = plan_matmul(k, 1, n_bits=n_bits, signed=signed)
    return dataclasses.replace(sched, macro="dot")


def plan_batched_matmul(batch: int, k: int, n_cols: int, n_bits: int = 8,
                        signed: bool = True,
                        resident_rhs: bool = False) -> Schedule:
    """Batched intN contraction [*B, M, K] x [*B, K, N] over the SAME
    broadcast word layout as `plan_matmul`, with the batch dims flattened
    onto the word/tile axis: the expanded operand stack is
    [B_flat * M, K_pad, N] and the step sequence — one shift-and-add
    multiply plus a log2(K_pad) stride-N tree reduction — is IDENTICAL to
    the 2-D plan. Batch size scales the word count (and therefore the tile
    placement) but NEVER the access count per tile: that independence is
    the whole eligibility argument for putting attention's per-head
    contractions in the banks.

    The stride-N reduction is correct in the flattened layout for the same
    reason it is correct across the 2-D plan's M axis: each (b, m) block
    owns a contiguous K_pad * N word segment, partial sums that a high-k
    shift drags across a block boundary land on k > 0 slots, and the exit
    gather reads only the k = 0 slice of every block.

    `resident_rhs` names the rhs (the attention K^T / V side) resident,
    exactly as in `plan_matmul`: same steps, different operand loading,
    different compiled-program identity."""
    if batch < 1:
        raise opset.CimOpError(f"batched matmul needs batch >= 1, got {batch}")
    if k < 1 or n_cols < 1:
        raise opset.CimOpError(f"matmul needs k, n >= 1, got {k}, {n_cols}")
    k_pad = 1 << _log2_ceil(k)
    mul = plan_multiply(n_bits, n_bits, signed_b=signed)
    red = plan_reduce_sum(k_pad, stride=n_cols, n_bits=mul.out_bits)
    sched = Schedule("batched_matmul", mul.steps + red.steps,
                     out_bits=red.out_bits, operands=("lhs", "rhs"))
    return sched.with_resident("rhs") if resident_rhs else sched


# ---------------------------------------------------------------------------
# cross-op schedule concatenation (region fusion)
# ---------------------------------------------------------------------------


def concat_schedules(schedules: Sequence[Schedule],
                     macro: str = "region") -> Schedule:
    """Fuse an ordered run of schedules into ONE region plan.

    The fused schedule is the step-wise concatenation: executing it through
    a single ScheduleCursor runs every constituent op back to back on the
    same PlanePack operands with no intermediate repacks — the plan-level
    form of the lowering compiler's region fusion. `segments` keeps the
    per-op boundaries so reports can attribute accesses back to eqns.
    """
    schedules = list(schedules)
    if not schedules:
        raise opset.CimOpError("cannot concatenate zero schedules")
    steps: Tuple[Step, ...] = ()
    segments = []
    for s in schedules:
        steps = steps + s.steps
        segments.append((s.macro, len(s.steps)))
    return Schedule(macro=macro, steps=steps,
                    out_bits=max(s.out_bits for s in schedules),
                    segments=tuple(segments))


PLANS = {
    "multiply": plan_multiply,
    "neg": plan_neg,
    "abs": plan_abs,
    "relu": plan_relu,
    "minimum": plan_minimum,
    "maximum": plan_maximum,
    "popcount": plan_popcount,
    "reduce_sum": plan_reduce_sum,
    "matmul": plan_matmul,
    "dot": plan_dot,
    "batched_matmul": plan_batched_matmul,
}


# ---------------------------------------------------------------------------
# traffic: fused (in-array intermediates) vs unfused (near-memory) schedules
# ---------------------------------------------------------------------------


def schedule_traffic_bytes(schedule: Schedule, n_bits: int, n_words32: int,
                           working_bits: Optional[int] = None
                           ) -> Dict[str, float]:
    """HBM-byte model of executing a schedule fused vs unfused.

    Fused: the macro streams both operand stacks ONCE and writes the final
    result once; every intermediate (partial products, accumulator, tree
    levels) stays in the array between accesses. Unfused (near-memory
    baseline): each scheduled step re-reads its two operand stacks at the
    working width and writes its outputs back — the k-access analogue of the
    paper's two-access baseline, generalized to macro schedules.

    A resident operand side streams ZERO bytes on the fused path (it already
    lives in the array rows — the paper's stored-operand assumption); the
    unfused baseline still re-reads both sides because near-memory compute
    has no rows to keep state in.
    """
    w = working_bits if working_bits is not None else schedule.out_bits
    plane_bytes = 4 * n_words32
    streamed_sides = 2 - min(len(schedule.resident), 2)
    fused = (streamed_sides * n_bits + schedule.out_bits) * plane_bytes
    baseline = 0.0
    for step in schedule.steps:
        out_rows = sum(opset.out_rows(op, w) for op in step.ops)
        baseline += (2 * w + out_rows) * plane_bytes
    return {"fused": float(fused), "baseline": float(baseline),
            "ratio": baseline / fused}

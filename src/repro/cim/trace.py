"""jaxpr -> CiM IR: the eligibility front end of the lowering compiler.

`trace(fn, *args)` stages a JAX function with `jax.make_jaxpr`, flattens
nested `pjit` calls, and classifies every equation into the ADRA cost model:

  single — elementwise integer ops one asymmetric dual-row access computes:
           add / sub / compare (lt, le, gt, ge, eq, ne) / bitwise
           and-or-xor / min / max / neg / abs.
  multi  — ops the macro planner (repro.cim.planner) lowers to explicit
           access schedules: mul (shift-and-add), integer dot_general in
           the canonical [*B,M,K]x[*B,K,N] form — 2-D or batched, the
           batch dims flattening onto the word/tile axis of the broadcast
           contraction layout — full reduce_sum (log-stride tree),
           population_count (pairwise plane tree).
  free   — zero-access peripheral wiring that keeps a fused region in the
           packed domain: int<->int convert_element_type (plane truncate /
           sign-extend), reshape, bitwise not (SA output complement),
           select_n on a predicate bitmap (predicated writeback), scalar
           broadcast_in_dim (row-buffer fanout).
  host   — everything else (floats, gathers, control flow, ...).

Each eligible equation carries its planner `Schedule`, its access count and
the operand word count one access covers — the SAME numbers the executor
(repro.cim.lower) will charge to the ledger and the offload estimator
(repro.core.offload, source="jaxpr") projects from. One classification,
three consumers: the estimator and the executor can never disagree about
eligibility.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import planner

#: jaxpr comparison primitive -> (engine predicate op, complement-at-periphery)
CMP_PRIMS: Dict[str, Tuple[str, bool]] = {
    "lt": ("lt", False), "gt": ("gt", False), "eq": ("eq", False),
    "ge": ("lt", True), "le": ("gt", True), "ne": ("eq", True),
}

#: elementwise single-access primitives (besides the comparisons)
SINGLE_PRIMS = ("add", "sub", "and", "or", "xor", "min", "max", "neg", "abs")

#: multi-access primitives lowered through the macro planner
MULTI_PRIMS = ("mul", "dot_general", "reduce_sum", "population_count")

#: zero-access peripheral primitives (free inside a fused region)
FREE_PRIMS = ("convert_element_type", "reshape", "select_n", "not",
              "broadcast_in_dim")


@dataclasses.dataclass(frozen=True)
class ConstVal:
    """A closed-over constant routed into the flat eqn list (the lowering
    analogue of a jaxpr constvar binding)."""

    val: Any

    @property
    def aval(self):
        v = self.val
        return jax.core.ShapedArray(np.shape(v), jnp.result_type(v))


def aval_of(atom) -> jax.core.ShapedArray:
    """aval of a Var, Literal, or ConstVal operand."""
    return atom.aval


@dataclasses.dataclass
class TracedOp:
    """One flattened jaxpr equation plus its ADRA classification."""

    prim: Any                      # jax Primitive (None for _alias passthrough)
    params: Dict[str, Any]
    invars: Tuple[Any, ...]        # Var | Literal | ConstVal
    outvars: Tuple[Any, ...]
    name: str = ""                 # normalized op name
    kind: str = "host"             # single | multi | free | host
    n_bits: int = 0                # operand word width the access works at
    accesses: int = 0              # planned ADRA accesses (0 for free/host)
    words: int = 0                 # operand words one access covers
    schedule: Optional[planner.Schedule] = None
    why_host: str = ""             # ineligibility reason (diagnostics)

    @property
    def eligible(self) -> bool:
        return self.kind != "host"


@dataclasses.dataclass
class Trace:
    """The flattened, classified eqn list of one staged function."""

    closed: jax.core.ClosedJaxpr
    ops: List[TracedOp]
    out_shape: Any                 # pytree of ShapeDtypeStruct (output tree)

    @property
    def eligible_ops(self) -> int:
        return sum(1 for op in self.ops if op.eligible and op.accesses)

    @property
    def adra_accesses(self) -> int:
        """Total planned accesses — what a lowered execution's ledger shows
        (unbanked); banked placement multiplies per-eqn by its tile count."""
        return sum(op.accesses for op in self.ops)


# ---------------------------------------------------------------------------
# dtype helpers
# ---------------------------------------------------------------------------


def dtype_bits(dtype) -> int:
    """Word width of an integer/bool dtype (int4 -> 4, bool -> 1)."""
    dtype = jnp.dtype(dtype)
    if dtype == jnp.bool_:
        return 1
    return jnp.iinfo(dtype).bits


def dtype_signed(dtype) -> bool:
    dtype = jnp.dtype(dtype)
    if dtype == jnp.bool_:
        return False
    return jnp.issubdtype(dtype, jnp.signedinteger)


def _intlike(aval) -> bool:
    return (aval.dtype == jnp.bool_
            or jnp.issubdtype(aval.dtype, jnp.integer))


def host_flops(op: "TracedOp") -> int:
    """Scalar-op count an XLA host execution of this eqn performs — the
    roofline numerator for the cost model (repro.cim.cost). Elementwise
    ops count one op per output element; dot_general counts the standard
    2*(out elements)*K."""
    if op.prim is None or not op.outvars:
        return 0
    out = aval_of(op.outvars[0])
    if op.name == "dot_general":
        k = int(aval_of(op.invars[0]).shape[-1])
        return 2 * _numel(out.shape) * k
    return _numel(out.shape)


def host_io_bits(op: "TracedOp") -> int:
    """Bits moved through HBM if this eqn ran alone on the host: every
    operand read once plus every result written once, at true element
    widths (accumulate bits, round to bytes ONCE at the consumer — the
    PR-4 sub-byte-dtype convention)."""
    bits = 0
    for v in tuple(op.invars) + tuple(op.outvars):
        if not hasattr(v, "aval"):
            continue
        aval = aval_of(v)
        if not hasattr(aval, "shape"):
            continue
        try:
            b = dtype_bits(aval.dtype)
        except Exception:
            b = aval.dtype.itemsize * 8
        bits += _numel(aval.shape) * b
    return bits


def _numel(shape) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


# ---------------------------------------------------------------------------
# classification
# ---------------------------------------------------------------------------


def _host(op: TracedOp, why: str) -> None:
    op.kind, op.why_host = "host", why


def _elementwise_shapes_ok(op: TracedOp) -> bool:
    """Operand shapes must equal the output shape or be scalar (the lax
    weak-literal broadcast the executor replays at pack time)."""
    out = aval_of(op.outvars[0]).shape
    return all(aval_of(v).shape in (out, ()) for v in op.invars)


def classify(op: TracedOp) -> None:
    """Fill in kind / n_bits / accesses / words / schedule for one eqn."""
    name = op.name
    if op.prim is None:                      # _alias passthrough
        _host(op, "alias")
        return
    if name not in SINGLE_PRIMS + MULTI_PRIMS + tuple(CMP_PRIMS) + FREE_PRIMS:
        _host(op, f"unsupported primitive {name!r}")
        return
    avals_in = [aval_of(v) for v in op.invars]
    avals_out = [aval_of(v) for v in op.outvars]
    if not all(_intlike(a) for a in avals_in + avals_out):
        _host(op, "non-integer operand or result")
        return

    out = avals_out[0]
    words = _numel(out.shape)

    # -- free peripheral ops ------------------------------------------------
    if name == "convert_element_type":
        src, dst = avals_in[0].dtype, out.dtype
        if dst == jnp.bool_ and src != jnp.bool_:
            _host(op, "int->bool convert is a != 0 test, not a truncation")
            return
        op.kind, op.n_bits = "free", dtype_bits(dst)
        return
    if name == "reshape":
        if op.params.get("dimensions") is not None:
            _host(op, "reshape with dimension permutation")
            return
        op.kind = "free"
        return
    if name == "not":
        op.kind, op.n_bits = "free", dtype_bits(out.dtype)
        return
    if name == "select_n":
        if len(op.invars) != 3:
            _host(op, "select_n with more than two cases")
            return
        if avals_in[0].dtype != jnp.bool_:
            _host(op, "select_n predicate is not boolean")
            return
        if not _elementwise_shapes_ok(op):
            _host(op, "select_n operand shapes differ from output")
            return
        op.kind = "free"
        return
    if name == "broadcast_in_dim":
        if avals_in[0].shape != ():
            _host(op, "only scalar broadcast is peripheral fanout")
            return
        op.kind = "free"
        return

    # -- single-access elementwise ops --------------------------------------
    if name in SINGLE_PRIMS or name in CMP_PRIMS:
        if not _elementwise_shapes_ok(op):
            _host(op, "operand shapes differ from output")
            return
        ref = next((a for a in avals_in if a.shape != ()), avals_in[0])
        n = dtype_bits(ref.dtype)
        op.kind, op.n_bits, op.words, op.accesses = "single", n, words, 1
        if name in ("add", "sub"):
            op.schedule = planner.plan_elementwise((name,), n + 1, macro=name)
        elif name in ("and", "or", "xor"):
            op.schedule = planner.plan_elementwise((name,), n, macro=name)
        elif name in CMP_PRIMS:
            base, _ = CMP_PRIMS[name]
            op.schedule = planner.plan_elementwise((base,), 1, macro=name)
        elif name == "min":
            op.schedule = planner.plan_minimum(n)
        elif name == "max":
            op.schedule = planner.plan_maximum(n)
        elif name == "neg":
            op.schedule = planner.plan_neg(n)
        elif name == "abs":
            op.schedule = planner.plan_abs(n)
        op.accesses = op.schedule.accesses
        return

    # -- multi-access macro ops ---------------------------------------------
    if name == "mul":
        if not _elementwise_shapes_ok(op):
            _host(op, "operand shapes differ from output")
            return
        n = dtype_bits(out.dtype)
        op.schedule = planner.plan_multiply(
            n, n, signed_b=dtype_signed(out.dtype))
        op.kind, op.n_bits, op.words = "multi", n, words
        op.accesses = op.schedule.accesses
        return
    if name == "population_count":
        n = dtype_bits(out.dtype)
        if n < 2:
            _host(op, "popcount of a 1-bit word is the identity")
            return
        op.schedule = planner.plan_popcount(n)
        op.kind, op.n_bits, op.words = "multi", n, words
        op.accesses = op.schedule.accesses
        return
    if name == "reduce_sum":
        src = avals_in[0]
        if tuple(op.params.get("axes", ())) != tuple(range(len(src.shape))):
            _host(op, "partial reductions not lowered (full-tree only)")
            return
        n_elems = _numel(src.shape)
        if n_elems < 2:
            _host(op, "reduction over fewer than two elements")
            return
        n = dtype_bits(src.dtype)
        op.schedule = planner.plan_reduce_sum(n_elems, stride=1, n_bits=n)
        op.kind, op.n_bits, op.words = "multi", n, n_elems
        op.accesses = op.schedule.accesses
        return
    if name == "dot_general":
        lhs, rhs = avals_in
        dims = op.params["dimension_numbers"]
        (lc, rc), (lb, rb) = dims
        nb = len(lb)
        # canonical (possibly batched) form: [*B, M, K] x [*B, K, N] with
        # the batch dims leading on BOTH sides, the lhs contracting last and
        # the rhs contracting second-to-last — exactly what jnp.matmul emits
        # for stacked operands. Batch dims map onto the word/tile axis of
        # the broadcast layout, so the plan's access count is independent of
        # batch size per tile (see planner.plan_batched_matmul).
        if (len(lhs.shape), len(rhs.shape)) != (nb + 2, nb + 2) or \
                tuple(lb) != tuple(range(nb)) or \
                tuple(rb) != tuple(range(nb)) or \
                tuple(lc) != (nb + 1,) or tuple(rc) != (nb,):
            _host(op, "only canonical [*B,M,K]x[*B,K,N] contractions "
                      "are lowered")
            return
        if lhs.dtype != rhs.dtype:
            _host(op, "mixed-dtype contraction")
            return
        batch = _numel(lhs.shape[:nb])
        m, k = int(lhs.shape[nb]), int(lhs.shape[nb + 1])
        n_cols = int(rhs.shape[nb + 1])
        n = dtype_bits(lhs.dtype)
        k_pad = 1 << planner._log2_ceil(k)
        if nb:
            op.schedule = planner.plan_batched_matmul(
                batch, k, n_cols, n_bits=n, signed=dtype_signed(lhs.dtype))
        else:
            op.schedule = planner.plan_matmul(
                k, n_cols, n_bits=n, signed=dtype_signed(lhs.dtype))
        op.kind, op.n_bits = "multi", n
        op.words = batch * m * k_pad * n_cols
        op.accesses = op.schedule.accesses
        return
    _host(op, f"unhandled primitive {name!r}")   # pragma: no cover


# ---------------------------------------------------------------------------
# jaxpr flattening (pjit inlining)
# ---------------------------------------------------------------------------


def _flatten(jaxpr, subst: Dict[Any, Any]) -> List[TracedOp]:
    """Flatten a jaxpr into TracedOps, inlining pjit calls so regions can
    fuse across `jnp.where`-style wrappers. `subst` maps this jaxpr's vars
    (invars of an inlined call, constvars) to outer atoms."""

    def res(atom):
        if isinstance(atom, jax.core.Literal):
            return atom
        return subst.get(atom, atom)

    ops: List[TracedOp] = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pjit":
            inner = eqn.params["jaxpr"]          # ClosedJaxpr
            inner_subst = dict(
                zip(inner.jaxpr.invars, (res(v) for v in eqn.invars)))
            inner_subst.update(
                (cv, ConstVal(c))
                for cv, c in zip(inner.jaxpr.constvars, inner.consts))
            inner_ops = _flatten(inner.jaxpr, inner_subst)
            # remap each inner output var to the outer eqn's outvar; a
            # passthrough (literal / invar / duplicated) output becomes an
            # explicit _alias op the executor runs as identity
            out_map: Dict[Any, Any] = {}
            aliases: List[Tuple[Any, Any]] = []
            for iv, ov in zip(inner.jaxpr.outvars, eqn.outvars):
                if isinstance(ov, jax.core.DropVar):
                    continue
                if isinstance(iv, jax.core.Literal):
                    aliases.append((iv, ov))
                elif iv in inner_subst:
                    aliases.append((inner_subst[iv], ov))
                elif iv in out_map:
                    aliases.append((out_map[iv], ov))
                else:
                    out_map[iv] = ov
            for op in inner_ops:
                op.outvars = tuple(out_map.get(v, v) for v in op.outvars)
                # consumers INSIDE the inlined jaxpr must follow the rename
                # (an inner output can also feed further inner eqns)
                op.invars = tuple(
                    out_map.get(v, v) if isinstance(v, jax.core.Var) else v
                    for v in op.invars)
            ops.extend(inner_ops)
            ops.extend(
                TracedOp(prim=None, params={}, invars=(src,), outvars=(dst,),
                         name="_alias")
                for src, dst in aliases)
        else:
            ops.append(TracedOp(
                prim=eqn.primitive, params=dict(eqn.params),
                invars=tuple(res(v) for v in eqn.invars),
                outvars=tuple(eqn.outvars),
                name=eqn.primitive.name))
    return ops


def trace(fn, *args) -> Trace:
    """Stage `fn` on example `args` and classify every eqn (see module doc).

    Positional arguments only; pytrees are allowed and flattened the same
    way `jax.make_jaxpr` flattens them.
    """
    closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*args)
    subst = {cv: ConstVal(c)
             for cv, c in zip(closed.jaxpr.constvars, closed.consts)}
    ops = _flatten(closed.jaxpr, subst)
    for op in ops:
        classify(op)
    return Trace(closed=closed, ops=ops, out_shape=out_shape)

from .base import (  # noqa: F401
    ArchConfig,
    MLAConfig,
    MoEConfig,
    SHAPES,
    ShapeSpec,
    input_specs,
    shape_applicable,
)
from .registry import ARCH_IDS, get_config  # noqa: F401

"""Architecture & shape configuration system.

Every assigned architecture gets one `ArchConfig` (exact published numbers)
plus a `.reduced()` variant for CPU smoke tests. Input shapes are the four
assigned workload cells; `input_specs()` builds ShapeDtypeStruct stand-ins
for the dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int                  # routed experts
    top_k: int
    d_ff_expert: int                # per-expert hidden width
    n_shared: int = 0               # always-on shared experts (DeepSeek)
    capacity_factor: float = 1.25
    router_renorm: bool = True      # renormalize top-k probs


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int                       # dense-FFN hidden (0 => arch has none)
    vocab_size: int

    gating: str = "swiglu"          # swiglu | geglu | none
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    first_dense_layers: int = 0     # leading dense layers in a MoE stack
    d_ff_first_dense: int = 0       # width of those layers (0 -> d_ff)

    # layer pattern, repeated to fill n_layers. kinds:
    #   attn (global), local (windowed attn), rec (RG-LRU), mlstm, slstm
    block_pattern: Tuple[str, ...] = ("attn",)
    local_window: int = 2048

    embed_stub: bool = False        # audio/vlm: inputs are precomputed embeddings
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    dtype: str = "bfloat16"         # activation dtype
    param_dtype: str = "float32"
    opt_state_dtype: str = "float32"
    remat: bool = True

    expert_sharding: str = "ep"     # ep | tp (grok: 8 experts < 16-way axis)
    sub_quadratic: bool = False     # can run long_500k
    microbatches: int = 1           # gradient-accumulation factor (train)
    tensor_parallel: bool = True    # False: replicate params across "model"
                                    # (125M-scale: TP all-reduces cost more
                                    # than the replicated weights save)
    cim_mlp_bits: int = 0           # >0: dense MLPs run through the
    #                                 jaxpr->CiM lowering pass at this
    #                                 quantization width (serve --cim-lower)
    cim_attention_bits: int = 0     # >0: GQA decode attention (QK^T + AV)
    #                                 runs through the lowering pass as
    #                                 batched CiM schedules; softmax/rotary
    #                                 stay host islands (serve --cim-lower)
    cim_resident: bool = False      # pin int8 MLP weight planes in the
    #                                 array's resident region across calls
    #                                 (serve --cim-resident): warm decode
    #                                 skips the weight-side entry pack
    cim_unroll_groups: bool = False  # unroll the grouped-layer scan outside
    #                                 training: per-layer params keep a
    #                                 stable identity so eager serving can
    #                                 charge (and pin) per call — the serve
    #                                 engine sets this for BOTH sides of the
    #                                 repack-vs-resident comparison

    # -- derived -----------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        """Megatron-style vocab padding to a multiple of 256: keeps the
        vocab axis shardable on the 16-wide model axis (granite's 49155 and
        internvl's 92553 are odd); pad columns are masked to -inf in the LM
        head so semantics are unchanged."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def pattern_layers(self) -> Tuple[str, ...]:
        """The full per-layer kind list (pattern repeated, truncated)."""
        p = self.block_pattern
        reps = -(-self.n_layers // len(p))
        full = (p * reps)[: self.n_layers]
        if self.first_dense_layers:
            # leading dense layers replace the first entries' moe-ness only;
            # kind stays as given (handled by the MoE layer itself)
            pass
        return full

    def activation_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe,
                n_experts=max(2, min(4, self.moe.n_experts)),
                top_k=min(2, self.moe.top_k),
                d_ff_expert=64,
                n_shared=min(1, self.moe.n_shared),
            )
        mla = None
        if self.mla is not None:
            mla = MLAConfig(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16)
        period = len(self.block_pattern)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=max(2, 2 * period) if period > 1 else 2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            moe=moe,
            mla=mla,
            local_window=32,
            microbatches=1,
            dtype="float32",
            param_dtype="float32",
            remat=False,
        )


# ---------------------------------------------------------------------------
# Workload shapes (assigned cells)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic attention (DESIGN.md §5)."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "full-attention arch: O(S^2) attention at 512k is out of scope (DESIGN.md §5)"
    return True, ""


def input_specs(arch: ArchConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train:   tokens/embeds + targets over the full sequence
    prefill: tokens/embeds (cache is an output)
    decode:  one new token + position (the KV/state cache of seq_len is part
             of the step signature and built abstractly by the caller)
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    act = arch.activation_dtype()
    if shape.kind == "train":
        if arch.embed_stub:
            return {
                "embeds": jax.ShapeDtypeStruct((b, s, arch.d_model), act),
                "targets": jax.ShapeDtypeStruct((b, s), i32),
            }
        return {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "targets": jax.ShapeDtypeStruct((b, s), i32),
        }
    if shape.kind == "prefill":
        if arch.embed_stub:
            return {"embeds": jax.ShapeDtypeStruct((b, s, arch.d_model), act)}
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    if shape.kind == "decode":
        tok = (
            {"embeds": jax.ShapeDtypeStruct((b, 1, arch.d_model), act)}
            if arch.embed_stub
            else {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
        )
        tok["positions"] = jax.ShapeDtypeStruct((b,), i32)
        return tok
    raise ValueError(shape.kind)

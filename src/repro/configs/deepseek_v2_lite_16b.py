"""Selectable config module (see registry.py for the definition)."""
from .registry import DEEPSEEK_V2_LITE as CONFIG  # noqa: F401

"""Selectable config module (see registry.py for the definition)."""
from .registry import GEMMA_2B as CONFIG  # noqa: F401

"""Selectable config module (see registry.py for the definition)."""
from .registry import GRANITE_3_8B as CONFIG  # noqa: F401

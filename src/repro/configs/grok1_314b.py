"""Selectable config module (see registry.py for the definition)."""
from .registry import GROK_1_314B as CONFIG  # noqa: F401

"""Selectable config module (see registry.py for the definition)."""
from .registry import INTERNVL2_26B as CONFIG  # noqa: F401

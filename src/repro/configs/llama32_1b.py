"""Selectable config module (see registry.py for the definition)."""
from .registry import LLAMA32_1B as CONFIG  # noqa: F401

"""Selectable config module (see registry.py for the definition)."""
from .registry import MUSICGEN_LARGE as CONFIG  # noqa: F401

"""Selectable config module (see registry.py for the definition)."""
from .registry import QWEN3_14B as CONFIG  # noqa: F401

"""Selectable config module (see registry.py for the definition)."""
from .registry import RECURRENTGEMMA_9B as CONFIG  # noqa: F401

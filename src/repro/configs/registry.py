"""The 10 assigned architectures, exact published configurations.

Sources are cited per entry ([arXiv/hf; tier] from the assignment). Every
entry is selectable via --arch <id> in the launchers.
"""
from __future__ import annotations

from .base import ArchConfig, MLAConfig, MoEConfig

_REGISTRY: dict[str, ArchConfig] = {}


def _reg(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


# --- [audio] decoder-only over EnCodec tokens [arXiv:2306.05284; hf] --------
MUSICGEN_LARGE = _reg(ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048,
    gating="none",                    # musicgen uses plain GELU FFN
    embed_stub=True,                  # EnCodec frame embeddings from input_specs()
))

# --- [moe] 8 experts top-2 [hf:xai-org/grok-1; unverified] ------------------
GROK_1_314B = _reg(ArchConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab_size=131072,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32768),
    expert_sharding="tp",             # 8 experts < 16-way model axis
    param_dtype="bfloat16",           # 314B: f32 params = 4.9 GB/chip alone
    opt_state_dtype="bfloat16",       # 314B: f32 m/v would not fit one pod
    microbatches=8,                   # activation residency /8 (see §Perf)
))

# --- [moe] MLA kv_lora=512, 2 shared + 64 routed top-6 [arXiv:2405.04434; hf]
DEEPSEEK_V2_LITE = _reg(ArchConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102400,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408, n_shared=2),
    mla=MLAConfig(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    first_dense_layers=1,             # layer 0 is a dense 10944-wide FFN
    d_ff_first_dense=10944,
    microbatches=4,
))

# --- [dense] small llama3 [hf:meta-llama/Llama-3.2-1B; unverified] ----------
LLAMA32_1B = _reg(ArchConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab_size=128256, rope_theta=500_000.0,
    tie_embeddings=True,
))

# --- [dense] qk_norm, GQA [hf:Qwen/Qwen3-8B; hf] ----------------------------
QWEN3_14B = _reg(ArchConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=17408, vocab_size=151936, rope_theta=1_000_000.0,
    qk_norm=True,
))

# --- [dense] GeGLU, head_dim=256, MQA [arXiv:2403.08295; hf] ----------------
GEMMA_2B = _reg(ArchConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256000,
    gating="geglu", tie_embeddings=True,
    microbatches=2,
))

# --- [dense] GQA [hf:ibm-granite/granite-3.0-2b-base; hf] -------------------
GRANITE_3_8B = _reg(ArchConfig(
    name="granite-3-8b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12800, vocab_size=49155,
))

# --- [hybrid] RG-LRU + local attn 1:2 [arXiv:2402.19427; unverified] --------
RECURRENTGEMMA_9B = _reg(ArchConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000,
    gating="geglu",
    block_pattern=("rec", "rec", "local"),   # Griffin 2:1 recurrent:local
    local_window=2048,
    sub_quadratic=True,
    microbatches=2,
))

# --- [ssm] sLSTM + mLSTM blocks [arXiv:2405.04517; unverified] --------------
XLSTM_125M = _reg(ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, head_dim=192,
    d_ff=0, vocab_size=50304,
    gating="none",
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),  # mLSTM-dominant mix
    sub_quadratic=True,
    tensor_parallel=False,            # 125M: TP ARs dominate (see §Perf)
))

# --- [vlm] InternViT frontend (stub) + InternLM2 backbone [arXiv:2404.16821]
INTERNVL2_26B = _reg(ArchConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92553,
    embed_stub=True,                  # patch embeddings from input_specs()
))


ARCH_IDS = tuple(sorted(_REGISTRY))


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return _REGISTRY[name]

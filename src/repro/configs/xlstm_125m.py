"""Selectable config module (see registry.py for the definition)."""
from .registry import XLSTM_125M as CONFIG  # noqa: F401

"""repro.core — the paper's contribution: ADRA digital computing-in-memory.

Layers:
  fefet          — HZO FeFET device model (Miller's equations)
  array          — asymmetric dual-row senseline model (the ADRA mechanism)
  sensing        — 3-SA reference scheme + OAI recovery of A
  compute_module — gate-level add/sub/compare peripheral (Fig 3d)
  bitplane       — int <-> bit-plane codecs
  adra           — composable JAX ops: cim_add / cim_sub / cim_compare /
                   cim_boolean (analog-validated and boolean fast paths)
  energy         — calibrated energy/latency/EDP model (Figs 4-7)
  offload        — HLO-level ADRA offload estimator for compiled programs
"""
from .adra import (  # noqa: F401
    AccessOutputs,
    ArithOut,
    CmpOut,
    adra_access,
    cim_add,
    cim_boolean,
    cim_compare,
    cim_sub,
    BOOLEAN_FUNCTIONS,
)
from .array import AdraArrayConfig, level_currents, senseline_current  # noqa: F401
from .compute_module import compare_from_sub, compute_module, ripple_chain  # noqa: F401
from .energy import (  # noqa: F401
    current_sensing,
    edp_summary,
    frequency_crossover_hz,
    parallelism_crossover,
    voltage_scheme1,
    voltage_scheme2,
)
from .fefet import BiasConditions, FeFETParams, FEParams  # noqa: F401
from .offload import OffloadReport, analyze, analyze_hlo, analyze_trace  # noqa: F401
from .sensing import SenseReferences, current_sense_margins, voltage_sense_margins  # noqa: F401

"""High-level ADRA CiM ops: the paper's technique as a composable JAX module.

Two execution models share one semantics:

  * mode="analog"  -- the faithful path: per-bit senseline currents from the
    calibrated FeFET device model, thresholded against the SA references,
    then the gate-level compute-module ripple. This is the *paper*.
  * mode="boolean" -- the same dataflow with ideal SAs (pure Boolean OR/AND/B),
    used as the fast path inside jitted programs and as the oracle layer for
    the Pallas bit-plane kernels.

All ops take ordinary integer arrays (any shape), decompose to two's-complement
bit-planes, run the single-access ADRA dataflow, and re-assemble. A single
"memory access" yields OR, AND and B simultaneously — hence add, sub, compare
and ALL 16 two-input Boolean functions each cost exactly one access, which is
what the energy model (repro.core.energy) charges for.

This module is the SEMANTIC ORACLE of the unified CiM engine (repro.cim): the
engine's analog-oracle backend routes packed bit-planes through adra_access
(mode="analog") and the gate-level compute modules here, validating that every
fast backend (Pallas kernel, jnp plane math) matches what the sensed circuit
actually computes. Production call sites should dispatch through repro.cim.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .array import AdraArrayConfig, senseline_current
from .bitplane import bits_to_int, int_to_bits
from .compute_module import compare_from_sub, ripple_chain
from .sensing import SenseOutputs, SenseReferences, oai21_recover_a, sense


class AccessOutputs(NamedTuple):
    """What one ADRA memory access yields, per bit position."""

    or_: jax.Array
    and_: jax.Array
    b: jax.Array
    a: jax.Array


def adra_access(
    a_bits: jax.Array,
    b_bits: jax.Array,
    mode: str = "boolean",
    cfg: AdraArrayConfig | None = None,
) -> AccessOutputs:
    """One asymmetric dual-row activation over bit arrays (0/1 ints).

    Returns the three SA outputs plus the OAI-recovered A. In analog mode the
    currents are computed from the device model and sensed against references
    derived from the level currents, verifying the circuit actually realizes
    the Boolean contract.
    """
    a_bits = jnp.asarray(a_bits, jnp.int32)
    b_bits = jnp.asarray(b_bits, jnp.int32)
    if mode == "analog":
        cfg = cfg or AdraArrayConfig()
        refs = SenseReferences.from_config(cfg)
        i_sl = senseline_current(a_bits, b_bits, cfg, asymmetric=True)
        s: SenseOutputs = sense(i_sl, refs)
        return AccessOutputs(or_=s.or_, and_=s.and_, b=s.b, a=s.a)
    if mode == "boolean":
        or_ = a_bits | b_bits
        and_ = a_bits & b_bits
        a_rec = oai21_recover_a(or_, and_, b_bits)
        return AccessOutputs(or_=or_, and_=and_, b=b_bits, a=a_rec)
    raise ValueError(f"unknown mode: {mode!r}")


# ---------------------------------------------------------------------------
# Arithmetic (single-access add / sub / compare)
# ---------------------------------------------------------------------------


class ArithOut(NamedTuple):
    value: jax.Array        # integer result, (n+1)-bit two's complement
    sum_bits: jax.Array     # raw module outputs [..., n+1]
    carry_out: jax.Array


def _arith(x: jax.Array, y: jax.Array, n_bits: int, select: int, mode: str) -> ArithOut:
    xb = int_to_bits(x, n_bits)
    yb = int_to_bits(y, n_bits)
    acc = adra_access(xb, yb, mode=mode)
    sum_bits, c_out = ripple_chain(acc.or_, acc.and_, acc.b, select=select)
    return ArithOut(value=bits_to_int(sum_bits, signed=True), sum_bits=sum_bits, carry_out=c_out)


@functools.partial(jax.jit, static_argnames=("n_bits", "mode"))
def cim_add(x: jax.Array, y: jax.Array, n_bits: int = 32, mode: str = "boolean") -> ArithOut:
    """x + y via ADRA: one access + (n+1) compute modules, SELECT=0."""
    return _arith(x, y, n_bits, select=0, mode=mode)


@functools.partial(jax.jit, static_argnames=("n_bits", "mode"))
def cim_sub(x: jax.Array, y: jax.Array, n_bits: int = 32, mode: str = "boolean") -> ArithOut:
    """x - y via ADRA: one access + (n+1) compute modules, SELECT=1.

    This is the paper's headline capability: single-cycle NON-commutative
    arithmetic, impossible under symmetric multi-wordline CiM.
    """
    return _arith(x, y, n_bits, select=1, mode=mode)


class CmpOut(NamedTuple):
    lt: jax.Array
    eq: jax.Array
    gt: jax.Array


@functools.partial(jax.jit, static_argnames=("n_bits", "mode"))
def cim_compare(x: jax.Array, y: jax.Array, n_bits: int = 32, mode: str = "boolean") -> CmpOut:
    """Single-access comparison: sign + AND-tree over the subtraction output."""
    out = _arith(x, y, n_bits, select=1, mode=mode)
    c = compare_from_sub(out.sum_bits)
    return CmpOut(lt=c.lt, eq=c.eq, gt=c.gt)


# ---------------------------------------------------------------------------
# All 16 two-input Boolean functions from one access
# ---------------------------------------------------------------------------

#: minterm weights (m3 m2 m1 m0) for f(A,B); index = m3*8+m2*4+m1*2+m0 with
#: minterms (A,B): m0=(0,0), m1=(0,1), m2=(1,0), m3=(1,1)
BOOLEAN_FUNCTIONS = (
    "false", "nor", "a_and_not_b", "not_b", "not_a_and_b", "not_a",
    "xor", "nand", "and", "xnor", "a", "a_or_not_b", "b", "not_a_or_b",
    "or", "true",
)


@functools.partial(jax.jit, static_argnames=("fn", "n_bits", "mode"))
def cim_boolean(
    x: jax.Array, y: jax.Array, fn: str, n_bits: int = 32, mode: str = "boolean"
) -> jax.Array:
    """Any two-input Boolean function of in-memory words, one access.

    Composes the function from the access outputs {OR, AND, B, A} and their
    complements — exactly the signal set the three SAs + OAI gate provide.
    """
    xb = int_to_bits(x, n_bits)
    yb = int_to_bits(y, n_bits)
    acc = adra_access(xb, yb, mode=mode)
    o, n, b, a = acc.or_, acc.and_, acc.b, acc.a
    table = {
        "false": jnp.zeros_like(o),
        "nor": 1 - o,
        "a_and_not_b": o & (1 - b),
        "not_b": 1 - b,
        "not_a_and_b": o & (1 - a),
        "not_a": 1 - a,
        "xor": o & (1 - n),
        "nand": 1 - n,
        "and": n,
        "xnor": 1 - (o & (1 - n)),
        "a": a,
        "a_or_not_b": 1 - (o & (1 - a)),   # a | ~b == ~(~a & b)
        "b": b,
        "not_a_or_b": 1 - (o & (1 - b)),   # ~a | b == ~(a & ~b)
        "or": o,
        "true": jnp.ones_like(o),
    }
    bits = table[fn]
    return bits_to_int(bits, signed=False)


class AddSubOut(NamedTuple):
    add: jax.Array
    sub: jax.Array


@functools.partial(jax.jit, static_argnames=("n_bits", "mode"))
def cim_add_sub(x: jax.Array, y: jax.Array, n_bits: int = 32,
                mode: str = "boolean") -> AddSubOut:
    """Paper Sec. III-B alternate module: x+y AND x-y from ONE access, the
    same cycle (dual-output design, +4 transistors over the mux design)."""
    from .compute_module import ripple_chain_dual

    xb = int_to_bits(x, n_bits)
    yb = int_to_bits(y, n_bits)
    acc = adra_access(xb, yb, mode=mode)
    sa, ss = ripple_chain_dual(acc.or_, acc.and_, acc.b)
    return AddSubOut(add=bits_to_int(sa, signed=True),
                     sub=bits_to_int(ss, signed=True))

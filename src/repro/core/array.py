"""ADRA senseline model: asymmetric dual-row activation on a 1T-FeFET column.

Implements the core mechanism of the paper (Sec. III-A): during a CiM access
the RBL is driven to V_READ, WL1 (operand A) is asserted to V_GREAD1 and WL2
(operand B) to V_GREAD2 > V_GREAD1. The senseline current is the sum of the two
bitcell currents; because cell current depends on both the stored bit and the
wordline voltage, the four input vectors (A,B) map ONE-TO-ONE onto four
distinct I_SL values:

    I(0,0) < I(1,0) < I(0,1) < I(1,1)

(the symmetric scheme of prior work collapses (0,1) and (1,0)).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .fefet import BiasConditions, FeFETParams, cell_current


@dataclasses.dataclass(frozen=True)
class AdraArrayConfig:
    """A rows x cols 1T-FeFET array with ADRA peripherals."""

    rows: int = 1024
    cols: int = 1024
    word_bits: int = 32
    device: FeFETParams = dataclasses.field(default_factory=FeFETParams)
    bias: BiasConditions = dataclasses.field(default_factory=BiasConditions)

    @property
    def words_per_row(self) -> int:
        return self.cols // self.word_bits


def senseline_current(
    a_bit: jax.Array,
    b_bit: jax.Array,
    cfg: AdraArrayConfig,
    asymmetric: bool = True,
) -> jax.Array:
    """I_SL for a dual-row activation; broadcasts over array-shaped inputs.

    asymmetric=True  -> ADRA (V_GREAD1 on WL_A, V_GREAD2 on WL_B)
    asymmetric=False -> prior-work symmetric assertion (both at V_GREAD),
                        which exhibits the many-to-one mapping.
    """
    b = cfg.bias
    v1 = b.v_gread1 if asymmetric else b.v_gread
    v2 = b.v_gread2 if asymmetric else b.v_gread
    i_a = cell_current(a_bit, jnp.asarray(v1), jnp.asarray(b.v_read), cfg.device)
    i_b = cell_current(b_bit, jnp.asarray(v2), jnp.asarray(b.v_read), cfg.device)
    return i_a + i_b


def level_currents(cfg: AdraArrayConfig, asymmetric: bool = True) -> jax.Array:
    """The four I_SL levels for input vectors (A,B) in order 00,10,01,11."""
    a = jnp.array([0, 1, 0, 1])
    b = jnp.array([0, 0, 1, 1])
    return senseline_current(a, b, cfg, asymmetric=asymmetric)


def single_cell_read_current(bit: jax.Array, cfg: AdraArrayConfig) -> jax.Array:
    """Standard single-WL read at V_GREAD (for the near-memory baseline)."""
    b = cfg.bias
    return cell_current(bit, jnp.asarray(b.v_gread), jnp.asarray(b.v_read), cfg.device)


def rbl_discharge_voltage(
    i_sl: jax.Array, t_sense: float, cfg: AdraArrayConfig, c_bl_per_row: float = 0.18e-15
) -> jax.Array:
    """Voltage-sensing view: RBL discharge dV = I_SL * t / C_BL.

    C_BL scales with the number of rows (drain-junction + wire capacitance per
    cell ~0.18 fF at 45 nm). Used to verify the > 50 mV voltage sense margin.
    """
    c_bl = c_bl_per_row * cfg.rows
    return i_sl * t_sense / c_bl

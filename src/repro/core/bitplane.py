"""Bit-plane codecs: integer tensors <-> LSB-first bit-planes / packed planes.

The ADRA array stores an n-bit word as n bits along a row; a CiM access
operates on ALL columns of a row pair at once. The natural TPU layout for the
same computation is the transpose: plane p holds bit p of many words, packed
32 words per uint32 lane element. The codecs here are used by the functional
ADRA ops (repro.core.adra) and by the Pallas bit-plane kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# trace-time codec call counters: the CiM engine's chained-op tests assert
# that PlanePack pipelines never re-enter these between ops
_CODEC_CALLS = {"pack": 0, "unpack": 0}


def codec_call_counts() -> dict:
    return dict(_CODEC_CALLS)


def reset_codec_call_counts() -> None:
    _CODEC_CALLS["pack"] = 0
    _CODEC_CALLS["unpack"] = 0


def int_to_bits(x: jax.Array, n_bits: int) -> jax.Array:
    """Two's-complement LSB-first bit decomposition: [...] -> [..., n_bits]."""
    x = jnp.asarray(x, dtype=jnp.int32)
    shifts = jnp.arange(n_bits, dtype=jnp.int32)
    shifted = x[..., None] >> shifts  # jnp broadcasts; arithmetic shift is fine pre-mask
    return (shifted & 1).astype(jnp.int32)


def bits_to_int(bits: jax.Array, signed: bool = True) -> jax.Array:
    """Inverse of int_to_bits; interprets the MSB as a sign bit if signed.

    Accumulates modulo 2^32 (int32 wrap semantics). Exact for words of up to
    31 value bits (signed) / 32 bits (wrapped); wider chains — e.g. the
    (n+1)-bit output of a 32-bit subtraction — are exact iff the result fits,
    otherwise use the raw bit pattern.
    """
    n = bits.shape[-1]
    k = min(n, 32)
    w = jnp.left_shift(jnp.uint32(1), jnp.arange(k, dtype=jnp.uint32))
    val = jnp.sum(bits[..., :k].astype(jnp.uint32) * w, axis=-1, dtype=jnp.uint32)
    val = val.astype(jnp.int32)
    if signed and n < 32:
        sign = bits[..., -1].astype(jnp.int32)
        # subtract 2^n per sign bit: two's complement sign extension.
        # (for n == 32 the int32 wrap already encodes the sign.)
        val = val - jnp.left_shift(sign, jnp.int32(min(n, 31)))
    return val


def pack_bitplanes(x: jax.Array, n_bits: int) -> jax.Array:
    """[words] int32 -> [n_bits, ceil(words/32)] uint32 packed planes.

    Plane p, lane word w, bit position j holds bit p of element 32*w + j.
    """
    _CODEC_CALLS["pack"] += 1
    x = jnp.asarray(x, dtype=jnp.int32).reshape(-1)
    n = x.shape[0]
    pad = (-n) % 32
    x = jnp.pad(x, (0, pad))
    bits = int_to_bits(x, n_bits)                        # [N, n_bits]
    bits = bits.T.reshape(n_bits, -1, 32)                # [n_bits, N/32, 32]
    weights = (1 << jnp.arange(32, dtype=jnp.uint32)).astype(jnp.uint32)
    return jnp.sum(bits.astype(jnp.uint32) * weights, axis=-1)


def unpack_bitplanes(planes: jax.Array, n_words: int, signed: bool = True) -> jax.Array:
    """[n_bits, W] uint32 packed planes -> [n_words] int (two's complement)."""
    _CODEC_CALLS["unpack"] += 1
    n_bits, w = planes.shape
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (planes[..., None] >> shifts) & jnp.uint32(1)  # [n_bits, W, 32]
    bits = bits.reshape(n_bits, w * 32).T.astype(jnp.int32)  # [N, n_bits]
    return bits_to_int(bits[:n_words], signed=signed)

"""The ADRA peripheral compute module (paper Fig. 3(d) and Sec. III-B).

Inputs per bit position: the three SA outputs OR=A+B, AND=AB, B (and their
complements, free from the differential SAs), a ripple carry C_IN, and a
global SELECT line (0 = addition, 1 = subtraction).

Derived signals (gate identities used by the module):
    XOR  = A ^ B      = OR * NOT(AND)
    XNOR = NOT(XOR)   = AND + NOR
    A*NOT(B)          = OR * NOT(B)          (needed for A - B)

Addition     (operands A, B):        SUM = XOR ^ Cin,  COUT = AND + Cin*XOR
Subtraction  (operands A, NOT(B)):   SUM = XNOR ^ Cin, COUT = A*NOT(B) + Cin*XNOR
with C_IN(0) = SELECT (two's complement: A - B = A + NOT(B) + 1).

An n-bit operation uses n+1 modules; the (n+1)-th handles overflow with
sign-extended inputs (paper Sec. III-B). Comparison comes for free from the
subtraction output: the MSB (sign) of the (n+1)-bit result gives A<B, and a
near-memory AND tree over the complemented SUM bits detects A==B.

Everything operates on integer 0/1 arrays of any shape (vectorized across
columns/words exactly like the physical array computes all columns at once).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class ModuleOut(NamedTuple):
    sum_: jax.Array
    carry: jax.Array


def compute_module(
    or_: jax.Array,
    and_: jax.Array,
    b: jax.Array,
    c_in: jax.Array,
    select: jax.Array,
) -> ModuleOut:
    """One ADRA compute module (per bit, per column). All args are 0/1 ints.

    select = 0 -> addition, 1 -> subtraction (A - B).
    """
    xor = or_ & (1 - and_)
    xnor = 1 - xor
    a_not_b = or_ & (1 - b)

    # 2:1 muxes controlled by SELECT (Fig. 3(d))
    half = jnp.where(select == 1, xnor, xor)          # A ^ B~  vs  A ^ B
    gen = jnp.where(select == 1, a_not_b, and_)       # A*~B    vs  A*B

    sum_ = half ^ c_in
    carry = gen | (c_in & half)
    return ModuleOut(sum_=sum_, carry=carry)


def ripple_chain(
    or_bits: jax.Array,
    and_bits: jax.Array,
    b_bits: jax.Array,
    select: int,
) -> Tuple[jax.Array, jax.Array]:
    """Chain n+1 compute modules over the bit axis (axis -1, LSB first).

    Inputs are the per-bit SA outputs of an n-bit word pair, shape [..., n].
    Returns (sum_bits [..., n+1], carry_out [...]). The (n+1)-th module uses
    sign-extended inputs (bit n-1 replicated), handling two's-complement
    overflow exactly as the paper prescribes.
    """
    n = or_bits.shape[-1]
    sel = jnp.asarray(select, dtype=or_bits.dtype)

    # sign extension for the overflow module: replicate MSB inputs
    ext = lambda x: jnp.concatenate([x, x[..., -1:]], axis=-1)
    or_e, and_e, b_e = ext(or_bits), ext(and_bits), ext(b_bits)

    def step(c_in, xs):
        o, a, bb = xs
        out = compute_module(o, a, bb, c_in, sel)
        return out.carry, out.sum_

    # scan over bit positions (the ripple is sequential in hardware too)
    xs = (
        jnp.moveaxis(or_e, -1, 0),
        jnp.moveaxis(and_e, -1, 0),
        jnp.moveaxis(b_e, -1, 0),
    )
    c0 = jnp.broadcast_to(sel, or_bits.shape[:-1]).astype(or_bits.dtype)
    c_out, sums = jax.lax.scan(step, c0, xs)
    return jnp.moveaxis(sums, 0, -1), c_out


class CompareOut(NamedTuple):
    lt: jax.Array   # A < B   (sign bit of the (n+1)-bit A-B)
    eq: jax.Array   # A == B  (AND tree over complemented SUM bits)
    gt: jax.Array   # derived: NOT(lt) AND NOT(eq)


def and_tree_zero_detect(sum_bits: jax.Array) -> jax.Array:
    """Near-memory AND-gate tree: 1 iff every SUM bit is 0 (n-1 two-input
    AND gates for an n-bit word -> one gate per memory column of overhead)."""
    return jnp.min(1 - sum_bits, axis=-1)


def compare_from_sub(sum_bits: jax.Array) -> CompareOut:
    """Comparison from the subtraction output (paper Sec. III-B)."""
    lt = sum_bits[..., -1]                      # sign of A - B in 2's complement
    eq = and_tree_zero_detect(sum_bits)
    gt = (1 - lt) & (1 - eq)
    return CompareOut(lt=lt, eq=eq, gt=gt)


# ------------------------------------------------------------------
# Gate-count accounting (used by the energy model's peripheral terms)
# ------------------------------------------------------------------

#: extra transistors vs the prior-work adder-only module (paper Sec. III-B):
#: two 2:1 muxes + one NOT + one NOR. The alternate design trades the muxes
#: for a duplicated XOR + AOI21 (4 extra transistors, same-cycle add AND sub).
EXTRA_GATES_MUX_DESIGN = {"mux2": 2, "not": 1, "nor": 1}
EXTRA_TRANSISTORS_MUX_DESIGN = 2 * 6 + 2 + 4            # ~20
EXTRA_TRANSISTORS_DUAL_OUTPUT_DESIGN = EXTRA_TRANSISTORS_MUX_DESIGN + 4


# ------------------------------------------------------------------
# Alternate compute-module design (paper Sec. III-B, last paragraph):
# instead of the two 2:1 muxes, duplicate the XOR and AOI21 gates to
# produce the ADDITION and SUBTRACTION outputs in the SAME cycle
# (4 extra transistors vs the mux design).
# ------------------------------------------------------------------


class DualModuleOut(NamedTuple):
    sum_add: jax.Array
    carry_add: jax.Array
    sum_sub: jax.Array
    carry_sub: jax.Array


def compute_module_dual(
    or_: jax.Array,
    and_: jax.Array,
    b: jax.Array,
    c_in_add: jax.Array,
    c_in_sub: jax.Array,
) -> DualModuleOut:
    """One dual-output module: both A+B and A-B bits per cycle."""
    xor = or_ & (1 - and_)
    xnor = 1 - xor
    a_not_b = or_ & (1 - b)
    return DualModuleOut(
        sum_add=xor ^ c_in_add,
        carry_add=and_ | (c_in_add & xor),
        sum_sub=xnor ^ c_in_sub,
        carry_sub=a_not_b | (c_in_sub & xnor),
    )


def ripple_chain_dual(
    or_bits: jax.Array,
    and_bits: jax.Array,
    b_bits: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """n+1 dual modules: (sum_add_bits [...,n+1], sum_sub_bits [...,n+1])
    from ONE memory access — the same-cycle add+sub capability."""
    ext = lambda x: jnp.concatenate([x, x[..., -1:]], axis=-1)
    or_e, and_e, b_e = ext(or_bits), ext(and_bits), ext(b_bits)

    def step(carries, xs):
        ca, cs = carries
        o, a, bb = xs
        out = compute_module_dual(o, a, bb, ca, cs)
        return (out.carry_add, out.carry_sub), (out.sum_add, out.sum_sub)

    xs = (jnp.moveaxis(or_e, -1, 0), jnp.moveaxis(and_e, -1, 0),
          jnp.moveaxis(b_e, -1, 0))
    zeros = jnp.zeros(or_bits.shape[:-1], or_bits.dtype)
    ones = jnp.ones(or_bits.shape[:-1], or_bits.dtype)
    _, (sa, ss) = jax.lax.scan(step, (zeros, ones), xs)
    return jnp.moveaxis(sa, 0, -1), jnp.moveaxis(ss, 0, -1)

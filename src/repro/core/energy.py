"""Component-level energy/latency/EDP model for ADRA (paper Sec. IV, Figs 4-7).

The paper's numbers come from SPICE on a 45 nm PTM FET + Verilog-A FE cap. We
rebuild the *component* model (bitline, wordline, current flow, sensing,
peripherals, leakage) and calibrate it to the paper's anchor measurements at a
1024x1024 array; the benchmark harness then reproduces each figure's sweep
from the model. The calibration is internally consistent with every quoted
relation in the paper:

  current sensing @1024^2 : CiM = 1.24x read energy, RBL = 91% of read /
                            74% of CiM energy, 1.94x speedup, -41.18% energy,
                            ~69% EDP decrease (paper: 69.04%)
  voltage scheme 1        : CiM bitline discharges 6*Delta vs 2*Delta for a
                            read -> 3x bitline energy (1.5x vs the 2-read
                            baseline), +20-23% energy, 1.57-1.73x speedup,
                            23.26-28.81% EDP decrease
  voltage scheme 2        : RBL charged per-op -> read-like CiM energy,
                            ~1.95x speedup, -35-46% energy, 66.8-72.6% EDP dec.
  scheme 1 vs scheme 2    : leakage/charge trade -> crossover at 7.53 MHz;
                            half-selected pseudo-CiM waste -> crossover at
                            parallelism P ~ 42%.

Units: internal energy unit = one standard read of a 32-bit word at 1024 rows
(per scheme family); multiply by E0_FJ for femtojoules. Latency unit = one
read at 1024 rows; multiply by T0_NS for nanoseconds. Relative claims
(speedups, percentage deltas, crossovers) are unit-free.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

# physical anchor scales (order-of-magnitude for a 45nm 1024-row array)
E0_FJ = 120.0      # fJ per 32-bit-word standard read @1024 rows
T0_NS = 2.0        # ns per standard read @1024 rows

# voltage-sensing design constants (shared by schemes 1/2 and the crossovers)
V_DD = 1.0
DELTA_SENSE = 0.1231          # voltage sense margin Delta (>50 mV, paper Sec. IV)
READ_SWING = 2 * DELTA_SENSE  # a standard read develops 2*Delta on the RBL
CIM_SWING = 6 * DELTA_SENSE   # ADRA must separate 4 levels -> 6*Delta
                              # => CiM bitline energy = 3x read (paper Sec. IV-B)


@dataclasses.dataclass(frozen=True)
class OpCosts:
    """Energy & latency of one operation on a 32-bit word (internal units)."""

    energy: float
    latency: float
    breakdown: Dict[str, float]

    @property
    def edp(self) -> float:
        return self.energy * self.latency


@dataclasses.dataclass(frozen=True)
class SchemeResult:
    """read / ADRA-CiM / near-memory-baseline costs + derived paper metrics."""

    read: OpCosts
    cim: OpCosts
    baseline: OpCosts

    @property
    def speedup(self) -> float:
        return self.baseline.latency / self.cim.latency

    @property
    def energy_decrease_pct(self) -> float:
        return 100.0 * (1.0 - self.cim.energy / self.baseline.energy)

    @property
    def edp_decrease_pct(self) -> float:
        return 100.0 * (1.0 - self.cim.edp / self.baseline.edp)


def _nhat(rows: int) -> float:
    return rows / 1024.0


# ---------------------------------------------------------------------------
# Current-based sensing (paper Fig. 4)
# ---------------------------------------------------------------------------

# calibrated component set (internal unit = CS read total @1024 rows)
_CS = dict(
    e_bl=0.91,        # RBL charge, prop. to rows (91% of read @1024, Fig 4a)
    e_wl=0.02,        # wordline charging (per-word share; const for square arrays)
    e_flow=0.03,      # read-current flow
    e_sa=0.04,        # one current SA
    e_wl_cim=0.0338,  # two WLs at (0.83^2 + 1.0^2) x the single-WL energy
    e_flow_cim=0.05,  # two cells conduct
    e_sa_cim=0.12,    # three SAs
    e_cm=0.126,       # ADRA compute module (muxes + OAI + adder)
    e_nc=0.108,       # near-memory compute unit (baseline, incl. operand latch)
    t_fix=0.30,       # wordline + SA latency
    t_bl=0.70,        # bitline development @1024 rows (prop. to rows)
    t_cm=0.05,        # compute-module latency
    t_nc=0.04,        # near-memory compute latency
)


def current_sensing(rows: int = 1024) -> SchemeResult:
    n = _nhat(rows)
    c = _CS
    e_read = c["e_bl"] * n + c["e_wl"] + c["e_flow"] + c["e_sa"]
    e_cim = c["e_bl"] * n + c["e_wl_cim"] + c["e_flow_cim"] + c["e_sa_cim"] + c["e_cm"]
    e_base = 2.0 * e_read + c["e_nc"]

    t_read = c["t_fix"] + c["t_bl"] * n
    t_cim = t_read + c["t_cm"]
    t_base = 2.0 * t_read + c["t_nc"]

    return SchemeResult(
        read=OpCosts(e_read, t_read, {"bitline": c["e_bl"] * n, "wordline": c["e_wl"],
                                      "flow": c["e_flow"], "periph": c["e_sa"]}),
        cim=OpCosts(e_cim, t_cim, {"bitline": c["e_bl"] * n, "wordline": c["e_wl_cim"],
                                   "flow": c["e_flow_cim"],
                                   "periph": c["e_sa_cim"] + c["e_cm"]}),
        baseline=OpCosts(e_base, t_base, {"two_reads": 2 * e_read, "near_compute": c["e_nc"]}),
    )


# ---------------------------------------------------------------------------
# Voltage-based sensing, schemes 1 & 2 (paper Figs. 5-7)
# ---------------------------------------------------------------------------

# common internal unit: scheme-2 read total @1024 rows = 1.0
_VS = dict(
    c_bl=0.93,        # full-swing (V_DD) bitline energy @1024 rows, prop. to rows
    s_read=0.07,      # read peripherals (SA + WL + decoder), both schemes
    s1_cim=0.167,     # scheme-1 CiM peripherals (3 SAs + compute module)
    s2_cim=0.25,      # scheme-2 CiM peripherals (incl. per-op precharge control)
    e_nc=0.108,
    # scheme-1 latency set
    t1_f=0.45, t1_b=0.55, t1_x=0.20, t1_nc=0.04,
    # scheme-2 latency set
    t2_f=0.30, t2_b=0.70, t2_cm=0.045, t2_nc=0.04,
    # leakage power of a precharged-RBL array (internal units / second):
    # calibrated so the scheme-1/2 energy crossover sits at 7.53 MHz (Fig 5a)
    p_leak=(0.93 + 0.25 - (3 * 0.93 * READ_SWING / V_DD + 0.167)) * 7.53e6,
)


def voltage_scheme1(rows: int = 1024, freq_hz: float | None = None) -> SchemeResult:
    """Scheme 1: RBL held precharged; ops discharge it partially.

    A read develops 2*Delta; ADRA CiM needs 6*Delta to separate four levels,
    i.e. 3x bitline energy (1.5x vs the two-read baseline). Optionally charges
    the hold-state leakage (p_leak / freq) to each op for Fig 5(a).
    """
    n = _nhat(rows)
    c = _VS
    e_bl_read = c["c_bl"] * (READ_SWING / V_DD) * n
    e_bl_cim = 3.0 * e_bl_read
    leak = (c["p_leak"] / freq_hz) if freq_hz else 0.0

    e_read = e_bl_read + c["s_read"] + leak
    e_cim = e_bl_cim + c["s1_cim"] + leak
    e_base = 2.0 * (e_bl_read + c["s_read"]) + c["e_nc"] + 2.0 * leak

    t_read = c["t1_f"] + c["t1_b"] * n
    t_cim = t_read + c["t1_x"]
    t_base = 2.0 * t_read + c["t1_nc"]

    return SchemeResult(
        read=OpCosts(e_read, t_read, {"bitline": e_bl_read, "periph": c["s_read"], "leak": leak}),
        cim=OpCosts(e_cim, t_cim, {"bitline": e_bl_cim, "periph": c["s1_cim"], "leak": leak}),
        baseline=OpCosts(e_base, t_base, {"two_reads": 2 * (e_bl_read + c["s_read"]),
                                          "near_compute": c["e_nc"], "leak": 2 * leak}),
    )


def voltage_scheme2(rows: int = 1024) -> SchemeResult:
    """Scheme 2: RBL at 0 during hold, charged to V_DD for every operation.

    Bitline energy is the full swing for read AND CiM alike, so ADRA's extra
    discharge is free -> current-sensing-like benefits (Fig 7)."""
    n = _nhat(rows)
    c = _VS
    e_bl = c["c_bl"] * n

    e_read = e_bl + c["s_read"]
    e_cim = e_bl + c["s2_cim"]
    e_base = 2.0 * e_read + c["e_nc"]

    t_read = c["t2_f"] + c["t2_b"] * n
    t_cim = t_read + c["t2_cm"]
    t_base = 2.0 * t_read + c["t2_nc"]

    return SchemeResult(
        read=OpCosts(e_read, t_read, {"bitline": e_bl, "periph": c["s_read"]}),
        cim=OpCosts(e_cim, t_cim, {"bitline": e_bl, "periph": c["s2_cim"]}),
        baseline=OpCosts(e_base, t_base, {"two_reads": 2 * e_read, "near_compute": c["e_nc"]}),
    )


# ---------------------------------------------------------------------------
# Fig 5(a): per-op energy vs operating frequency (leakage trade-off)
# ---------------------------------------------------------------------------


def scheme_energies_vs_frequency(freq_hz: float, rows: int = 1024) -> Dict[str, float]:
    """Per-CiM-op energy of both schemes at a given op frequency.

    Scheme 1 pays hold-state leakage between ops (amortized as p_leak/f);
    scheme 2 pays the full RBL charge every op but has ~no hold leakage."""
    s1 = voltage_scheme1(rows, freq_hz=freq_hz)
    s2 = voltage_scheme2(rows)
    return {"scheme1": s1.cim.energy, "scheme2": s2.cim.energy}


def frequency_crossover_hz(rows: int = 1024) -> float:
    """Frequency below which scheme 2 is more energy-efficient (paper: 7.53 MHz)."""
    c = _VS
    e1_dyn = voltage_scheme1(rows).cim.energy
    e2_dyn = voltage_scheme2(rows).cim.energy
    return c["p_leak"] / (e2_dyn - e1_dyn)


# ---------------------------------------------------------------------------
# Fig 5(b): per-row-op energy vs CiM parallelism P = N_w,CiM / N_w,TOT
# ---------------------------------------------------------------------------


def scheme_energies_vs_parallelism(p: float, rows: int = 1024, n_words: int = 32) -> Dict[str, float]:
    """Energy per row operation when a fraction p of the row's words compute.

    Scheme 1: the asserted wordlines span the whole row, so HALF-SELECTED
    words undergo a pseudo-CiM discharge (~2*Delta, like a pseudo-read) that
    must be recharged -> wasted energy prop. to (1-p). Scheme 2 only charges
    the selected words' RBLs. (paper: crossover at P ~ 42%)."""
    n = _nhat(rows)
    c = _VS
    sel_bl = 3.0 * c["c_bl"] * (READ_SWING / V_DD) * n      # 6*Delta swing
    half_bl = c["c_bl"] * (READ_SWING / V_DD) * n           # 2*Delta pseudo-CiM
    e1 = n_words * (p * (sel_bl + c["s1_cim"]) + (1.0 - p) * half_bl)
    e2 = n_words * p * (c["c_bl"] * n + c["s2_cim"])
    return {"scheme1": e1, "scheme2": e2}


def parallelism_crossover(rows: int = 1024) -> float:
    """P below which scheme 2 wins (paper: ~42%)."""
    lo, hi = 1e-4, 1.0
    for _ in range(80):
        mid = 0.5 * (lo + hi)
        e = scheme_energies_vs_parallelism(mid, rows)
        if e["scheme1"] > e["scheme2"]:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


# ---------------------------------------------------------------------------
# sweeps (the actual paper figures) + physical-unit helpers
# ---------------------------------------------------------------------------

ARRAY_SIZES = (256, 512, 1024, 2048)


def sweep(scheme: str, sizes=ARRAY_SIZES) -> Dict[int, SchemeResult]:
    fn = {"current": current_sensing, "scheme1": voltage_scheme1, "scheme2": voltage_scheme2}[scheme]
    return {s: fn(s) for s in sizes}


def to_fj(e_internal: float) -> float:
    return e_internal * E0_FJ


def to_ns(t_internal: float) -> float:
    return t_internal * T0_NS


def edp_summary(rows: int = 1024) -> Dict[str, Dict[str, float]]:
    """The paper's headline table: EDP decrease per sensing scheme."""
    out = {}
    for name, fn in [("current", current_sensing), ("scheme1", voltage_scheme1),
                     ("scheme2", voltage_scheme2)]:
        r = fn(rows)
        out[name] = {
            "speedup": r.speedup,
            "energy_decrease_pct": r.energy_decrease_pct,
            "edp_decrease_pct": r.edp_decrease_pct,
        }
    return out


# ---------------------------------------------------------------------------
# paper-reported anchors (one source of truth for figure scripts and docs)
# ---------------------------------------------------------------------------

#: Figures the ADRA paper reports for Figs. 4-7, as (lo, hi) ranges per
#: scheme and metric (point anchors have lo == hi). The fig4-fig7 scripts
#: annotate their output from THIS table — a calibration fix here can
#: never diverge the figures from the cost model.
PAPER_ANCHORS: Dict[str, Dict[str, tuple]] = {
    "current": {
        "energy_decrease_pct": (41.18, 41.18),   # @1024 rows
        "speedup": (1.94, 1.94),
        "edp_decrease_pct": (69.04, 69.04),
    },
    "scheme1": {
        "bitline_ratio_cim_over_read": (3.0, 3.0),   # 6*Delta vs 2*Delta
        "energy_decrease_pct": (-23.0, -20.0),       # CiM costs more
        "speedup": (1.57, 1.73),
        "edp_decrease_pct": (23.26, 28.81),
    },
    "scheme2": {
        "energy_decrease_pct": (35.5, 45.8),
        "speedup": (1.945, 1.983),
        "edp_decrease_pct": (66.83, 72.6),
    },
    "crossover": {
        "frequency_mhz": (7.53, 7.53),
        "parallelism": (0.42, 0.42),
    },
}


def anchor_note(scheme: str, metric: str, at_1024: bool = False,
                suffix: str = "") -> str:
    """The figure scripts' annotation string for one paper anchor."""
    lo, hi = PAPER_ANCHORS[scheme][metric]
    where = "paper@1024" if at_1024 else "paper"
    body = f"{lo:g}" if lo == hi else f"{lo:g}..{hi:g}"
    return f"{where}: {body}{suffix}"

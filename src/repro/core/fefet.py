"""HZO FeFET device model for the ADRA array.

The paper models the ferroelectric layer with Miller's equations (Preisach-based
domain distribution) in Verilog-A on top of a 45 nm PTM FET. We re-derive the
same behaviour in JAX:

  P(E)   = Ps * tanh[(E +/- Ec) / (2*sigma)]          (eq. 1)
  sigma  = alpha / ln[(Ps + Pr) / (Ps - Pr)]          (eq. 2)

The retained +/-P state shifts the FET threshold voltage; read currents follow a
smooth EKV-style I-V so that both the super-threshold (LRS at V_GREAD) and the
deep-subthreshold (HRS) regimes are captured by one expression.

All quantities are SI unless noted. Calibration targets (paper Sec. IV):
  V_READ = 1.0 V, V_GREAD2 = 1.0 V, V_GREAD1 = 0.83 V,
  four distinct I_SL levels with > 1 uA current sense margin and > 50 mV
  voltage sense margin.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Ferroelectric layer (Miller / Preisach average-polarization model)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FEParams:
    """Hf0.5Zr0.5O2 (HZO) ferroelectric parameters (paper Fig. 2(b) regime).

    Values follow the experimentally-calibrated HZO FeFET literature the paper
    cites ([17] Ni et al. VLSI'18, [18] Chatterjee et al. EDL'17).
    """

    Ps: float = 23.0e-2          # saturation polarization, C/m^2  (23 uC/cm^2)
    Pr: float = 17.0e-2          # remanent polarization,   C/m^2  (17 uC/cm^2)
    Ec: float = 1.0e8            # coercive field, V/m             (1 MV/cm)
    alpha: float = 2.5e7         # material-specific spread parameter, V/m
    eps_r: float = 32.0          # background relative permittivity of HZO
    t_fe: float = 8.0e-9         # FE layer thickness, m
    tau: float = 50.0e-9         # polarization response lag, s

    @property
    def sigma(self) -> float:
        """Eq. (2): sigma = alpha * ln[(Ps+Pr)/(Ps-Pr)]^-1."""
        import math

        return self.alpha / math.log((self.Ps + self.Pr) / (self.Ps - self.Pr))

    @property
    def coercive_voltage(self) -> float:
        return self.Ec * self.t_fe

    @property
    def c_fe_linear(self) -> float:
        """Background (linear) FE capacitance per unit area, C_B = eps0*eps_r/t_fe."""
        eps0 = 8.8541878128e-12
        return eps0 * self.eps_r / self.t_fe


def polarization(v_fe: jax.Array, fe: FEParams, branch: int = +1) -> jax.Array:
    """Average polarization from Miller's equation (eq. 1).

    branch = +1 selects the ascending saturation loop branch (E - Ec), -1 the
    descending branch (E + Ec). Static reads sit on the retained branch.
    """
    e_fe = v_fe / fe.t_fe
    shift = -branch * fe.Ec
    return fe.Ps * jnp.tanh((e_fe + shift) / (2.0 * fe.sigma))


def fe_charge(v_fe: jax.Array, fe: FEParams, branch: int = +1) -> jax.Array:
    """Total FE charge density Q = eps0*eps_r*E + P (paper Sec. II-C)."""
    eps0 = 8.8541878128e-12
    e_fe = v_fe / fe.t_fe
    return eps0 * fe.eps_r * e_fe + polarization(v_fe, fe, branch)


def fe_capacitance(v_fe: jax.Array, fe: FEParams, branch: int = +1) -> jax.Array:
    """C_FE = dQ/dV = C_B + C_P, evaluated analytically."""
    e_fe = v_fe / fe.t_fe
    shift = -branch * fe.Ec
    sech2 = 1.0 / jnp.cosh((e_fe + shift) / (2.0 * fe.sigma)) ** 2
    c_p = fe.Ps * sech2 / (2.0 * fe.sigma * fe.t_fe)
    return fe.c_fe_linear + c_p


# ---------------------------------------------------------------------------
# FeFET: FE layer in the gate stack of a 45 nm FET
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FeFETParams:
    """1T FeFET bitcell parameters.

    The retained polarization state shifts the effective threshold voltage:
    +P (LRS, logic '1') lowers V_T, -P (HRS, logic '0') raises it. The memory
    window is calibrated to the paper's bias points: at V_GREAD1 = 0.83 V and
    V_GREAD2 = 1.0 V an LRS cell conducts strongly while an HRS cell stays in
    deep subthreshold, producing the I_SL ordering of Fig. 3(c).
    """

    fe: FEParams = dataclasses.field(default_factory=FEParams)
    vt_lrs: float = 0.25         # V_T with +P retained (low-resistance state)
    vt_hrs: float = 1.45         # V_T with -P retained (high-resistance state)
    k_beta: float = 3.2e-4       # transconductance factor, A/V^2 (45nm, W/L~4)
    n_ss: float = 1.45           # subthreshold slope factor
    lambda_ch: float = 0.08      # channel-length modulation, 1/V
    temp_vt: float = 0.02585     # thermal voltage at 300 K, V

    @property
    def memory_window(self) -> float:
        return self.vt_hrs - self.vt_lrs


def drain_current(
    v_gs: jax.Array, v_ds: jax.Array, v_t: jax.Array, p: FeFETParams
) -> jax.Array:
    """Smooth EKV-style I-V: valid from deep subthreshold to strong inversion.

    I_D = 2 n k vt^2 * [ln(1 + exp((Vgs - Vt)/(2 n vt)))]^2
          * (1 - exp(-Vds/vt)) * (1 + lambda Vds)
    """
    vt = p.temp_vt
    x = (v_gs - v_t) / (2.0 * p.n_ss * vt)
    # log1p(exp(x)) with overflow-safe formulation
    soft = jnp.where(x > 30.0, x, jnp.log1p(jnp.exp(jnp.minimum(x, 30.0))))
    i_sat = 2.0 * p.n_ss * p.k_beta * vt**2 * soft**2
    return i_sat * (1.0 - jnp.exp(-v_ds / vt)) * (1.0 + p.lambda_ch * v_ds)


def cell_current(
    stored_bit: jax.Array, v_wl: jax.Array, v_rbl: jax.Array, p: FeFETParams
) -> jax.Array:
    """Read current of one 1T FeFET bitcell.

    stored_bit: 1 -> +P retained (LRS), 0 -> -P retained (HRS).
    v_wl: wordline (gate) voltage; v_rbl: read-bitline (drain) voltage.
    """
    bit = jnp.asarray(stored_bit)
    v_t = jnp.where(bit > 0, p.vt_lrs, p.vt_hrs)
    return drain_current(jnp.asarray(v_wl), jnp.asarray(v_rbl), v_t, p)


# Convenience: the paper's bias set -------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BiasConditions:
    """Paper Sec. IV bias conditions."""

    v_read: float = 1.0          # RBL drive
    v_gread: float = 1.0         # standard read wordline voltage (= V_GREAD2)
    v_gread1: float = 0.83       # ADRA: WL of word A
    v_gread2: float = 1.0        # ADRA: WL of word B
    v_set: float = 3.7
    v_reset: float = -5.0


@partial(jax.jit, static_argnames=("p",))
def read_currents(p: FeFETParams = FeFETParams(), bias: float = 1.0) -> jax.Array:
    """[I_HRS, I_LRS] at wordline voltage `bias` (V_DS = V_READ = 1 V)."""
    bits = jnp.array([0, 1])
    return cell_current(bits, jnp.asarray(bias), jnp.asarray(1.0), p)


def write_polarization(v_gs: float, p: FeFETParams) -> int:
    """Static write model: V_GS > +Vc writes +P (LRS, '1');
    V_GS < -Vc writes -P (HRS, '0'); otherwise state is retained (-1)."""
    vc = p.fe.coercive_voltage
    if v_gs > vc:
        return 1
    if v_gs < -vc:
        return 0
    return -1

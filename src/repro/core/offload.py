"""ADRA offload estimator: project CiM savings for a JAX/XLA program.

Two sources, one report:

  source="jaxpr" (default, via `analyze`) — stage the function with
    `repro.cim.trace` and walk the SAME classified eqn list the lowering
    compiler (repro.cim.lower) executes. Estimator and executor share one
    eligibility classification, so they can never disagree: the report's
    `adra_accesses` equals the ledger access count of one lowered
    (unbanked) execution, and `banked_accesses` equals the placed count on
    the given ArraySpec.

  source="hlo" (fallback, via `analyze_hlo`) — regex-scan compiled HLO
    text. Kept for post-XLA programs where no jaxpr is available (fusion
    dumps, serialized computations); it is a projection only and is not
    guaranteed to agree with an executed lowering.

Two eligibility tiers in both sources:

  single-access — elementwise integer add / subtract / compare / bitwise /
    min / max: one ADRA access each (the paper's primitive set).
  multi-access  — integer multiply / dot / (jaxpr only) full reduce_sum and
    population_count: lowered by the macro-op planner (repro.cim.planner)
    to shift-and-add / tree-reduction access schedules; the estimator
    charges the PLANNED access count per op, so the projection stays
    faithful to the access-count cost model rather than pretending
    multiplication is free.

Byte accounting is done in BITS and rounded up once at the end, so 4-bit
dtypes (s4/u4) contribute exact sub-byte traffic instead of fractional
"bytes" leaking into the totals.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

from . import energy

# HLO ops whose semantics ADRA computes in-array in ONE access
_ELIGIBLE = ("add", "subtract", "compare", "and", "or", "xor", "maximum", "minimum")
# the multi-access tier ("multiply", "dot") is matched by _MUL_RE / _DOT_RE
# below, each lowered through the planner's access schedules
_INT_TYPES = ("s8", "u8", "s16", "u16", "s32", "u32", "s4", "u4")

_SHAPE_RE = re.compile(r"(" + "|".join(_INT_TYPES) + r")\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(" + "|".join(_INT_TYPES) + r"|pred)\[([0-9,]*)\][^=]*?\s("
    + "|".join(_ELIGIBLE) + r")\(",
    re.M,
)
_MUL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(" + "|".join(_INT_TYPES) + r")\[([0-9,]*)\][^=]*?\smultiply\(",
    re.M,
)
# dot: result may be wider than the operands (s8 x s8 -> s32); capture the
# lhs operand's dtype/shape and the contracting dims clause when present
_DOT_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:" + "|".join(_INT_TYPES)
    + r")\[([0-9,]*)\][^=]*?\sdot\(\s*(" + "|".join(_INT_TYPES)
    + r")\[([0-9,]*)\][^)]*\)(?:[^\n]*lhs_contracting_dims=\{(\d+)\})?",
    re.M,
)

#: element widths in BITS (accumulate in bits, round to bytes ONCE) — preds
#: are stored as one byte per element in HLO buffers
_BITS = {"s4": 4, "u4": 4, "s8": 8, "u8": 8, "s16": 16, "u16": 16,
         "s32": 32, "u32": 32, "pred": 8}


def _numel(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


def _bits_to_bytes(bits: int) -> int:
    return -(-int(bits) // 8)


@dataclasses.dataclass
class OffloadReport:
    eligible_ops: int
    eligible_bytes: int
    total_bytes_estimate: int
    words32: int                     # 32-bit-word operations ADRA would execute
    edp_decrease_pct: float          # paper model, current sensing @1024^2
    energy_saved_fj: float
    op_histogram: Dict[str, int]
    multi_access_ops: int = 0        # multiply/dot/... lowered by the planner
    planner_accesses: int = 0        # total planned accesses for those ops
    banked_accesses: int = 0         # bank activations on the given ArraySpec
    bank_waves: int = 0              # serialized wave count (critical path)
    adra_accesses: int = 0           # TOTAL planned accesses (single + multi):
    #                                  == the executed ledger count of one
    #                                  unbanked repro.cim.lower run (jaxpr src)
    stream_load_accesses: int = 0    # operand row-write loads per call if every
    #                                  operand streams in (UPPER BOUND: region
    #                                  fusion memoizes entry packs, so the
    #                                  executed ledger charge is <= this)
    resident_savable_accesses: int = 0  # the slice of those loads a pinned
    #                                  dot-rhs (repro.cim.lower resident mode)
    #                                  removes from every warm call
    source: str = "hlo"
    policy: str = "always"           # offload policy the report was cut under
    demoted_eqns: int = 0            # eligible eqns the cost model kept on host
    demoted_accesses: int = 0        # planned accesses those demotions remove
    fused_losses: int = 0            # losing eqns kept fused (pack/unpack toll)
    eqn_verdicts: tuple = ()         # cost.EqnVerdict per eligible eqn (jaxpr)

    @property
    def eligible_fraction(self) -> float:
        return self.eligible_bytes / max(1, self.total_bytes_estimate)

    @property
    def bank_parallel_speedup(self) -> float:
        """Activation-count / wave-count: how much of the banked access bill
        the banks absorb in parallel (1.0 = fully serialized)."""
        return self.banked_accesses / max(1, self.bank_waves)


# ---------------------------------------------------------------------------
# source="jaxpr": the lowering compiler's own eqn list
# ---------------------------------------------------------------------------


def analyze(fn, *args, scheme: str = "current", rows: int = 1024,
            spec=None, source: str = "jaxpr", policy: str = "always",
            device=None) -> OffloadReport:
    """Project ADRA savings for `fn` called with example `args`.

    source="jaxpr" (default) analyzes the traced eqn list shared with the
    lowering compiler; source="hlo" compiles through XLA and falls back to
    the regex scan of `analyze_hlo`. `policy`/`device` select the offload
    policy (repro.cim.cost) the projection is cut under — the default
    "always" preserves the historical project-everything report; pass the
    policy actually given to `lower()` to project the DECIDED offload
    (demoted eqns drop out of the access counts, mirroring the executed
    ledger).
    """
    if source == "hlo":
        import jax

        lowered = jax.jit(fn).lower(*args)
        try:
            hlo = lowered.as_text("hlo")         # classic HLO text
        except Exception:                        # pragma: no cover
            hlo = lowered.as_text()              # StableHLO fallback
        return analyze_hlo(hlo, scheme=scheme, rows=rows, spec=spec)
    if source != "jaxpr":
        raise ValueError(f"unknown offload source {source!r} "
                         "(expected 'jaxpr' or 'hlo')")
    from repro.cim.trace import trace

    return analyze_trace(trace(fn, *args), scheme=scheme, rows=rows,
                         spec=spec, policy=policy, device=device)


def analyze_trace(tr, scheme: str = "current", rows: int = 1024,
                  spec=None, policy: str = "always",
                  device=None) -> OffloadReport:
    """OffloadReport from a `repro.cim.trace.Trace` — the estimator half of
    the shared-eligibility contract (see module docstring). The offload
    decision and the per-eqn word accounting come from repro.cim.cost's
    `plan_offload` — the SAME call the lowering compiler makes — so the
    report's demotion list is the executor's demotion list."""
    # lazy imports break the core<->cim module cycle
    from repro.cim import cost as cost_mod
    from repro.cim.accounting import project_savings
    from repro.cim.trace import aval_of, dtype_bits

    plan = cost_mod.plan_offload(tr, spec=spec, scheme=scheme, rows=rows,
                                 device=device, policy=policy)
    demoted = plan.demoted

    hist: Dict[str, int] = {}
    eligible_bits = 0
    words32 = 0.0
    n_ops = 0
    n_multi = 0
    planner_accesses = 0
    adra_accesses = 0
    banked_accesses = 0
    bank_waves = 0
    stream_loads = 0
    resident_savable = 0

    def place(op_words: int, logical_accesses: int) -> None:
        nonlocal banked_accesses, bank_waves
        if spec is None or op_words < 1:
            return
        plan = spec.plan(op_words)
        banked_accesses += logical_accesses * plan.n_tiles
        bank_waves += logical_accesses * plan.waves

    _HIST_NAMES = {"mul": "multiply", "dot_general": "dot",
                   "population_count": "popcount"}
    for i, op in enumerate(tr.ops):
        if not op.eligible or op.accesses == 0:
            continue                 # free peripherals do no array work
        if i in demoted:
            continue                 # the cost model keeps this eqn on host
        bits = op.n_bits
        n_ops += 1
        adra_accesses += op.accesses
        name = _HIST_NAMES.get(op.name, op.name)
        if op.name == "dot_general" and \
                len(op.params["dimension_numbers"][1][0]) > 0:
            # attention's QK^T/AV land here: batch dims on tile rows, the
            # contraction on the broadcast layout (plan_batched_matmul)
            name = "batched_dot"
        hist[name] = hist.get(name, 0) + 1
        place(op.words, op.accesses)
        # words32 and streamed loads come from the cost model's shared
        # per-eqn accounting (one implementation, two consumers); the
        # stream-load count is an upper bound by construction (region
        # fusion memoizes entry packs)
        words32 += cost_mod.eqn_words32(op)
        stream_loads += cost_mod.eqn_stream_loads(op)
        if op.name == "dot_general":
            # a pinnable rhs removes exactly its side of the dot's loads —
            # for batched_dot that side is the K^T / V operand (the KV
            # cache under `sdpa_cim(resident=True)`)
            resident_savable += 1

        if op.kind == "single":
            out_aval = aval_of(op.outvars[0])
            out_bits = dtype_bits(out_aval.dtype)
            # two operand reads + the result write, at true element widths
            eligible_bits += (2 * bits + out_bits) * op.words
            continue

        n_multi += 1
        planner_accesses += op.accesses
        if op.name == "mul":
            eligible_bits += 3 * op.words * bits
        elif op.name == "dot_general":
            lhs = aval_of(op.invars[0])
            out = aval_of(op.outvars[0])
            k = int(lhs.shape[-1])       # contracting dim (2-D and batched)
            out_nel = 1
            for d in out.shape:
                out_nel *= int(d)
            eligible_bits += out_nel * k * 2 * bits + out_nel * 32
        elif op.name == "reduce_sum":
            eligible_bits += op.words * bits + 32
        else:                        # population_count
            eligible_bits += 2 * op.words * bits

    # total traffic estimate: every aval the program touches, once
    total_bits = 0
    seen = set()
    all_ops_vars = [v for op in tr.ops for v in op.outvars]
    for v in list(tr.closed.jaxpr.invars) + all_ops_vars:
        if id(v) in seen or not hasattr(v, "aval"):
            continue
        seen.add(id(v))
        aval = v.aval
        if not hasattr(aval, "shape"):
            continue
        nel = 1
        for d in aval.shape:
            nel *= int(d)
        try:
            b = dtype_bits(aval.dtype)
        except Exception:
            b = aval.dtype.itemsize * 8
        total_bits += nel * b
    total_bits = max(total_bits, eligible_bits)

    proj = project_savings(words32, scheme=scheme, rows=rows)
    return OffloadReport(
        eligible_ops=n_ops,
        eligible_bytes=_bits_to_bytes(eligible_bits),
        total_bytes_estimate=_bits_to_bytes(total_bits),
        words32=int(words32),
        edp_decrease_pct=proj["edp_decrease_pct"],
        energy_saved_fj=proj["energy_saved_fj"],
        op_histogram=hist,
        multi_access_ops=n_multi,
        planner_accesses=planner_accesses,
        banked_accesses=banked_accesses,
        bank_waves=bank_waves,
        adra_accesses=adra_accesses,
        stream_load_accesses=stream_loads,
        resident_savable_accesses=resident_savable,
        source="jaxpr",
        policy=plan.policy,
        demoted_eqns=plan.demoted_eqns,
        demoted_accesses=plan.demoted_accesses,
        fused_losses=plan.fused_losses,
        eqn_verdicts=plan.verdicts,
    )


# ---------------------------------------------------------------------------
# source="hlo": regex fallback over compiled HLO text
# ---------------------------------------------------------------------------


def analyze_hlo(hlo_text: str, scheme: str = "current", rows: int = 1024,
                spec=None) -> OffloadReport:
    """Scan HLO for ADRA-eligible integer ops and project savings.

    With an `ArraySpec` (repro.cim.array), every op's operand words are
    placed onto the banked geometry: each logical access becomes one
    activation per tile (`banked_accesses`) and the per-op critical path is
    its wave count (`bank_waves`) — banks run concurrently, waves serialize.
    """
    # lazy imports break the core<->cim module cycle
    from repro.cim.accounting import project_savings
    from repro.cim.planner import plan_matmul, plan_multiply

    hist: Dict[str, int] = {}
    eligible_bits = 0
    words32 = 0.0
    n_ops = 0
    n_multi = 0
    planner_accesses = 0
    adra_accesses = 0
    banked_accesses = 0
    bank_waves = 0

    def place(op_words: int, logical_accesses: int) -> None:
        nonlocal banked_accesses, bank_waves
        if spec is None or op_words < 1:
            return
        plan = spec.plan(op_words)
        banked_accesses += logical_accesses * plan.n_tiles
        bank_waves += logical_accesses * plan.waves

    for m in _OP_RE.finditer(hlo_text):
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        nel = _numel(dims)
        # two operand reads + one result write at the op's element width
        bits = _BITS.get(dtype, 32)
        eligible_bits += 3 * nel * bits
        words32 += nel * bits / 32.0
        n_ops += 1
        adra_accesses += 1
        hist[op] = hist.get(op, 0) + 1
        place(nel, 1)

    for m in _MUL_RE.finditer(hlo_text):
        dtype, dims = m.group(1), m.group(2)
        nel = _numel(dims)
        bits = _BITS.get(dtype, 32)
        accesses = plan_multiply(bits, bits).accesses
        # shift-and-add works at the 2n-bit product width on every access
        words32 += accesses * nel * (2 * bits) / 32.0
        eligible_bits += 3 * nel * bits
        n_ops += 1
        n_multi += 1
        planner_accesses += accesses
        adra_accesses += accesses
        hist["multiply"] = hist.get("multiply", 0) + 1
        place(nel, accesses)

    for m in _DOT_RE.finditer(hlo_text):
        out_dims, lhs_dtype, lhs_dims, cdim = m.groups()
        lhs_shape = [int(d) for d in lhs_dims.split(",")] if lhs_dims else []
        k = 1
        if lhs_shape:
            ci = int(cdim) if cdim is not None else len(lhs_shape) - 1
            k = lhs_shape[ci] if ci < len(lhs_shape) else lhs_shape[-1]
        bits = _BITS.get(lhs_dtype, 32)
        out_nel = _numel(out_dims)
        sched = plan_matmul(k, 1, n_bits=bits)
        # the packed contraction layout holds out_nel * K_pad product words
        k_pad = 1 << max(0, (k - 1).bit_length())
        words32 += sched.accesses * out_nel * k_pad * (2 * bits) / 32.0
        # operand reads at the input width + the (32-bit) wide result write
        eligible_bits += out_nel * k * 2 * bits + out_nel * 32
        n_ops += 1
        n_multi += 1
        planner_accesses += sched.accesses
        adra_accesses += sched.accesses
        hist["dot"] = hist.get("dot", 0) + 1
        place(out_nel * k_pad, sched.accesses)

    # crude total-traffic estimate: every shaped tensor literal in the module
    total_bits = 0
    for m in _SHAPE_RE.finditer(hlo_text):
        total_bits += _numel(m.group(2)) * _BITS.get(m.group(1), 32)
    total_bits = max(total_bits, eligible_bits)

    proj = project_savings(words32, scheme=scheme, rows=rows)
    return OffloadReport(
        eligible_ops=n_ops,
        eligible_bytes=_bits_to_bytes(eligible_bits),
        total_bytes_estimate=_bits_to_bytes(total_bits),
        words32=int(words32),
        edp_decrease_pct=proj["edp_decrease_pct"],
        energy_saved_fj=proj["energy_saved_fj"],
        op_histogram=hist,
        multi_access_ops=n_multi,
        planner_accesses=planner_accesses,
        banked_accesses=banked_accesses,
        bank_waves=bank_waves,
        adra_accesses=adra_accesses,
        source="hlo",
    )

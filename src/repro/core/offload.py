"""ADRA offload estimator: project CiM savings for a compiled XLA program.

Scans HLO text for ADRA-eligible ops and projects the energy-delay saving
were those ops served by ADRA CiM arrays instead of read+compute passes,
using the calibrated model in repro.core.energy. Two tiers:

  single-access — elementwise integer add / subtract / compare / bitwise /
    min / max: one ADRA access each (the paper's primitive set).
  multi-access  — integer `multiply` and `dot`: lowered by the macro-op
    planner (repro.cim.planner) to shift-and-add / tree-reduction access
    schedules; the estimator charges the PLANNED access count per op, so
    the projection stays faithful to the access-count cost model rather
    than pretending multiplication is free.

This ties the paper's array-level numbers to LM-scale workloads (and
quantifies, honestly, how big that slice of a transformer step actually is).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

from . import energy

# HLO ops whose semantics ADRA computes in-array in ONE access
_ELIGIBLE = ("add", "subtract", "compare", "and", "or", "xor", "maximum", "minimum")
# the multi-access tier ("multiply", "dot") is matched by _MUL_RE / _DOT_RE
# below, each lowered through the planner's access schedules
_INT_TYPES = ("s8", "u8", "s16", "u16", "s32", "u32", "s4", "u4")

_SHAPE_RE = re.compile(r"(" + "|".join(_INT_TYPES) + r")\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(" + "|".join(_INT_TYPES) + r"|pred)\[([0-9,]*)\][^=]*?\s("
    + "|".join(_ELIGIBLE) + r")\(",
    re.M,
)
_MUL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(" + "|".join(_INT_TYPES) + r")\[([0-9,]*)\][^=]*?\smultiply\(",
    re.M,
)
# dot: result may be wider than the operands (s8 x s8 -> s32); capture the
# lhs operand's dtype/shape and the contracting dims clause when present
_DOT_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:" + "|".join(_INT_TYPES)
    + r")\[([0-9,]*)\][^=]*?\sdot\(\s*(" + "|".join(_INT_TYPES)
    + r")\[([0-9,]*)\][^)]*\)(?:[^\n]*lhs_contracting_dims=\{(\d+)\})?",
    re.M,
)

_BYTES = {"s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
          "s32": 4, "u32": 4, "pred": 1}
_BITS = {"s4": 4, "u4": 4, "s8": 8, "u8": 8, "s16": 16, "u16": 16,
         "s32": 32, "u32": 32}


def _numel(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


@dataclasses.dataclass
class OffloadReport:
    eligible_ops: int
    eligible_bytes: int
    total_bytes_estimate: int
    words32: int                     # 32-bit-word operations ADRA would execute
    edp_decrease_pct: float          # paper model, current sensing @1024^2
    energy_saved_fj: float
    op_histogram: Dict[str, int]
    multi_access_ops: int = 0        # multiply/dot ops lowered by the planner
    planner_accesses: int = 0        # total planned accesses for those ops
    banked_accesses: int = 0         # bank activations on the given ArraySpec
    bank_waves: int = 0              # serialized wave count (critical path)

    @property
    def eligible_fraction(self) -> float:
        return self.eligible_bytes / max(1, self.total_bytes_estimate)

    @property
    def bank_parallel_speedup(self) -> float:
        """Activation-count / wave-count: how much of the banked access bill
        the banks absorb in parallel (1.0 = fully serialized)."""
        return self.banked_accesses / max(1, self.bank_waves)


def analyze_hlo(hlo_text: str, scheme: str = "current", rows: int = 1024,
                spec=None) -> OffloadReport:
    """Scan HLO for ADRA-eligible integer ops and project savings.

    With an `ArraySpec` (repro.cim.array), every op's operand words are
    placed onto the banked geometry: each logical access becomes one
    activation per tile (`banked_accesses`) and the per-op critical path is
    its wave count (`bank_waves`) — banks run concurrently, waves serialize.
    """
    # lazy imports break the core<->cim module cycle
    from repro.cim.accounting import project_savings
    from repro.cim.planner import plan_matmul, plan_multiply

    hist: Dict[str, int] = {}
    eligible_bytes = 0
    words32 = 0.0
    n_ops = 0
    n_multi = 0
    planner_accesses = 0
    banked_accesses = 0
    bank_waves = 0

    def place(op_words: int, logical_accesses: int) -> None:
        nonlocal banked_accesses, bank_waves
        if spec is None or op_words < 1:
            return
        plan = spec.plan(op_words)
        banked_accesses += logical_accesses * plan.n_tiles
        bank_waves += logical_accesses * plan.waves

    for m in _OP_RE.finditer(hlo_text):
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        nel = _numel(dims)
        # two operand reads + one result write at the op's element width
        width = _BYTES.get(dtype, 4)
        eligible_bytes += int(3 * nel * width)
        words32 += nel * width / 4.0
        n_ops += 1
        hist[op] = hist.get(op, 0) + 1
        place(nel, 1)

    for m in _MUL_RE.finditer(hlo_text):
        dtype, dims = m.group(1), m.group(2)
        nel = _numel(dims)
        bits = _BITS.get(dtype, 32)
        accesses = plan_multiply(bits, bits).accesses
        # shift-and-add works at the 2n-bit product width on every access
        words32 += accesses * nel * (2 * bits) / 32.0
        eligible_bytes += int(3 * nel * _BYTES.get(dtype, 4))
        n_ops += 1
        n_multi += 1
        planner_accesses += accesses
        hist["multiply"] = hist.get("multiply", 0) + 1
        place(nel, accesses)

    for m in _DOT_RE.finditer(hlo_text):
        out_dims, lhs_dtype, lhs_dims, cdim = m.groups()
        lhs_shape = [int(d) for d in lhs_dims.split(",")] if lhs_dims else []
        k = 1
        if lhs_shape:
            ci = int(cdim) if cdim is not None else len(lhs_shape) - 1
            k = lhs_shape[ci] if ci < len(lhs_shape) else lhs_shape[-1]
        bits = _BITS.get(lhs_dtype, 32)
        out_nel = _numel(out_dims)
        sched = plan_matmul(k, 1, n_bits=bits)
        # the packed contraction layout holds out_nel * K_pad product words
        k_pad = 1 << max(0, (k - 1).bit_length())
        words32 += sched.accesses * out_nel * k_pad * (2 * bits) / 32.0
        # operand reads at the input width + the (4-byte) wide result write
        eligible_bytes += int(out_nel * k * 2 * _BYTES.get(lhs_dtype, 4)
                              + out_nel * 4)
        n_ops += 1
        n_multi += 1
        planner_accesses += sched.accesses
        hist["dot"] = hist.get("dot", 0) + 1
        place(out_nel * k_pad, sched.accesses)

    # crude total-traffic estimate: every shaped tensor literal in the module
    total = 0
    for m in _SHAPE_RE.finditer(hlo_text):
        total += int(_numel(m.group(2)) * _BYTES.get(m.group(1), 4))
    total = max(total, eligible_bytes)

    proj = project_savings(words32, scheme=scheme, rows=rows)
    return OffloadReport(
        eligible_ops=n_ops,
        eligible_bytes=eligible_bytes,
        total_bytes_estimate=total,
        words32=int(words32),
        edp_decrease_pct=proj["edp_decrease_pct"],
        energy_saved_fj=proj["energy_saved_fj"],
        op_histogram=hist,
        multi_access_ops=n_multi,
        planner_accesses=planner_accesses,
        banked_accesses=banked_accesses,
        bank_waves=bank_waves,
    )

"""ADRA offload estimator: project CiM savings for a compiled XLA program.

Scans HLO text for ADRA-eligible ops — elementwise integer add / subtract /
compare — sums their operand bytes, and projects the energy-delay saving were
those bytes served by ADRA CiM arrays instead of two-pass read+compute, using
the calibrated model in repro.core.energy. This ties the paper's array-level
numbers to LM-scale workloads (and quantifies, honestly, how big that slice
of a transformer step actually is).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

from . import energy

# HLO ops whose semantics ADRA computes in-array for integer operands
_ELIGIBLE = ("add", "subtract", "compare", "and", "or", "xor", "maximum", "minimum")
_INT_TYPES = ("s8", "u8", "s16", "u16", "s32", "u32", "s4", "u4")

_SHAPE_RE = re.compile(r"(" + "|".join(_INT_TYPES) + r")\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(" + "|".join(_INT_TYPES) + r"|pred)\[([0-9,]*)\][^=]*?\s("
    + "|".join(_ELIGIBLE) + r")\(",
    re.M,
)

_BYTES = {"s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
          "s32": 4, "u32": 4, "pred": 1}


def _numel(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


@dataclasses.dataclass
class OffloadReport:
    eligible_ops: int
    eligible_bytes: int
    total_bytes_estimate: int
    words32: int                     # 32-bit-word operations ADRA would execute
    edp_decrease_pct: float          # paper model, current sensing @1024^2
    energy_saved_fj: float
    op_histogram: Dict[str, int]

    @property
    def eligible_fraction(self) -> float:
        return self.eligible_bytes / max(1, self.total_bytes_estimate)


def analyze_hlo(hlo_text: str, scheme: str = "current", rows: int = 1024) -> OffloadReport:
    """Scan HLO for ADRA-eligible integer elementwise ops and project savings."""
    hist: Dict[str, int] = {}
    eligible_bytes = 0
    n_ops = 0
    for m in _OP_RE.finditer(hlo_text):
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        nel = _numel(dims)
        # two operand reads + one result write at the op's element width
        width = _BYTES.get(dtype, 4)
        eligible_bytes += int(3 * nel * width)
        n_ops += 1
        hist[op] = hist.get(op, 0) + 1

    # crude total-traffic estimate: every shaped tensor literal in the module
    total = 0
    for m in _SHAPE_RE.finditer(hlo_text):
        total += int(_numel(m.group(2)) * _BYTES.get(m.group(1), 4))
    total = max(total, eligible_bytes)

    # project through the CiM engine's accounting layer (same ledger math the
    # engine charges per executed op-set); lazy import breaks the core<->cim
    # module cycle
    from repro.cim.accounting import project_savings

    words32 = eligible_bytes // 4
    proj = project_savings(words32, scheme=scheme, rows=rows)
    return OffloadReport(
        eligible_ops=n_ops,
        eligible_bytes=eligible_bytes,
        total_bytes_estimate=total,
        words32=words32,
        edp_decrease_pct=proj["edp_decrease_pct"],
        energy_saved_fj=proj["energy_saved_fj"],
        op_histogram=hist,
    )

"""Sense amplifiers and reference generation for ADRA (paper Fig. 3(b)).

Three SAs share the senseline:
  SA_OR  : ref between I(0,0) and I(1,0)   -> outputs A+B  (OR)
  SA_B   : ref between I(1,0) and I(0,1)   -> outputs B
  SA_AND : ref between I(0,1) and I(1,1)   -> outputs AB   (AND)

Complements are available from the differential SA outputs. The fourth signal,
A, is recovered with one OAI21 gate (paper Sec. III-A):

    A = NOT( NAND(A,B) * (B + NOR(A,B)) )

Both current-based and voltage-based sensing are supported; voltage sensing
compares the RBL discharge against voltage references with the same level
ordering.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .array import AdraArrayConfig, level_currents, rbl_discharge_voltage


class SenseOutputs(NamedTuple):
    """Digital outputs of the three SAs (plus derived A) for each column."""

    or_: jax.Array       # A + B
    and_: jax.Array      # A * B
    b: jax.Array         # B (the word under V_GREAD2)
    a: jax.Array         # recovered via the OAI21 gate


@dataclasses.dataclass(frozen=True)
class SenseReferences:
    """Reference currents (A) placed midway between adjacent I_SL levels."""

    i_ref_or: float
    i_ref_b: float
    i_ref_and: float

    @classmethod
    def from_config(cls, cfg: AdraArrayConfig) -> "SenseReferences":
        # references depend only on the static device config: force
        # compile-time evaluation so this also works inside jitted programs
        with jax.ensure_compile_time_eval():
            lv = jax.device_get(level_currents(cfg, asymmetric=True))  # [I00,I10,I01,I11]
        return cls(
            i_ref_or=float(0.5 * (lv[0] + lv[1])),
            i_ref_b=float(0.5 * (lv[1] + lv[2])),
            i_ref_and=float(0.5 * (lv[2] + lv[3])),
        )


def current_sense_margins(cfg: AdraArrayConfig) -> jax.Array:
    """Adjacent-level separations [I10-I00, I01-I10, I11-I01] (amperes).

    The paper reports > 1 uA margin for current-based sensing.
    """
    lv = level_currents(cfg, asymmetric=True)
    return jnp.diff(lv)


def voltage_sense_margins(cfg: AdraArrayConfig, t_sense: float = 1.0e-9) -> jax.Array:
    """Adjacent-level RBL discharge separations (volts); paper: > 50 mV."""
    lv = level_currents(cfg, asymmetric=True)
    dv = rbl_discharge_voltage(lv, t_sense, cfg)
    return jnp.diff(dv)


def oai21_recover_a(or_: jax.Array, and_: jax.Array, b: jax.Array) -> jax.Array:
    """A = NOT( NOT(AND) * (B + NOT(OR)) )  -- one OAI21 on the SA outputs."""
    nand_ = 1 - and_
    nor_ = 1 - or_
    return 1 - (nand_ & (b | nor_))


def sense(
    i_sl: jax.Array, refs: SenseReferences
) -> SenseOutputs:
    """Threshold the senseline current against the three references."""
    or_ = (i_sl > refs.i_ref_or).astype(jnp.int32)
    b = (i_sl > refs.i_ref_b).astype(jnp.int32)
    and_ = (i_sl > refs.i_ref_and).astype(jnp.int32)
    a = oai21_recover_a(or_, and_, b)
    return SenseOutputs(or_=or_, and_=and_, b=b, a=a)


def symmetric_sense_is_ambiguous(cfg: AdraArrayConfig) -> bool:
    """Demonstrates the many-to-one problem of prior (symmetric) CiM:
    I(0,1) == I(1,0) to within sensing resolution, so (0,1) and (1,0)
    cannot be distinguished and non-commutative functions are infeasible."""
    lv = jax.device_get(level_currents(cfg, asymmetric=False))
    sep_mid = abs(float(lv[2] - lv[1]))
    # sub-1% of the smallest commutative-level gap == indistinguishable
    gap = min(float(lv[1] - lv[0]), float(lv[3] - lv[2]))
    return sep_mid < 0.01 * gap

from .pipeline import DataConfig, embed_stub_batch, iterator, sharded_batch, synthetic_batch  # noqa: F401

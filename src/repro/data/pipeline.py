"""Deterministic synthetic LM data pipeline, sharding-aware and
restart-exact.

Tokens are a stateless function of (seed, step, position): resuming from a
checkpoint at step k reproduces batch k bit-exactly with no iterator state to
persist — the property the fault-tolerance tests assert. Batches are placed
with jax.make_array_from_callback so each host only materializes its
addressable shards (multi-host ready; on one host it degenerates to
device_put with the right layout).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 32000
    batch: int = 8
    seq_len: int = 128


def _tokens_for(step: int, cfg: DataConfig, start_row: int, n_rows: int) -> np.ndarray:
    """Stateless token block [n_rows, seq_len+1] for global rows
    [start_row, start_row+n_rows) of batch `step`."""
    rows = np.arange(start_row, start_row + n_rows, dtype=np.uint64)[:, None]
    cols = np.arange(cfg.seq_len + 1, dtype=np.uint64)[None, :]
    with np.errstate(over="ignore"):  # modular uint64 mixing is intended
        x = (rows * np.uint64(6364136223846793005)
             + cols * np.uint64(1442695040888963407)
             + np.uint64(step) * np.uint64(2862933555777941757)
             + np.uint64(cfg.seed) * np.uint64(3202034522624059733))
    # splitmix-style scramble (modular)
    with np.errstate(over="ignore"):
        x = (x ^ (x >> np.uint64(33))) * np.uint64(0xFF51AFD7ED558CCD)
        x = (x ^ (x >> np.uint64(33))) * np.uint64(0xC4CEB9FE1A85EC53)
        x = x ^ (x >> np.uint64(33))
    return (x % np.uint64(cfg.vocab_size)).astype(np.int32)


def synthetic_batch(step: int, cfg: DataConfig) -> Dict[str, np.ndarray]:
    """Host-global batch: inputs = block[:, :-1], targets = block[:, 1:]
    (next-token prediction packing)."""
    block = _tokens_for(step, cfg, 0, cfg.batch)
    return {"tokens": block[:, :-1], "targets": block[:, 1:]}


def sharded_batch(step: int, cfg: DataConfig, mesh, batch_sharding) -> Dict[str, jax.Array]:
    """Build the global batch directly into its sharding, per-shard."""
    out = {}
    full = synthetic_batch(step, cfg)
    for name, host_arr in full.items():
        shape = host_arr.shape

        def cb(index):
            return host_arr[index]

        out[name] = jax.make_array_from_callback(shape, batch_sharding[name], cb)
    return out


def iterator(cfg: DataConfig, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield synthetic_batch(step, cfg)
        step += 1


def embed_stub_batch(step: int, arch: ArchConfig, batch: int, seq: int,
                     seed: int = 0) -> Dict[str, np.ndarray]:
    """Precomputed-frontend stand-in for audio/VLM archs: deterministic
    pseudo-embeddings + token targets (DESIGN.md §5)."""
    dcfg = DataConfig(seed=seed, vocab_size=arch.vocab_size, batch=batch, seq_len=seq)
    toks = _tokens_for(step, dcfg, 0, batch)
    rng = np.random.RandomState((seed * 1_000_003 + step) % (2**31))
    emb = rng.randn(batch, seq, arch.d_model).astype(np.float32) * 0.02
    return {"embeds": emb, "targets": toks[:, 1:][:, :seq]}

"""Pallas TPU kernels for the perf-critical compute layers.

  adra_bitplane   — the paper's technique: single-pass fused bit-plane
                    add/sub/compare (+ the two-pass near-memory baseline)
  flash_attention — blocked online-softmax GQA attention (prefill hot spot)
  rglru           — RG-LRU recurrence with VMEM-resident state
  slstm           — sLSTM recurrence with VMEM-RESIDENT recurrent weights
                    (kills the per-step R re-read; EXPERIMENTS §Perf B2)

Each kernel ships an ops.py jit wrapper (backend dispatch) and a ref.py
pure-jnp oracle; tests sweep shapes/dtypes asserting kernel == oracle in
interpret mode.
"""
from . import ops, ref  # noqa: F401
from .adra_bitplane import adra_bitplane_op, traffic_model_bytes  # noqa: F401
from .flash_attention import flash_attention  # noqa: F401
from .rglru import rglru  # noqa: F401
from .slstm import slstm_scan  # noqa: F401

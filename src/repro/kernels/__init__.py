"""Pallas TPU kernels for the perf-critical compute layers.

  adra_bitplane   — compat shims over the generalized fused CiM kernel
                    (the real kernel lives in repro.cim.fused_kernel and
                    emits ANY subset of add/sub/carry/compare/Boolean ops
                    from one streamed pass)
  flash_attention — blocked online-softmax GQA attention (prefill hot spot)
  rglru           — RG-LRU recurrence with VMEM-resident state
  slstm           — sLSTM recurrence with VMEM-RESIDENT recurrent weights
                    (kills the per-step R re-read; EXPERIMENTS §Perf B2)

Each kernel ships an ops.py jit wrapper (backend dispatch through the
repro.cim registry) and a ref.py pure-jnp oracle; tests sweep shapes/dtypes
asserting kernel == oracle in interpret mode.
"""
from . import ops, ref  # noqa: F401
from .adra_bitplane import adra_bitplane_op, traffic_model_bytes  # noqa: F401
from .flash_attention import flash_attention  # noqa: F401
from .rglru import rglru  # noqa: F401
from .slstm import slstm_scan  # noqa: F401

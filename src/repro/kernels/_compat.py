"""Version-portability shims for the Pallas TPU API surface.

jax renamed pltpu.TPUCompilerParams -> pltpu.CompilerParams (~0.5); support
both so the kernels run on whichever toolchain the container bakes in.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

"""Legacy entry points for the ADRA bit-plane kernel (compat shims).

The actual kernel now lives in repro.cim.fused_kernel: ONE generalized Pallas
pass that emits any requested subset of {add, sub, carry, lt/eq/gt, all 16
Boolean function plane stacks} — superseding the add-only/sub-only special
cases that used to live here. These wrappers preserve the original
(select-based) call contract for existing callers and tests; new code should
go through repro.cim.engine / repro.cim.fused_planes_op directly.
"""
from __future__ import annotations

import functools
import operator

import jax

from repro.cim.engine import traffic_model_bytes as _traffic_model
from repro.cim.fused_kernel import DEFAULT_BLOCK_W, fused_planes_op  # noqa: F401


def adra_bitplane_op(
    a_planes: jax.Array,
    b_planes: jax.Array,
    select: int,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = False,
):
    """Single-pass fused bit-plane add (select=0) / sub (select=1).

    Returns (sum_planes uint32[n_bits+1, W], carry uint32[1, W],
             lt uint32[1, W], eq uint32[1, W]).
    lt/eq are per-column bitmaps (only meaningful for select=1; for select=0
    they are the legacy sign/zero bitmaps of the ADD chain).
    """
    if select == 1:
        sum_p, carry, lt, eq = fused_planes_op(
            a_planes, b_planes, ("sub", "carry_sub", "lt", "eq"),
            block_w=block_w, interpret=interpret)
        return sum_p, carry, lt, eq
    sum_p, carry = fused_planes_op(
        a_planes, b_planes, ("add", "carry_add"),
        block_w=block_w, interpret=interpret)
    # legacy select=0 contract: sign/zero detect over the ADD output planes
    lt = sum_p[-1:, :]
    nz = functools.reduce(operator.or_, [sum_p[i] for i in range(sum_p.shape[0])])
    eq = (~nz)[None, :]
    return sum_p, carry, lt, eq


def baseline_bitplane_sub_then_cmp(
    a_planes: jax.Array,
    b_planes: jax.Array,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = False,
):
    """Near-memory baseline: subtraction pass, then a SEPARATE comparison pass
    (operands re-read — the second memory access of the paper's baseline)."""
    (sum_p,) = fused_planes_op(a_planes, b_planes, ("sub",),
                               block_w=block_w, interpret=interpret)
    lt, eq = fused_planes_op(a_planes, b_planes, ("lt", "eq"),
                             block_w=block_w, interpret=interpret)
    return sum_p, lt, eq


def traffic_model_bytes(n_bits: int, n_words32: int) -> dict:
    """HBM traffic (bytes) of fused-ADRA vs per-function baseline passes.

    Legacy two-pass shape (sub+carry+cmp fused vs sub pass then cmp pass);
    the generalized model is repro.cim.traffic_model_bytes."""
    return _traffic_model(
        n_bits, n_words32, ops=("sub", "carry_sub", "lt", "eq"),
        baseline_passes=(("sub",), ("lt", "eq")))

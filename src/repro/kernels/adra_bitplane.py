"""Pallas TPU kernel: ADRA bit-plane arithmetic in a single memory pass.

TPU-native adaptation of the paper's mechanism (DESIGN.md §2): integer words
are stored as packed bit-planes (plane p = bit p of 32 words per uint32 lane
element; the plane index plays the wordline-pair role). ONE streamed HBM->VMEM
pass over both operand plane stacks produces — simultaneously, like the three
sense amplifiers + compute module do — the sum/difference planes, the carry
plane, and the lt/eq/gt comparison bitmaps, using only VPU bitwise ops.

The near-memory baseline (two full accesses + compute, what the paper beats)
is the UNFUSED execution: one pass per requested function, re-reading the
operands each time. `benchmarks/kernel_bench.py` quantifies the traffic ratio.

Layout:  a_planes, b_planes : uint32[n_bits, n_words32]
         (n_words32 = number of 32-column groups; lane dim, multiple of 128)

Grid:    1-D over word blocks; the whole bit dimension stays resident in VMEM
         (n_bits+1 planes x block_w x 4 B ~= 33 x 512 x 4 B = 66 KiB per ref,
         well inside the ~16 MiB v5e VMEM budget, MXU-free / pure VPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_W = 512  # lane-dim block (multiple of 128 for VPU alignment)


def _adra_kernel(a_ref, b_ref, select_ref, sum_ref, carry_ref, lt_ref, eq_ref):
    """Fused single-pass ADRA pass over one word block.

    a_ref/b_ref: uint32[n_bits, bw]; select_ref: int32[1,1] (0=add, 1=sub);
    sum_ref: uint32[n_bits+1, bw] (incl. the (n+1)-th overflow-module plane);
    carry_ref/lt_ref/eq_ref: uint32[1, bw] bitmaps.
    """
    n_bits = a_ref.shape[0]
    select = select_ref[0, 0]
    bw = a_ref.shape[1]
    zeros = jnp.zeros((bw,), jnp.uint32)
    ones = jnp.full((bw,), 0xFFFFFFFF, jnp.uint32)

    # C_IN(0) = SELECT : A - B = A + ~B + 1
    carry0 = jnp.where(select == 1, ones, zeros)
    nz0 = zeros  # accumulates OR of result planes for the zero-detect AND tree

    def module(i, state):
        carry, nz = state
        a = a_ref[i, :]
        b = b_ref[i, :]
        b_eff = jnp.where(select == 1, ~b, b)      # mux: B vs NOT(B)
        half = a ^ b_eff                           # XOR / XNOR plane
        s = half ^ carry
        carry = (a & b_eff) | (carry & half)       # generate | propagate
        sum_ref[i, :] = s
        nz = nz | s
        return carry, nz

    carry, nz = jax.lax.fori_loop(0, n_bits, module, (carry0, nz0))

    # (n+1)-th compute module: sign-extended inputs (paper Sec. III-B)
    a_msb = a_ref[n_bits - 1, :]
    b_msb = b_ref[n_bits - 1, :]
    b_eff = jnp.where(select == 1, ~b_msb, b_msb)
    half = a_msb ^ b_eff
    s_ext = half ^ carry
    carry_out = (a_msb & b_eff) | (carry & half)
    sum_ref[n_bits, :] = s_ext
    nz = nz | s_ext

    carry_ref[0, :] = carry_out
    lt_ref[0, :] = s_ext          # sign bit of the (n+1)-bit result => A < B
    eq_ref[0, :] = ~nz            # AND tree over complemented SUM bits


@functools.partial(
    jax.jit, static_argnames=("select", "block_w", "interpret")
)
def adra_bitplane_op(
    a_planes: jax.Array,
    b_planes: jax.Array,
    select: int,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = False,
):
    """Single-pass fused bit-plane add (select=0) / sub (select=1).

    Returns (sum_planes uint32[n_bits+1, W], carry uint32[1, W],
             lt uint32[1, W], eq uint32[1, W]).
    lt/eq are per-column bitmaps (only meaningful for select=1).
    """
    n_bits, w = a_planes.shape
    assert b_planes.shape == (n_bits, w)
    if w % block_w != 0:
        pad = (-w) % block_w
        a_planes = jnp.pad(a_planes, ((0, 0), (0, pad)))
        b_planes = jnp.pad(b_planes, ((0, 0), (0, pad)))
    wp = a_planes.shape[1]
    sel = jnp.full((1, 1), select, jnp.int32)

    grid = (wp // block_w,)
    out_shapes = (
        jax.ShapeDtypeStruct((n_bits + 1, wp), jnp.uint32),  # sum planes
        jax.ShapeDtypeStruct((1, wp), jnp.uint32),           # carry out
        jax.ShapeDtypeStruct((1, wp), jnp.uint32),           # lt bitmap
        jax.ShapeDtypeStruct((1, wp), jnp.uint32),           # eq bitmap
    )
    plane_spec = pl.BlockSpec((n_bits, block_w), lambda i: (0, i))
    row_spec = pl.BlockSpec((1, block_w), lambda i: (0, i))
    outs = pl.pallas_call(
        _adra_kernel,
        grid=grid,
        in_specs=[
            plane_spec,
            plane_spec,
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # scalar SELECT, broadcast
        ],
        out_specs=(
            pl.BlockSpec((n_bits + 1, block_w), lambda i: (0, i)),
            row_spec,
            row_spec,
            row_spec,
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(a_planes, b_planes, sel)
    sum_p, carry, lt, eq = outs
    return sum_p[:, :w], carry[:, :w], lt[:, :w], eq[:, :w]


# ---------------------------------------------------------------------------
# The near-memory baseline: one pass PER function (two full accesses each in
# the paper's cycle accounting; in TPU terms, operands re-streamed per output).
# ---------------------------------------------------------------------------


def _sub_only_kernel(a_ref, b_ref, sum_ref):
    n_bits = a_ref.shape[0]
    bw = a_ref.shape[1]
    carry0 = jnp.full((bw,), 0xFFFFFFFF, jnp.uint32)

    def module(i, carry):
        a = a_ref[i, :]
        nb = ~b_ref[i, :]
        half = a ^ nb
        sum_ref[i, :] = half ^ carry
        return (a & nb) | (carry & half)

    carry = jax.lax.fori_loop(0, n_bits, module, carry0)
    a_msb = a_ref[n_bits - 1, :]
    nb_msb = ~b_ref[n_bits - 1, :]
    half = a_msb ^ nb_msb
    sum_ref[n_bits, :] = half ^ carry


def _cmp_only_kernel(a_ref, b_ref, lt_ref, eq_ref):
    n_bits = a_ref.shape[0]
    bw = a_ref.shape[1]
    carry0 = jnp.full((bw,), 0xFFFFFFFF, jnp.uint32)
    nz0 = jnp.zeros((bw,), jnp.uint32)

    def module(i, state):
        carry, nz = state
        a = a_ref[i, :]
        nb = ~b_ref[i, :]
        half = a ^ nb
        return (a & nb) | (carry & half), nz | (half ^ carry)

    carry, nz = jax.lax.fori_loop(0, n_bits, module, (carry0, nz0))
    a_msb = a_ref[n_bits - 1, :]
    nb_msb = ~b_ref[n_bits - 1, :]
    half = a_msb ^ nb_msb
    s_ext = half ^ carry
    lt_ref[0, :] = s_ext
    eq_ref[0, :] = ~(nz | s_ext)


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def baseline_bitplane_sub_then_cmp(
    a_planes: jax.Array,
    b_planes: jax.Array,
    block_w: int = DEFAULT_BLOCK_W,
    interpret: bool = False,
):
    """Near-memory baseline: subtraction pass, then a SEPARATE comparison pass
    (operands re-read — the second memory access of the paper's baseline)."""
    n_bits, w = a_planes.shape
    pad = (-w) % block_w
    if pad:
        a_planes = jnp.pad(a_planes, ((0, 0), (0, pad)))
        b_planes = jnp.pad(b_planes, ((0, 0), (0, pad)))
    wp = a_planes.shape[1]
    grid = (wp // block_w,)
    plane_spec = pl.BlockSpec((n_bits, block_w), lambda i: (0, i))
    row_spec = pl.BlockSpec((1, block_w), lambda i: (0, i))

    sum_p = pl.pallas_call(
        _sub_only_kernel,
        grid=grid,
        in_specs=[plane_spec, plane_spec],
        out_specs=pl.BlockSpec((n_bits + 1, block_w), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n_bits + 1, wp), jnp.uint32),
        interpret=interpret,
    )(a_planes, b_planes)

    lt, eq = pl.pallas_call(
        _cmp_only_kernel,
        grid=grid,
        in_specs=[plane_spec, plane_spec],
        out_specs=(row_spec, row_spec),
        out_shape=(
            jax.ShapeDtypeStruct((1, wp), jnp.uint32),
            jax.ShapeDtypeStruct((1, wp), jnp.uint32),
        ),
        interpret=interpret,
    )(a_planes, b_planes)
    return sum_p[:, :w], lt[:, :w], eq[:, :w]


def traffic_model_bytes(n_bits: int, n_words32: int) -> dict:
    """HBM traffic (bytes) of fused-ADRA vs per-function baseline passes.

    The memory-roofline analogue of the paper's one-vs-two access argument."""
    plane_bytes = 4 * n_words32
    ops_in = 2 * n_bits * plane_bytes                  # read A + B stacks
    sum_out = (n_bits + 1) * plane_bytes
    maps_out = 3 * plane_bytes
    fused = ops_in + sum_out + maps_out
    baseline = (ops_in + sum_out) + (ops_in + 2 * plane_bytes)  # sub pass + cmp pass
    return {"fused": fused, "baseline": baseline, "ratio": baseline / fused}

"""Pallas TPU flash attention (GQA-aware, causal), with online softmax.

Blocked q/k streaming with running (m, l, acc) statistics held in VMEM
scratch across the innermost (sequential) k-block grid dimension. Block
shapes are MXU-aligned (q/k blocks multiples of 128 where the head_dim
allows). Used for the prefill hot spot; validated in interpret mode against
ref.mha_ref. The XLA path (ref) is used for dry-run lowering on non-TPU
backends — see DESIGN.md §7.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, block_q: int, block_k: int,
    seq_q: int, seq_k: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale          # [bq, d]
    k = k_ref[0, :, 0, :].astype(jnp.float32)                  # [bk, d]
    v = v_ref[0, :, 0, :].astype(jnp.float32)                  # [bk, d]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]

    # causal mask in global coordinates (q aligned to the END of the kv span)
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    if causal:
        mask = (q_pos + (seq_k - seq_q)) >= k_pos
    else:
        mask = jnp.ones((block_q, block_k), jnp.bool_)
    mask = mask & (k_pos < seq_k)                              # kv padding
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]                                       # [bq]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)

    l_new = alpha * l_ref[:, 0] + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[:, 0]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret", "scale"),
)
def flash_attention(
    q: jax.Array,                  # [B, Tq, Hq, D]
    k: jax.Array,                  # [B, Tk, Hkv, D]
    v: jax.Array,                  # [B, Tk, Hkv, D]
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, tq, hq, d = q.shape
    _, tk, hkv, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)

    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    pad_q = (-tq) % block_q
    pad_k = (-tk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    tqp, tkp = q.shape[1], k.shape[1]

    grid = (b, hq, tqp // block_q, tkp // block_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, seq_q=tq, seq_k=tk,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda b_, h, qi, ki: (b_, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda b_, h, qi, ki: (b_, ki, h // group, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda b_, h, qi, ki: (b_, ki, h // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d), lambda b_, h, qi, ki: (b_, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, tqp, hq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :tq]

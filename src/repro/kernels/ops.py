"""Jitted public wrappers for the Pallas kernels, with backend dispatch.

ADRA integer ops route through the unified CiM engine (repro.cim): backend
resolution comes from the registry (pallas-tpu on TPU, jnp-boolean elsewhere,
REPRO_CIM_BACKEND / set_default_backend to override) instead of ad-hoc
platform checks. The legacy `interpret` flag maps onto the pallas-interpret /
pallas-tpu backends for callers that pin the Pallas path explicitly.

Attention / recurrence wrappers keep the same dispatch idea: Pallas on TPU,
interpret mode in tests, pure-jnp reference for dry-run lowering.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.cim import PlanePack, execute, execute_unfused, macro, on_tpu
from repro.cim.array import ArraySpec
from repro.cim.dispatch import execute_tiled
from repro.cim.planepack import mask_to_ints
from . import ref
from .adra_bitplane import adra_bitplane_op, baseline_bitplane_sub_then_cmp  # noqa: F401
from .flash_attention import flash_attention as _flash
from .rglru import rglru as _rglru


def _resolve_backend(interpret: Optional[bool], backend: Optional[str]) -> Optional[str]:
    """Map the legacy interpret flag to a registry backend name.

    None/None defers to the registry default (platform- or env-resolved)."""
    if backend is not None:
        return backend
    if interpret is None:
        return None
    return "pallas-interpret" if interpret else "pallas-tpu"


# ---------------------------------------------------------------------------
# ADRA integer ops through the CiM engine
# ---------------------------------------------------------------------------


def adra_sub(a: jax.Array, b: jax.Array, n_bits: int = 16,
             interpret: bool | None = None, backend: str | None = None,
             spec: ArraySpec | None = None, mesh=None):
    """Fused single-pass subtraction + comparison over integer arrays.

    Returns (diff int32[...], lt int32[...], eq int32[...]). With `spec`
    the operands are tiled over the banked array substrate (optionally
    shard_mapped over `mesh`); results are identical, the ledger charges
    per-bank activations instead of one infinite-array access.
    """
    bk = _resolve_backend(interpret, backend)
    pa, pb = PlanePack.pack(a, n_bits), PlanePack.pack(b, n_bits)
    if spec is not None or mesh is not None:
        out = execute_tiled(pa, pb, ("sub", "lt", "eq"), spec=spec,
                            backend=bk, mesh=mesh)
    else:
        out = execute(pa, pb, ("sub", "lt", "eq"), backend=bk)
    return out["sub"].unpack(), out["lt"].unpack(), out["eq"].unpack()


def adra_add(a: jax.Array, b: jax.Array, n_bits: int = 16,
             interpret: bool | None = None, backend: str | None = None,
             spec: ArraySpec | None = None, mesh=None):
    bk = _resolve_backend(interpret, backend)
    pa, pb = PlanePack.pack(a, n_bits), PlanePack.pack(b, n_bits)
    if spec is not None or mesh is not None:
        out = execute_tiled(pa, pb, ("add",), spec=spec, backend=bk,
                            mesh=mesh)
    else:
        out = execute(pa, pb, ("add",), backend=bk)
    return out["add"].unpack()


def unpack_bits_mask(bitmap: jax.Array, n: int) -> jax.Array:
    """uint32[1, W] bitmap -> int32[n] of 0/1 (compat; see planepack)."""
    return mask_to_ints(bitmap, (n,))


def baseline_sub_then_cmp(a: jax.Array, b: jax.Array, n_bits: int = 16,
                          interpret: bool | None = None,
                          backend: str | None = None):
    """The paper's near-memory baseline: separate passes (for benchmarks)."""
    bk = _resolve_backend(interpret, backend)
    out = execute_unfused(PlanePack.pack(a, n_bits), PlanePack.pack(b, n_bits),
                          (("sub",), ("lt", "eq")), backend=bk)
    return out["sub"].unpack(), out["lt"].unpack(), out["eq"].unpack()


# ---------------------------------------------------------------------------
# Macro ops (multi-access schedules from the CiM planner)
# ---------------------------------------------------------------------------


def cim_matmul(a: jax.Array, b: jax.Array, n_bits: int = 8,
               interpret: bool | None = None, backend: str | None = None,
               spec: ArraySpec | None = None, mesh=None):
    """Exact intN x intN -> int32 matmul through planned CiM access schedules.

    a [M, K], b [K, N] with entries representable in n_bits signed. The
    LOGICAL access count is (2*n_bits - 1) + ceil(log2 K) — independent of
    M and N; placed on a banked `spec`, each access becomes one activation
    per operand tile and the schedule carries its placement. The whole
    schedule executes as ONE jitted XLA program (repro.cim.macro.
    run_schedule_program): warm calls are a single dispatch with ledger
    charges replayed from the plan.
    """
    return macro.matmul(a, b, n_bits=n_bits,
                        backend=_resolve_backend(interpret, backend),
                        spec=spec, mesh=mesh)


def cim_relu(x: jax.Array, n_bits: int = 16,
             interpret: bool | None = None, backend: str | None = None,
             spec: ArraySpec | None = None, mesh=None):
    """max(x, 0) over integer arrays: ONE access (gt predicate + peripheral
    select) regardless of width."""
    bk = _resolve_backend(interpret, backend)
    return macro.relu(PlanePack.pack(x, n_bits), backend=bk,
                      spec=spec, mesh=mesh).unpack()


def cim_lower(fn, interpret: bool | None = None, backend: str | None = None,
              spec: ArraySpec | None = None, mesh=None):
    """Compile an unmodified JAX function into the hybrid CiM/host callable
    (repro.cim.lower): ADRA-eligible integer subgraphs fuse into planned
    access schedules executed through the banked dispatcher, everything
    else runs on the host. The kernels-level entry point applies the same
    legacy `interpret` flag resolution as the other wrappers here."""
    from repro.cim.lower import lower

    return lower(fn, backend=_resolve_backend(interpret, backend),
                 spec=spec, mesh=mesh)


# ---------------------------------------------------------------------------
# Attention / recurrence with backend dispatch
# ---------------------------------------------------------------------------


def attention(q, k, v, causal: bool = True, use_pallas: bool | None = None,
              interpret: bool = False):
    """GQA attention: Pallas flash kernel on TPU, jnp reference elsewhere."""
    use_pallas = on_tpu() if use_pallas is None else use_pallas
    if use_pallas or interpret:
        return _flash(q, k, v, causal=causal, interpret=interpret or not on_tpu())
    return ref.mha_ref(q, k, v, causal=causal)


def rglru_scan(x, r, i, log_lambda, h0=None, c: float = 8.0,
               use_pallas: bool | None = None, interpret: bool = False):
    use_pallas = on_tpu() if use_pallas is None else use_pallas
    if use_pallas or interpret:
        return _rglru(x, r, i, log_lambda, h0=h0, c=c,
                      interpret=interpret or not on_tpu())
    return ref.rglru_ref(x, r, i, log_lambda, h0=h0, c=c)

"""Jitted public wrappers for the Pallas kernels, with backend dispatch.

On TPU the Pallas implementations run natively; elsewhere they run in
interpret mode (tests/benchmarks) or fall back to the pure-jnp reference
(dry-run lowering), so every call site is portable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bitplane import pack_bitplanes, unpack_bitplanes
from . import ref
from .adra_bitplane import adra_bitplane_op, baseline_bitplane_sub_then_cmp
from .flash_attention import flash_attention as _flash
from .rglru import rglru as _rglru


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# ADRA integer ops over packed bit-planes
# ---------------------------------------------------------------------------


def adra_sub(a: jax.Array, b: jax.Array, n_bits: int = 16, interpret: bool | None = None):
    """Fused single-pass subtraction + comparison over integer arrays.

    Returns (diff int32[...], lt int32[...], eq int32[...]).
    """
    interpret = (not _on_tpu()) if interpret is None else interpret
    shape = a.shape
    n = int(jnp.size(a)) if not hasattr(a, "size") else a.size
    ap = pack_bitplanes(a, n_bits)
    bp = pack_bitplanes(b, n_bits)
    sum_p, _carry, lt, eq = adra_bitplane_op(ap, bp, select=1, interpret=interpret)
    diff = unpack_bitplanes(sum_p, n, signed=True)
    lt_bits = unpack_bits_mask(lt, n)
    eq_bits = unpack_bits_mask(eq, n)
    return diff.reshape(shape), lt_bits.reshape(shape), eq_bits.reshape(shape)


def adra_add(a: jax.Array, b: jax.Array, n_bits: int = 16, interpret: bool | None = None):
    interpret = (not _on_tpu()) if interpret is None else interpret
    shape = a.shape
    n = a.size
    ap = pack_bitplanes(a, n_bits)
    bp = pack_bitplanes(b, n_bits)
    sum_p, _c, _l, _e = adra_bitplane_op(ap, bp, select=0, interpret=interpret)
    return unpack_bitplanes(sum_p, n, signed=True).reshape(shape)


def unpack_bits_mask(bitmap: jax.Array, n: int) -> jax.Array:
    """uint32[1, W] bitmap -> int32[n] of 0/1."""
    w = bitmap.shape[-1]
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (bitmap.reshape(w)[:, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(w * 32)[:n].astype(jnp.int32)


def baseline_sub_then_cmp(a: jax.Array, b: jax.Array, n_bits: int = 16,
                          interpret: bool | None = None):
    """The paper's near-memory baseline: separate passes (for benchmarks)."""
    interpret = (not _on_tpu()) if interpret is None else interpret
    shape = a.shape
    n = a.size
    ap = pack_bitplanes(a, n_bits)
    bp = pack_bitplanes(b, n_bits)
    sum_p, lt, eq = baseline_bitplane_sub_then_cmp(ap, bp, interpret=interpret)
    return (
        unpack_bitplanes(sum_p, n, signed=True).reshape(shape),
        unpack_bits_mask(lt, n).reshape(shape),
        unpack_bits_mask(eq, n).reshape(shape),
    )


# ---------------------------------------------------------------------------
# Attention / recurrence with backend dispatch
# ---------------------------------------------------------------------------


def attention(q, k, v, causal: bool = True, use_pallas: bool | None = None,
              interpret: bool = False):
    """GQA attention: Pallas flash kernel on TPU, jnp reference elsewhere."""
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    if use_pallas or interpret:
        return _flash(q, k, v, causal=causal, interpret=interpret or not _on_tpu())
    return ref.mha_ref(q, k, v, causal=causal)


def rglru_scan(x, r, i, log_lambda, h0=None, c: float = 8.0,
               use_pallas: bool | None = None, interpret: bool = False):
    use_pallas = _on_tpu() if use_pallas is None else use_pallas
    if use_pallas or interpret:
        return _rglru(x, r, i, log_lambda, h0=h0, c=c,
                      interpret=interpret or not _on_tpu())
    return ref.rglru_ref(x, r, i, log_lambda, h0=h0, c=c)

"""Pure-jnp oracles for every Pallas kernel in this package.

Each function mirrors one kernel's contract exactly; tests sweep shapes and
dtypes asserting allclose/equality between kernel (interpret=True on CPU) and
oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# adra_bitplane oracle
# ---------------------------------------------------------------------------


def adra_bitplane_ref(a_planes: jax.Array, b_planes: jax.Array, select: int):
    """Oracle for adra_bitplane_op: plane-wise ripple in pure jnp."""
    n_bits, w = a_planes.shape
    b_eff = (~b_planes) if select == 1 else b_planes
    carry = jnp.full((w,), 0xFFFFFFFF if select == 1 else 0, jnp.uint32)
    sums = []
    nz = jnp.zeros((w,), jnp.uint32)
    for i in range(n_bits):
        a, b = a_planes[i], b_eff[i]
        half = a ^ b
        s = half ^ carry
        carry = (a & b) | (carry & half)
        sums.append(s)
        nz = nz | s
    a_msb, b_msb = a_planes[n_bits - 1], b_eff[n_bits - 1]
    half = a_msb ^ b_msb
    s_ext = half ^ carry
    carry_out = (a_msb & b_msb) | (carry & half)
    nz = nz | s_ext
    sums.append(s_ext)
    sum_p = jnp.stack(sums)
    return sum_p, carry_out[None, :], s_ext[None, :], (~nz)[None, :]


def adra_int_ref(a: jax.Array, b: jax.Array, select: int, n_bits: int):
    """Integer-semantics oracle: what the bit-plane machinery must equal."""
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    res = a - b if select == 1 else a + b
    lt = (a < b).astype(jnp.int32)
    eq = (a == b).astype(jnp.int32)
    return res, lt, eq


# ---------------------------------------------------------------------------
# flash attention oracle (GQA-aware, causal or full)
# ---------------------------------------------------------------------------


def mha_ref(
    q: jax.Array,        # [B, Tq, Hq, D]
    k: jax.Array,        # [B, Tk, Hkv, D]
    v: jax.Array,        # [B, Tk, Hkv, D]
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    """Reference grouped-query attention in f32 accumulation."""
    b, tq, hq, d = q.shape
    _, tk, hkv, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # expand kv heads to q heads
    kf = jnp.repeat(kf, group, axis=2)
    vf = jnp.repeat(vf, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    if causal:
        mask = jnp.tril(jnp.ones((tq, tk), bool), k=tk - tq)
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# RG-LRU oracle (Griffin / RecurrentGemma recurrence)
# ---------------------------------------------------------------------------


def rglru_ref(
    x: jax.Array,        # [B, T, D] gated input
    r: jax.Array,        # [B, T, D] recurrence gate pre-activation
    i: jax.Array,        # [B, T, D] input gate pre-activation
    log_lambda: jax.Array,  # [D] learnable decay parameter (pre-softplus)
    h0: jax.Array | None = None,
    c: float = 8.0,
) -> tuple[jax.Array, jax.Array]:
    """h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
    a_t = exp(-c * softplus(log_lambda) * sigmoid(r_t)).  Returns (ys, h_T)."""
    b, t, d = x.shape
    decay = jax.nn.softplus(log_lambda.astype(jnp.float32))
    a = jnp.exp(-c * decay[None, None, :] * jax.nn.sigmoid(r.astype(jnp.float32)))
    gated = jax.nn.sigmoid(i.astype(jnp.float32)) * x.astype(jnp.float32)
    mult = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))

    def step(h, xs):
        a_t, g_t, m_t = xs
        h = a_t * h + m_t * g_t
        return h, h

    h_init = jnp.zeros((b, d), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    from repro.models.scan_utils import chunked_scan, pick_chunk

    h_last, ys = chunked_scan(
        step, h_init, (a.swapaxes(0, 1), gated.swapaxes(0, 1), mult.swapaxes(0, 1)),
        chunk=pick_chunk(t),
    )
    return ys.swapaxes(0, 1).astype(x.dtype), h_last

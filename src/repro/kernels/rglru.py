"""Pallas TPU kernel for the RG-LRU recurrence (Griffin / RecurrentGemma).

    a_t = exp(-c * softplus(log_lambda) * sigmoid(r_t))
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (sigmoid(i_t) * x_t)

Time is blocked along a sequential grid dimension; the hidden state h is
carried across time blocks in VMEM scratch (the TPU analogue of keeping the
recurrence register-resident instead of round-tripping HBM per step). Feature
dim is blocked lane-aligned (multiples of 128). Validated in interpret mode
against ref.rglru_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams


def _rglru_kernel(x_ref, r_ref, i_ref, ll_ref, h0_ref, y_ref, hout_ref, h_ref, *, c: float):
    ti = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = h0_ref[0, :][None, :].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)            # [bt, bd]
    r = r_ref[0].astype(jnp.float32)
    i = i_ref[0].astype(jnp.float32)
    ll = ll_ref[0].astype(jnp.float32)          # [bd]

    decay = jax.nn.softplus(ll)[None, :]
    a = jnp.exp(-c * decay * jax.nn.sigmoid(r))  # [bt, bd]
    gated = jax.nn.sigmoid(i) * x
    mult = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))

    def step(h, xs):
        a_t, g_t, m_t = xs
        h = a_t * h + m_t * g_t
        return h, h

    h0 = h_ref[0, :]
    h_last, ys = jax.lax.scan(step, h0, (a, gated, mult))
    y_ref[0] = ys.astype(y_ref.dtype)
    h_ref[...] = h_last[None, :]

    @pl.when(ti == nt - 1)
    def _emit_state():
        hout_ref[0, :] = h_last.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("c", "block_t", "block_d", "interpret"))
def rglru(
    x: jax.Array,            # [B, T, D]
    r: jax.Array,            # [B, T, D]
    i: jax.Array,            # [B, T, D]
    log_lambda: jax.Array,   # [D]
    h0: jax.Array | None = None,   # [B, D]
    c: float = 8.0,
    block_t: int = 128,
    block_d: int = 128,
    interpret: bool = False,
):
    """Returns (y [B,T,D], h_T [B,D])."""
    b, t, d = x.shape
    if h0 is None:
        h0 = jnp.zeros((b, d), jnp.float32)
    block_t = min(block_t, t)
    block_d = min(block_d, d)
    assert t % block_t == 0 and d % block_d == 0, (t, d, block_t, block_d)

    grid = (b, d // block_d, t // block_t)
    seq_spec = pl.BlockSpec((1, block_t, block_d), lambda b_, di, ti: (b_, ti, di))
    out = pl.pallas_call(
        functools.partial(_rglru_kernel, c=c),
        grid=grid,
        in_specs=[
            seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, block_d), lambda b_, di, ti: (0, di)),
            pl.BlockSpec((1, block_d), lambda b_, di, ti: (b_, di)),
        ],
        out_specs=(
            seq_spec,
            pl.BlockSpec((1, block_d), lambda b_, di, ti: (b_, di)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, t, d), x.dtype),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, r, i, log_lambda[None, :], h0)
    return out

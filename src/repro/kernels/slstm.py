"""Pallas TPU kernel for the sLSTM recurrence with VMEM-RESIDENT recurrent
weights (EXPERIMENTS.md §Perf B2).

sLSTM is inherently sequential (h_{t-1} feeds the gate pre-activations), so
XLA re-streams the recurrent matrix R [D, 4, D] from HBM every timestep:
9.4 MB x 4096 steps x 3 layers ~ 116 GB of redundant traffic per xlstm-125m
train step. R fits VMEM (9.4 MB f32 < 16 MiB), so this kernel pins it there
for the whole sequence: traffic becomes read-once + O(T) activations.

Grid: (B_blocks, T) with T sequential ("arbitrary"); the (h, c, n, m) state
is carried across timesteps in VMEM scratch. Per step: one [bb, D] x [D, 4D]
MXU matmul + elementwise gating.

    pre = wx_t + h R + b;  z = tanh(pre_0); i = pre_1; f = log_sigmoid(pre_2)
    m' = max(f + m, i);  c = e^{f+m-m'} c + e^{i-m'} z;  n = e^{f+m-m'} n + e^{i-m'}
    h = sigmoid(pre_3) * c / max(n, 1e-6)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams


def _slstm_kernel(wx_ref, r_ref, b_ref, h0_ref, c0_ref, n0_ref, m0_ref,
                  y_ref, hout_ref, cout_ref, nout_ref, mout_ref, state_ref):
    t = pl.program_id(1)
    nt = pl.num_programs(1)

    @pl.when(t == 0)
    def _init():
        state_ref[0] = h0_ref[...].astype(jnp.float32)
        state_ref[1] = c0_ref[...].astype(jnp.float32)
        state_ref[2] = n0_ref[...].astype(jnp.float32)
        state_ref[3] = m0_ref[...].astype(jnp.float32)

    h = state_ref[0]                                     # [bb, D]
    c = state_ref[1]
    n = state_ref[2]
    m = state_ref[3]

    d = h.shape[-1]
    r = r_ref[...].reshape(d, 4 * d)                     # VMEM-resident
    rec = jax.lax.dot_general(h, r, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    rec = rec.reshape(h.shape[0], 4, d)
    pre = wx_ref[:, 0].astype(jnp.float32) + rec + b_ref[...][None]

    z = jnp.tanh(pre[:, 0])
    i_t = pre[:, 1]
    f_t = jax.nn.log_sigmoid(pre[:, 2])
    o = jax.nn.sigmoid(pre[:, 3])
    m_new = jnp.maximum(f_t + m, i_t)
    i_eff = jnp.exp(i_t - m_new)
    f_eff = jnp.exp(f_t + m - m_new)
    c = f_eff * c + i_eff * z
    n = f_eff * n + i_eff
    h = o * c / jnp.maximum(n, 1e-6)

    state_ref[0], state_ref[1], state_ref[2], state_ref[3] = h, c, n, m_new
    y_ref[:, 0] = h.astype(y_ref.dtype)

    @pl.when(t == nt - 1)
    def _emit():
        hout_ref[...] = h.astype(hout_ref.dtype)
        cout_ref[...] = c.astype(cout_ref.dtype)
        nout_ref[...] = n.astype(nout_ref.dtype)
        mout_ref[...] = m_new.astype(mout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def slstm_scan(
    wx: jax.Array,          # [B, T, 4, D] input-projected gate pre-activations
    r_gates: jax.Array,     # [D, 4, D] recurrent weights (pinned in VMEM)
    b_gates: jax.Array,     # [4, D]
    h0: jax.Array, c0: jax.Array, n0: jax.Array, m0: jax.Array,  # [B, D]
    block_b: int = 8,
    interpret: bool = False,
):
    """Returns (y [B,T,D], (h,c,n,m) [B,D] final state)."""
    b, t, four, d = wx.shape
    assert four == 4
    block_b = min(block_b, b)
    pad = (-b) % block_b
    if pad:
        wx = jnp.pad(wx, ((0, pad), (0, 0), (0, 0), (0, 0)))
        h0, c0, n0, m0 = (jnp.pad(a, ((0, pad), (0, 0))) for a in (h0, c0, n0, m0))
    bp = wx.shape[0]
    grid = (bp // block_b, t)

    state_spec = pl.BlockSpec((block_b, d), lambda i, tt: (i, 0))
    outs = pl.pallas_call(
        _slstm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, 1, 4, d), lambda i, tt: (i, tt, 0, 0)),
            pl.BlockSpec((d, 4, d), lambda i, tt: (0, 0, 0)),
            pl.BlockSpec((4, d), lambda i, tt: (0, 0)),
            state_spec, state_spec, state_spec, state_spec,
        ],
        out_specs=(
            pl.BlockSpec((block_b, 1, d), lambda i, tt: (i, tt, 0)),
            state_spec, state_spec, state_spec, state_spec,
        ),
        out_shape=(
            jax.ShapeDtypeStruct((bp, t, d), wx.dtype),
            jax.ShapeDtypeStruct((bp, d), jnp.float32),
            jax.ShapeDtypeStruct((bp, d), jnp.float32),
            jax.ShapeDtypeStruct((bp, d), jnp.float32),
            jax.ShapeDtypeStruct((bp, d), jnp.float32),
        ),
        scratch_shapes=[pltpu.VMEM((4, block_b, d), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(wx, r_gates, b_gates, h0, c0, n0, m0)
    y, h, c, n, m = outs
    return y[:b], (h[:b], c[:b], n[:b], m[:b])

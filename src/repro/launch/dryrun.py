import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device
# count at first init). Everything else follows.
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract memory / cost / collective analyses.

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k --mesh single --out experiments/dryrun

Succeeding here proves the distribution config is coherent: the sharded
program partitions, the collectives XLA inserts are supported, and the
per-device memory fits. Results are cached as JSON per cell (reruns skip).
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import SHAPES, get_config, input_specs, shape_applicable
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models import build
from repro.optim import adamw
from repro.sharding import batch_specs, cache_specs, param_specs, state_specs, to_named
from repro.train import (
    init_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def lower_cell(arch_name: str, shape_name: str, multi_pod: bool,
               overrides: dict | None = None):
    """Lower + compile one cell; returns (compiled, lowered, meta)."""
    cfg = get_config(arch_name)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None, None, {"skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build(cfg)
    opt_cfg = adamw.AdamWConfig(state_dtype=cfg.opt_state_dtype)
    key = jax.random.PRNGKey(0)

    specs_in = input_specs(cfg, shape)
    with mesh:
        if shape.kind == "train":
            state_abs = _abstract(
                lambda k: init_state(model, k, opt_cfg), key)
            st_specs = state_specs(cfg, state_abs, mesh)
            b_specs = batch_specs(cfg, specs_in, mesh)
            step = make_train_step(model, opt_cfg)
            jitted = jax.jit(
                step,
                in_shardings=(to_named(mesh, st_specs), to_named(mesh, b_specs)),
                out_shardings=(to_named(mesh, st_specs), None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_abs, specs_in)
            params_abs = state_abs["params"]
        elif shape.kind == "prefill":
            params_abs = _abstract(model.init, key)
            p_specs = param_specs(cfg, params_abs, mesh)
            b_specs = batch_specs(cfg, specs_in, mesh)
            prefill = make_prefill_step(model, max_len=shape.seq_len)
            caches_abs = _abstract(
                lambda: model.init_caches(shape.global_batch, shape.seq_len))
            c_specs = cache_specs(cfg, caches_abs, mesh)
            jitted = jax.jit(
                prefill,
                in_shardings=(to_named(mesh, p_specs), to_named(mesh, b_specs)),
                out_shardings=(to_named(mesh, c_specs), None),
            )
            lowered = jitted.lower(params_abs, specs_in)
        else:  # decode
            params_abs = _abstract(model.init, key)
            p_specs = param_specs(cfg, params_abs, mesh)
            caches_abs = _abstract(
                lambda: model.init_caches(shape.global_batch, shape.seq_len))
            c_specs = cache_specs(cfg, caches_abs, mesh)
            b_specs = batch_specs(cfg, specs_in, mesh)
            decode = make_decode_step(model)
            jitted = jax.jit(
                decode,
                in_shardings=(to_named(mesh, p_specs), to_named(mesh, c_specs),
                              to_named(mesh, b_specs)),
                out_shardings=(to_named(mesh, c_specs), None),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_abs, caches_abs, specs_in)

        t0 = time.monotonic()
        compiled = lowered.compile()
        compile_s = time.monotonic() - t0

    params_bytes = sum(
        l.size * l.dtype.itemsize for l in jax.tree.leaves(params_abs))
    if shape.kind == "train":
        # p + m + v (+ grads transiently)
        opt_itemsize = 2 if cfg.opt_state_dtype == "bfloat16" else 4
        opt_bytes = sum(l.size * opt_itemsize for l in jax.tree.leaves(params_abs))
        state_bytes = params_bytes + 2 * opt_bytes
        cache_bytes = 0.0
    else:
        caches_abs_local = _abstract(
            lambda: model.init_caches(shape.global_batch, shape.seq_len))
        cache_bytes = sum(
            l.size * l.dtype.itemsize for l in jax.tree.leaves(caches_abs_local))
        state_bytes = params_bytes

    meta = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": 512 if multi_pod else 256,
        "compile_seconds": compile_s,
        "model_flops": rl.model_flops(cfg, params_abs, shape),
        "analytic_flops": rl.analytic_flops(cfg, shape),
        "analytic_bytes": rl.analytic_bytes(cfg, shape, float(params_bytes),
                                            float(cache_bytes)),
        "params_bytes": float(params_bytes),
        "state_bytes": float(state_bytes),
        "cache_bytes": float(cache_bytes),
    }
    return compiled, lowered, meta


def analyze(compiled, lowered, meta: dict) -> dict:
    n_chips = meta["n_chips"]
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    # cost_analysis is per-partition under SPMD
    flops_pp = float(cost.get("flops", 0.0))
    bytes_pp = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = rl.collective_bytes(hlo)

    # XLA cost_analysis counts scan bodies once (verified empirically), so
    # the compiled numbers undercount the layer stack: take the max of the
    # HLO-derived and analytic models per term (both recorded).
    device = rl.DEFAULT_DEVICE
    terms = rl.RooflineTerms(
        flops_global=max(flops_pp * n_chips, meta["analytic_flops"]),
        bytes_global=max(bytes_pp * n_chips, meta["analytic_bytes"]),
        collective_bytes_per_chip=coll.total_bytes,
        n_chips=n_chips,
        model_flops=meta["model_flops"],
        device=device,
    )
    out = {
        **meta,
        "device": device.to_dict(),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "cost": {"flops_per_partition": flops_pp,
                 "bytes_per_partition": bytes_pp},
        "collectives": {"bytes_by_op": coll.bytes_by_op,
                        "count_by_op": coll.count_by_op},
        "roofline": terms.to_dict(),
    }
    return out


def run_cell(arch: str, shape: str, mesh: str, out_dir: str,
             force: bool = False, overrides: dict | None = None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape}__{mesh}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    try:
        compiled, lowered, meta = lower_cell(arch, shape, mesh == "multi",
                                             overrides=overrides)
        if compiled is None:
            result = {"arch": arch, "shape": shape, "mesh": mesh, **meta}
        else:
            result = analyze(compiled, lowered, meta)
            result["status"] = "ok"
    except Exception as e:  # a failure here is a bug in the system
        result = {"arch": arch, "shape": shape, "mesh": mesh,
                  "status": "error", "error": repr(e),
                  "traceback": traceback.format_exc()}
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    res = run_cell(args.arch, args.shape, args.mesh, args.out, args.force)
    status = res.get("status", "skipped" if "skipped" in res else "?")
    print(json.dumps(res.get("roofline", res), indent=1))
    if status == "error":
        print(res.get("traceback", ""), file=sys.stderr)
        return 1
    if "memory" in res:
        per_dev = sum(v for v in res["memory"].values() if v)
        print(f"[{args.arch} x {args.shape} x {args.mesh}] compiled OK; "
              f"~{per_dev/2**30:.2f} GiB/device accounted; "
              f"bottleneck={res['roofline']['bottleneck']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

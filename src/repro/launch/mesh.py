"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's forced 512-device
host platform to initialize first.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh.

    Axes: "data" = FSDP + DP within a pod; "model" = tensor/expert parallel;
    "pod" = pure DP across pods (slow inter-pod links: ZeRO-1 + optional int8
    compressed gradient all-reduce live on this axis).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(n_devices: int | None = None) -> Mesh:
    """Tiny mesh over whatever devices exist (tests / CPU)."""
    n = n_devices or len(jax.devices())
    model = 1
    for cand in (4, 2, 1):
        if n % cand == 0:
            model = cand
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


def elastic_mesh_shape(n_devices: int, prefer_model: int = 16) -> tuple:
    """Elastic re-mesh planning: pick (data, model) for a changed device count
    (node failure / scale-up). Keeps the model axis as close to `prefer_model`
    as divisibility allows, shrinking data-parallel width first — params stay
    shardable, only the batch layout changes."""
    for model in range(min(prefer_model, n_devices), 0, -1):
        if n_devices % model == 0:
            return (n_devices // model, model)
    return (n_devices, 1)

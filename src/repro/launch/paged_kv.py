"""Bank-aligned paged KV block table for the serve engine.

The dense [slots, max_len, ...] cache the decode step computes on stays as
it is — what this module adds is the RESIDENCY model over it: the KV state
of an in-flight request is held in the CiM array as fixed-size blocks of
rows, one block per `block_tokens` tokens, each block pinned to one bank
(bank = block_id % banks, the planner's round-robin placement). Blocks are
claimed from the shared `ResidentSet` as NON-evictable reservations — a
request's KV must never be silently dropped mid-generation, so pressure
surfaces as a failed allocation (the engine then defers admission) instead
of an eviction.

Accounting-first by design: `alloc`/`extend`/`free` drive the ResidentSet
row budget and the utilization/failed-alloc counters that `serve.py`
reports, mirroring vLLM-style block tables at the row-budget layer rather
than re-laying-out the dense cache arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.cim.array import ArraySpec, DEFAULT_SPEC, ResidentSet


@dataclasses.dataclass
class PagedStats:
    n_blocks: int
    block_tokens: int
    blocks_in_use: int
    peak_blocks: int
    failed_allocs: int

    @property
    def utilization(self) -> float:
        return self.blocks_in_use / max(1, self.n_blocks)


class PagedKV:
    """Fixed-pool block table: `n_blocks` blocks of `block_tokens` tokens.

    Each block reserves `kv_bits` rows (the bit-planes of its token words)
    in bank `block_id % spec.banks` of the shared ResidentSet.
    """

    def __init__(self, spec: Optional[ArraySpec] = None, n_blocks: int = 64,
                 block_tokens: int = 16, kv_bits: int = 16,
                 resident_set: Optional[ResidentSet] = None):
        self.spec = spec or DEFAULT_SPEC
        self.n_blocks = int(n_blocks)
        self.block_tokens = int(block_tokens)
        self.kv_bits = int(kv_bits)
        self.rs = resident_set
        self._free: List[int] = list(range(self.n_blocks))
        # request id -> ordered block ids; lengths in tokens
        self.tables: Dict[int, List[int]] = {}
        self.lengths: Dict[int, int] = {}
        self.peak_blocks = 0
        self.failed_allocs = 0

    @classmethod
    def for_model(cls, cfg, spec: Optional[ArraySpec] = None,
                  slots: int = 4, max_len: int = 64,
                  kv_bits: int = 16,
                  resident_set: Optional[ResidentSet] = None) -> "PagedKV":
        """Size the pool for `slots` concurrent requests of `max_len`
        tokens: one token's KV is 2 * kv_dim * n_layers words, and a block
        holds as many tokens as fit one tile of the array."""
        spec = spec or DEFAULT_SPEC
        words_per_token = max(1, 2 * cfg.kv_dim * cfg.n_layers)
        block_tokens = max(1, spec.tile_words // words_per_token)
        per_req = -(-max_len // block_tokens)
        return cls(spec=spec, n_blocks=slots * per_req,
                   block_tokens=block_tokens, kv_bits=kv_bits,
                   resident_set=resident_set)

    # -- block lifecycle -----------------------------------------------------

    def bank_of_block(self, bid: int) -> int:
        """Round-robin over the LIVE banks only: a degraded spec skips its
        dead banks, so new reservations never land on failed hardware."""
        live = self.spec.enabled_banks
        return live[bid % len(live)]

    def _claim(self, rid: int) -> bool:
        if not self._free:
            return False
        bid = self._free.pop(0)
        if self.rs is not None:
            try:
                self.rs.reserve(("kv", bid), self.kv_bits,
                                bank=self.bank_of_block(bid),
                                words32=self.block_tokens * self.kv_bits / 32.0)
            except Exception:
                self._free.insert(0, bid)
                return False
        self.tables[rid].append(bid)
        return True

    def alloc(self, rid: int, n_tokens: int) -> bool:
        """Claim blocks for a new request's first `n_tokens` (the prefill).
        All-or-nothing: a partial claim is rolled back."""
        if rid in self.tables:
            raise ValueError(f"request {rid} already has a block table")
        need = max(1, -(-n_tokens // self.block_tokens))
        self.tables[rid] = []
        self.lengths[rid] = 0
        for _ in range(need):
            if not self._claim(rid):
                self.free(rid)
                self.failed_allocs += 1
                return False
        self.lengths[rid] = n_tokens
        self.peak_blocks = max(self.peak_blocks, self.blocks_in_use)
        return True

    def extend(self, rid: int, n_tokens: int = 1) -> bool:
        """Grow a request by `n_tokens` decoded tokens, claiming a new
        block whenever the last one fills."""
        if rid not in self.tables:
            raise ValueError(f"request {rid} has no block table")
        new_len = self.lengths[rid] + n_tokens
        need = -(-new_len // self.block_tokens) - len(self.tables[rid])
        for _ in range(max(0, need)):
            if not self._claim(rid):
                self.failed_allocs += 1
                return False
        self.lengths[rid] = new_len
        self.peak_blocks = max(self.peak_blocks, self.blocks_in_use)
        return True

    def free(self, rid: int) -> None:
        """Return a retired request's blocks to the pool."""
        for bid in self.tables.pop(rid, []):
            if self.rs is not None:
                self.rs.release(("kv", bid))
            self._free.append(bid)
        self.lengths.pop(rid, None)
        self._free.sort()

    # -- failover ------------------------------------------------------------

    def migrate(self, new_spec: ArraySpec,
                new_rs: Optional[ResidentSet] = None) -> int:
        """Move every in-use block off the banks `new_spec` disables.

        All-or-nothing: each block is re-reserved in `new_rs` (or the
        current set) under the live-bank mapping of `new_spec` FIRST; only
        when every block lands does the table release the old reservations
        and adopt the new spec/set. A failed re-reserve rolls back every
        reservation made so far and leaves the table untouched — the
        caller falls back to shedding or host demotion. Returns the number
        of blocks migrated."""
        target = new_rs if new_rs is not None else self.rs
        in_use = sorted(bid for blocks in self.tables.values()
                        for bid in blocks)
        live = new_spec.enabled_banks
        placed: List[int] = []
        if target is not None:
            try:
                for bid in in_use:
                    target.reserve(("kv_mig", bid), self.kv_bits,
                                   bank=live[bid % len(live)],
                                   words32=(self.block_tokens
                                            * self.kv_bits / 32.0))
                    placed.append(bid)
            except Exception:
                for bid in placed:
                    target.release(("kv_mig", bid))
                raise
            # commit: drop the old claims, rename the staged ones
            for bid in in_use:
                if self.rs is not None:
                    self.rs.release(("kv", bid))
            for bid in in_use:
                entry = target._entries.pop(("kv_mig", bid))
                entry.key = ("kv", bid)
                target._entries[("kv", bid)] = entry
        self.spec = new_spec
        self.rs = target
        return len(in_use)

    # -- reporting -----------------------------------------------------------

    @property
    def blocks_in_use(self) -> int:
        return self.n_blocks - len(self._free)

    def stats(self) -> PagedStats:
        return PagedStats(n_blocks=self.n_blocks,
                          block_tokens=self.block_tokens,
                          blocks_in_use=self.blocks_in_use,
                          peak_blocks=self.peak_blocks,
                          failed_allocs=self.failed_allocs)

"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds, from a shared
`repro.cim.cost.DeviceSpec` (default: the TPU v5e constants below —
another target is one CSV row away via `DeviceSpec.from_csv`):

  compute    = HLO_FLOPs_global   / (chips * peak_flops)   [197e12 bf16]
  memory     = HLO_bytes_global   / (chips * hbm_bw)       [819e9  B/s]
  collective = collective_bytes   / ici_bw                 [50e9   B/s]

HLO_FLOPs / bytes come from compiled.cost_analysis() (per-partition module
under SPMD -> multiplied by n_devices for the global figure). Collective
bytes are NOT in cost_analysis: we parse the partitioned HLO text, build a
name->bytes symbol table from instruction output shapes, and sum OPERAND
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.cim.cost import DEFAULT_DEVICE, DeviceSpec

#: module-level aliases kept for callers that predate DeviceSpec
PEAK_FLOPS = DEFAULT_DEVICE.peak_flops   # bf16 per chip
HBM_BW = DEFAULT_DEVICE.hbm_bw           # B/s per chip
ICI_BW = DEFAULT_DEVICE.ici_bw           # B/s per chip (~1 link)

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

#: element widths in BITS — s4/u4 are sub-byte, so per-element byte widths
#: would be fractional; accumulate bits per instruction and round ONCE (the
#: same convention as the PR-4 offload estimator fix)
_DTYPE_BITS = {
    "pred": 8, "s4": 4, "u4": 4, "s8": 8, "u8": 8, "s16": 16, "u16": 16,
    "s32": 32, "u32": 32, "s64": 64, "u64": 64, "f8e4m3fn": 8, "f8e5m2": 8,
    "bf16": 16, "f16": 16, "f32": 32, "f64": 64, "c64": 64, "c128": 128,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
# instruction: [ROOT] %name = <shape-or-tuple> opcode(
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%?[\w.\-]+)\s*=\s*(\([^=]*?\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)\s+([\w\-]+)",
)


def _shape_bytes(shape_str: str) -> int:
    """Byte size of one instruction's output shape (tuples summed), rounded
    up from exact bit totals once per instruction — an s4[7] is 4 bytes,
    never a fractional 3.5 leaking into the symbol table."""
    bits = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BITS:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        bits += n * _DTYPE_BITS[dt]
    return -(-bits // 8)


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, float]
    count_by_op: Dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_op.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective in (partitioned) HLO text."""
    sizes: Dict[str, float] = {}
    by_op: Dict[str, float] = {c: 0.0 for c in COLLECTIVES}
    counts: Dict[str, int] = {c: 0 for c in COLLECTIVES}

    lines = hlo_text.splitlines()
    # pass 1: symbol table  name -> output bytes
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if m:
            name = m.group(1).lstrip("%")
            sizes[name] = _shape_bytes(m.group(2))

    # pass 2: collectives — sum operand bytes
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if not m:
            continue
        op = m.group(3)
        base = None
        for c in COLLECTIVES:
            if op == c or op.startswith(c + "-start") or op == c + "-done":
                base = c
                break
        if base is None:
            continue
        if op.endswith("-done"):
            continue  # avoid double counting start/done pairs
        # operand list: first (...) after the opcode
        rest = ln[m.end():]
        paren = rest.find("(")
        if paren < 0:
            continue
        depth, j = 0, paren
        for j in range(paren, len(rest)):
            if rest[j] == "(":
                depth += 1
            elif rest[j] == ")":
                depth -= 1
                if depth == 0:
                    break
        args = rest[paren + 1 : j]
        total = 0.0
        for tok in re.finditer(r"%?([\w.\-]+)", args):
            nm = tok.group(1)
            if nm in sizes:
                total += sizes[nm]
        by_op[base] += total
        counts[base] += 1
    return CollectiveStats(bytes_by_op=by_op, count_by_op=counts)


@dataclasses.dataclass
class RooflineTerms:
    flops_global: float
    bytes_global: float
    collective_bytes_per_chip: float
    n_chips: int
    model_flops: float
    device: Optional[DeviceSpec] = None    # DEFAULT_DEVICE when None

    @property
    def _dev(self) -> DeviceSpec:
        return self.device or DEFAULT_DEVICE

    @property
    def t_compute(self) -> float:
        return self.flops_global / (self.n_chips * self._dev.peak_flops)

    @property
    def t_memory(self) -> float:
        return self.bytes_global / (self.n_chips * self._dev.hbm_bw)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_chip / self._dev.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / max(self.flops_global, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """useful-FLOPs time / achievable step time (max of the 3 terms):
        the headline 'fraction of roofline' figure."""
        t_useful = self.model_flops / (self.n_chips * self._dev.peak_flops)
        t_step = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / max(t_step, 1e-30)

    def to_dict(self) -> Dict[str, float]:
        return {
            "device": self._dev.name,
            "flops_global": self.flops_global,
            "bytes_global": self.bytes_global,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "n_chips": self.n_chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def active_param_count(cfg, params_abstract) -> float:
    """N_active for MODEL_FLOPS: excludes the embedding lookup table; routed
    expert tensors scaled by top_k / n_experts."""
    import jax

    total = 0.0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_abstract)[0]:
        keys = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        n = 1
        for d in leaf.shape:
            n *= d
        if keys.endswith("embed/table"):
            continue
        if cfg.moe is not None and ("/w_in" in keys or "/w_gate" in keys or "/w_out" in keys) \
                and len(leaf.shape) >= 3 and ("groups" in keys or "rem" in keys or "first_dense" in keys):
            # stacked moe expert weights: [G?, E, ., .]
            if leaf.shape[-3] == cfg.moe.n_experts:
                n = n * cfg.moe.top_k / cfg.moe.n_experts
        total += n
    return total


def model_flops(cfg, params_abstract, shape) -> float:
    """6*N_active*tokens (train) / 2*N_active*tokens (inference)."""
    n_active = active_param_count(cfg, params_abstract)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


# ---------------------------------------------------------------------------
# Analytic FLOPs / bytes models
# ---------------------------------------------------------------------------
# XLA's cost_analysis counts a while/scan BODY ONCE (empirically verified:
# a scan of 8 matmuls reports the flops of 1), so compiled-artifact numbers
# undercount the layer-stack by ~n_groups. The roofline therefore uses
# max(HLO, analytic) per term, with both recorded. The analytic model mirrors
# the actual lowered compute paths (blockwise attention, scatter-MoE with
# capacity, absorbed MLA, chunked recurrences, remat factor 4/3 on fwd).


def analytic_flops(cfg, shape) -> float:
    """Forward FLOPs from the layer composition; train = 4x fwd (remat)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        t = b                       # one token per sequence
        ctx = s                     # attended context
        s_sq = 0.0                  # no quadratic term
    else:
        t = b * s
        ctx = s
        s_sq = 0.5 * b * s * s      # causal half of the S^2 term

    d, h, kv, hd, f = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_ff
    fl = 0.0

    def attn_flops(window=0):
        x = 2.0 * t * d * (h + 2 * kv) * hd          # qkv proj
        x += 2.0 * t * h * hd * d                    # out proj
        if shape.kind == "decode":
            span = min(window, ctx) if window else ctx
            x += 2.0 * 2.0 * t * span * h * hd       # qk + av vs cache
        else:
            span_sq = (min(window, s) * s * b) if window else s_sq
            x += 2.0 * 2.0 * span_sq * h * hd
        return x

    def mla_flops():
        m = cfg.mla
        qd = m.qk_nope_dim + m.qk_rope_dim
        r = m.kv_lora_rank
        x = 2.0 * t * d * h * qd + 2.0 * t * d * (r + m.qk_rope_dim)
        if shape.kind == "decode":
            # ABSORBED form: score/combine via the latent (per-token q
            # absorption, no per-position decompression of the whole cache)
            x += 2.0 * t * h * m.qk_nope_dim * r
            x += 2.0 * t * ctx * h * (r + m.qk_rope_dim) + 2.0 * t * ctx * h * r
            x += 2.0 * t * h * r * m.v_head_dim
        else:
            # EXPLICIT form (prefill/train): decompress K/V once, attend in
            # (nope+rope)-dim heads — 5.7x fewer S^2 FLOPs than absorbed
            x += 2.0 * t * r * h * (m.qk_nope_dim + m.v_head_dim)
            x += 2.0 * s_sq * h * qd + 2.0 * s_sq * h * m.v_head_dim
        x += 2.0 * t * h * m.v_head_dim * d
        return x

    def mlp_flops(width):
        mults = 3 if cfg.gating in ("swiglu", "geglu") else 2
        return 2.0 * t * d * width * mults

    def moe_flops():
        m = cfg.moe
        x = 2.0 * t * d * m.n_experts                # router
        routed_tokens = m.capacity_factor * m.top_k * t
        x += 2.0 * routed_tokens * d * m.d_ff_expert * 3
        if m.n_shared:
            x += 2.0 * t * d * (m.d_ff_expert * m.n_shared) * 3
        return x

    def rec_flops():
        dr = d
        x = 2.0 * 2.0 * t * d * dr + 2.0 * 2.0 * t * dr * dr
        x += t * dr * 14.0                           # conv4 + gates + recurrence
        x += 2.0 * t * dr * d
        return x

    def mlstm_flops():
        di = int(2.0 * d)
        dh_i = di // h
        x = 2.0 * t * d * di + 2.0 * t * di * 3 * di + 2.0 * t * di * 3 * h
        x += 6.0 * t * di * dh_i                     # C update + read per token
        x += 2.0 * t * di * d
        return x

    def slstm_flops():
        df = int(4.0 / 3.0 * d)
        return 2.0 * t * d * 4 * d * 2 + 2.0 * t * d * df * 3

    kinds = list(cfg.pattern_layers())
    for li, kind in enumerate(kinds):
        if kind == "attn":
            fl += mla_flops() if cfg.mla else attn_flops()
            if cfg.moe is not None and li >= cfg.first_dense_layers:
                fl += moe_flops()
            else:
                fl += mlp_flops(cfg.d_ff_first_dense or f)
        elif kind == "local":
            fl += attn_flops(window=cfg.local_window)
            fl += moe_flops() if (cfg.moe is not None) else mlp_flops(f)
        elif kind == "rec":
            fl += rec_flops() + mlp_flops(f)
        elif kind == "mlstm":
            fl += mlstm_flops()
        elif kind == "slstm":
            fl += slstm_flops()
    fl += 2.0 * t * d * cfg.vocab_size               # lm head
    if shape.kind == "train":
        fl *= 4.0                                    # fwd + bwd(2x) + remat fwd
    return fl


def analytic_bytes(cfg, shape, params_bytes: float, cache_bytes: float) -> float:
    """First-order HBM traffic (global, bytes) per step.

    train:  params+grads+opt read/write (8x P: p r/w, m r/w, v r/w, grad r/w)
            + activation save/reload at chunk boundaries
    prefill: params once + activations + cache write
    decode:  params once + full cache read + write of the new slot
    """
    b, s = shape.global_batch, shape.seq_len
    act_elt = 2.0  # bf16
    l, d = cfg.n_layers, cfg.d_model
    if shape.kind == "train":
        acts = 10.0 * b * s * d * l * act_elt
        return 8.0 * params_bytes + acts
    if shape.kind == "prefill":
        acts = 6.0 * b * s * d * l * act_elt
        return params_bytes + acts + cache_bytes
    return params_bytes + cache_bytes + 4.0 * b * d * l * act_elt

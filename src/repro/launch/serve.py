"""Batched serving driver: prefill + decode loop with greedy or ADRA
(quantized in-memory comparison) sampling.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --preset reduced \
      --batch 4 --prompt-len 32 --gen 16 --sampler adra

`--cim-lower` routes every dense decode MLP through the jaxpr->CiM lowering
compiler (repro.cim.lower): the MLP's quantized integer contractions
execute as planned CiM access schedules (float gating/rescale stays on the
host) and a ledger report after the request prints the charged accesses,
the per-op histogram and the projected ADRA savings. Charge semantics (the
report labels them): the jitted model path charges ONCE per compiled shape
at trace time, while the eager ADRA sampler charges one access per
tournament level per invocation — so the totals describe the programs
compiled-and-run for this request, not a per-token traffic recount.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.launch.train import preset_config
from repro.models import build
from repro.train import adra_sample, greedy_sample, make_decode_step, make_prefill_step


def _print_cim_report(n_requests: int) -> None:
    from repro.cim import cache_stats, ledger

    led = ledger()
    proj = led.projected()
    hist = ", ".join(f"{k}:{v}" for k, v in sorted(led.per_op.items()))
    print(f"cim-lower ledger (request {n_requests}): "
          f"{led.accesses} accesses, {led.words32:.0f} word32-ops")
    print("  (jitted MLP regions charge once per compiled shape at trace "
          "time; eager sampler levels charge per invocation)")
    print(f"  per-op: {hist}")
    print(f"  projected: {proj['edp_decrease_pct']:.1f}% EDP decrease, "
          f"{proj['energy_saved_fj']:.0f} fJ saved vs near-memory "
          f"(current sensing @1024^2)")
    cs = cache_stats()
    print(f"  schedule cache: {cs['hits']} hits / {cs['misses']} misses / "
          f"{cs['evictions']} evictions (capacity {cs['capacity']}), "
          f"{cs['dispatches']} jitted dispatches (one per warm macro/region)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--preset", default="reduced", choices=("reduced", "100m", "full"))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--sampler", default="greedy", choices=("greedy", "adra"))
    ap.add_argument("--cim-lower", action="store_true",
                    help="serve the quantized decode MLP through the "
                         "jaxpr->CiM lowering compiler and print a "
                         "per-request ledger report")
    ap.add_argument("--cim-bits", type=int, default=8,
                    help="quantization width for --cim-lower (default 8)")
    args = ap.parse_args()

    cfg = preset_config(args.arch, args.preset)
    if args.cim_lower:
        cfg = dataclasses.replace(cfg, cim_mlp_bits=args.cim_bits)
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    max_len = args.prompt_len + args.gen

    sample = greedy_sample if args.sampler == "greedy" else adra_sample
    prefill = jax.jit(make_prefill_step(model, max_len))
    decode = jax.jit(make_decode_step(model), donate_argnums=(1,))

    if args.cim_lower:
        from repro.cim import ledger

        ledger().reset()

    B = args.batch
    prompts = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab_size)
    if cfg.embed_stub:
        emb = jax.random.normal(key, (B, args.prompt_len, cfg.d_model)) * 0.02
        caches, logits = prefill(params, {"embeds": emb})
    else:
        caches, logits = prefill(params, {"tokens": prompts})

    out_tokens = []
    tok = sample(logits)
    out_tokens.append(tok)
    t0 = time.monotonic()
    for t in range(args.prompt_len, max_len - 1):
        pos = jnp.full((B,), t, jnp.int32)
        if cfg.embed_stub:
            step_in = {"embeds": jax.random.normal(
                jax.random.fold_in(key, t), (B, 1, cfg.d_model)) * 0.02,
                "positions": pos}
        else:
            step_in = {"tokens": tok[:, None], "positions": pos}
        caches, logits = decode(params, caches, step_in)
        tok = sample(logits)
        out_tokens.append(tok)
    dt = time.monotonic() - t0
    gen = jnp.stack(out_tokens, axis=1)
    print(f"sampler={args.sampler}  generated {gen.shape} tokens "
          f"in {dt:.2f}s ({B * (len(out_tokens)-1) / max(dt, 1e-9):.1f} tok/s)")
    print("first sequence:", jax.device_get(gen[0])[:16], "...")
    if args.cim_lower:
        _print_cim_report(n_requests=1)


if __name__ == "__main__":
    main()

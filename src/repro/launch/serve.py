"""Batched serving driver: prefill + decode loop with greedy or ADRA
(quantized in-memory comparison) sampling.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --preset reduced \
      --batch 4 --prompt-len 32 --gen 16 --sampler adra
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.launch.train import preset_config
from repro.models import build
from repro.train import adra_sample, greedy_sample, make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--preset", default="reduced", choices=("reduced", "100m", "full"))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--sampler", default="greedy", choices=("greedy", "adra"))
    args = ap.parse_args()

    cfg = preset_config(args.arch, args.preset)
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    max_len = args.prompt_len + args.gen

    sample = greedy_sample if args.sampler == "greedy" else adra_sample
    prefill = jax.jit(make_prefill_step(model, max_len))
    decode = jax.jit(make_decode_step(model), donate_argnums=(1,))

    B = args.batch
    prompts = jax.random.randint(key, (B, args.prompt_len), 0, cfg.vocab_size)
    if cfg.embed_stub:
        emb = jax.random.normal(key, (B, args.prompt_len, cfg.d_model)) * 0.02
        caches, logits = prefill(params, {"embeds": emb})
    else:
        caches, logits = prefill(params, {"tokens": prompts})

    out_tokens = []
    tok = sample(logits)
    out_tokens.append(tok)
    t0 = time.monotonic()
    for t in range(args.prompt_len, max_len - 1):
        pos = jnp.full((B,), t, jnp.int32)
        if cfg.embed_stub:
            step_in = {"embeds": jax.random.normal(
                jax.random.fold_in(key, t), (B, 1, cfg.d_model)) * 0.02,
                "positions": pos}
        else:
            step_in = {"tokens": tok[:, None], "positions": pos}
        caches, logits = decode(params, caches, step_in)
        tok = sample(logits)
        out_tokens.append(tok)
    dt = time.monotonic() - t0
    gen = jnp.stack(out_tokens, axis=1)
    print(f"sampler={args.sampler}  generated {gen.shape} tokens "
          f"in {dt:.2f}s ({B * (len(out_tokens)-1) / max(dt, 1e-9):.1f} tok/s)")
    print("first sequence:", jax.device_get(gen[0])[:16], "...")


if __name__ == "__main__":
    main()

"""Continuous-batching serve engine over the CiM-lowered model.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --preset reduced \
      --slots 2 --requests 4 --prompt-len 8 --gen 8 --cim-lower --cim-resident

The engine holds `slots` concurrent sequences in one batched KV cache.
Requests enter a queue with arrival times; each loop iteration admits at
most one due request (a batch-1 prefill, inserted into its slot between
decode steps — prefill and decode interleave, vLLM-style) and then runs ONE
full-batch decode step for every in-flight sequence. Retired sequences free
their slot and their paged KV blocks immediately, so the next queued
request starts without draining the batch.

Timing discipline: every prefill and decode step is bracketed by
`jax.block_until_ready` + perf_counter, so a step's latency is the real
device time, not dispatch time. Steady-state tok/s and the p50/p99
per-token latencies EXCLUDE prefill and the first `--warmup-steps` decode
steps (compile happens there); prefill cost is reported separately per
request (`prefill_ms`).

Charge semantics with --cim-lower: the decode step runs UNJITTED (the
grouped-layer scan is unrolled, see ArchConfig.cim_unroll_groups) so every
step's lowered MLP regions charge the ledger per call — `accesses` is the
compute bill, `load_accesses` the streamed-operand row-write bill. The
jitted prefill still charges once at trace time (labeled: it lands on the
first request). Per-request attribution splits each decode step's ledger
delta evenly across the slots active in that step.

--cim-resident pins the int8 MLP weight planes in the arrays' resident
rows (repro.cim.lower resident mode): warm decode steps charge ZERO loads
for the weight side. The --cim-lower bench mode runs the SAME request
schedule twice — streamed repack, then resident — and asserts the resident
phase's total accesses/token is strictly lower at identical compute
accesses/token; --assert-warm replays the resident phase and asserts no
program-cache misses and no new pins (everything stayed warm).
"""
from __future__ import annotations

import argparse
import dataclasses
import json as json_lib
import time
from collections import deque
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.launch.paged_kv import PagedKV
from repro.launch.train import preset_config
from repro.models import build
from repro.train import (adra_sample, greedy_sample, make_decode_step,
                         make_prefill_step)


# ---------------------------------------------------------------------------
# requests
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServeRequest:
    """One queued generation job and its measured lifecycle."""

    rid: int
    prompt_len: int
    gen: int                       # tokens to produce (incl. the prefill one)
    arrival_s: float = 0.0
    slot: int = -1
    tokens: List[int] = dataclasses.field(default_factory=list)
    prefill_ms: float = 0.0
    first_token_s: float = -1.0
    done_s: float = -1.0
    accesses: float = 0.0          # ledger attribution (see module docstring)
    load_accesses: float = 0.0
    token_latencies_ms: List[float] = dataclasses.field(default_factory=list)
    shed: bool = False             # dropped by admission control, never ran
    repairs: int = 0               # retried decode steps attributed here

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.gen

    def report(self) -> Dict[str, Any]:
        return {
            "rid": self.rid,
            "arrival_s": round(self.arrival_s, 6),
            "first_token_s": round(self.first_token_s, 6),
            "done_s": round(self.done_s, 6),
            "prefill_ms": round(self.prefill_ms, 3),
            "tokens": len(self.tokens),
            # the generated ids themselves: what the chaos harness compares
            # bit-exactly against a fault-free run
            "token_ids": list(self.tokens),
            "shed": self.shed,
            "repairs": self.repairs,
            "accesses": round(self.accesses, 3),
            "load_accesses": round(self.load_accesses, 3),
            "total_accesses": round(self.accesses + self.load_accesses, 3),
        }


def _percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    ys = sorted(xs)
    i = min(len(ys) - 1, max(0, int(round(q / 100.0 * (len(ys) - 1)))))
    return ys[i]


def _ledger():
    from repro.cim import ledger
    return ledger()


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class ServeEngine:
    """Slot-based continuous batching over one batched cache pytree."""

    def __init__(self, model, params, slots: int, max_len: int,
                 sampler: str = "greedy", cim_lower: bool = False,
                 paged: Optional[PagedKV] = None, warmup_steps: int = 1,
                 seed: int = 0, spec=None, retry_budget: int = 2,
                 queue_limit: Optional[int] = None,
                 timeout_s: Optional[float] = None, scrub_every: int = 0):
        self.model, self.params, self.cfg = model, params, model.cfg
        self.slots, self.max_len = int(slots), int(max_len)
        self.sample = greedy_sample if sampler == "greedy" else adra_sample
        self.cim_lower = cim_lower
        self.paged = paged
        self.warmup_steps = int(warmup_steps)
        self.key = jax.random.PRNGKey(seed)
        # -- self-healing / admission knobs ---------------------------------
        self.spec = spec                      # CiM geometry this engine serves
        self.retry_budget = int(retry_budget)  # decode retries per request
        self.queue_limit = queue_limit        # waiting beyond this are shed
        self.timeout_s = timeout_s            # max unadmitted wait before shed
        self.scrub_every = int(scrub_every)   # decode steps between ECC scrubs
        self.repairs = 0                      # uncorrectable -> re-pin+retry
        self.failovers = 0                    # bank-kill remaps executed
        self.shed_count = 0
        self.scrub_report = {"scanned": 0, "dropped": 0,
                             "corrected": 0, "uncorrected": 0}
        self.prefill_fn = jax.jit(make_prefill_step(model, max_len))
        dec = make_decode_step(model)
        # unjitted with --cim-lower: lowered regions then execute (and
        # charge) per call, which is what residency accelerates
        self.decode_fn = dec if cim_lower else \
            jax.jit(dec, donate_argnums=(1,))
        self._insert = jax.jit(self._insert_slot)

    # -- fault handling ------------------------------------------------------

    def _check_faults(self, step: int) -> None:
        """Advance the installed FaultModel to `step` and fail over when it
        has killed a bank this engine still serves from."""
        from repro.cim import faults as faults_mod

        fm = faults_mod.active()
        if fm is None:
            return
        fm.on_step(step)
        if self.spec is None or not self.cim_lower:
            return
        dead = [b for b in fm.dead_banks
                if b not in self.spec.disabled_banks
                and b < self.spec.banks]
        if dead:
            self._failover(dead)

    def _failover(self, dead_banks: List[int]) -> None:
        """Remap the serving process off `dead_banks`: degraded spec, paged
        KV migrated (all-or-nothing), stale weight pins dropped so they
        re-pin under the new geometry, and the process-wide spec override
        installed — every spec=None layer re-routes from the next call on.
        Regions whose degraded-geometry cost no longer beats the host are
        demoted by the offload policy when the fresh lowering re-plans."""
        from repro.cim import array as array_mod

        new_spec = self.spec
        for b in dead_banks:
            new_spec = new_spec.disable_bank(b)
        new_rs = array_mod.resident_set(new_spec)
        if self.paged is not None:
            self.paged.migrate(new_spec, new_rs)
        old_rs = array_mod._RESIDENT_SETS.get(self.spec)
        if old_rs is not None and old_rs is not new_rs:
            old_rs.clear()              # stale pins: re-pin under new_spec
        array_mod.set_current_spec(new_spec)
        self.spec = new_spec
        self.failovers += 1

    def _scrub(self) -> None:
        from repro.cim import array as array_mod

        rs = array_mod._RESIDENT_SETS.get(self.spec)
        if rs is None or not rs.ecc:
            return
        r = rs.scrub()
        for k in self.scrub_report:
            self.scrub_report[k] += r.get(k, 0)

    @staticmethod
    def _insert_slot(batched, single, slot):
        """Land a batch-1 cache pytree in slot `slot` of the batched one.
        The batch axis of each leaf is the first axis where the two shapes
        disagree (leading group axes make it leaf-dependent)."""
        def one(b, s):
            ax = 0
            for i, (db, ds) in enumerate(zip(b.shape, s.shape)):
                if db != ds:
                    ax = i
                    break
            return jax.lax.dynamic_update_slice_in_dim(
                b, s.astype(b.dtype), slot, axis=ax)
        return jax.tree.map(one, batched, single)

    # -- inputs --------------------------------------------------------------

    def _prompt_inputs(self, req: ServeRequest) -> Dict[str, jax.Array]:
        cfg = self.cfg
        k = jax.random.fold_in(self.key, req.rid)
        if cfg.embed_stub:
            return {"embeds": jax.random.normal(
                k, (1, req.prompt_len, cfg.d_model)) * 0.02}
        return {"tokens": jax.random.randint(
            k, (1, req.prompt_len), 0, cfg.vocab_size)}

    def _step_inputs(self, tok, positions, step: int) -> Dict[str, jax.Array]:
        cfg = self.cfg
        pos = jnp.asarray(positions, jnp.int32)
        if cfg.embed_stub:
            return {"embeds": jax.random.normal(
                jax.random.fold_in(self.key, 10_000 + step),
                (self.slots, 1, cfg.d_model)) * 0.02,
                "positions": pos}
        return {"tokens": tok[:, None], "positions": pos}

    # -- run -----------------------------------------------------------------

    def run(self, requests: List[ServeRequest]) -> Dict[str, Any]:
        led = _ledger()
        pending = deque(sorted(requests, key=lambda r: (r.arrival_s, r.rid)))
        active: Dict[int, ServeRequest] = {}
        free = list(range(self.slots))
        caches = self.model.init_caches(self.slots, self.max_len)
        tok = jnp.zeros((self.slots,), jnp.int32)
        positions = [0] * self.slots
        decode_steps = 0
        steady_tokens = 0
        steady_time = 0.0
        token_lat_ms: List[float] = []
        t0 = time.perf_counter()

        def now() -> float:
            return time.perf_counter() - t0

        def _shed(req: ServeRequest) -> None:
            req.shed = True
            req.done_s = now()
            self.shed_count += 1

        while pending or active:
            self._check_faults(decode_steps)

            # admission control: shed the head when it has waited past the
            # per-request timeout, and the tail when more requests are due
            # than the bounded queue admits — a degraded array sheds load
            # instead of stretching every in-flight request's latency
            if self.timeout_s is not None and not free:
                # only a request actually stuck waiting can time out — a
                # due head with a free slot is admitted this iteration
                while pending and pending[0].arrival_s <= now() \
                        and now() - pending[0].arrival_s > self.timeout_s:
                    _shed(pending.popleft())
            if self.queue_limit is not None:
                # the bounded queue holds what cannot go straight into a
                # slot: shed the tail past `free slots + queue_limit`
                while sum(1 for r in pending
                          if r.arrival_s <= now()) - len(free) \
                        > self.queue_limit:
                    _shed(pending.pop())

            # admit at most one due request per iteration: prefill
            # interleaves with decode instead of draining the batch
            if pending and free and pending[0].arrival_s <= now():
                req = pending[0]
                if self.paged is not None and \
                        not self.paged.alloc(req.rid, req.prompt_len):
                    if not active:
                        raise RuntimeError(
                            f"request {req.rid}: prompt of {req.prompt_len} "
                            f"tokens cannot fit the KV block pool even with "
                            f"every slot idle")
                    # pool pressure: wait for a retirement to free blocks
                else:
                    pending.popleft()
                    slot = free.pop(0)
                    req.slot = slot
                    ta = time.perf_counter()
                    l0 = (led.accesses, led.load_accesses)
                    c1, logits1 = self.prefill_fn(self.params,
                                                  self._prompt_inputs(req))
                    jax.block_until_ready(logits1)
                    req.prefill_ms = (time.perf_counter() - ta) * 1e3
                    req.accesses += led.accesses - l0[0]
                    req.load_accesses += led.load_accesses - l0[1]
                    caches = self._insert(caches, c1, slot)
                    first = self.sample(logits1)[0]
                    tok = tok.at[slot].set(first)
                    req.tokens.append(int(first))
                    req.first_token_s = now()
                    positions[slot] = req.prompt_len
                    active[slot] = req
                    if req.done:                       # gen == 1
                        self._retire(req, free, active, now())
                    continue                           # admit before decode

            if not active:
                if pending:
                    time.sleep(max(0.0, pending[0].arrival_s - now()))
                continue

            # one full-batch decode step — retried within the per-request
            # budget when an ECC verify finds uncorrectable damage (the
            # failing entry is already invalidated, so the retry re-pins
            # from the host weights: detect -> repair -> redo)
            from repro.cim.faults import UncorrectableFaultError

            step_in = self._step_inputs(tok, positions, decode_steps)
            ts = time.perf_counter()
            l0 = (led.accesses, led.load_accesses)
            attempts = 0
            while True:
                try:
                    caches, logits = self.decode_fn(self.params, caches,
                                                    step_in)
                    break
                except UncorrectableFaultError:
                    attempts += 1
                    self.repairs += 1
                    for req in active.values():
                        req.repairs += 1
                    if attempts > self.retry_budget:
                        raise
            jax.block_until_ready((caches, logits))
            dt = time.perf_counter() - ts
            d_acc = led.accesses - l0[0]
            d_load = led.load_accesses - l0[1]
            tok = self.sample(logits)
            n_active = len(active)
            decode_steps += 1
            steady = decode_steps > self.warmup_steps
            if steady:
                steady_tokens += n_active
                steady_time += dt
            for slot, req in list(active.items()):
                req.tokens.append(int(tok[slot]))
                req.accesses += d_acc / n_active
                req.load_accesses += d_load / n_active
                req.token_latencies_ms.append(dt * 1e3)
                if steady:
                    token_lat_ms.append(dt * 1e3)
                positions[slot] += 1
                if self.paged is not None:
                    self.paged.extend(req.rid)
                if req.done:
                    self._retire(req, free, active, now())
            if self.scrub_every and decode_steps % self.scrub_every == 0:
                self._scrub()

        total_tokens = sum(len(r.tokens) for r in requests)
        # first token of each SERVED request comes from its prefill (shed
        # requests produced nothing, so an all-shed run reports 0, not -n)
        decode_tokens = sum(max(0, len(r.tokens) - 1) for r in requests)
        report: Dict[str, Any] = {
            "slots": self.slots,
            "requests": len(requests),
            "total_tokens": total_tokens,
            "decode_tokens": decode_tokens,
            "decode_steps": decode_steps,
            "warmup_steps": self.warmup_steps,
            "wall_s": round(now(), 4),
            "tok_s_steady": round(steady_tokens / steady_time, 2)
            if steady_time > 0 else 0.0,
            "steady_tokens": steady_tokens,
            "p50_ms": round(_percentile(token_lat_ms, 50), 3),
            "p99_ms": round(_percentile(token_lat_ms, 99), 3),
            "prefill_ms_mean": round(
                sum(r.prefill_ms for r in requests) / max(1, len(requests)),
                3),
            "shed": self.shed_count,
            "completed": sum(1 for r in requests
                             if not r.shed and r.done),
            "per_request": [r.report() for r in requests],
        }
        from repro.cim import faults as faults_mod

        fm = faults_mod.active()
        if fm is not None or self.repairs or self.failovers:
            fstats = fm.stats() if fm is not None else {}
            report["faults"] = {
                **fstats,
                "repairs": self.repairs,
                "failovers": self.failovers,
                "shed": self.shed_count,
                "scrub": dict(self.scrub_report),
            }
            from repro.cim.array import resident_stats
            rst = resident_stats()
            for k in ("ecc_verifies", "ecc_corrected", "ecc_uncorrected"):
                report["faults"][k] = rst.get(k, 0)
        if self.paged is not None:
            st = self.paged.stats()
            report["kv"] = {
                "n_blocks": st.n_blocks, "block_tokens": st.block_tokens,
                "peak_blocks": st.peak_blocks,
                "failed_allocs": st.failed_allocs,
                "utilization_peak": round(st.peak_blocks
                                          / max(1, st.n_blocks), 4),
            }
        if self.cim_lower:
            led = _ledger()
            per_tok = max(1, decode_tokens)
            report["ledger"] = {
                "accesses": led.accesses,
                "load_accesses": led.load_accesses,
                "total_accesses": led.total_accesses,
                "resident_reuses": led.resident_reuses,
            }
            report["accesses_per_token"] = round(led.accesses / per_tok, 4)
            report["load_accesses_per_token"] = round(
                led.load_accesses / per_tok, 4)
            report["total_accesses_per_token"] = round(
                led.total_accesses / per_tok, 4)
            # cost-model offload decisions cut while lowering this run
            # (deliberately NOT perf-gated keys: verdict counts change
            # whenever the policy or cost calibration does)
            from repro.cim import cost as _cost
            report["offload"] = dict(_cost.PLAN_STATS)
        return report

    def _retire(self, req: ServeRequest, free, active, t: float) -> None:
        req.done_s = t
        if req.slot in active:
            del active[req.slot]
        free.append(req.slot)
        free.sort()
        if self.paged is not None:
            self.paged.free(req.rid)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def _requests(args) -> List[ServeRequest]:
    return [ServeRequest(rid=i, prompt_len=args.prompt_len, gen=args.gen,
                         arrival_s=i * args.arrival_interval)
            for i in range(args.requests)]


def _fresh_cim_state() -> None:
    from repro.cim import clear_schedule_cache
    from repro.cim import cost as _cost
    from repro.cim import faults as faults_mod
    from repro.cim.array import clear_resident, set_current_spec
    _ledger().reset()
    clear_resident()
    clear_schedule_cache()
    _cost.reset_plan_stats()
    set_current_spec(None)
    faults_mod.reset_fault_stats()


def _serve_once(model, params, args) -> Dict[str, Any]:
    cfg = model.cfg
    spec = None
    rs = None
    if args.cim_lower:
        from repro.cim.array import DEFAULT_SPEC, resident_set
        spec = DEFAULT_SPEC
        rs = resident_set(spec)
    paged = PagedKV.for_model(cfg, spec=spec, slots=args.slots,
                              max_len=args.prompt_len + args.gen,
                              resident_set=rs)
    engine = ServeEngine(model, params, slots=args.slots,
                         max_len=args.prompt_len + args.gen,
                         sampler=args.sampler, cim_lower=args.cim_lower,
                         paged=paged, warmup_steps=args.warmup_steps,
                         spec=spec,
                         scrub_every=getattr(args, "scrub_every", 0))
    return engine.run(_requests(args))


def _print_cim_report(tag: str) -> None:
    from repro.cim import cache_stats

    led = _ledger()
    proj = led.projected()
    hist = ", ".join(f"{k}:{v}" for k, v in sorted(led.per_op.items()))
    print(f"cim-lower ledger ({tag}): {led.accesses} compute accesses + "
          f"{led.load_accesses} streamed loads = {led.total_accesses} total, "
          f"{led.resident_reuses} resident reuses, "
          f"{led.words32:.0f} word32-ops")
    print(f"  per-op: {hist}")
    print(f"  projected: {proj['edp_decrease_pct']:.1f}% EDP decrease, "
          f"{proj['energy_saved_fj']:.0f} fJ saved vs near-memory "
          f"(current sensing @1024^2)")
    cs = cache_stats()
    print(f"  schedule cache: {cs['hits']} hits / {cs['misses']} misses, "
          f"{cs['dispatches']} jitted dispatches; resident: "
          f"{cs.get('resident_pins', 0)} pins / "
          f"{cs.get('resident_hits', 0)} hits / "
          f"{cs.get('resident_evictions', 0)} evictions, "
          f"{cs.get('resident_rows', 0)} rows held")
    from repro.cim import cost as _cost
    ps = _cost.PLAN_STATS
    print(f"  offload policy: {ps['plans']} plans cut, "
          f"{ps['eqns_lowered']} eqns lowered / {ps['eqns_demoted']} "
          f"demoted ({ps['demoted_accesses']} accesses kept on host), "
          f"{ps['fused_despite_loss']} losing eqns kept fused")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--preset", default="reduced",
                    choices=("reduced", "100m", "full"))
    ap.add_argument("--slots", "--batch", type=int, default=4,
                    dest="slots", help="concurrent sequences in the batch")
    ap.add_argument("--requests", type=int, default=0,
                    help="queued requests (default: one per slot)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--arrival-interval", type=float, default=0.0,
                    help="seconds between request arrivals (0: all at once)")
    ap.add_argument("--warmup-steps", type=int, default=1,
                    help="decode steps excluded from steady-state metrics")
    ap.add_argument("--sampler", default="greedy", choices=("greedy", "adra"))
    ap.add_argument("--json", default="",
                    help="write the serve report to this JSON file")
    ap.add_argument("--cim-lower", action="store_true",
                    help="run decode MLPs through the jaxpr->CiM lowering "
                         "compiler (unjitted decode, per-call ledger) and "
                         "bench streamed-repack vs resident-weight phases")
    ap.add_argument("--cim-bits", type=int, default=8,
                    help="quantization width for --cim-lower (default 8)")
    ap.add_argument("--cim-resident", action="store_true",
                    help="pin int8 MLP weight planes in array rows "
                         "(with --cim-lower: also run the repack/resident "
                         "comparison)")
    ap.add_argument("--assert-warm", action="store_true",
                    help="replay the resident phase and fail unless every "
                         "program and pin stayed warm")
    ap.add_argument("--cim-faults", action="store_true",
                    help="with --cim-lower: run an extra chaos phase under "
                         "the REPRO_CIM_FAULT_SEED/BER env fault campaign "
                         "with ECC-protected resident operands, asserting "
                         "bit-identical tokens to the fault-free phase")
    ap.add_argument("--scrub-every", type=int, default=0,
                    help="decode steps between ECC scrub passes (0: off)")
    args = ap.parse_args()
    if args.requests <= 0:
        args.requests = args.slots

    cfg = preset_config(args.arch, args.preset)
    if args.cim_lower:
        cfg = dataclasses.replace(cfg, cim_mlp_bits=args.cim_bits,
                                  cim_attention_bits=args.cim_bits,
                                  cim_unroll_groups=True)
    if args.cim_resident and not args.cim_lower:
        cfg = dataclasses.replace(cfg, cim_resident=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    out: Dict[str, Any] = {
        "bench": "serve", "arch": args.arch, "preset": args.preset,
        "slots": args.slots, "requests": args.requests,
        "prompt_len": args.prompt_len, "gen": args.gen,
        "sampler": args.sampler,
        "cim": {"lower": bool(args.cim_lower), "bits": args.cim_bits,
                "resident": bool(args.cim_resident)},
    }

    if not args.cim_lower:
        rep = _serve_once(model, params, args)
        out.update(rep)
        print(f"served {rep['requests']} requests / "
              f"{rep['total_tokens']} tokens in {rep['wall_s']:.2f}s: "
              f"{rep['tok_s_steady']:.1f} tok/s steady, "
              f"p50 {rep['p50_ms']:.1f} ms, p99 {rep['p99_ms']:.1f} ms")
    else:
        # one model per phase, built ONCE: the resident model's memoized
        # param slices must keep their identity for the warm replay
        model_resident = build(dataclasses.replace(cfg, cim_resident=True))
        # phase 1: streamed repack — every decode step re-packs the weights
        _fresh_cim_state()
        repack = _serve_once(model, params, args)
        _print_cim_report("repack")
        # phase 2: resident — weight planes pinned at first touch
        _fresh_cim_state()
        resident = _serve_once(model_resident, params, args)
        _print_cim_report("resident")

        assert resident["accesses_per_token"] == repack["accesses_per_token"], \
            (f"compute accesses/token must not change with residency: "
             f"{resident['accesses_per_token']} != "
             f"{repack['accesses_per_token']}")
        assert resident["total_accesses_per_token"] \
            < repack["total_accesses_per_token"], \
            (f"resident serving must charge strictly fewer total "
             f"accesses/token: {resident['total_accesses_per_token']} !< "
             f"{repack['total_accesses_per_token']}")
        assert resident["ledger"]["resident_reuses"] > 0

        if args.assert_warm:
            from repro.cim import cache_stats
            cs0 = cache_stats()
            warm = _serve_once(model_resident, params, args)
            cs1 = cache_stats()
            miss_delta = cs1["misses"] - cs0["misses"]
            pin_delta = cs1.get("resident_pins", 0) \
                - cs0.get("resident_pins", 0)
            assert miss_delta == 0, \
                f"warm replay compiled {miss_delta} new programs"
            assert pin_delta == 0, \
                f"warm replay re-pinned {pin_delta} resident operands"
            assert warm["tok_s_steady"] > 0
            out["warm_replay"] = {
                "tok_s_steady": warm["tok_s_steady"],
                "program_cache_miss_delta": miss_delta,
                "resident_pin_delta": pin_delta,
            }
            print(f"warm replay: {warm['tok_s_steady']:.1f} tok/s, "
                  f"0 new programs, 0 new pins")

        ratio = resident["tok_s_steady"] / max(1e-9, repack["tok_s_steady"])
        out["phases"] = {"repack": repack, "resident": resident}
        out["tok_s_resident_vs_repack_ratio"] = round(ratio, 4)
        # promote the resident phase's per-token bill to the top level:
        # the quantities check_regression gates as never-grow counters
        for k in ("accesses_per_token", "load_accesses_per_token",
                  "total_accesses_per_token", "tok_s_steady", "p50_ms",
                  "p99_ms"):
            out[k] = resident[k]
        print(f"resident vs repack: {resident['tok_s_steady']:.1f} vs "
              f"{repack['tok_s_steady']:.1f} tok/s (x{ratio:.2f}), "
              f"total accesses/token {resident['total_accesses_per_token']} "
              f"vs {repack['total_accesses_per_token']}")

        if args.cim_faults:
            # chaos phase: the resident run again, under the env-configured
            # fault campaign with ECC-protected pins. Stored under
            # phases.chaos (NOT promoted to the gated top-level keys: its
            # tok/s includes verify overhead by design).
            from repro.cim import array as array_mod
            from repro.cim import faults as faults_mod
            _fresh_cim_state()
            array_mod.set_resident_ecc(True)
            fcfg = faults_mod.FaultConfig.from_env(
                raise_on_uncorrectable=True)
            try:
                with faults_mod.faults(fcfg) as fm:
                    chaos = _serve_once(model_resident, params, args)
            finally:
                array_mod.set_resident_ecc(False)
                array_mod.set_current_spec(None)
            out["phases"]["chaos"] = chaos
            fr = chaos.get("faults", {})
            tokens_match = (
                [r["token_ids"] for r in chaos["per_request"]]
                == [r["token_ids"] for r in resident["per_request"]])
            assert tokens_match, \
                "chaos phase tokens diverged from the fault-free run"
            assert fr.get("uncorrected", 0) == 0, \
                f"chaos phase left {fr.get('uncorrected')} uncorrected bits"
            if fcfg.resident_ber > 0:
                assert fr.get("corrected", 0) > 0, \
                    "resident BER configured but ECC corrected nothing"
            print(f"chaos phase (seed {fcfg.seed}, resident BER "
                  f"{fcfg.resident_ber:g}): bit-identical tokens, "
                  f"{fr.get('injected', 0)} bits injected / "
                  f"{fr.get('corrected', 0)} corrected / 0 uncorrected, "
                  f"{chaos['tok_s_steady']:.1f} tok/s under verify")

    if args.json:
        with open(args.json, "w") as f:
            json_lib.dump(out, f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()

"""Dry-run sweep driver: every (arch x shape x mesh) cell, one subprocess
per cell (isolation: a cell failure cannot poison the sweep; each process
gets the forced 512-device platform via dryrun.py's XLA_FLAGS header).

  PYTHONPATH=src python -m repro.launch.sweep --out experiments/dryrun [--mesh single|multi|both]

Cells are ordered cheap->expensive (decode < prefill < train; small archs
first) and cached: reruns only execute missing/failed cells.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCH_COST = [  # rough size order for scheduling
    "xlstm-125m", "llama3.2-1b", "gemma-2b", "musicgen-large",
    "granite-3-8b", "recurrentgemma-9b", "qwen3-14b",
    "deepseek-v2-lite-16b", "internvl2-26b", "grok-1-314b",
]
SHAPE_COST = ["decode_32k", "long_500k", "prefill_32k", "train_4k"]


def cells(meshes):
    for shape in SHAPE_COST:
        for arch in ARCH_COST:
            for mesh in meshes:
                yield arch, shape, mesh


def run(out_dir: str, meshes, force: bool = False, timeout: int = 3000) -> int:
    os.makedirs(out_dir, exist_ok=True)
    failures = 0
    for arch, shape, mesh in cells(meshes):
        tag = f"{arch}__{shape}__{mesh}"
        path = os.path.join(out_dir, tag + ".json")
        if os.path.exists(path) and not force:
            with open(path) as f:
                prev = json.load(f)
            if prev.get("status") == "ok" or "skipped" in prev:
                print(f"[cached] {tag}: {prev.get('status', 'skipped')}", flush=True)
                continue
        t0 = time.monotonic()
        cmd = [sys.executable, "-W", "ignore", "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", mesh,
               "--out", out_dir, "--force"]
        try:
            r = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout)
            ok = r.returncode == 0
        except subprocess.TimeoutExpired:
            ok = False
            with open(path, "w") as f:
                json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                           "status": "error", "error": "compile timeout"}, f)
        dt = time.monotonic() - t0
        status = "ok" if ok else "FAIL"
        if not ok:
            failures += 1
        print(f"[{status}] {tag}  ({dt:.0f}s)", flush=True)
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--mesh", default="both", choices=("single", "multi", "both"))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    n = run(args.out, meshes, args.force)
    print(f"sweep complete; {n} failures")
    sys.exit(1 if n else 0)


if __name__ == "__main__":
    main()

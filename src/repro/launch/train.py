"""End-to-end training driver with fault tolerance.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --steps 200 --preset reduced --batch 8 --seq 128

Presets: reduced (CPU-friendly smoke), 100m (~100M-param variant for the
end-to-end example), full (the published config — production meshes only).
The loop runs under the Supervisor: async checkpoints, NaN sentinel,
restore-on-failure.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, embed_stub_batch, synthetic_batch
from repro.launch.mesh import make_smoke_mesh
from repro.models import build
from repro.optim import AdamWConfig, cosine_schedule
from repro.runtime import Supervisor, SupervisorConfig
from repro.sharding import batch_specs, state_specs, to_named
from repro.train import init_state, make_train_step


def preset_config(name: str, preset: str):
    cfg = get_config(name)
    if preset == "reduced":
        return cfg.reduced()
    if preset == "100m":
        # ~100M-param same-family variant (for the end-to-end example)
        return dataclasses.replace(
            cfg.reduced(), name=cfg.name + "-100m",
            n_layers=8, d_model=768, n_heads=12, n_kv_heads=min(cfg.n_kv_heads, 4),
            head_dim=64, d_ff=3072 if cfg.d_ff else 0, vocab_size=32768,
        )
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--preset", default="reduced", choices=("reduced", "100m", "full"))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = preset_config(args.arch, args.preset)
    model = build(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, state_dtype=cfg.opt_state_dtype)
    sched = cosine_schedule(args.lr, warmup=max(args.steps // 20, 5), total=args.steps)

    mesh = make_smoke_mesh()
    key = jax.random.PRNGKey(0)
    state = init_state(model, key, opt_cfg, compress_grads=args.compress_grads)
    st_specs = to_named(mesh, state_specs(cfg, state, mesh))
    state = jax.device_put(state, st_specs)

    dcfg = DataConfig(vocab_size=cfg.vocab_size, batch=args.batch, seq_len=args.seq)

    def make_batch(step: int):
        if cfg.embed_stub:
            return {k: jnp.asarray(v) for k, v in
                    embed_stub_batch(step, cfg, args.batch, args.seq).items()}
        return {k: jnp.asarray(v) for k, v in synthetic_batch(step, dcfg).items()}

    example_batch = make_batch(0)  # host-side numpy: shapes only
    step_fn = jax.jit(
        make_train_step(model, opt_cfg, lr_schedule=sched,
                        compress_grads=args.compress_grads),
        in_shardings=(st_specs, to_named(mesh, batch_specs(cfg, example_batch, mesh))),
        out_shardings=(st_specs, None),
        donate_argnums=(0,),
    )

    ckpt = CheckpointManager(args.ckpt_dir)
    sup = Supervisor(step_fn, make_batch, ckpt,
                     SupervisorConfig(ckpt_every=args.ckpt_every))

    t0 = time.monotonic()
    n_done = 0

    def logging_step(state, batch):
        nonlocal n_done
        out = step_fn(state, batch)
        n_done += 1
        if n_done % args.log_every == 0:
            m = {k: float(jax.device_get(v)) for k, v in out[1].items()
                 if hasattr(v, "shape") or isinstance(v, (int, float))}
            rate = n_done / (time.monotonic() - t0)
            print(f"step {n_done:5d}  loss {m['loss']:.4f}  ce {m['ce']:.4f} "
                  f" gnorm {m['grad_norm']:.3f}  {rate:.2f} it/s", flush=True)
        return out

    sup.train_step = logging_step
    with mesh:
        state, metrics = sup.run(state, args.steps)
    print(f"done: {args.steps} steps in {time.monotonic()-t0:.1f}s; "
          f"final loss {float(jax.device_get(metrics['loss'])):.4f}")


if __name__ == "__main__":
    main()

from .model import Model, StackLayout, build  # noqa: F401

"""Attention variants: GQA/MQA (+qk-norm, RoPE), sliding-window local
attention with a ring-buffer cache, and DeepSeek-V2 MLA with a latent cache.

Each variant exposes:
  *_init(key, cfg)                      -> params
  *_apply(p, cfg, x, positions)         -> y                       (train/prefill, no cache)
  *_prefill(p, cfg, x, positions)       -> (y, cache)
  *_decode(p, cfg, x, cache, positions) -> (y, cache)              (T == 1)

Caches are plain pytrees so they shard/checkpoint like params.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from .blockwise_attention import blockwise_attention
from .layers import (
    _dense_init,
    _lru_get,
    apply_rope,
    quantized_batched_matmul,
    rmsnorm,
    rmsnorm_init,
)

#: sequences at or above this length use the blockwise custom-VJP attention
#: (never materializes T x T); shorter ones use the exact dense path.
BLOCKWISE_MIN_LEN = 1024

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# dense attention core (shared by GQA & local)
# ---------------------------------------------------------------------------


def _sdpa(q, k, v, mask, scale) -> jax.Array:
    """[B,Tq,H,D] x [B,Tk,Hkv,D] grouped attention with explicit mask.

    Operands stay in their storage dtype (bf16 on the production path) with
    f32 ACCUMULATION via preferred_element_type — upcasting the KV operands
    to f32 would double decode's dominant HBM term (the full-cache read) and
    materialize an f32 copy of the cache (measured on llama decode_32k:
    6.2 -> 2.9 GB/partition, EXPERIMENTS.md §Perf)."""
    b, tq, hq, d = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    qg = (q * jnp.asarray(scale, q.dtype)).reshape(b, tq, hkv, group, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32)
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, tq, hq, d).astype(q.dtype)


def _sdpa_quantized_core(qs, k, v, mask, n_bits: int) -> jax.Array:
    """Quantized SDPA body staged through the CiM lowering pass.

    `qs` is the PRE-SCALED query [B,Tq,Hq,D] (scale applied by the caller so
    the lowered trace is keyed only on shapes/n_bits, never on a closed-over
    float). Both contractions are canonical batched dot_generals — batch
    dims (B, Hkv) map onto CiM tile rows, the grouped-query axis folds into
    the matmul M axis — so `plan_batched_matmul` covers QK^T and AV with a
    per-tile access count independent of batch and head count. Everything
    between them (mask select, softmax, the layout transposes) is a host
    island."""
    b, tq, hq, d = qs.shape
    tk, hkv, dv = k.shape[1], k.shape[2], v.shape[-1]
    g = hq // hkv
    qg = qs.reshape(b, tq, hkv, g, d).transpose(0, 2, 3, 1, 4) \
        .reshape(b, hkv, g * tq, d)
    kt = k.astype(jnp.float32).transpose(0, 2, 3, 1)           # [B,Hkv,D,Tk]
    logits = quantized_batched_matmul(qg, kt, n_bits) \
        .reshape(b, hkv, g, tq, tk)
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    vt = v.astype(jnp.float32).transpose(0, 2, 1, 3)           # [B,Hkv,Tk,Dv]
    out = quantized_batched_matmul(
        probs.reshape(b, hkv, g * tq, tk), vt, n_bits)
    return out.reshape(b, hkv, g, tq, dv).transpose(0, 3, 1, 2, 4) \
        .reshape(b, tq, hq, dv)


def _sdpa_quantized(q, k, v, mask, scale, n_bits: int = 8) -> jax.Array:
    """Plain-JAX quantized twin of `_sdpa` — the un-lowered reference that
    `sdpa_cim` must match bit-for-bit."""
    qs = q.astype(jnp.float32) * jnp.asarray(scale, jnp.float32)
    return _sdpa_quantized_core(qs, k, v, mask, n_bits).astype(q.dtype)


#: bounded LRU of lowered SDPA callables (see layers._LOWERED_LINEAR)
_LOWERED_SDPA: "OrderedDict" = OrderedDict()


def _lowered_sdpa(n_bits: int, backend, spec, mesh, resident: bool = False):
    from repro.cim import array
    from repro.cim.lower import lower

    return _lru_get(
        _LOWERED_SDPA, (n_bits, backend, spec, mesh, resident),
        lambda: lower(
            lambda qs, k, v, mask: _sdpa_quantized_core(qs, k, v, mask,
                                                        n_bits),
            backend=backend, spec=spec, mesh=mesh,
            resident_argnums=(1, 2) if resident else (),
            resident_set=array.resident_set(spec) if resident else None))


def sdpa_cim(q, k, v, mask, scale, n_bits: int = 8,
             backend: str | None = None, spec=None, mesh=None,
             resident: bool = False) -> jax.Array:
    """Grouped SDPA with QK^T and AV executed as planned CiM schedules.

    Two fused regions per call (one per contraction) — warm calls are
    exactly two dispatches regardless of batch, heads, or context length.
    `resident=True` pins the packed K^T/V planes by array identity: pass
    the SAME k/v arrays across calls to skip their entry packs (decode with
    a functionally-updated cache gets fresh arrays each step, so the serve
    path streams KV instead — see `gqa_decode_cim`)."""
    qs = q.astype(jnp.float32) * jnp.asarray(scale, jnp.float32)
    lf = _lowered_sdpa(n_bits, backend, spec, mesh, resident)
    return lf(qs, k, v, mask).astype(q.dtype)


def _causal_mask(tq: int, tk: int) -> jax.Array:
    # query block aligned to the END of the key span
    return jnp.tril(jnp.ones((tq, tk), bool), k=tk - tq)[None]


# ---------------------------------------------------------------------------
# GQA / MQA global attention
# ---------------------------------------------------------------------------


def gqa_init(key, cfg: ArchConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": _dense_init(ks[0], (d, h, hd), d, dtype),
        "wk": _dense_init(ks[1], (d, hkv, hd), d, dtype),
        "wv": _dense_init(ks[2], (d, hkv, hd), d, dtype),
        "wo": _dense_init(ks[3], (h, hd, d), h * hd, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _gqa_qkv(p, cfg: ArchConfig, x, positions):
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"], preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"], preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"], preferred_element_type=jnp.float32).astype(x.dtype)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attend(q, k, v, scale, window: int = 0):
    """Dense for short sequences (exact), blockwise custom-VJP for long."""
    if q.shape[1] >= BLOCKWISE_MIN_LEN:
        return blockwise_attention(q, k, v, True, scale, window, 512)
    tq, tk = q.shape[1], k.shape[1]
    mask = _causal_mask(tq, tk)
    if window:
        qpos = jnp.arange(tq)[:, None] + (tk - tq)
        kpos = jnp.arange(tk)[None, :]
        mask = mask & (qpos - kpos < window)[None]
    return _sdpa(q, k, v, mask, scale)


def gqa_apply(p, cfg: ArchConfig, x, positions, use_flash: bool = False) -> jax.Array:
    q, k, v = _gqa_qkv(p, cfg, x, positions)
    if use_flash:
        o = kops.attention(q, k, v, causal=True)
    else:
        o = _attend(q, k, v, 1.0 / cfg.head_dim ** 0.5)
    return jnp.einsum("bthk,hkd->btd", o, p["wo"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


def gqa_make_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> Params:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_prefill(p, cfg: ArchConfig, x, positions, max_len: int) -> Tuple[jax.Array, Params]:
    q, k, v = _gqa_qkv(p, cfg, x, positions)
    o = _attend(q, k, v, 1.0 / cfg.head_dim ** 0.5)
    y = jnp.einsum("bthk,hkd->btd", o, p["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    t = k.shape[1]
    cache = gqa_make_cache(cfg, x.shape[0], max_len, x.dtype)
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0)),
    }
    return y, cache


def gqa_decode(p, cfg: ArchConfig, x, cache: Params, positions) -> Tuple[jax.Array, Params]:
    """x: [B, 1, D]; positions: [B] = index of the new token."""
    from .moe import _hint

    pos2 = positions[:, None]
    q, k, v = _gqa_qkv(p, cfg, x, pos2)
    # align the attention compute layout with the cache layout (batch on DP,
    # head_dim on "model") — otherwise GSPMD reshards the WHOLE cache to the
    # projections' head-sharded layout every step (SPMD 'involuntary full
    # rematerialization': a full-cache copy per layer per token)
    q = _hint(q, ("DP", None, None, "model"))
    k = _hint(k, ("DP", None, None, "model"))
    v = _hint(v, ("DP", None, None, "model"))
    bidx = jnp.arange(x.shape[0])
    ck = cache["k"].at[bidx, positions].set(k[:, 0])
    cv = cache["v"].at[bidx, positions].set(v[:, 0])
    t_max = ck.shape[1]
    valid = jnp.arange(t_max)[None, :] <= positions[:, None]        # [B, Tmax]
    o = _sdpa(q, ck, cv, valid[:, None, :], 1.0 / cfg.head_dim ** 0.5)
    y = jnp.einsum("bthk,hkd->btd", o, p["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y, {"k": ck, "v": cv}


def gqa_decode_cim(p, cfg: ArchConfig, x, cache: Params, positions
                   ) -> Tuple[jax.Array, Params]:
    """`gqa_decode` with the attention core routed through the jaxpr->CiM
    lowering: QK^T and AV execute as planned batched schedules (two region
    dispatches per layer per step), while rotary, softmax, and the cache
    update stay on the host. Quantization width comes from
    `cfg.cim_attention_bits`. KV streams into the banks each step — the
    functional cache update makes a fresh array per token, so identity-
    fingerprinted resident pins would churn, never hit (resident KV reuse
    is exercised where the arrays are stable: `sdpa_cim(resident=True)`
    with a fixed cache, as in the bench's attention section)."""
    from .moe import _hint

    pos2 = positions[:, None]
    q, k, v = _gqa_qkv(p, cfg, x, pos2)
    q = _hint(q, ("DP", None, None, "model"))
    k = _hint(k, ("DP", None, None, "model"))
    v = _hint(v, ("DP", None, None, "model"))
    bidx = jnp.arange(x.shape[0])
    ck = cache["k"].at[bidx, positions].set(k[:, 0])
    cv = cache["v"].at[bidx, positions].set(v[:, 0])
    t_max = ck.shape[1]
    valid = jnp.arange(t_max)[None, :] <= positions[:, None]
    o = sdpa_cim(q, ck, cv, valid[:, None, :], 1.0 / cfg.head_dim ** 0.5,
                 n_bits=cfg.cim_attention_bits)
    y = jnp.einsum("bthk,hkd->btd", o, p["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# Sliding-window local attention with a RING-BUFFER cache
# (cache is O(window), not O(context) — required for long_500k decode)
# ---------------------------------------------------------------------------


def local_apply(p, cfg: ArchConfig, x, positions) -> jax.Array:
    q, k, v = _gqa_qkv(p, cfg, x, positions)
    o = _attend(q, k, v, 1.0 / cfg.head_dim ** 0.5, window=cfg.local_window)
    return jnp.einsum("bthk,hkd->btd", o, p["wo"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


def local_make_cache(cfg: ArchConfig, batch: int, dtype) -> Params:
    w = cfg.local_window
    shape = (batch, w, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def local_prefill(p, cfg: ArchConfig, x, positions) -> Tuple[jax.Array, Params]:
    y = local_apply(p, cfg, x, positions)
    # recompute the last-window K/V into the ring buffer
    q, k, v = _gqa_qkv(p, cfg, x, positions)
    w = cfg.local_window
    t = k.shape[1]
    if t >= w:
        k_tail, v_tail = k[:, t - w:], v[:, t - w:]
        # ring layout: slot = pos % w
        slots = (jnp.arange(t - w, t)) % w
        ck = jnp.zeros_like(k_tail).at[:, slots].set(k_tail)
        cv = jnp.zeros_like(v_tail).at[:, slots].set(v_tail)
    else:
        ck = jnp.zeros((k.shape[0], w) + k.shape[2:], k.dtype).at[:, :t].set(k)
        cv = jnp.zeros((v.shape[0], w) + v.shape[2:], v.dtype).at[:, :t].set(v)
    return y, {"k": ck, "v": cv}


def local_decode(p, cfg: ArchConfig, x, cache: Params, positions) -> Tuple[jax.Array, Params]:
    q, k, v = _gqa_qkv(p, cfg, x, positions[:, None])
    w = cfg.local_window
    slot = positions % w
    bidx = jnp.arange(x.shape[0])
    ck = cache["k"].at[bidx, slot].set(k[:, 0])
    cv = cache["v"].at[bidx, slot].set(v[:, 0])
    # slot s holds absolute position: valid iff within window of `positions`
    slot_ids = jnp.arange(w)[None, :]
    # absolute position stored in slot s (given current head at `positions`):
    # pos_s = positions - ((positions - slot_ids) mod w)
    offset = (positions[:, None] - slot_ids) % w
    abs_pos = positions[:, None] - offset
    valid = (abs_pos >= 0) & (abs_pos >= positions[:, None] - (w - 1))
    o = _sdpa(q, ck, cv, valid[:, None, :], 1.0 / cfg.head_dim ** 0.5)
    y = jnp.einsum("bthk,hkd->btd", o, p["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# DeepSeek-V2 Multi-head Latent Attention (MLA)
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ArchConfig, dtype) -> Params:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    qd = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq": _dense_init(ks[0], (d, h, qd), d, dtype),
        "w_kv_a": _dense_init(ks[1], (d, m.kv_lora_rank + m.qk_rope_dim), d, dtype),
        "kv_a_norm": rmsnorm_init(m.kv_lora_rank, dtype),
        "w_uk": _dense_init(ks[2], (m.kv_lora_rank, h, m.qk_nope_dim), m.kv_lora_rank, dtype),
        "w_uv": _dense_init(ks[3], (m.kv_lora_rank, h, m.v_head_dim), m.kv_lora_rank, dtype),
        "wo": _dense_init(ks[4], (h, m.v_head_dim, d), h * m.v_head_dim, dtype),
    }


def _mla_project(p, cfg: ArchConfig, x, positions):
    m = cfg.mla
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"], preferred_element_type=jnp.float32).astype(x.dtype)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv_a = jnp.einsum("btd,dr->btr", x, p["w_kv_a"],
                      preferred_element_type=jnp.float32).astype(x.dtype)
    c_kv, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(p["kv_a_norm"], c_kv, cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def _mla_attend(p, cfg: ArchConfig, q_nope, q_rope, c_kv, k_rope, mask):
    """Absorbed-form attention: score via the 512-d latent, never expanding
    per-head K for the whole context (the MLA memory win)."""
    m = cfg.mla
    scale = 1.0 / (m.qk_nope_dim + m.qk_rope_dim) ** 0.5
    # fold W_uk into q: q_lat [B,Tq,H,R]
    q_lat = jnp.einsum("bthk,rhk->bthr", q_nope.astype(jnp.float32),
                       p["w_uk"].astype(jnp.float32))
    s_nope = jnp.einsum("bthr,bsr->bhts", q_lat, c_kv.astype(jnp.float32))
    s_rope = jnp.einsum("bthk,bsk->bhts", q_rope.astype(jnp.float32),
                        k_rope.astype(jnp.float32))
    logits = (s_nope + s_rope) * scale
    logits = jnp.where(mask[:, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    # attend in latent space then decompress once per query
    o_lat = jnp.einsum("bhts,bsr->bthr", probs, c_kv.astype(jnp.float32))
    o = jnp.einsum("bthr,rhv->bthv", o_lat, p["w_uv"].astype(jnp.float32))
    return o


def _mla_attend_blockwise(p, cfg, q_nope, q_rope, c_kv, k_rope):
    """EXPLICIT (non-absorbed) MLA for prefill/train: decompress per-head
    K_nope/V from the latent once, then flash attention over 192-dim heads.

    The absorbed form (decode's win: score via the 1088-dim [c_kv, k_rope])
    costs 2*S^2*h*(R+rope) + 2*S^2*h*R score/combine FLOPs — ~5.7x the
    explicit form's 2*S^2*h*(nope+rope) at kv_lora=512. Absorption pays when
    S^2 work is small relative to the per-token decompression (decode);
    prefill at 32k is the opposite regime (EXPERIMENTS §Perf D). DeepSeek-V2
    itself trains in the explicit form and absorbs only for inference."""
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"],
                        preferred_element_type=c_kv.dtype)
    v = jnp.einsum("bsr,rhv->bshv", c_kv, p["w_uv"],
                   preferred_element_type=c_kv.dtype)
    h = k_nope.shape[2]
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  k_rope.shape[:2] + (h, k_rope.shape[-1]))],
        axis=-1)
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
    m = cfg.mla
    scale = 1.0 / (m.qk_nope_dim + m.qk_rope_dim) ** 0.5
    return blockwise_attention(q_cat, k_cat, v, True, scale, 0, 512)


def mla_apply(p, cfg: ArchConfig, x, positions) -> jax.Array:
    q_nope, q_rope, c_kv, k_rope = _mla_project(p, cfg, x, positions)
    if x.shape[1] >= BLOCKWISE_MIN_LEN:
        o = _mla_attend_blockwise(p, cfg, q_nope, q_rope, c_kv, k_rope)
    else:
        mask = _causal_mask(x.shape[1], x.shape[1])
        o = _mla_attend(p, cfg, q_nope, q_rope, c_kv, k_rope, mask)
    return jnp.einsum("bthv,hvd->btd", o.astype(x.dtype), p["wo"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


def mla_make_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> Params:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_dim), dtype),
    }


def mla_prefill(p, cfg: ArchConfig, x, positions, max_len: int):
    q_nope, q_rope, c_kv, k_rope = _mla_project(p, cfg, x, positions)
    if x.shape[1] >= BLOCKWISE_MIN_LEN:
        o = _mla_attend_blockwise(p, cfg, q_nope, q_rope, c_kv, k_rope)
    else:
        mask = _causal_mask(x.shape[1], x.shape[1])
        o = _mla_attend(p, cfg, q_nope, q_rope, c_kv, k_rope, mask)
    y = jnp.einsum("bthv,hvd->btd", o.astype(x.dtype), p["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    cache = mla_make_cache(cfg, x.shape[0], max_len, x.dtype)
    cache = {
        "c_kv": jax.lax.dynamic_update_slice(cache["c_kv"], c_kv, (0, 0, 0)),
        "k_rope": jax.lax.dynamic_update_slice(cache["k_rope"], k_rope, (0, 0, 0)),
    }
    return y, cache


def mla_decode(p, cfg: ArchConfig, x, cache, positions):
    q_nope, q_rope, c_kv, k_rope = _mla_project(p, cfg, x, positions[:, None])
    bidx = jnp.arange(x.shape[0])
    cc = cache["c_kv"].at[bidx, positions].set(c_kv[:, 0])
    cr = cache["k_rope"].at[bidx, positions].set(k_rope[:, 0])
    valid = jnp.arange(cc.shape[1])[None, :] <= positions[:, None]
    o = _mla_attend(p, cfg, q_nope, q_rope, cc, cr, valid[:, None, :])
    y = jnp.einsum("bthv,hvd->btd", o.astype(x.dtype), p["wo"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return y, {"c_kv": cc, "k_rope": cr}

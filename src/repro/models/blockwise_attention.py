"""Blockwise (FlashAttention-style) attention for the XLA path, with a
custom VJP so neither forward nor backward ever materializes the T x T
score matrix.

This is the memory substrate that makes train_4k / prefill_32k fit on a
16 GB/chip pod (the naive _sdpa stores B*H*T^2 logits: ~1.3 TB/device for
qwen3-14b train_4k). The Pallas kernel covers real-TPU execution; this
covers every jnp/dry-run path with the same asymptotics:

  fwd : scan over kv blocks, carry (m, l, acc); save (q, k, v, o, lse)
  bwd : FlashAttention-2 recomputation — D = rowsum(dO*O), one scan over
        kv blocks accumulating dq and emitting (dk_j, dv_j) per block.

Supports GQA (q heads grouped over kv heads), causal masking with
end-aligned query positions, and an optional local window.
"""
from __future__ import annotations

import functools
from collections import OrderedDict

import jax
import jax.numpy as jnp

NEG = -1e30

#: bounded LRU of lowered batched-matmul callables (see layers._lru_get)
_LOWERED_BMM: "OrderedDict" = OrderedDict()


def _mask(tq, tk, kj0, bq, bk, causal, window):
    """[bq, bk] bool for q rows 0..tq and kv cols kj0.. (end-aligned causal)."""
    q_pos = jnp.arange(bq)[:, None] + (tk - tq)
    k_pos = kj0 + jnp.arange(bk)[None, :]
    m = k_pos < tk
    if causal:
        m = m & (q_pos >= k_pos)
    if window:
        m = m & (q_pos - k_pos < window)
    return m


def _pad_kv(k, v, bk):
    pad = (-k.shape[1]) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return k, v


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def blockwise_attention(q, k, v, causal=True, scale=None, window=0, block_k=512):
    out, _ = _fwd(q, k, v, causal, scale, window, block_k)
    return out


def _fwd(q, k, v, causal, scale, window, block_k):
    b, tq, hq, d = q.shape
    _, tk, hkv, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    scale_v = scale if scale is not None else 1.0 / d ** 0.5
    bk = min(block_k, tk) if tk % min(block_k, tk) == 0 else block_k
    kp, vp = _pad_kv(k, v, bk)
    nk = kp.shape[1] // bk

    qg = (q.astype(jnp.float32) * scale_v).reshape(b, tq, hkv, g, d)
    ks = kp.astype(jnp.float32).reshape(b, nk, bk, hkv, d)
    vs = vp.astype(jnp.float32).reshape(b, nk, bk, hkv, dv)

    def body(carry, xs):
        m_run, l_run, acc = carry
        kb, vb, j = xs                                     # [B,bk,Hkv,D]
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb)        # [B,Hkv,G,Tq,bk]
        msk = _mask(tq, tk, j * bk, tq, bk, causal, window)
        s = jnp.where(msk[None, None, None], s, NEG)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_run - m_new)
        l_new = alpha * l_run + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vb)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, g, tq), NEG, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, tq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, tq, dv), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (ks.swapaxes(0, 1), vs.swapaxes(0, 1), jnp.arange(nk)))

    safe_l = jnp.where(l_f == 0.0, 1.0, l_f)
    o = acc / safe_l[..., None]                                  # [B,Hkv,G,Tq,D]
    o = o.transpose(0, 3, 1, 2, 4).reshape(b, tq, hq, dv).astype(q.dtype)
    lse = m_f + jnp.log(safe_l)                                  # [B,Hkv,G,Tq]
    return o, (q, k, v, o, lse)


def _bwd(causal, scale, window, block_k, res, do):
    q, k, v, o, lse = res
    b, tq, hq, d = q.shape
    _, tk, hkv, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    scale_v = scale if scale is not None else 1.0 / d ** 0.5
    bk = min(block_k, tk) if tk % min(block_k, tk) == 0 else block_k
    kp, vp = _pad_kv(k, v, bk)
    nk = kp.shape[1] // bk

    qg = (q.astype(jnp.float32) * scale_v).reshape(b, tq, hkv, g, d)
    dog = do.astype(jnp.float32).reshape(b, tq, hkv, g, dv)
    og = o.astype(jnp.float32).reshape(b, tq, hkv, g, dv)
    delta = jnp.einsum("bqhgd,bqhgd->bhgq", dog, og)             # [B,Hkv,G,Tq]

    ks = kp.astype(jnp.float32).reshape(b, nk, bk, hkv, d)
    vs = vp.astype(jnp.float32).reshape(b, nk, bk, hkv, dv)

    def body(dq_acc, xs):
        kb, vb, j = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb)
        msk = _mask(tq, tk, j * bk, tq, bk, causal, window)
        s = jnp.where(msk[None, None, None], s, NEG)
        p = jnp.exp(s - lse[..., None])                          # [B,Hkv,G,Tq,bk]
        dv_j = jnp.einsum("bhgqk,bqhgd->bkhd", p, dog)
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", dog, vb)
        ds = p * (dp - delta[..., None])
        dq_acc = dq_acc + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kb)
        dk_j = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qg)
        return dq_acc, (dk_j, dv_j)

    dq0 = jnp.zeros((b, tq, hkv, g, d), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(
        body, dq0, (ks.swapaxes(0, 1), vs.swapaxes(0, 1), jnp.arange(nk)))

    dq = (dq * scale_v).reshape(b, tq, hq, d).astype(q.dtype)
    dk = dks.swapaxes(0, 1).reshape(b, nk * bk, hkv, d)[:, :tk].astype(k.dtype)
    dv_out = dvs.swapaxes(0, 1).reshape(b, nk * bk, hkv, dv)[:, :tk].astype(v.dtype)
    return dq, dk, dv_out


def _fwd_rule(q, k, v, causal, scale, window, block_k):
    return _fwd(q, k, v, causal, scale, window, block_k)


blockwise_attention.defvjp(_fwd_rule, _bwd)


# ---------------------------------------------------------------------------
# Quantized blockwise attention (host reference + CiM-lowered execution)
# ---------------------------------------------------------------------------


def blockwise_attention_quantized(q, k, v, causal=True, scale=None, window=0,
                                  block_k=512, n_bits=8, bmm=None):
    """Forward-only quantized blockwise attention with a pluggable batched
    matmul.

    Same online-softmax recurrence as `_fwd`, but the per-block QK^T and AV
    contractions go through `bmm(a, b)` on canonical [B*, M, K] x [B*, K, N]
    operands — `quantized_batched_matmul` when `bmm` is None (the float-
    quantized host reference), or a `lower()`-compiled twin of it for CiM
    execution (`blockwise_attention_cim`). The kv loop is a Python loop over
    FIXED block shapes, not a scan: every block (and every layer sharing the
    config) presents the same two operand signatures, so the lowered bmm
    compiles exactly two programs and replays them 2 x n_blocks times."""
    if bmm is None:
        def bmm(a, bb):
            from .layers import quantized_batched_matmul
            return quantized_batched_matmul(a, bb, n_bits)
    b, tq, hq, d = q.shape
    _, tk, hkv, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    scale_v = scale if scale is not None else 1.0 / d ** 0.5
    bk = min(block_k, tk) if tk % min(block_k, tk) == 0 else block_k
    kp, vp = _pad_kv(k, v, bk)
    nk = kp.shape[1] // bk

    qm = (q.astype(jnp.float32) * scale_v).reshape(b, tq, hkv, g, d) \
        .transpose(0, 2, 3, 1, 4).reshape(b, hkv, g * tq, d)
    m_run = jnp.full((b, hkv, g, tq), NEG, jnp.float32)
    l_run = jnp.zeros((b, hkv, g, tq), jnp.float32)
    acc = jnp.zeros((b, hkv, g, tq, dv), jnp.float32)
    for j in range(nk):
        kb = kp[:, j * bk:(j + 1) * bk].astype(jnp.float32)  # [B,bk,Hkv,D]
        vb = vp[:, j * bk:(j + 1) * bk].astype(jnp.float32)
        s = bmm(qm, kb.transpose(0, 2, 3, 1)) \
            .reshape(b, hkv, g, tq, bk)                      # [B,Hkv,G,Tq,bk]
        msk = _mask(tq, tk, j * bk, tq, bk, causal, window)
        s = jnp.where(msk[None, None, None], s, NEG)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_run - m_new)
        l_run = alpha * l_run + jnp.sum(p, axis=-1)
        pv = bmm(p.reshape(b, hkv, g * tq, bk),
                 vb.transpose(0, 2, 1, 3)).reshape(b, hkv, g, tq, dv)
        acc = acc * alpha[..., None] + pv
        m_run = m_new
    safe_l = jnp.where(l_run == 0.0, 1.0, l_run)
    o = acc / safe_l[..., None]
    return o.transpose(0, 3, 1, 2, 4).reshape(b, tq, hq, dv).astype(q.dtype)


def blockwise_attention_cim(q, k, v, causal=True, scale=None, window=0,
                            block_k=512, n_bits=8, backend=None, spec=None,
                            mesh=None, resident=False):
    """Blockwise attention whose integer contractions execute in the CiM
    array: bit-exact with `blockwise_attention_quantized` on the same
    operands, 2 dispatches per kv block, and (by the structural region key)
    ONE compiled program per contraction shape shared across all blocks and
    all layers."""
    from .layers import _lru_get, quantized_batched_matmul

    def make():
        from repro.cim import array
        from repro.cim.lower import lower

        return lower(lambda a, bb: quantized_batched_matmul(a, bb, n_bits),
                     backend=backend, spec=spec, mesh=mesh,
                     resident_argnums=(1,) if resident else (),
                     resident_set=array.resident_set(spec)
                     if resident else None)

    bmm = _lru_get(_LOWERED_BMM, (n_bits, backend, spec, mesh, resident),
                   make)
    return blockwise_attention_quantized(q, k, v, causal, scale, window,
                                         block_k, n_bits, bmm=bmm)

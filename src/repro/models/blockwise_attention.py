"""Blockwise (FlashAttention-style) attention for the XLA path, with a
custom VJP so neither forward nor backward ever materializes the T x T
score matrix.

This is the memory substrate that makes train_4k / prefill_32k fit on a
16 GB/chip pod (the naive _sdpa stores B*H*T^2 logits: ~1.3 TB/device for
qwen3-14b train_4k). The Pallas kernel covers real-TPU execution; this
covers every jnp/dry-run path with the same asymptotics:

  fwd : scan over kv blocks, carry (m, l, acc); save (q, k, v, o, lse)
  bwd : FlashAttention-2 recomputation — D = rowsum(dO*O), one scan over
        kv blocks accumulating dq and emitting (dk_j, dv_j) per block.

Supports GQA (q heads grouped over kv heads), causal masking with
end-aligned query positions, and an optional local window.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG = -1e30


def _mask(tq, tk, kj0, bq, bk, causal, window):
    """[bq, bk] bool for q rows 0..tq and kv cols kj0.. (end-aligned causal)."""
    q_pos = jnp.arange(bq)[:, None] + (tk - tq)
    k_pos = kj0 + jnp.arange(bk)[None, :]
    m = k_pos < tk
    if causal:
        m = m & (q_pos >= k_pos)
    if window:
        m = m & (q_pos - k_pos < window)
    return m


def _pad_kv(k, v, bk):
    pad = (-k.shape[1]) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return k, v


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def blockwise_attention(q, k, v, causal=True, scale=None, window=0, block_k=512):
    out, _ = _fwd(q, k, v, causal, scale, window, block_k)
    return out


def _fwd(q, k, v, causal, scale, window, block_k):
    b, tq, hq, d = q.shape
    _, tk, hkv, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    scale_v = scale if scale is not None else 1.0 / d ** 0.5
    bk = min(block_k, tk) if tk % min(block_k, tk) == 0 else block_k
    kp, vp = _pad_kv(k, v, bk)
    nk = kp.shape[1] // bk

    qg = (q.astype(jnp.float32) * scale_v).reshape(b, tq, hkv, g, d)
    ks = kp.astype(jnp.float32).reshape(b, nk, bk, hkv, d)
    vs = vp.astype(jnp.float32).reshape(b, nk, bk, hkv, dv)

    def body(carry, xs):
        m_run, l_run, acc = carry
        kb, vb, j = xs                                     # [B,bk,Hkv,D]
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb)        # [B,Hkv,G,Tq,bk]
        msk = _mask(tq, tk, j * bk, tq, bk, causal, window)
        s = jnp.where(msk[None, None, None], s, NEG)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_run - m_new)
        l_new = alpha * l_run + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhgqk,bkhd->bhgqd", p, vb)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, g, tq), NEG, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, tq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, tq, dv), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (ks.swapaxes(0, 1), vs.swapaxes(0, 1), jnp.arange(nk)))

    safe_l = jnp.where(l_f == 0.0, 1.0, l_f)
    o = acc / safe_l[..., None]                                  # [B,Hkv,G,Tq,D]
    o = o.transpose(0, 3, 1, 2, 4).reshape(b, tq, hq, dv).astype(q.dtype)
    lse = m_f + jnp.log(safe_l)                                  # [B,Hkv,G,Tq]
    return o, (q, k, v, o, lse)


def _bwd(causal, scale, window, block_k, res, do):
    q, k, v, o, lse = res
    b, tq, hq, d = q.shape
    _, tk, hkv, _ = k.shape
    dv = v.shape[-1]
    g = hq // hkv
    scale_v = scale if scale is not None else 1.0 / d ** 0.5
    bk = min(block_k, tk) if tk % min(block_k, tk) == 0 else block_k
    kp, vp = _pad_kv(k, v, bk)
    nk = kp.shape[1] // bk

    qg = (q.astype(jnp.float32) * scale_v).reshape(b, tq, hkv, g, d)
    dog = do.astype(jnp.float32).reshape(b, tq, hkv, g, dv)
    og = o.astype(jnp.float32).reshape(b, tq, hkv, g, dv)
    delta = jnp.einsum("bqhgd,bqhgd->bhgq", dog, og)             # [B,Hkv,G,Tq]

    ks = kp.astype(jnp.float32).reshape(b, nk, bk, hkv, d)
    vs = vp.astype(jnp.float32).reshape(b, nk, bk, hkv, dv)

    def body(dq_acc, xs):
        kb, vb, j = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb)
        msk = _mask(tq, tk, j * bk, tq, bk, causal, window)
        s = jnp.where(msk[None, None, None], s, NEG)
        p = jnp.exp(s - lse[..., None])                          # [B,Hkv,G,Tq,bk]
        dv_j = jnp.einsum("bhgqk,bqhgd->bkhd", p, dog)
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", dog, vb)
        ds = p * (dp - delta[..., None])
        dq_acc = dq_acc + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kb)
        dk_j = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qg)
        return dq_acc, (dk_j, dv_j)

    dq0 = jnp.zeros((b, tq, hkv, g, d), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(
        body, dq0, (ks.swapaxes(0, 1), vs.swapaxes(0, 1), jnp.arange(nk)))

    dq = (dq * scale_v).reshape(b, tq, hq, d).astype(q.dtype)
    dk = dks.swapaxes(0, 1).reshape(b, nk * bk, hkv, d)[:, :tk].astype(k.dtype)
    dv_out = dvs.swapaxes(0, 1).reshape(b, nk * bk, hkv, dv)[:, :tk].astype(v.dtype)
    return dq, dk, dv_out


def _fwd_rule(q, k, v, causal, scale, window, block_k):
    return _fwd(q, k, v, causal, scale, window, block_k)


blockwise_attention.defvjp(_fwd_rule, _bwd)

"""Shared model layers: norms, rotary embeddings, MLPs, embedding tables.

Pure-functional (params are plain pytrees of jnp arrays); initializers take an
explicit PRNG key. Matmul-bearing layers compute in the config activation
dtype with f32 accumulation via preferred_element_type.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict

import jax
import jax.numpy as jnp

Params = Dict[str, jax.Array]


def _dense_init(key, shape, in_axis_size, dtype):
    scale = 1.0 / jnp.sqrt(jnp.asarray(in_axis_size, jnp.float32))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Interleaved (adjacent-pair) RoPE: x [B, T, H, D], positions [B, T].

    The pair (2i, 2i+1) layout keeps every rotation WITHIN a shard when the
    head_dim axis is model-sharded (the half-split layout splits the sharded
    axis and forces SPMD to fully replicate — observed as 'involuntary full
    rematerialization' costing 100s of GB/device on qwen/gemma)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                         # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B, T, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    xr = x.astype(jnp.float32).reshape(x.shape[:-1] + (d // 2, 2))
    x1, x2 = xr[..., 0], xr[..., 1]
    out = jnp.stack([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


def hint_batch_sharding(x: jax.Array) -> jax.Array:
    """Best-effort sharding hint: leading (batch) dim on the DP axes.

    GSPMD occasionally drops batch sharding through scan carries / reshapes;
    this re-pins it. No-op when no mesh is in scope (CPU unit tests)."""
    from jax.sharding import PartitionSpec as P

    for dp in (("pod", "data"), "data"):
        try:
            return jax.lax.with_sharding_constraint(
                x, P(*((dp,) + (None,) * (x.ndim - 1))))
        except Exception:
            continue
    return x


def hint_activation_sharding(x: jax.Array) -> jax.Array:
    """Layer-boundary activation hint: batch on DP axes AND sequence on the
    model axis (sequence parallelism, Korthikanti et al.): the per-group
    saved carries of the layer scan are the dominant train-time residency
    (n_groups x [B, S, d]); 2-D sharding cuts them by the model-axis width.
    Falls back to batch-only for short sequences / decode steps."""
    from jax.sharding import PartitionSpec as P

    if x.ndim >= 3 and x.shape[1] >= 64:
        for dp in (("pod", "data"), "data"):
            try:
                return jax.lax.with_sharding_constraint(
                    x, P(*((dp, "model") + (None,) * (x.ndim - 2))))
            except Exception:
                continue
    return hint_batch_sharding(x)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / plain GELU)
# ---------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, gating: str, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_in": _dense_init(k1, (d_model, d_ff), d_model, dtype),
        "w_out": _dense_init(k2, (d_ff, d_model), d_ff, dtype),
    }
    if gating in ("swiglu", "geglu"):
        p["w_gate"] = _dense_init(k3, (d_model, d_ff), d_model, dtype)
    return p


def mlp(p: Params, x: jax.Array, gating: str) -> jax.Array:
    h = jnp.einsum("btd,df->btf", x, p["w_in"], preferred_element_type=jnp.float32)
    if gating == "swiglu":
        g = jnp.einsum("btd,df->btf", x, p["w_gate"], preferred_element_type=jnp.float32)
        h = jax.nn.silu(g) * h
    elif gating == "geglu":
        g = jnp.einsum("btd,df->btf", x, p["w_gate"], preferred_element_type=jnp.float32)
        h = jax.nn.gelu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = h.astype(x.dtype)
    return jnp.einsum("btf,fd->btd", h, p["w_out"],
                      preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# Opt-in CiM-quantized linear path (compiled through the lowering pass)
# ---------------------------------------------------------------------------


def quantize_symmetric(x: jax.Array, n_bits: int = 8):
    """Per-tensor symmetric quantization: x ~ q * scale, q in intN range."""
    qmax = float(2 ** (n_bits - 1) - 1)
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-8) / qmax
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -qmax, qmax)
    return q.astype(jnp.int32), scale


def _cim_int_dtype(n_bits: int):
    """Narrowest jnp integer dtype holding symmetric n_bits quantized values
    — the dtype IS the eligibility signal the lowering compiler reads."""
    if n_bits <= 8:
        return jnp.int8
    if n_bits <= 16:
        return jnp.int16
    return jnp.int32


def _quantized_linear(x: jax.Array, w: jax.Array, n_bits: int) -> jax.Array:
    """Pure-jnp quantized linear: fake-quantize both operands, contract
    EXACTLY in narrow integers, rescale. This is the function the lowering
    compiler stages — its integer `dot_general` is the CiM-eligible eqn;
    the float quantize/rescale stays on the host."""
    d, f = w.shape
    lead = x.shape[:-1]
    xq, sx = quantize_symmetric(x, n_bits)
    wq, sw = quantize_symmetric(w, n_bits)
    dt = _cim_int_dtype(n_bits)
    y = jnp.matmul(xq.reshape(-1, d).astype(dt), wq.astype(dt),
                   preferred_element_type=jnp.int32)
    return (y.astype(jnp.float32) * (sx * sw)).reshape(lead + (f,))


def quantized_batched_matmul(a: jax.Array, b: jax.Array,
                             n_bits: int = 8) -> jax.Array:
    """Per-tensor-quantized batched matmul: [*B,M,K] x [*B,K,N] -> f32.

    Built on an EXPLICIT `lax.dot_general` with canonical batch dims —
    `jnp.matmul` rewrites singleton batch axes into squeeze + transpose
    around a non-canonical contraction, which the lowering classifier
    (correctly) rejects. The canonical form is what `plan_batched_matmul`
    lowers with a per-tile access count independent of the batch size."""
    nb = a.ndim - 2
    aq, sa = quantize_symmetric(a, n_bits)
    bq, sb = quantize_symmetric(b, n_bits)
    dt = _cim_int_dtype(n_bits)
    y = jax.lax.dot_general(
        aq.astype(dt), bq.astype(dt),
        (((nb + 1,), (nb,)), (tuple(range(nb)), tuple(range(nb)))),
        preferred_element_type=jnp.int32)
    return y.astype(jnp.float32) * (sa * sb)


def _mlp_quantized(p: Params, x: jax.Array, gating: str,
                   n_bits: int) -> jax.Array:
    """The quantized MLP as one plain JAX function — the un-lowered
    reference `mlp_cim` must match bit-for-bit."""
    h = _quantized_linear(x, p["w_in"], n_bits)
    if gating == "swiglu":
        g = _quantized_linear(x, p["w_gate"], n_bits)
        h = jax.nn.silu(g) * h
    elif gating == "geglu":
        g = _quantized_linear(x, p["w_gate"], n_bits)
        h = jax.nn.gelu(g) * h
    else:
        h = jax.nn.gelu(h)
    return _quantized_linear(h, p["w_out"], n_bits).astype(x.dtype)


#: bounded LRU caches of lowered callables, keyed by everything that shapes
#: the trace (each LoweredFunction additionally LRU-bounds its per-shape
#: signature traces — no layer of this path grows without limit)
_LOWERED_CACHE_CAPACITY = 32
_LOWERED_LINEAR: "OrderedDict" = OrderedDict()
_LOWERED_MLP: "OrderedDict" = OrderedDict()


def _lru_get(cache, key, make):
    lf = cache.get(key)
    if lf is None:
        lf = cache[key] = make()
        while len(cache) > _LOWERED_CACHE_CAPACITY:
            cache.popitem(last=False)
    else:
        cache.move_to_end(key)
    return lf


def _lowered_linear(n_bits: int, backend, spec, mesh, resident: bool = False):
    from repro.cim.lower import lower

    # resident_set stays None: the lowered callable resolves the registry
    # set per execution, so clear_resident()/set_resident_ecc()/failover
    # are honored even though this LRU outlives them
    return _lru_get(
        _LOWERED_LINEAR, (n_bits, backend, spec, mesh, resident),
        lambda: lower(lambda x, w: _quantized_linear(x, w, n_bits),
                      backend=backend, spec=spec, mesh=mesh,
                      resident_argnums=(1,) if resident else ()))


def _lowered_mlp(gating: str, n_bits: int, backend, spec, mesh,
                 resident: bool = False):
    from repro.cim.lower import lower

    return _lru_get(
        _LOWERED_MLP, (gating, n_bits, backend, spec, mesh, resident),
        lambda: lower(lambda p, x: _mlp_quantized(p, x, gating, n_bits),
                      backend=backend, spec=spec, mesh=mesh,
                      resident_argnums=(0,) if resident else ()))


def cim_linear(x: jax.Array, w: jax.Array, n_bits: int = 8,
               backend: str | None = None, spec=None, mesh=None,
               resident: bool = False) -> jax.Array:
    """Opt-in CiM execution of x @ w via intN symmetric quantization.

    x [..., D], w [D, F] -> f32 [..., F]. A `lower()` application: the
    quantized-linear function is staged once per argument signature and its
    integer contraction executes through the planner's access schedules
    (banked/tiled when `spec` is given) while quantize/rescale run on the
    host — bit-exact with the un-lowered function. Each fused region is
    ONE compiled XLA program (warm calls: one dispatch per region, zero
    retrace). Still a functional-simulation path for model-scale integer
    offload studies, not a fast path: the packed broadcast layout
    materializes M*K*N words, so use it on reduced configs / layer slices.

    `resident=True` pins the int8 weight planes in the array's resident
    region at first call: warm calls skip the weight-side entry pack (and
    its quantization eqns) entirely — the paper's stored-operand execution.
    Pass the SAME `w` array object each call to stay warm.

    `spec=None` resolves through `array.spec_override()` — the failover
    lever: installing a degraded spec re-routes every subsequent call
    through the degraded geometry (fresh lowered callables, fresh pins);
    with no override installed, None keeps meaning unbanked lowering.
    """
    if spec is None:
        from repro.cim import array
        spec = array.spec_override()
    return _lowered_linear(n_bits, backend, spec, mesh, resident)(x, w)


def mlp_cim(p: Params, x: jax.Array, gating: str, n_bits: int = 8,
            backend: str | None = None, spec=None, mesh=None,
            resident: bool = False) -> jax.Array:
    """The MLP compiled through the jaxpr->CiM lowering pass: every integer
    matmul executes in the CiM array, every float op (quantization scales,
    SiLU/GELU gating) on the host — the opt-in twin of `mlp` for offload
    studies on reduced configs. `resident=True` pins the int8 weight planes
    across calls (see cim_linear). `spec=None` resolves through
    `array.spec_override()` — bank failover re-routes here too."""
    if spec is None:
        from repro.cim import array
        spec = array.spec_override()
    return _lowered_mlp(gating, n_bits, backend, spec, mesh, resident)(p, x)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def embed_init(key, vocab: int, d_model: int, dtype) -> Params:
    return {"table": _dense_init(key, (vocab, d_model), d_model, dtype)}


def embed(p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def lm_head_init(key, d_model: int, vocab: int, dtype) -> Params:
    return {"w": _dense_init(key, (d_model, vocab), d_model, dtype)}


def lm_head(p: Params, x: jax.Array, tied_table: jax.Array | None = None) -> jax.Array:
    w = tied_table.T if tied_table is not None else p["w"]
    return jnp.einsum("btd,dv->btv", x, w, preferred_element_type=jnp.float32)


def chunked_lm_loss(
    x: jax.Array,            # [B, S, D] final hidden states
    w_head: jax.Array,       # [D, V_padded]
    targets: jax.Array,      # [B, S]
    real_vocab: int,
    chunk: int = 512,
) -> jax.Array:
    """Mean CE without ever materializing the full [B, S, V] logits.

    Scans over sequence chunks; each chunk's logits are recomputed in the
    backward pass (jax.checkpoint), so peak memory is one chunk's logits.
    Padded vocab columns (Megatron-style padding) are masked to -inf.
    """
    b, s, d = x.shape
    v = w_head.shape[-1]
    c = chunk
    while s % c:
        c -= 1
    n_chunks = s // c
    pad_mask = (jnp.arange(v) >= real_vocab) * (-1e30)

    def body(total, xs):
        xc, tc = xs                                     # [B, c, D], [B, c]
        logits = jnp.einsum("btd,dv->btv", xc, w_head,
                            preferred_element_type=jnp.float32) + pad_mask
        total = total + jnp.sum(cross_entropy(logits, tc))
        return total, None

    xs = (
        jnp.moveaxis(x.reshape(b, n_chunks, c, d), 1, 0),
        jnp.moveaxis(targets.reshape(b, n_chunks, c), 1, 0),
    )
    total, _ = jax.lax.scan(jax.checkpoint(body, prevent_cse=False),
                            jnp.zeros((), jnp.float32), xs)
    return total / (b * s)


def cross_entropy(logits_f32: jax.Array, targets: jax.Array) -> jax.Array:
    """Sharded-vocab-safe CE: the target logit is extracted with an
    iota==target mask (elementwise + reduce stays sharded under GSPMD;
    a gather would force an all-gather of the vocab axis)."""
    v = logits_f32.shape[-1]
    m = jnp.max(logits_f32, axis=-1, keepdims=True)
    shifted = logits_f32 - jax.lax.stop_gradient(m)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    onehot_sel = (
        jax.lax.broadcasted_iota(jnp.int32, logits_f32.shape, logits_f32.ndim - 1)
        == targets[..., None]
    )
    tgt = jnp.sum(jnp.where(onehot_sel, logits_f32, 0.0), axis=-1)
    return lse - tgt

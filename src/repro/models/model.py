"""Model assembly: layer stacks (grouped lax.scan), caches, train/prefill/
decode paths for every assigned architecture family.

Layer-stack layout (supports mixed block patterns a la Griffin/xLSTM while
keeping a scannable structure):

  params = {
    "embed":       token table                  (absent for embed-stub archs)
    "first_dense": [layer, ...]                 (unscanned; e.g. DeepSeek layer 0)
    "groups":      (stack_p0, ..., stack_p{P-1})  each stacked over G groups
    "rem":         [layer, ...]                 (pattern remainder, unscanned)
    "final_norm", "lm_head"
  }

The pattern period P repeats G = (n_layers - first_dense) // P times; one
scan step applies one full period (P heterogeneous layers), so heterogeneous
stacks (rec,rec,local / m,m,m,s) still compile as a single rolled loop.

Caches mirror the same structure; every cache/state is a plain pytree.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import attention as attn
from . import moe as moe_lib
from . import recurrent as rec_lib
from . import xlstm as xlstm_lib
from .layers import (
    chunked_lm_loss,
    embed,
    embed_init,
    lm_head,
    lm_head_init,
    mlp,
    mlp_cim,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
)

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------


def _apply_mlp(cfg: ArchConfig, p: Params, h):
    """Dense-MLP dispatch: the jaxpr->CiM lowered quantized path when the
    config opts in (cim_mlp_bits > 0), the plain dense path otherwise."""
    if cfg.cim_mlp_bits:
        return mlp_cim(p, h, cfg.gating, n_bits=cfg.cim_mlp_bits,
                       resident=cfg.cim_resident)
    return mlp(p, h, cfg.gating)


def _layer_init(key, cfg: ArchConfig, kind: str, layer_idx: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    p: Params = {"ln1": rmsnorm_init(cfg.d_model, dtype)}
    if kind in ("attn", "local"):
        p["attn"] = (attn.mla_init(ks[0], cfg, dtype) if cfg.mla
                     else attn.gqa_init(ks[0], cfg, dtype))
        p["ln2"] = rmsnorm_init(cfg.d_model, dtype)
        if cfg.moe is not None and layer_idx >= cfg.first_dense_layers:
            p["mlp"] = moe_lib.moe_init(ks[1], cfg, dtype)
        else:
            width = cfg.d_ff_first_dense or cfg.d_ff
            p["mlp"] = mlp_init(ks[1], cfg.d_model, width, cfg.gating, dtype)
    elif kind == "rec":
        p["rec"] = rec_lib.rglru_block_init(ks[0], cfg, dtype)
        p["ln2"] = rmsnorm_init(cfg.d_model, dtype)
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.gating, dtype)
    elif kind == "mlstm":
        p["cell"] = xlstm_lib.mlstm_init(ks[0], cfg, dtype)
    elif kind == "slstm":
        p["cell"] = xlstm_lib.slstm_init(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    return p


def _layer_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int, dtype):
    if kind == "attn":
        if cfg.mla:
            return attn.mla_make_cache(cfg, batch, max_len, dtype)
        return attn.gqa_make_cache(cfg, batch, max_len, dtype)
    if kind == "local":
        return attn.local_make_cache(cfg, batch, dtype)
    if kind == "rec":
        return rec_lib.rglru_make_state(cfg, batch, dtype)
    if kind == "mlstm":
        return xlstm_lib.mlstm_make_state(cfg, batch)
    if kind == "slstm":
        return xlstm_lib.slstm_make_state(cfg, batch)
    raise ValueError(kind)


_KEEP_F32 = ("router",)  # routing logits stay full precision


def _compute_cast(p: Params, act_dtype) -> Params:
    """Mixed precision at compute time: f32 master weights are cast to the
    activation dtype before every matmul. Without this, bf16 x f32 einsums
    promote to f32 dots and the per-layer tensor-parallel all-reduces move
    f32 partial sums — 2x the collective bytes (measured: EXPERIMENTS.md
    §Perf, xlstm prefill cell)."""
    if act_dtype == jnp.float32:
        return p

    def cast(path, a):
        name = str(getattr(path[-1], "key", ""))
        if a.dtype == jnp.float32 and a.ndim >= 2 and name not in _KEEP_F32:
            return a.astype(act_dtype)
        return a

    return jax.tree_util.tree_map_with_path(cast, p)


def _layer_apply(
    p: Params, cfg: ArchConfig, kind: str, layer_idx: int,
    x: jax.Array, positions: jax.Array, mode: str,
    cache, max_len: Optional[int],
):
    """Returns (x, aux_loss, new_cache)."""
    p = _compute_cast(p, cfg.activation_dtype())
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)

    if kind in ("attn", "local"):
        if cfg.mla:
            if mode == "train":
                y, new_cache = attn.mla_apply(p["attn"], cfg, h, positions), None
            elif mode == "prefill":
                y, new_cache = attn.mla_prefill(p["attn"], cfg, h, positions, max_len)
            else:
                y, new_cache = attn.mla_decode(p["attn"], cfg, h, cache, positions)
        elif kind == "local":
            if mode == "train":
                y, new_cache = attn.local_apply(p["attn"], cfg, h, positions), None
            elif mode == "prefill":
                y, new_cache = attn.local_prefill(p["attn"], cfg, h, positions)
            else:
                y, new_cache = attn.local_decode(p["attn"], cfg, h, cache, positions)
        else:
            if mode == "train":
                y, new_cache = attn.gqa_apply(p["attn"], cfg, h, positions), None
            elif mode == "prefill":
                y, new_cache = attn.gqa_prefill(p["attn"], cfg, h, positions, max_len)
            elif cfg.cim_attention_bits:
                y, new_cache = attn.gqa_decode_cim(p["attn"], cfg, h, cache,
                                                   positions)
            else:
                y, new_cache = attn.gqa_decode(p["attn"], cfg, h, cache, positions)
        x = x + y
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        if cfg.moe is not None and layer_idx >= cfg.first_dense_layers:
            y2, aux = moe_lib.moe_apply(p["mlp"], cfg, h2)
        else:
            y2 = _apply_mlp(cfg, p["mlp"], h2)
        return x + y2, aux, new_cache

    if kind == "rec":
        state = cache if mode == "decode" else None
        y, new_state = rec_lib.rglru_block_apply(p["rec"], cfg, h, state)
        x = x + y
        h2 = rmsnorm(p["ln2"], x, cfg.norm_eps)
        x = x + _apply_mlp(cfg, p["mlp"], h2)
        new_cache = new_state if mode in ("prefill", "decode") else None
        return x, aux, new_cache

    if kind in ("mlstm", "slstm"):
        state = cache if mode == "decode" else None
        fn = xlstm_lib.mlstm_apply if kind == "mlstm" else xlstm_lib.slstm_apply
        y, new_state = fn(p["cell"], cfg, h, state)
        new_cache = new_state if mode in ("prefill", "decode") else None
        return x + y, aux, new_cache

    raise ValueError(kind)


# ---------------------------------------------------------------------------
# the Model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StackLayout:
    pattern: Tuple[str, ...]
    n_first_dense: int
    n_groups: int
    n_rem: int

    @classmethod
    def from_config(cls, cfg: ArchConfig) -> "StackLayout":
        p = cfg.block_pattern
        body = cfg.n_layers - cfg.first_dense_layers
        return cls(pattern=p, n_first_dense=cfg.first_dense_layers,
                   n_groups=body // len(p), n_rem=body % len(p))


class Model:
    """Functional model wrapper for one ArchConfig."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.layout = StackLayout.from_config(cfg)
        # memoized per-group param slices for the unrolled (resident) stack:
        # the SAME jax.Arrays must be handed to every call so the lowered
        # MLPs' identity fingerprints stay warm across decode steps
        self._group_slices: Dict[int, Tuple[Any, list]] = {}

    # -- init ---------------------------------------------------------------

    def init(self, key) -> Params:
        cfg = self.cfg
        lay = self.layout
        dtype = jnp.float32 if cfg.param_dtype == "float32" else jnp.bfloat16
        keys = jax.random.split(key, 8)
        params: Params = {}
        if not cfg.embed_stub:
            # padded vocab (multiple of 256): model-axis shardable; pad rows
            # are never indexed and pad logits are masked to -inf
            params["embed"] = embed_init(keys[0], cfg.vocab_padded, cfg.d_model, dtype)

        params["first_dense"] = [
            _layer_init(jax.random.fold_in(keys[1], i), cfg, "attn", i, dtype)
            for i in range(lay.n_first_dense)
        ]

        def make_stack(pos_in_period: int):
            kind = lay.pattern[pos_in_period]

            def one(i):
                li = lay.n_first_dense + i * len(lay.pattern) + pos_in_period
                return _layer_init(
                    jax.random.fold_in(keys[2], li), cfg, kind, li, dtype)

            layers = [one(i) for i in range(lay.n_groups)]
            return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)

        params["groups"] = tuple(make_stack(p) for p in range(len(lay.pattern)))
        params["rem"] = [
            _layer_init(
                jax.random.fold_in(keys[3], 10_000 + r), cfg, lay.pattern[r],
                lay.n_first_dense + lay.n_groups * len(lay.pattern) + r, dtype)
            for r in range(lay.n_rem)
        ]
        params["final_norm"] = rmsnorm_init(cfg.d_model, dtype)
        if not (cfg.tie_embeddings and not cfg.embed_stub):
            params["lm_head"] = lm_head_init(keys[4], cfg.d_model, cfg.vocab_padded, dtype)
        return params

    # -- caches ---------------------------------------------------------------

    def init_caches(self, batch: int, max_len: int) -> Params:
        cfg, lay = self.cfg, self.layout
        dtype = cfg.activation_dtype()

        def stack_cache(pos: int):
            kind = lay.pattern[pos]
            one = _layer_cache(cfg, kind, batch, max_len, dtype)
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (lay.n_groups,) + x.shape), one)

        return {
            "first_dense": [
                _layer_cache(cfg, "attn", batch, max_len, dtype)
                for _ in range(lay.n_first_dense)
            ],
            "groups": tuple(stack_cache(p) for p in range(len(lay.pattern))),
            "rem": [
                _layer_cache(cfg, lay.pattern[r], batch, max_len, dtype)
                for r in range(lay.n_rem)
            ],
        }

    # -- stack execution ------------------------------------------------------

    def _embed_inputs(self, params: Params, inputs: Dict[str, jax.Array]):
        cfg = self.cfg
        if cfg.embed_stub:
            x = inputs["embeds"].astype(cfg.activation_dtype())
        else:
            x = embed(params["embed"], inputs["tokens"]).astype(cfg.activation_dtype())
        return x

    def _positions(self, inputs, x, mode):
        if mode == "decode":
            return inputs["positions"]
        b, t = x.shape[0], x.shape[1]
        return jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))

    def _run_stack(self, params, x, positions, mode, caches=None, max_len=None):
        cfg, lay = self.cfg, self.layout
        aux_total = jnp.zeros((), jnp.float32)
        new_caches: Dict[str, Any] = {"first_dense": [], "groups": [], "rem": []}

        def get_cache(part, idx):
            if caches is None:
                return None
            return caches[part][idx]

        for i, p in enumerate(params["first_dense"]):
            x, aux, nc = _layer_apply(p, cfg, "attn", i, x, positions, mode,
                                      get_cache("first_dense", i), max_len)
            aux_total += aux
            new_caches["first_dense"].append(nc)

        period = len(lay.pattern)

        def period_body(carry, xs):
            x, aux_acc = carry
            from .layers import hint_activation_sharding
            if mode == "train":
                x = hint_activation_sharding(x)   # 2-D (batch x seq) residency
            group_params, group_caches = xs
            ncs = []
            for pos in range(period):
                kind = lay.pattern[pos]
                # any group layer is past the first_dense prefix, so the
                # moe-vs-dense choice is static: use n_first_dense + pos
                li = lay.n_first_dense + pos
                c = None if group_caches is None else group_caches[pos]
                x, aux, nc = _layer_apply(group_params[pos], cfg, kind, li,
                                          x, positions, mode, c, max_len)
                aux_acc = aux_acc + aux
                ncs.append(nc)
            return (x, aux_acc), tuple(ncs)

        body = period_body
        if cfg.remat and mode == "train":
            body = jax.checkpoint(period_body, prevent_cse=False)

        if lay.n_groups > 0:
            xs = (
                params["groups"],
                caches["groups"] if caches is not None else None,
            )
            # resident serving unrolls the group scan: inside lax.scan the
            # per-layer params are Tracers, whose identity is per-trace, so
            # the lowered MLPs could never hold a warm pin. The unrolled
            # path hands each layer the SAME memoized param slice every
            # call (train keeps the scan: remat + compile time matter more)
            if (cfg.cim_resident or cfg.cim_unroll_groups) \
                    and mode != "train":
                carry = (x, aux_total)
                ncs_stacked = []
                slices = self._group_param_slices(params["groups"])
                for g, gp in enumerate(slices):
                    gc = (jax.tree.map(lambda a: a[g], caches["groups"])
                          if caches is not None else None)
                    carry, ncs = body(carry, (gp, gc))
                    ncs_stacked.append(ncs)
                x, aux_total = carry
                new_caches["groups"] = jax.tree.map(
                    lambda *xs_: jnp.stack(xs_), *ncs_stacked)
            else:
                (x, aux_total), group_caches_new = jax.lax.scan(
                    body, (x, aux_total), xs)
                new_caches["groups"] = group_caches_new

        base = lay.n_first_dense + lay.n_groups * period
        for r in range(lay.n_rem):
            x, aux, nc = _layer_apply(params["rem"][r], cfg, lay.pattern[r],
                                      base + r, x, positions, mode,
                                      get_cache("rem", r), max_len)
            aux_total += aux
            new_caches["rem"].append(nc)

        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return x, aux_total, new_caches

    def _group_param_slices(self, groups):
        """Per-group views of the stacked group params, built ONCE per
        params object and reused verbatim thereafter — the stability the
        resident fingerprints (id-based, see repro.cim.lower) depend on.
        The cache entry keeps a strong reference to the keyed object so a
        recycled id() can never alias a dead pytree."""
        key = id(groups)
        hit = self._group_slices.get(key)
        if hit is not None and hit[0] is groups:
            return hit[1]
        slices = [jax.tree.map(lambda a: a[g], groups)
                  for g in range(self.layout.n_groups)]
        self._group_slices[key] = (groups, slices)
        return slices

    # -- public paths -----------------------------------------------------------

    def _head_weight(self, params) -> jax.Array:
        cfg = self.cfg
        if cfg.tie_embeddings and not cfg.embed_stub:
            return params["embed"]["table"].T
        return params["lm_head"]["w"]

    def logits(self, params, x_final) -> jax.Array:
        """Full logits over the padded vocab, pad columns masked to -inf."""
        cfg = self.cfg
        out = jnp.einsum("btd,dv->btv", x_final, self._head_weight(params),
                         preferred_element_type=jnp.float32)
        if cfg.vocab_padded != cfg.vocab_size:
            out = out + (jnp.arange(cfg.vocab_padded) >= cfg.vocab_size) * (-1e30)
        return out

    def forward(self, params, inputs) -> Tuple[jax.Array, jax.Array]:
        """Full-sequence forward (train path). Returns (logits_f32, aux)."""
        x = self._embed_inputs(params, inputs)
        positions = self._positions(inputs, x, "train")
        x, aux, _ = self._run_stack(params, x, positions, "train")
        return self.logits(params, x), aux

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Chunked-CE loss: never materializes the [B, S, V] logits."""
        cfg = self.cfg
        x = self._embed_inputs(params, batch)
        positions = self._positions(batch, x, "train")
        x, aux, _ = self._run_stack(params, x, positions, "train")
        ce = chunked_lm_loss(x, self._head_weight(params), batch["targets"],
                             real_vocab=cfg.vocab_size)
        loss = ce + 0.01 * aux
        return loss, {"ce": ce, "aux": aux}

    def prefill(self, params, inputs, max_len: int):
        """Returns (caches, last_token_logits [B, V])."""
        x = self._embed_inputs(params, inputs)
        positions = self._positions(inputs, x, "prefill")
        x, _, caches = self._run_stack(params, x, positions, "prefill",
                                       caches=None, max_len=max_len)
        return caches, self.logits(params, x[:, -1:])[:, 0]

    def decode_step(self, params, caches, inputs):
        """One token step. inputs: tokens/embeds [B,1] + positions [B].
        Returns (new_caches, logits [B, V])."""
        x = self._embed_inputs(params, inputs)
        positions = inputs["positions"]
        x, _, new_caches = self._run_stack(params, x, positions, "decode",
                                           caches=caches)
        return new_caches, self.logits(params, x)[:, 0]


def build(cfg: ArchConfig) -> Model:
    return Model(cfg)

"""Mixture-of-Experts layer: top-k token-choice routing with capacity,
optional shared (always-on) experts, expert- or tensor-parallel expert
weights.

Dispatch is SCATTER-based (tokens scattered into per-expert [E, C, D] buffers
by (expert, position-in-expert) and gathered back), not the classic GShard
one-hot einsum: the [N, E, C] dispatch tensor is O(tokens^2/E) and would be
~20 TB for grok-1 train_4k, while the scatter form materializes only
[N*k, D] + [E, C, D]. Capacity-dropped tokens fall through to the residual
(standard GShard semantics); serving paths can raise capacity_factor for
dropless behaviour.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import _dense_init

Params = Dict[str, Any]


def _hint(x, axes):
    """Best-effort sharding constraint; "DP" slots try ("pod","data") then
    "data"; silently no-op outside a mesh (CPU unit tests)."""
    from jax.sharding import PartitionSpec as P

    for dp in (("pod", "data"), "data"):
        spec = tuple(dp if a == "DP" else a for a in axes)
        try:
            return jax.lax.with_sharding_constraint(x, P(*spec))
        except Exception:
            continue
    return x


def moe_init(key, cfg: ArchConfig, dtype) -> Params:
    m = cfg.moe
    d, f = cfg.d_model, m.d_ff_expert
    ks = jax.random.split(key, 7)
    p = {
        "router": _dense_init(ks[0], (d, m.n_experts), d, jnp.float32),
        "w_in": _dense_init(ks[1], (m.n_experts, d, f), d, dtype),
        "w_gate": _dense_init(ks[2], (m.n_experts, d, f), d, dtype),
        "w_out": _dense_init(ks[3], (m.n_experts, f, d), f, dtype),
    }
    if m.n_shared:
        fs = f * m.n_shared
        p["shared_in"] = _dense_init(ks[4], (d, fs), d, dtype)
        p["shared_gate"] = _dense_init(ks[5], (d, fs), d, dtype)
        p["shared_out"] = _dense_init(ks[6], (fs, d), fs, dtype)
    return p


def _top_k_gating(logits: jax.Array, k: int, renorm: bool) -> Tuple[jax.Array, jax.Array]:
    """logits [N, E] -> (weights [N, k], indices [N, k])."""
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, k)
    if renorm:
        weights = weights / jnp.maximum(jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    return weights, idx


def moe_apply(p: Params, cfg: ArchConfig, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: [B, T, D] -> (y, aux_loss)."""
    m = cfg.moe
    b, t, d = x.shape
    n = b * t
    e, k = m.n_experts, m.top_k
    cap = max(int(m.capacity_factor * k * n / e), 1)

    xf = x.reshape(n, d)
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"])
    weights, idx = _top_k_gating(logits, k, m.router_renorm)          # [N,k]

    # position of each (token, choice) within its expert's capacity buffer
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)                  # [N,k,E]
    flat_oh = onehot.reshape(n * k, e)
    pos = (jnp.cumsum(flat_oh, axis=0) - flat_oh)                     # [N*k,E]
    pos = jnp.sum(pos.reshape(n, k, e) * onehot, axis=-1)             # [N,k]
    keep = (pos < cap).astype(x.dtype)                                # [N,k]

    # ---- scatter dispatch: [E, C, D] expert inputs
    # capacity dim on the DP axes, expert-FFN hidden on "model"; the expert
    # dim stays UNSHARDED at the scatter (a data-dependent scatter across a
    # sharded dim forces GSPMD to fully replicate: +177 GB/device measured
    # on deepseek). EP weights are all-gathered at use instead; a shard_map
    # all-to-all dispatch is the recorded follow-up (EXPERIMENTS.md §Perf).
    e_ax = None
    f_ax = "model"
    fe = idx.reshape(n * k)                                            # expert id
    fp = jnp.minimum(pos.reshape(n * k), cap - 1)                      # slot
    fk = keep.reshape(n * k)
    src = _hint(jnp.repeat(xf, k, axis=0) * fk[:, None], ("DP", None))  # [N*k, D]
    xe = jnp.zeros((e, cap, d), x.dtype).at[fe, fp].add(src)
    xe = _hint(xe, (e_ax, "DP", None))

    # ---- expert FFNs (swiglu)
    h = jnp.einsum("ecd,edf->ecf", xe, p["w_in"], preferred_element_type=jnp.float32)
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"], preferred_element_type=jnp.float32)
    h = _hint(h, (e_ax, "DP", f_ax))
    g = _hint(g, (e_ax, "DP", f_ax))
    h = (jax.nn.silu(g) * h).astype(x.dtype)
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_out"], preferred_element_type=jnp.float32).astype(x.dtype)
    ye = _hint(ye, (e_ax, "DP", None))

    # ---- gather combine
    back = _hint(ye[fe, fp] * fk[:, None], ("DP", None))               # [N*k, D]
    back = back.reshape(n, k, d) * weights[..., None].astype(x.dtype)
    y = jnp.sum(back, axis=1)

    if m.n_shared:
        hs = jnp.einsum("nd,df->nf", xf, p["shared_in"], preferred_element_type=jnp.float32)
        gs = jnp.einsum("nd,df->nf", xf, p["shared_gate"], preferred_element_type=jnp.float32)
        hs = (jax.nn.silu(gs) * hs).astype(x.dtype)
        y = y + jnp.einsum("nf,fd->nd", hs, p["shared_out"],
                           preferred_element_type=jnp.float32).astype(x.dtype)

    # load-balancing aux loss (Switch/GShard form)
    me = jnp.mean(jax.nn.softmax(logits, axis=-1), axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)
    return y.reshape(b, t, d), aux

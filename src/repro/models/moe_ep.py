"""Expert-parallel MoE via shard_map (the EXPERIMENTS §Perf 6b follow-up).

The in-model scatter dispatch keeps the expert dim unsharded (a
data-dependent scatter across a sharded dim makes GSPMD replicate), paying
an expert-weight all-gather per layer instead. This module provides the true
EP execution: each "model"-axis shard OWNS n_experts/ep experts, tokens are
model-replicated per data shard, every shard routes its tokens to its LOCAL
experts only, and one psum over "model" combines the outputs.

Collective cost per layer: psum of [N_tokens, D] activations
vs the scatter design's all-gather of the layer's expert weights — EP wins
when expert params/layer exceed the token bytes (grok-1: 9.7 GB weights vs
~4 GB bf16 tokens at train_4k => ~2.4x less collective traffic).

Semantics note: capacity is enforced per (data-shard, expert) rather than
globally, so token drops can differ from the reference under saturation; in
the no-drop regime (capacity_factor high enough) outputs are identical —
asserted by tests/test_sharding.py::test_moe_ep_matches_reference.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig

Params = Dict[str, Any]


def _shard_map(body, mesh, in_specs, out_specs):
    """Version-portable shard_map: jax>=0.6 exposes jax.shard_map with
    check_vma; older releases ship jax.experimental.shard_map with check_rep.
    Replication checking is disabled on both paths (the psum combine is the
    only collective and its spec is explicit)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def _local_moe(router, w_in, w_gate, w_out, xf, *, cfg: ArchConfig,
               e_local: int, axis: str):
    """Per-shard body: route local tokens to LOCAL experts, psum the combine.

    xf: [N_loc, D] (this data-shard's tokens, replicated over `axis`);
    w_*: [E_loc, ...] (this shard's experts). Output [N_loc, D], combined.
    """
    m = cfg.moe
    n, d = xf.shape
    e, k = m.n_experts, m.top_k
    shard = jax.lax.axis_index(axis)
    e0 = shard * e_local

    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, k)
    if m.router_renorm:
        weights = weights / jnp.maximum(jnp.sum(weights, -1, keepdims=True), 1e-9)

    # keep only choices routed to THIS shard's experts
    local = (idx >= e0) & (idx < e0 + e_local)              # [N, k]
    lidx = jnp.where(local, idx - e0, 0)

    cap = max(int(m.capacity_factor * k * n / e), 1)
    onehot = jax.nn.one_hot(lidx, e_local, dtype=jnp.int32) * local[..., None]
    flat = onehot.reshape(n * k, e_local)
    pos = jnp.cumsum(flat, axis=0) - flat
    pos = jnp.sum(pos.reshape(n, k, e_local) * onehot, axis=-1)
    keep = (local & (pos < cap)).astype(xf.dtype)

    fe = lidx.reshape(n * k)
    fp = jnp.minimum(pos.reshape(n * k), cap - 1)
    fk = keep.reshape(n * k)
    src = jnp.repeat(xf, k, axis=0) * fk[:, None]
    xe = jnp.zeros((e_local, cap, d), xf.dtype).at[fe, fp].add(src)

    h = jnp.einsum("ecd,edf->ecf", xe, w_in, preferred_element_type=jnp.float32)
    g = jnp.einsum("ecd,edf->ecf", xe, w_gate, preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * h).astype(xf.dtype)
    ye = jnp.einsum("ecf,efd->ecd", h, w_out,
                    preferred_element_type=jnp.float32).astype(xf.dtype)

    back = ye[fe, fp] * fk[:, None]
    back = back.reshape(n, k, d) * weights[..., None].astype(xf.dtype)
    y = jnp.sum(back, axis=1)
    # ONE collective: combine expert outputs across the expert-parallel axis
    return jax.lax.psum(y, axis)


def moe_apply_ep(p: Params, cfg: ArchConfig, x: jax.Array, mesh: Mesh,
                 axis: str = "model") -> jax.Array:
    """Routed-expert output under true expert parallelism (shared experts and
    the aux loss are computed by the caller / standard path)."""
    m = cfg.moe
    ep = mesh.shape[axis]
    assert m.n_experts % ep == 0, (m.n_experts, ep)
    e_local = m.n_experts // ep
    b, t, d = x.shape
    dp = "data" if "data" in mesh.axis_names else mesh.axis_names[0]

    body = functools.partial(_local_moe, cfg=cfg, e_local=e_local, axis=axis)
    fn = _shard_map(
        body, mesh,
        in_specs=(P(), P(axis, None, None), P(axis, None, None),
                  P(axis, None, None), P(dp, None)),
        out_specs=P(dp, None),
    )
    y = fn(p["router"], p["w_in"], p["w_gate"], p["w_out"], x.reshape(b * t, d))
    return y.reshape(b, t, d)

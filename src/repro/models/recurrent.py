"""Griffin / RecurrentGemma recurrent block: conv1d + RG-LRU mixer.

Block structure (Griffin):
    x -> [linear -> conv1d(w=4) -> RG-LRU] * gelu(linear gate) -> out proj

The RG-LRU recurrence runs through the Pallas kernel on TPU (VMEM-resident
state); the jnp reference path elsewhere. Decode carries (conv tail, h) as
an O(1) state cache.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops as kops
from .layers import _dense_init

Params = Dict[str, Any]

CONV_W = 4


def rglru_block_init(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    dr = d  # recurrent width = d_model
    ks = jax.random.split(key, 7)
    return {
        "w_x": _dense_init(ks[0], (d, dr), d, dtype),
        "w_gate": _dense_init(ks[1], (d, dr), d, dtype),
        "conv_w": _dense_init(ks[2], (CONV_W, dr), CONV_W, dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "w_r": _dense_init(ks[3], (dr, dr), dr, dtype),
        "w_i": _dense_init(ks[4], (dr, dr), dr, dtype),
        # init so that a ~ U[0.9, 0.999]-ish decay band (Griffin appendix)
        "log_lambda": jnp.asarray(
            jnp.log(jnp.expm1(jnp.linspace(0.3, 0.8, dr))), jnp.float32),
        "w_out": _dense_init(ks[5], (dr, d), dr, dtype),
    }


def _conv1d(x: jax.Array, w: jax.Array, b: jax.Array, tail: jax.Array | None):
    """Causal depthwise conv, width CONV_W. tail: [B, CONV_W-1, D] history."""
    bsz, t, d = x.shape
    if tail is None:
        tail = jnp.zeros((bsz, CONV_W - 1, d), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)
    out = sum(
        xp[:, i : i + t] * w[i][None, None, :] for i in range(CONV_W)
    ) + b[None, None, :]
    new_tail = xp[:, -(CONV_W - 1):]
    return out.astype(x.dtype), new_tail


def rglru_block_apply(
    p: Params, cfg: ArchConfig, x: jax.Array,
    state: Dict[str, jax.Array] | None = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: [B, T, D]. state: {"h": [B,D], "conv": [B,3,D]} or None (train)."""
    xr = jnp.einsum("btd,de->bte", x, p["w_x"], preferred_element_type=jnp.float32).astype(x.dtype)
    gate = jnp.einsum("btd,de->bte", x, p["w_gate"], preferred_element_type=jnp.float32).astype(x.dtype)

    tail = state["conv"] if state is not None else None
    xc, new_tail = _conv1d(xr, p["conv_w"], p["conv_b"], tail)

    r = jnp.einsum("bte,ef->btf", xc, p["w_r"], preferred_element_type=jnp.float32).astype(x.dtype)
    i = jnp.einsum("bte,ef->btf", xc, p["w_i"], preferred_element_type=jnp.float32).astype(x.dtype)
    # recurrence is elementwise over features: keep the f32 gate tensors
    # feature-sharded on "model" (time cannot shard; batch stays on DP)
    from .moe import _hint
    xc = _hint(xc, ("DP", None, "model"))
    r = _hint(r, ("DP", None, "model"))
    i = _hint(i, ("DP", None, "model"))
    h0 = state["h"] if state is not None else None
    y, h_last = kops.rglru_scan(xc, r, i, p["log_lambda"], h0=h0,
                                use_pallas=False)  # jnp path; Pallas on TPU
    y = y * jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bte,ed->btd", y, p["w_out"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out, {"h": h_last, "conv": new_tail}


def rglru_make_state(cfg: ArchConfig, batch: int, dtype) -> Dict[str, jax.Array]:
    d = cfg.d_model
    return {
        "h": jnp.zeros((batch, d), jnp.float32),
        "conv": jnp.zeros((batch, CONV_W - 1, d), dtype),
    }

"""Memory-bounded sequential scans.

jax.lax.scan saves every step's carry for the backward pass: a recurrence
over T=4096 steps with an O(B*D)+ state would checkpoint T copies — the
dominant memory term for the recurrent architectures (xLSTM's matrix memory
is B*H*dh^2 *per step*). `chunked_scan` nests two scans: the outer one saves
only chunk-boundary carries and each chunk body is rematerialized in the
backward pass (sqrt-style checkpointing), bounding saved state to
T/chunk * |state| while keeping per-step semantics bit-exact.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax


def chunked_scan(
    body: Callable[[Any, Any], Tuple[Any, Any]],
    init: Any,
    xs: Any,
    chunk: int = 256,
    remat: bool = True,
):
    """Drop-in lax.scan with chunk-boundary-only checkpointing.

    body(carry, x_t) -> (carry, y_t), scanned over leading axis T of `xs`.
    T must be divisible by `chunk` (callers pad or pick a divisor).
    """
    leaves = jax.tree.leaves(xs)
    t = leaves[0].shape[0]
    if t <= chunk:
        return jax.lax.scan(body, init, xs)
    assert t % chunk == 0, (t, chunk)
    n_chunks = t // chunk

    xs_chunked = jax.tree.map(
        lambda a: a.reshape((n_chunks, chunk) + a.shape[1:]), xs)

    def chunk_body(carry, x_chunk):
        return jax.lax.scan(body, carry, x_chunk)

    if remat:
        chunk_body = jax.checkpoint(chunk_body, prevent_cse=False)

    carry, ys = jax.lax.scan(chunk_body, init, xs_chunked)
    ys = jax.tree.map(lambda a: a.reshape((t,) + a.shape[2:]), ys)
    return carry, ys


def pick_chunk(t: int, target: int = 256) -> int:
    """Largest divisor of t that is <= target (fallback: t)."""
    for c in range(min(target, t), 0, -1):
        if t % c == 0:
            return c
    return t

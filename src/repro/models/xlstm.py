"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory with exponential gating and stabilizer state).

mLSTM block (xLSTM paper, Fig. 9 left): pre-norm -> up-proj (factor 2) ->
{q, k, v from conv'd path, i/f/o gates} -> mLSTM cell -> down-proj.
sLSTM block: pre-norm -> sLSTM cell (per-head) -> gated FFN (factor 4/3).

Both recurrences are linear in T (sub-quadratic: xlstm runs long_500k); decode
carries O(1) state per layer:
  mLSTM: C [B,H,dk,dv], n [B,H,dk], m [B,H]
  sLSTM: c,n,h [B,D], m [B,D]
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from .layers import _dense_init, rmsnorm, rmsnorm_init
from .scan_utils import chunked_scan, pick_chunk

Params = Dict[str, Any]

PF_MLSTM = 2.0
PF_SLSTM = 4.0 / 3.0


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    di = int(PF_MLSTM * d)
    h = cfg.n_heads
    dh = di // h
    ks = jax.random.split(key, 8)
    return {
        "w_up": _dense_init(ks[0], (d, di), d, dtype),
        "w_qkv": _dense_init(ks[1], (di, 3, h, dh), di, dtype),
        "w_ifo": _dense_init(ks[2], (di, 3, h), di, jnp.float32),
        "b_if": jnp.stack([jnp.zeros((h,)), 3.0 * jnp.ones((h,))]),  # f-gate bias >0
        "out_norm": rmsnorm_init(di, dtype),
        "w_down": _dense_init(ks[3], (di, d), di, dtype),
    }


def _mlstm_cell(q, k, v, i_pre, f_pre, state):
    """Sequential mLSTM with exponential gating + stabilizer m.

    q,k,v: [B,T,H,Dh]; i_pre,f_pre: [B,T,H]; state: (C, n, m) or None.
    Returns (h_out [B,T,H,Dh], state')."""
    b, t, h, dh = q.shape
    scale = 1.0 / jnp.sqrt(dh)
    if state is None:
        c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, h, dh), jnp.float32)
        m0 = jnp.full((b, h), -jnp.inf, jnp.float32)
    else:
        c0, n0, m0 = state

    def step(carry, xs):
        c, n, m = carry
        q_t, k_t, v_t, i_t, f_t = xs                 # [B,H,Dh], ..., [B,H]
        m_new = jnp.maximum(f_t + m, i_t)            # log-space stabilizer
        i_eff = jnp.exp(i_t - m_new)
        f_eff = jnp.exp(f_t + m - m_new)
        k_s = k_t * scale
        c = f_eff[..., None, None] * c + i_eff[..., None, None] * (
            k_s[..., :, None] * v_t[..., None, :])
        n = f_eff[..., None] * n + i_eff[..., None] * k_s
        num = jnp.einsum("bhkv,bhk->bhv", c, q_t)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, q_t))
        h_t = num / jnp.maximum(den, 1.0)[..., None]
        return (c, n, m_new), h_t

    xs = (
        q.swapaxes(0, 1).astype(jnp.float32),
        k.swapaxes(0, 1).astype(jnp.float32),
        v.swapaxes(0, 1).astype(jnp.float32),
        i_pre.swapaxes(0, 1).astype(jnp.float32),
        f_pre.swapaxes(0, 1).astype(jnp.float32),
    )
    (c, n, m), hs = chunked_scan(step, (c0, n0, m0), xs, chunk=pick_chunk(t))
    return hs.swapaxes(0, 1), (c, n, m)



def _mlstm_chunkwise(q, k, v, i_pre, f_pre, state, chunk: int = 256):
    """Chunkwise-parallel mLSTM: same semantics as _mlstm_cell, but the
    matrix state C is touched once per CHUNK instead of once per step.

    The sequential form reads+writes C [B,H,dh,dh] every timestep — measured
    ~198 GB/partition HBM traffic on xlstm-125m train_4k (6.9% roofline).
    Derivation: with F_t = cumsum(f), D_s = i_s - F_s, M_t = cummax(D),
    g_t = max(m_0, M_t), the stabilizer is m_t = F_t + g_t and

        h_t = [ e^{m0-g_t} (q_t C_0) + sum_{s<=t} e^{D_s-g_t} (q_t.k_s) v_s ]
              / max(| e^{m0-g_t} (q_t n_0) + sum_s e^{D_s-g_t} (q_t.k_s) |, 1)

    — the intra-chunk sum is an L x L masked matmul (parallel) and the carry
    (C, n, m) updates once per chunk with g_L. All weights e^{D_s-g_t} <= 1.
    """
    b, t, h, dh = q.shape
    scale = 1.0 / jnp.sqrt(dh)
    c0, n0, m0 = state
    L = pick_chunk(t, chunk)
    nc = t // L

    def feat_chunks(a):        # [B,T,H,dh] -> [nc,B,H,L,dh]
        a = a.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(b, h, nc, L, dh)
        return jnp.moveaxis(a, 2, 0)

    def gate_chunks(a):        # [B,T,H] -> [nc,B,H,L]
        a = a.astype(jnp.float32).transpose(0, 2, 1).reshape(b, h, nc, L)
        return jnp.moveaxis(a, 2, 0)

    qs, ks, vs = feat_chunks(q) , feat_chunks(k) * scale, feat_chunks(v)
    is_, fs = gate_chunks(i_pre), gate_chunks(f_pre)
    causal = jnp.tril(jnp.ones((L, L), jnp.float32))

    def chunk_body(carry, xs):
        c, n, m_in = carry
        qc, kc, vc, ic, fc = xs                    # [B,H,L,dh] / [B,H,L]
        F = jnp.cumsum(fc, axis=-1)                # [B,H,L]
        D = ic - F
        M = jax.lax.cummax(D, axis=2)
        g = jnp.maximum(m_in[..., None], M)        # [B,H,L]
        alpha = jnp.exp(m_in[..., None] - g)       # inter coefficient

        qk = jnp.einsum("bhld,bhsd->bhls", qc, kc)             # [B,H,L,L]
        w = jnp.exp(D[:, :, None, :] - g[..., None]) * causal  # e^{D_s-g_t}
        qkw = qk * w
        intra = jnp.einsum("bhls,bhsd->bhld", qkw, vc)
        num = alpha[..., None] * jnp.einsum("bhkv,bhlk->bhlv", c, qc) + intra
        den = alpha * jnp.einsum("bhk,bhlk->bhl", n, qc) + jnp.sum(qkw, axis=-1)
        h_out = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]

        g_l = g[..., -1]                                        # [B,H]
        decay = jnp.exp(D - g_l[..., None])[..., None]          # [B,H,L,1]
        beta = jnp.exp(m_in - g_l)
        c_new = beta[..., None, None] * c + jnp.einsum("bhsk,bhsv->bhkv",
                                                       kc * decay, vc)
        n_new = beta[..., None] * n + jnp.sum(kc * decay, axis=2)
        m_new = F[..., -1] + g_l
        return (c_new, n_new, m_new), h_out

    body = jax.checkpoint(chunk_body, prevent_cse=False)
    (c, n, m), hs = jax.lax.scan(body, (c0, n0, m0), (qs, ks, vs, is_, fs))
    hs = jnp.moveaxis(hs, 0, 2).reshape(b, h, t, dh).transpose(0, 2, 1, 3)
    return hs, (c, n, m)


def mlstm_apply(p: Params, cfg: ArchConfig, x: jax.Array, state=None):
    b, t, d = x.shape
    h = cfg.n_heads
    # store/AR in the activation dtype: the TP all-reduce after the di
    # contraction otherwise moves f32 (xlstm prefill_32k was collective-bound
    # at 4.6 GB/chip of f32 partials — EXPERIMENTS.md §Perf)
    up = jnp.einsum("btd,de->bte", x, p["w_up"],
                    preferred_element_type=x.dtype)
    qkv = jnp.einsum("bte,eshk->btshk", up, p["w_qkv"],
                     preferred_element_type=x.dtype)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    ifo = jnp.einsum("bte,esh->btsh", up.astype(jnp.float32), p["w_ifo"])
    i_pre = ifo[:, :, 0] + p["b_if"][0][None, None]
    f_pre = jax.nn.log_sigmoid(ifo[:, :, 1] + p["b_if"][1][None, None])
    o_gate = jax.nn.sigmoid(ifo[:, :, 2])
    if t >= 32:
        init = state if state is not None else (
            jnp.zeros((b, h, q.shape[-1], q.shape[-1]), jnp.float32),
            jnp.zeros((b, h, q.shape[-1]), jnp.float32),
            jnp.full((b, h), -jnp.inf, jnp.float32))
        hs, new_state = _mlstm_chunkwise(q, k, v, i_pre, f_pre, init)
    else:
        hs, new_state = _mlstm_cell(q, k, v, i_pre, f_pre, state)
    hs = hs * o_gate[..., None]
    hs = hs.reshape(b, t, -1).astype(x.dtype)
    hs = rmsnorm(p["out_norm"], hs, cfg.norm_eps)
    return jnp.einsum("bte,ed->btd", hs, p["w_down"],
                      preferred_element_type=x.dtype), new_state


def mlstm_make_state(cfg: ArchConfig, batch: int):
    h = cfg.n_heads
    dh = int(PF_MLSTM * cfg.d_model) // h
    return (
        jnp.zeros((batch, h, dh, dh), jnp.float32),
        jnp.zeros((batch, h, dh), jnp.float32),
        jnp.full((batch, h), -jnp.inf, jnp.float32),
    )


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    df = int(PF_SLSTM * d)
    ks = jax.random.split(key, 6)
    return {
        "w_gates": _dense_init(ks[0], (d, 4, d), d, jnp.float32),   # z,i,f,o
        "r_gates": _dense_init(ks[1], (d, 4, d), d, jnp.float32),   # recurrent
        "b_gates": jnp.zeros((4, d)).at[2].set(3.0),                # f bias > 0
        "ffn_in": _dense_init(ks[2], (d, df), d, dtype),
        "ffn_gate": _dense_init(ks[3], (d, df), d, dtype),
        "ffn_out": _dense_init(ks[4], (df, d), df, dtype),
        "ffn_norm": rmsnorm_init(d, dtype),
    }


def slstm_apply(p: Params, cfg: ArchConfig, x: jax.Array, state=None):
    b, t, d = x.shape
    wx = jnp.einsum("btd,dge->btge", x, p["w_gates"].astype(x.dtype),
                    preferred_element_type=x.dtype).astype(jnp.float32)
    if state is None:
        h0 = jnp.zeros((b, d), jnp.float32)
        c0 = jnp.zeros((b, d), jnp.float32)
        n0 = jnp.ones((b, d), jnp.float32)
        m0 = jnp.zeros((b, d), jnp.float32)
    else:
        h0, c0, n0, m0 = state

    r_g = p["r_gates"]
    b_g = p["b_gates"]

    def step(carry, wx_t):
        h, c, n, m = carry
        pre = wx_t + jnp.einsum("bd,dge->bge", h, r_g) + b_g[None]
        z = jnp.tanh(pre[:, 0])
        i_t = pre[:, 1]
        f_t = jax.nn.log_sigmoid(pre[:, 2])
        o = jax.nn.sigmoid(pre[:, 3])
        m_new = jnp.maximum(f_t + m, i_t)
        i_eff = jnp.exp(i_t - m_new)
        f_eff = jnp.exp(f_t + m - m_new)
        c = f_eff * c + i_eff * z
        n = f_eff * n + i_eff
        h = o * c / jnp.maximum(n, 1e-6)
        return (h, c, n, m_new), h

    (h, c, n, m), hs = chunked_scan(step, (h0, c0, n0, m0), wx.swapaxes(0, 1),
                                    chunk=pick_chunk(t))
    y = hs.swapaxes(0, 1).astype(x.dtype)
    # gated FFN (PF 4/3)
    yn = rmsnorm(p["ffn_norm"], y, cfg.norm_eps)
    hi = jnp.einsum("btd,df->btf", yn, p["ffn_in"], preferred_element_type=x.dtype)
    gi = jnp.einsum("btd,df->btf", yn, p["ffn_gate"], preferred_element_type=x.dtype)
    hi = (jax.nn.gelu(gi.astype(jnp.float32)) * hi.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("btf,fd->btd", hi, p["ffn_out"],
                     preferred_element_type=x.dtype)
    return y + out, (h, c, n, m)


def slstm_make_state(cfg: ArchConfig, batch: int):
    d = cfg.d_model
    return (
        jnp.zeros((batch, d), jnp.float32),
        jnp.zeros((batch, d), jnp.float32),
        jnp.ones((batch, d), jnp.float32),
        jnp.zeros((batch, d), jnp.float32),
    )

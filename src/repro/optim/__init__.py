from . import compression  # noqa: F401
from .adamw import AdamWConfig, clip_by_global_norm, cosine_schedule, global_norm, init, update  # noqa: F401

"""Sharding-friendly AdamW with configurable state dtypes.

Pure-functional: states are pytrees shaped like params (so they inherit the
params' sharding specs 1:1 — 2-D sharded at 314 B scale, see DESIGN.md §6).
`state_dtype="bfloat16"` halves optimizer HBM (used by grok-1-314b to fit a
256-chip pod); the update math always runs in f32.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"


def init(params: Params, cfg: AdamWConfig) -> Dict[str, Any]:
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads: Params, max_norm: float) -> Tuple[Params, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def update(
    grads: Params, state: Dict[str, Any], params: Params,
    cfg: AdamWConfig, lr: jax.Array | float | None = None,
) -> Tuple[Params, Dict[str, Any], Dict[str, jax.Array]]:
    lr = cfg.lr if lr is None else lr
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        step = (mf / b1c) / (jnp.sqrt(vf / b2c) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step + cfg.weight_decay * pf)
        return pf.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    # flatten to avoid confusing structural tuples with the (p, m, v) triple
    g_leaves, treedef = jax.tree.flatten(grads)
    m_leaves = treedef.flatten_up_to(state["m"])
    v_leaves = treedef.flatten_up_to(state["v"])
    p_leaves = treedef.flatten_up_to(params)
    triples = [upd(g, m, v, p) for g, m, v, p in zip(g_leaves, m_leaves, v_leaves, p_leaves)]
    new_params = jax.tree.unflatten(treedef, [t[0] for t in triples])
    new_m = jax.tree.unflatten(treedef, [t[1] for t in triples])
    new_v = jax.tree.unflatten(treedef, [t[2] for t in triples])
    return new_params, {"m": new_m, "v": new_v, "count": count}, {"grad_norm": gnorm}


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr

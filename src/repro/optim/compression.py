"""Int8 gradient compression with error feedback, for the cross-pod axis.

At 1000+ node scale the pod-level DP all-reduce crosses the slow inter-pod
links; int8 quantization cuts those bytes 4x (bf16) with error-feedback
residuals keeping the update unbiased over time.

Mechanism (per leaf): g' = g + residual; q = round(g' / s) clipped to int8
with s = max|g'| / 127; decompressed dq = q * s; residual' = g' - dq. Under
pjit the quantize/dequantize pair brackets the gradient reduction so the
collective moves int8; here we implement the numerics (tested) and mark the
shard_map hook point.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any


def init_residuals(grads_like: Params) -> Params:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compress(g: jax.Array, residual: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """-> (q int8, scale f32 scalar, new_residual)."""
    gf = g.astype(jnp.float32) + residual
    scale = jnp.max(jnp.abs(gf)) / 127.0
    safe = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(gf / safe), -127, 127).astype(jnp.int8)
    dq = q.astype(jnp.float32) * safe
    return q, scale, gf - dq


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * jnp.maximum(scale, 1e-20)


def compress_tree(grads: Params, residuals: Params) -> Tuple[Params, Params]:
    """Quantize->dequantize every leaf with error feedback.

    Returns (grads_after_qdq, new_residuals). In deployment the int8 tensors
    are what cross the 'pod' axis (jax.lax.psum inside shard_map); the qdq
    pair here reproduces the numerics bit-exactly for testing and for
    single-pod simulation.
    """
    g_leaves, treedef = jax.tree.flatten(grads)
    r_leaves = treedef.flatten_up_to(residuals)
    triples = [compress(g, r) for g, r in zip(g_leaves, r_leaves)]
    dq = jax.tree.unflatten(
        treedef, [decompress(q, s).astype(jnp.float32) for q, s, _ in triples])
    new_res = jax.tree.unflatten(treedef, [t[2] for t in triples])
    return dq, new_res

from .elastic import plan_mesh, restore_on_mesh  # noqa: F401
from .supervisor import (  # noqa: F401
    SimulatedHostFailure,
    StragglerDetector,
    Supervisor,
    SupervisorConfig,
)

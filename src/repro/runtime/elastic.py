"""Elastic scaling: recompute the mesh for a changed device count and
re-place a checkpointed state onto it.

On a real fleet this runs in the coordinator after a slice change; here the
planner + resharding restore are exercised by tests with a forced multi-device
host platform.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh

from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig
from repro.launch.mesh import elastic_mesh_shape
from repro.sharding import state_specs, to_named


def plan_mesh(n_devices: int, prefer_model: int = 16) -> Mesh:
    shape = elastic_mesh_shape(n_devices, prefer_model)
    return jax.make_mesh(shape, ("data", "model"))


def restore_on_mesh(
    ckpt: CheckpointManager, step: int, abstract_state: Any,
    cfg: ArchConfig, mesh: Mesh,
) -> Any:
    """Re-shard a checkpoint onto a (possibly different) mesh."""
    specs = state_specs(cfg, abstract_state, mesh)
    shardings = to_named(mesh, specs)
    return ckpt.restore(step, abstract_state, shardings=shardings)

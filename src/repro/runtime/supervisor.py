"""Training supervisor: the fault-tolerance control loop.

Responsibilities (all covered by tests/test_runtime.py):
  * periodic async checkpointing
  * NaN sentinel: a non-finite loss triggers restore-from-last-checkpoint
    and skips the poisoned data window
  * simulated host failure (exceptions from the step fn): restore + resume;
    restart-exact data means the recovered run is bit-identical to an
    uninterrupted one
  * straggler detection: per-step wall-time EWMA; hosts slower than
    `straggler_factor` x the median are flagged (on real fleets this feeds
    the re-slicing controller; here it is surfaced in metrics)

Fault seeding convention: chaos tests build their `fault_hook` callables
via `repro.cim.faults.host_failure_hook`, which seeds from the same
REPRO_CIM_FAULT_SEED env var as the serving-side FaultModel — one seed
drives both training and serving fault campaigns deterministically.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


class SimulatedHostFailure(RuntimeError):
    """Raised by fault-injection hooks to emulate a node loss."""


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 8
    straggler_factor: float = 2.0
    ewma_alpha: float = 0.2


class StragglerDetector:
    """Per-host step-time EWMA vs the fleet median."""

    def __init__(self, n_hosts: int, cfg: SupervisorConfig):
        self.cfg = cfg
        self.ewma = np.zeros(n_hosts)
        self.seen = np.zeros(n_hosts, dtype=bool)

    def update(self, host_times: np.ndarray) -> List[int]:
        a = self.cfg.ewma_alpha
        self.ewma = np.where(self.seen, (1 - a) * self.ewma + a * host_times, host_times)
        self.seen[:] = True
        med = float(np.median(self.ewma))
        return [int(i) for i in np.nonzero(self.ewma > self.cfg.straggler_factor * med)[0]]


class Supervisor:
    def __init__(
        self,
        train_step: Callable,
        make_batch: Callable[[int], Any],
        ckpt: CheckpointManager,
        cfg: SupervisorConfig = SupervisorConfig(),
        fault_hook: Optional[Callable[[int], None]] = None,
        n_hosts: int = 1,
    ):
        self.train_step = train_step
        self.make_batch = make_batch
        self.ckpt = ckpt
        self.cfg = cfg
        self.fault_hook = fault_hook
        self.straggler = StragglerDetector(n_hosts, cfg)
        self.events: List[Dict[str, Any]] = []

    def _restore(self, state):
        step = self.ckpt.latest_step()
        if step is None:
            return state, 0
        restored = self.ckpt.restore(step, state)
        return restored, int(step)

    def run(self, state, n_steps: int):
        """Run to n_steps with restart-on-failure. Returns (state, metrics)."""
        restarts = 0
        step = int(jax.device_get(state["step"]))
        last_metrics: Dict[str, Any] = {}
        while step < n_steps:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                batch = self.make_batch(step)
                t0 = time.monotonic()
                state, metrics = self.train_step(state, batch)
                loss = float(jax.device_get(metrics["loss"]))
                dt = time.monotonic() - t0
                stragglers = self.straggler.update(np.array([dt]))
                if not np.isfinite(loss):
                    raise FloatingPointError(f"non-finite loss at step {step}")
                step += 1
                last_metrics = {**metrics, "stragglers": stragglers}
                if step % self.cfg.ckpt_every == 0:
                    self.ckpt.save(step, state, blocking=False)
            except (SimulatedHostFailure, FloatingPointError) as e:
                restarts += 1
                self.events.append({"step": step, "error": repr(e), "restart": restarts})
                if restarts > self.cfg.max_restarts:
                    raise RuntimeError(f"exceeded max_restarts: {e}") from e
                self.ckpt.wait()
                state, step = self._restore(state)
        self.ckpt.wait()
        return state, last_metrics

from .rules import batch_specs, cache_specs, param_specs, state_specs, to_named  # noqa: F401

"""Logical sharding rules: param/cache/batch pytrees -> PartitionSpec trees.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.

Conventions (DESIGN.md §6):
  * params are 2-D sharded: FSDP dim -> "data", tensor dim -> "model"
    (256-way within a pod); params are replicated across "pod" (optimizer
    states inherit param specs 1:1).
  * attention head dims: shard the head axis on "model" when divisible by the
    axis size, else the head_dim axis (qwen's 40 heads, MQA's single kv head),
    else replicate.
  * MoE experts: expert dim -> "model" when divisible ("ep"), else TP within
    the expert FFN ("tp": grok's 8 experts on a 16-wide axis).
  * caches: batch -> dp axes when divisible (long_500k's batch=1 falls back
    to replicated batch + "model"-sharded feature dims).

jax requires every sharded dim to divide exactly, so every rule is checked
against the actual leaf shape and mesh axis sizes (`_fit`) and non-divisible
axes are dropped dim-by-dim — the rule set degrades gracefully on any mesh
(production 16x16 or the tests' tiny meshes).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


def _dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def _axes_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def _fit(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop (dim-by-dim) any mesh axis that does not divide the dim size."""
    fitted = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            fitted.append(None)
        elif dim % _axes_size(mesh, entry) == 0:
            fitted.append(entry)
        else:
            fitted.append(None)
    return P(*fitted)


def _head_axis(cfg: ArchConfig, n_heads: int, mesh: Mesh):
    """('model' on heads) | ('model' on head_dim) | replicated."""
    m = mesh.shape["model"]
    if n_heads % m == 0:
        return "heads"
    if cfg.head_dim % m == 0:
        return "head_dim"
    return "none"


def _rule(path: str, ndim: int, cfg: ArchConfig, mesh: Mesh) -> P:
    """Base (unstacked) PartitionSpec for a param leaf."""
    ep = cfg.moe is not None and cfg.expert_sharding == "ep" \
        and cfg.moe.n_experts % mesh.shape["model"] == 0

    def ends(*names):
        return any(path.endswith(n) for n in names)

    q_mode = _head_axis(cfg, cfg.n_heads, mesh)
    kv_mode = _head_axis(cfg, cfg.n_kv_heads, mesh)

    # ---- embeddings / head
    if ends("embed/table"):
        return P("model", "data")
    if ends("lm_head/w"):
        return P("data", "model")

    # ---- attention (GQA + MLA)
    if ends("attn/wq"):
        return {"heads": P("data", "model", None),
                "head_dim": P("data", None, "model"),
                "none": P("data", None, None)}[q_mode]
    if ends("attn/wk", "attn/wv"):
        return {"heads": P("data", "model", None),
                "head_dim": P("data", None, "model"),
                "none": P("data", None, None)}[kv_mode]
    if ends("attn/wo"):
        return {"heads": P("model", None, "data"),
                "head_dim": P(None, "model", "data"),
                "none": P(None, None, "data")}[q_mode]
    if ends("attn/w_kv_a"):
        return P("data", None)
    if ends("attn/w_uk", "attn/w_uv"):
        return {"heads": P(None, "model", None),
                "head_dim": P("model", None, None),
                "none": P(None, None, None)}[q_mode]

    # ---- MoE
    if ends("mlp/router"):
        return P("data", None)
    if ends("mlp/w_in", "mlp/w_gate") and ndim == 3:
        return P("model", "data", None) if ep else P(None, "data", "model")
    if ends("mlp/w_out") and ndim == 3:
        return P("model", None, "data") if ep else P(None, "model", "data")
    if ends("mlp/shared_in", "mlp/shared_gate"):
        return P("data", "model")
    if ends("mlp/shared_out"):
        return P("model", "data")

    # ---- dense MLP
    if ends("mlp/w_in", "mlp/w_gate"):
        return P("data", "model")
    if ends("mlp/w_out"):
        return P("model", "data")

    # ---- RG-LRU block
    if ends("rec/w_x", "rec/w_gate"):
        return P("data", "model")
    if ends("rec/w_r", "rec/w_i"):
        return P("model", None)
    if ends("rec/conv_w"):
        return P(None, "model")
    if ends("rec/w_out"):
        return P("model", "data")

    # ---- xLSTM
    if ends("cell/w_up"):
        return P("data", "model")
    if ends("cell/w_qkv"):
        return P("model", None, None, None)
    if ends("cell/w_ifo"):
        return P("model", None, None)
    if ends("cell/w_down"):
        return P("model", "data")
    if ends("cell/w_gates", "cell/r_gates"):
        return P("data", None, "model")
    if ends("cell/ffn_in", "cell/ffn_gate"):
        return P("data", "model")
    if ends("cell/ffn_out"):
        return P("model", "data")

    # ---- norms, biases, router scalars: replicated
    return P(*([None] * ndim))


def _path_str(key_path) -> str:
    parts = []
    for k in key_path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(cfg: ArchConfig, params: Any, mesh: Mesh) -> Any:
    """PartitionSpec tree matching `params` (works on ShapeDtypeStructs)."""

    def spec_for(key_path, leaf) -> P:
        path = _path_str(key_path)
        stacked = "/groups/" in "/" + path + "/"
        ndim = leaf.ndim - (1 if stacked else 0)
        base = _rule(path, ndim, cfg, mesh)
        if not cfg.tensor_parallel:
            # small-model policy: params replicated across "model" (the DP
            # axes still shard FSDP dims); kills every per-layer TP AR
            base = P(*(None if e == "model" else e for e in tuple(base)))
        if stacked:
            base = P(*((None,) + tuple(base)))
        return _fit(base, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def state_specs(cfg: ArchConfig, state: Any, mesh: Mesh) -> Any:
    """Specs for the full TrainState {"params","opt":{m,v,count},"step",...}."""
    out = {
        "params": param_specs(cfg, state["params"], mesh),
        "opt": {
            "m": param_specs(cfg, state["opt"]["m"], mesh),
            "v": param_specs(cfg, state["opt"]["v"], mesh),
            "count": P(),
        },
        "step": P(),
    }
    if "residuals" in state:
        out["residuals"] = param_specs(cfg, state["residuals"], mesh)
    return out


def cache_specs(cfg: ArchConfig, caches: Any, mesh: Mesh) -> Any:
    """KV/state caches: batch -> dp axes; widest trailing dim -> "model".

    Cache layouts (batch is the first unstacked dim everywhere):
      dense KV   [B, S, Hkv, hd]   -> (dp, None, model-on-heads-or-hd)
      MLA latent [B, S, R]         -> (dp, None, "model")
      ring       [B, W, Hkv, hd]   -> like dense
      states     [B, ...]          -> (dp, None..., "model" on the last dim)
    """
    dp = _dp_axes(mesh)

    def spec_for(key_path, leaf) -> P:
        path = _path_str(key_path)
        stacked = "/groups/" in "/" + path + "/"
        nd = leaf.ndim - (1 if stacked else 0)
        entries: list = [dp] + [None] * (nd - 1)
        if nd >= 2:
            entries[-1] = "model"   # feature dim (hd / latent / state width)
        base: tuple = tuple(entries)
        if stacked:
            base = (None,) + base
        return _fit(P(*base), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, caches)


def batch_specs(cfg: ArchConfig, batch: Any, mesh: Mesh) -> Any:
    dp = _dp_axes(mesh)

    def spec_for(_key_path, leaf) -> P:
        return _fit(P(*((dp,) + (None,) * (leaf.ndim - 1))), leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, batch)


def to_named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )

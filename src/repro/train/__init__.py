from .step import (  # noqa: F401
    adra_sample,
    greedy_sample,
    init_state,
    make_decode_step,
    make_eval_step,
    make_prefill_step,
    make_train_step,
)

"""Train / serve step factories.

TrainState is a plain dict pytree {"params", "opt", "step"} so checkpointing
and sharding-spec derivation stay structural.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim import adamw
from repro.optim import compression as gcomp

TrainState = Dict[str, Any]


def init_state(model: Model, key, opt_cfg: adamw.AdamWConfig,
               compress_grads: bool = False) -> TrainState:
    params = model.init(key)
    state: TrainState = {
        "params": params,
        "opt": adamw.init(params, opt_cfg),
        "step": jnp.zeros((), jnp.int32),
    }
    if compress_grads:
        state["residuals"] = gcomp.init_residuals(params)
    return state


def make_train_step(
    model: Model,
    opt_cfg: adamw.AdamWConfig,
    lr_schedule: Optional[Callable] = None,
    compress_grads: bool = False,
    microbatches: Optional[int] = None,
) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    microbatches > 1 enables gradient accumulation: the global batch is split
    along dim 0 and scanned, cutting peak activation residency ~linearly
    (the lever that fits grok-1's train_4k on a 16 GB/chip pod)."""
    n_micro = microbatches if microbatches is not None else model.cfg.microbatches

    def _grads(params, batch):
        def loss_fn(p):
            return model.loss(p, batch)
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        params = state["params"]
        if n_micro <= 1:
            (loss, parts), grads = _grads(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
                batch)

            def body(acc, microbatch):
                (l, pts), g = _grads(params, microbatch)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype) / n_micro,
                    acc, (g, {"loss": l, **pts}))
                return acc, None

            zeros_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zeros_m = {"loss": jnp.zeros((), jnp.float32),
                       "ce": jnp.zeros((), jnp.float32),
                       "aux": jnp.zeros((), jnp.float32)}
            (grads, acc_m), _ = jax.lax.scan(
                jax.checkpoint(body, prevent_cse=False), (zeros_g, zeros_m), mb)
            loss, parts = acc_m["loss"], {"ce": acc_m["ce"], "aux": acc_m["aux"]}

        if compress_grads:
            # int8 + error-feedback on the cross-pod gradient reduction
            grads, new_res = gcomp.compress_tree(grads, state["residuals"])
        lr = lr_schedule(state["step"]) if lr_schedule else opt_cfg.lr
        params, opt, om = adamw.update(grads, state["opt"], state["params"], opt_cfg, lr)
        new_state: TrainState = {
            "params": params,
            "opt": opt,
            "step": state["step"] + 1,
        }
        if compress_grads:
            new_state["residuals"] = new_res
        metrics = {"loss": loss, "ce": parts["ce"], "aux": parts["aux"],
                   "grad_norm": om["grad_norm"], "lr": jnp.asarray(lr)}
        return new_state, metrics

    return train_step


def make_eval_step(model: Model) -> Callable:
    def eval_step(params, batch):
        loss, parts = model.loss(params, batch)
        return {"loss": loss, **parts}
    return eval_step


def make_prefill_step(model: Model, max_len: int) -> Callable:
    def prefill(params, inputs):
        return model.prefill(params, inputs, max_len)
    return prefill


def make_decode_step(model: Model) -> Callable:
    def decode(params, caches, inputs):
        return model.decode_step(params, caches, inputs)
    return decode


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def _adra_level(a, b, ia, ib):
    """One tournament level: strict a < b picks the right entrant (ties keep
    the earlier index, argmax semantics). Written as plain jnp so the
    lowering compiler stages it: the comparison is a single-access engine
    `lt` and both selects are zero-access peripheral writebacks — the whole
    level fuses into a one-access Schedule."""
    take_b = a < b
    return jnp.where(take_b, b, a), jnp.where(take_b, ib, ia)


_ADRA_LEVEL_LOWERED = None


def adra_sample(logits: jax.Array, n_bits: int = 8) -> jax.Array:
    """Quantized argmax through the ADRA comparison primitive: logits are
    quantized to n_bits and the winner found with single-access in-memory
    comparisons (a reduction tree of engine compares) — the serving-path
    integration of the paper's technique. Each tournament level is compiled
    by the jaxpr->CiM lowering pass (repro.cim.lower), which fuses the
    compare and both index/value selects into ONE planned access; the
    backend (Pallas kernel on TPU, jnp plane math elsewhere) follows the
    registry default."""
    global _ADRA_LEVEL_LOWERED
    if _ADRA_LEVEL_LOWERED is None:
        from repro.cim.lower import lower

        _ADRA_LEVEL_LOWERED = lower(_adra_level)
    level = _ADRA_LEVEL_LOWERED

    x = logits.astype(jnp.float32)
    # padded-vocab columns are -inf-masked: clamp them to the finite floor so
    # they do not destroy the quantization scale (they can never win argmax)
    finite_lo = jnp.min(jnp.where(x < -1e29, jnp.inf, x), axis=-1, keepdims=True)
    x = jnp.maximum(x, finite_lo)
    lo = finite_lo
    hi = jnp.max(x, axis=-1, keepdims=True)
    scale = (hi - lo) / (2 ** n_bits - 2)
    q = jnp.round((x - lo) / jnp.maximum(scale, 1e-9)).astype(
        jnp.int16 if n_bits + 1 <= 16 else jnp.int32)

    def tree_reduce(vals, idxs):
        # pairwise single-access comparisons until one winner per row
        while vals.shape[-1] > 1:
            n = vals.shape[-1]
            if n % 2:
                vals = jnp.concatenate([vals, vals[..., -1:]], -1)
                idxs = jnp.concatenate([idxs, idxs[..., -1:]], -1)
                n += 1
            a, b = vals[..., 0::2], vals[..., 1::2]
            ia, ib = idxs[..., 0::2], idxs[..., 1::2]
            vals, idxs = level(a, b, ia, ib)
        return idxs[..., 0]

    idx0 = jnp.broadcast_to(jnp.arange(q.shape[-1], dtype=jnp.int32), q.shape)
    return tree_reduce(q, idx0)

"""Import-or-fallback shim for hypothesis.

hypothesis is an OPTIONAL dev dependency (see requirements-dev.txt). When it
is installed, this module re-exports the real `given`/`settings`/`st`. When
it is not, property tests fall back to deterministic seeded-numpy
parametrization: each @given test runs N_EXAMPLES times, drawing every
strategy from a per-example np.random.RandomState — weaker shrinking, same
coverage shape, zero extra dependencies.
"""
try:
    from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as _np
    import pytest as _pytest

    N_EXAMPLES = 25

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 — mirrors `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.randint(min_value, max_value + 1)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.randint(0, 2)))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(lambda rng: [
                elements.draw(rng)
                for _ in range(int(rng.randint(min_size, max_size + 1)))])

    class HealthCheck:  # noqa: N801 — mirrors hypothesis.HealthCheck
        function_scoped_fixture = "function_scoped_fixture"
        too_slow = "too_slow"
        data_too_large = "data_too_large"
        filter_too_much = "filter_too_much"

    def settings(*_args, **_kwargs):
        def deco(f):
            return f
        return deco

    def given(*strategies):
        def deco(f):
            def wrapper(_hyp_seed):
                rng = _np.random.RandomState(0xADAA ^ _hyp_seed)
                f(*[s.draw(rng) for s in strategies])

            wrapper.__name__ = f.__name__
            wrapper.__doc__ = f.__doc__
            return _pytest.mark.parametrize(
                "_hyp_seed", range(N_EXAMPLES))(wrapper)
        return deco

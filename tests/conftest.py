"""Shared fixtures for the tier-1 suite."""
import pytest

from repro.cim.accounting import LEDGER


@pytest.fixture(autouse=True)
def _reset_cim_ledger():
    """The engine charges a process-wide ledger; reset it around every test
    so access-count assertions can never leak across tests (and a test that
    forgets to reset cannot poison a later one)."""
    LEDGER.reset()
    yield
    LEDGER.reset()

"""Shared fixtures for the tier-1 suite."""
import pytest

from repro.cim.accounting import LEDGER
from repro.cim.array import clear_resident


@pytest.fixture(autouse=True)
def _reset_cim_ledger():
    """The engine charges a process-wide ledger and pins into process-wide
    resident sets; reset both around every test so access counts and pinned
    rows can never leak across tests (and a test that forgets to reset
    cannot poison a later one)."""
    LEDGER.reset()
    clear_resident()
    yield
    LEDGER.reset()
    clear_resident()

"""Core ADRA correctness: device levels, sense margins, truth tables, and
n-bit arithmetic — the paper's Sec. III claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    BOOLEAN_FUNCTIONS,
    adra_access,
    cim_add,
    cim_boolean,
    cim_compare,
    cim_sub,
)
from repro.core.array import AdraArrayConfig, level_currents
from repro.core.sensing import (
    SenseReferences,
    current_sense_margins,
    oai21_recover_a,
    sense,
    symmetric_sense_is_ambiguous,
    voltage_sense_margins,
)

CFG = AdraArrayConfig()


# ---------------------------------------------------------------------------
# device / sensing layer (Fig 3b-c)
# ---------------------------------------------------------------------------


def test_four_distinct_levels_strictly_ordered():
    lv = np.array(jax.device_get(level_currents(CFG)))
    # one-to-one mapping: I(0,0) < I(1,0) < I(0,1) < I(1,1)
    assert np.all(np.diff(lv) > 0), lv


def test_current_sense_margin_exceeds_1uA():
    margins = np.array(jax.device_get(current_sense_margins(CFG)))
    assert np.all(margins > 1e-6), margins  # paper: > 1 uA


def test_voltage_sense_margin_exceeds_50mV():
    margins = np.array(jax.device_get(voltage_sense_margins(CFG)))
    assert np.all(margins > 50e-3), margins  # paper: > 50 mV


def test_symmetric_assertion_is_many_to_one():
    # prior-work failure mode the paper fixes: (0,1) vs (1,0) ambiguous
    assert symmetric_sense_is_ambiguous(CFG)


def test_sense_amp_outputs_match_boolean_contract():
    refs = SenseReferences.from_config(CFG)
    a = jnp.array([0, 1, 0, 1])
    b = jnp.array([0, 0, 1, 1])
    from repro.core.array import senseline_current

    out = sense(senseline_current(a, b, CFG), refs)
    np.testing.assert_array_equal(np.array(out.or_), np.array(a | b))
    np.testing.assert_array_equal(np.array(out.and_), np.array(a & b))
    np.testing.assert_array_equal(np.array(out.b), np.array(b))
    np.testing.assert_array_equal(np.array(out.a), np.array(a))


def test_oai21_truth_table():
    for a in (0, 1):
        for b in (0, 1):
            got = oai21_recover_a(jnp.array(a | b), jnp.array(a & b), jnp.array(b))
            assert int(got) == a, (a, b)


def test_analog_equals_boolean_mode():
    rng = np.random.RandomState(0)
    x = jnp.array(rng.randint(-128, 128, 64), jnp.int32)
    y = jnp.array(rng.randint(-128, 128, 64), jnp.int32)
    np.testing.assert_array_equal(
        np.array(cim_sub(x, y, 8, "analog").value),
        np.array(cim_sub(x, y, 8, "boolean").value))


# ---------------------------------------------------------------------------
# arithmetic (Sec. III-B): subtraction, comparison, overflow module
# ---------------------------------------------------------------------------


def test_subtraction_exhaustive_4bit():
    v = np.arange(-8, 8, dtype=np.int32)
    a, b = np.meshgrid(v, v, indexing="ij")
    a, b = a.ravel(), b.ravel()
    got = np.array(cim_sub(jnp.array(a), jnp.array(b), n_bits=4).value)
    np.testing.assert_array_equal(got, a - b)  # (n+1)-bit output: never overflows


def test_addition_exhaustive_4bit():
    v = np.arange(-8, 8, dtype=np.int32)
    a, b = np.meshgrid(v, v, indexing="ij")
    a, b = a.ravel(), b.ravel()
    got = np.array(cim_add(jnp.array(a), jnp.array(b), n_bits=4).value)
    np.testing.assert_array_equal(got, a + b)


def test_comparison_exhaustive_4bit():
    v = np.arange(-8, 8, dtype=np.int32)
    a, b = np.meshgrid(v, v, indexing="ij")
    a, b = a.ravel(), b.ravel()
    c = cim_compare(jnp.array(a), jnp.array(b), n_bits=4)
    np.testing.assert_array_equal(np.array(c.lt), (a < b).astype(np.int32))
    np.testing.assert_array_equal(np.array(c.eq), (a == b).astype(np.int32))
    np.testing.assert_array_equal(np.array(c.gt), (a > b).astype(np.int32))


@settings(max_examples=200, deadline=None)
@given(st.lists(st.integers(-2**15, 2**15 - 1), min_size=1, max_size=32),
       st.lists(st.integers(-2**15, 2**15 - 1), min_size=1, max_size=32))
def test_sub_compare_property_16bit(xs, ys):
    n = min(len(xs), len(ys))
    a = jnp.array(xs[:n], jnp.int32)
    b = jnp.array(ys[:n], jnp.int32)
    out = cim_sub(a, b, n_bits=16)
    np.testing.assert_array_equal(np.array(out.value), np.array(a) - np.array(b))
    c = cim_compare(a, b, n_bits=16)
    np.testing.assert_array_equal(np.array(c.lt), (np.array(a) < np.array(b)).astype(np.int32))


@pytest.mark.parametrize("fn", BOOLEAN_FUNCTIONS)
def test_all_16_boolean_functions(fn):
    A = jnp.arange(16, dtype=jnp.int32)
    B = jnp.arange(16, dtype=jnp.int32)
    AA, BB = [x.ravel() for x in jnp.meshgrid(A, B, indexing="ij")]
    a, b = np.array(AA), np.array(BB)
    m = 15
    ref = {
        "false": np.zeros_like(a), "true": np.full_like(a, m),
        "and": a & b, "or": a | b, "xor": a ^ b,
        "nand": (~(a & b)) & m, "nor": (~(a | b)) & m, "xnor": (~(a ^ b)) & m,
        "a": a, "b": b, "not_a": (~a) & m, "not_b": (~b) & m,
        "a_and_not_b": a & ((~b) & m), "not_a_and_b": ((~a) & m) & b,
        "a_or_not_b": a | ((~b) & m), "not_a_or_b": ((~a) & m) | b,
    }[fn]
    got = np.array(cim_boolean(AA, BB, fn, n_bits=4))
    np.testing.assert_array_equal(got, ref)


def test_single_access_yields_all_three_sa_outputs():
    """The one-access contract: OR, AND, B (and A) from a single activation."""
    a = jnp.array([[0, 1, 0, 1]])
    b = jnp.array([[0, 0, 1, 1]])
    acc = adra_access(a, b, mode="analog")
    np.testing.assert_array_equal(np.array(acc.or_[0]), [0, 1, 1, 1])
    np.testing.assert_array_equal(np.array(acc.and_[0]), [0, 0, 0, 1])
    np.testing.assert_array_equal(np.array(acc.b[0]), [0, 0, 1, 1])
    np.testing.assert_array_equal(np.array(acc.a[0]), [0, 1, 0, 1])


def test_dual_output_module_add_and_sub_same_cycle():
    """Paper Sec. III-B alternate design: both outputs from one access."""
    from repro.core.adra import cim_add_sub

    v = np.arange(-8, 8, dtype=np.int32)
    a, b = np.meshgrid(v, v, indexing="ij")
    a, b = a.ravel(), b.ravel()
    out = cim_add_sub(jnp.array(a), jnp.array(b), n_bits=4)
    np.testing.assert_array_equal(np.array(out.add), a + b)
    np.testing.assert_array_equal(np.array(out.sub), a - b)
    out_an = cim_add_sub(jnp.array(a), jnp.array(b), n_bits=4, mode="analog")
    np.testing.assert_array_equal(np.array(out_an.add), a + b)
    np.testing.assert_array_equal(np.array(out_an.sub), a - b)


def test_dual_module_transistor_overhead_documented():
    from repro.core.compute_module import (
        EXTRA_TRANSISTORS_DUAL_OUTPUT_DESIGN,
        EXTRA_TRANSISTORS_MUX_DESIGN,
    )
    # paper: the dual-output design costs 4 extra transistors vs the muxes
    assert EXTRA_TRANSISTORS_DUAL_OUTPUT_DESIGN - EXTRA_TRANSISTORS_MUX_DESIGN == 4

"""Attention on the CiM banks: lowered SDPA / blockwise / decode parity.

The quantized attention cores route QK^T and AV through batched CiM
schedules while softmax, masking, and rotary stay host islands. These
tests pin down: lowered-vs-host bit-exactness (the lowering must be an
exact interpreter of the quantized reference), the warm per-call dispatch
count (2 regions for dense SDPA, 2 per kv block for blockwise), resident
KV reuse, the structural region cache sharing one compiled program pair
across block counts, and `gqa_decode_cim` matching `gqa_decode` caches.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.cim import dispatch
from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models.blockwise_attention import (blockwise_attention,
                                              blockwise_attention_cim,
                                              blockwise_attention_quantized)

from _hypothesis_compat import HealthCheck, given, settings, st

_PROP = dict(max_examples=25, deadline=None,
             suppress_health_check=[HealthCheck.function_scoped_fixture])


def _qkv(seed, b=2, tq=2, tk=8, hq=4, hkv=2, d=8, dv=8):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, tq, hq, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, tk, hkv, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, tk, hkv, dv)).astype(np.float32))
    return q, k, v


def _causal(b, tq, tk):
    m = jnp.arange(tq)[:, None] + (tk - tq) >= jnp.arange(tk)[None, :]
    return jnp.broadcast_to(m[None], (b, tq, tk))


# ---------------------------------------------------------------------------
# dense SDPA
# ---------------------------------------------------------------------------


def test_sdpa_cim_bit_exact_vs_host():
    q, k, v = _qkv(0)
    mask = _causal(2, 2, 8)
    scale = 1.0 / q.shape[-1] ** 0.5
    host = attn._sdpa_quantized(q, k, v, mask, scale)
    lowered = attn.sdpa_cim(q, k, v, mask, scale)
    np.testing.assert_array_equal(np.asarray(lowered), np.asarray(host))


def test_sdpa_cim_warm_dispatches_exactly_two():
    q, k, v = _qkv(1)
    mask = _causal(2, 2, 8)
    attn.sdpa_cim(q, k, v, mask, 0.35)               # warm programs
    before = dispatch.cache_stats()
    attn.sdpa_cim(q, k, v, mask, 0.35)
    after = dispatch.cache_stats()
    assert after["misses"] == before["misses"]        # fully warm
    assert after["dispatches"] - before["dispatches"] == 2   # QK^T + AV


def test_sdpa_cim_resident_kv_hits_on_stable_cache():
    q1, k, v = _qkv(2)
    q2 = q1 + 1.0                                     # query varies, KV pinned
    mask = _causal(2, 2, 8)
    attn.sdpa_cim(q1, k, v, mask, 0.35, resident=True)
    before = dispatch.cache_stats()
    out = attn.sdpa_cim(q2, k, v, mask, 0.35, resident=True)
    after = dispatch.cache_stats()
    assert after["resident_hits"] > before["resident_hits"]
    assert after["resident_pins"] == before["resident_pins"]
    ref = attn._sdpa_quantized(q2, k, v, mask, 0.35)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# blockwise
# ---------------------------------------------------------------------------


@settings(**_PROP)
@given(st.integers(0, 10_000), st.integers(0, 3), st.booleans())
def test_blockwise_cim_bit_exact_property(seed, bk_idx, causal):
    """Lowered blockwise attention is bit-exact vs the float-quantized host
    reference across block sizes — including a block that does not divide
    the kv length (padding path)."""
    bk = (4, 8, 16, 12)[bk_idx]                       # 12 does not divide 16
    q, k, v = _qkv(seed, b=1, tq=4, tk=16, hq=2, hkv=1, d=4, dv=4)
    host = blockwise_attention_quantized(q, k, v, causal=causal, block_k=bk)
    low = blockwise_attention_cim(q, k, v, causal=causal, block_k=bk)
    np.testing.assert_array_equal(np.asarray(low), np.asarray(host))


def test_blockwise_cim_structural_cache_shared_across_blocks():
    q, k, v = _qkv(3, b=1, tq=4, tk=32, hq=2, hkv=1, d=4, dv=4)
    blockwise_attention_cim(q, k, v, block_k=8)       # warm: nk=4 blocks
    stats = dispatch.cache_stats()
    before = stats["misses"], stats["dispatches"]
    blockwise_attention_cim(q, k, v, block_k=8)
    stats = dispatch.cache_stats()
    # fixed block shapes: ONE compiled program pair serves all 4 blocks
    assert stats["misses"] == before[0]
    assert stats["dispatches"] - before[1] == 2 * 4   # (QK + AV) per block
    # a different kv length with the SAME block shape stays warm too
    q2, k2, v2 = _qkv(4, b=1, tq=4, tk=16, hq=2, hkv=1, d=4, dv=4)
    blockwise_attention_cim(q2, k2, v2, block_k=8)
    assert dispatch.cache_stats()["misses"] == before[0]


def test_blockwise_quantized_close_to_float():
    q, k, v = _qkv(5, b=1, tq=8, tk=8, hq=2, hkv=2, d=8, dv=8)
    ref = blockwise_attention(q, k, v, True, None, 0, 8)
    got = blockwise_attention_quantized(q, k, v, causal=True, block_k=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=0.08, rtol=0.0)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def _decode_cfg(**kw):
    return ArchConfig(name="t", family="dense", n_layers=1, d_model=16,
                      n_heads=4, n_kv_heads=2, head_dim=8, d_ff=32,
                      vocab_size=64, dtype="float32", tensor_parallel=False,
                      **kw)


def test_gqa_decode_cim_matches_host_decode():
    cfg = _decode_cfg(cim_attention_bits=8)
    key = jax.random.PRNGKey(0)
    p = attn.gqa_init(key, cfg, jnp.float32)
    cache = attn.gqa_make_cache(cfg, batch=2, max_len=8, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 1, 16), jnp.float32)
    positions = jnp.array([3, 5])
    y_ref, c_ref = attn.gqa_decode(p, cfg, x, cache, positions)
    y_cim, c_cim = attn.gqa_decode_cim(p, cfg, x, cache, positions)
    # cache updates are identical (pure host bookkeeping on both paths)
    np.testing.assert_array_equal(np.asarray(c_cim["k"]),
                                  np.asarray(c_ref["k"]))
    np.testing.assert_array_equal(np.asarray(c_cim["v"]),
                                  np.asarray(c_ref["v"]))
    # int8-quantized attention core: close, not bit-equal, to float SDPA
    np.testing.assert_allclose(np.asarray(y_cim), np.asarray(y_ref),
                               atol=0.05, rtol=0.0)


def test_gqa_decode_cim_dispatches_per_step():
    cfg = _decode_cfg(cim_attention_bits=8)
    key = jax.random.PRNGKey(2)
    p = attn.gqa_init(key, cfg, jnp.float32)
    cache = attn.gqa_make_cache(cfg, batch=1, max_len=8, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 16), jnp.float32)
    attn.gqa_decode_cim(p, cfg, x, cache, jnp.array([0]))   # warm
    before = dispatch.cache_stats()["dispatches"]
    attn.gqa_decode_cim(p, cfg, x, cache, jnp.array([1]))
    assert dispatch.cache_stats()["dispatches"] - before == 2

"""The banked array substrate: tiling round-trips, the per-bank ledger, the
compiled-schedule cache, placement-carrying schedules, and the shard_map
multi-device path.

The core property (issue: tiling must be invisible): for random shapes —
including word counts that are NOT multiples of the bank width — tile ->
execute -> untile equals untiled execution bit-for-bit on every CPU
backend, and the ledger's bank-access totals equal the analytic tile count.
"""
import dataclasses
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro import cim
from repro.cim import ArraySpec, PlanePack, dispatch, macro, planner
from repro.cim.accounting import LEDGER, Ledger
from repro.cim.opset import CimOpError

from _hypothesis_compat import HealthCheck, given, settings, st

PORTABLE = ("jnp-boolean", "pallas-interpret")
OPS = ("sub", "lt", "eq", "xor")

_PROP = dict(max_examples=20, deadline=None,
             suppress_health_check=[HealthCheck.function_scoped_fixture])


def _operands(n_bits, n_words, seed):
    rng = np.random.RandomState(seed)
    lo, hi = -(1 << (n_bits - 1)), 1 << (n_bits - 1)
    a = rng.randint(lo, hi, n_words)
    b = rng.randint(lo, hi, n_words)
    return jnp.array(a, jnp.int32), jnp.array(b, jnp.int32)


# ---------------------------------------------------------------------------
# tiling round-trip == untiled execution (the substrate's core invariant)
# ---------------------------------------------------------------------------


@given(st.integers(2, 12), st.integers(1, 300), st.integers(1, 5),
       st.integers(1, 3), st.integers(0, 2**31 - 1))
@settings(**_PROP)
def test_tiling_round_trip_matches_untiled(n_bits, n_words, banks,
                                           subarrays, seed):
    a, b = _operands(n_bits, n_words, seed)
    pa, pb = PlanePack.pack(a, n_bits), PlanePack.pack(b, n_bits)
    spec = ArraySpec(banks=banks, subarrays=subarrays, rows=128,
                     bitline_words=32)

    for backend in PORTABLE:
        ref = cim.execute(pa, pb, OPS, backend=backend)
        LEDGER.reset()
        out = dispatch.execute_tiled(pa, pb, OPS, spec=spec, backend=backend)

        for op in OPS:
            np.testing.assert_array_equal(np.array(out[op].planes),
                                          np.array(ref[op].planes),
                                          err_msg=op)
            assert out[op].shape == ref[op].shape
            assert out[op].n_bits == ref[op].n_bits

        # ledger totals == analytic tile count, round-robin over banks
        n_tiles = -(-n_words // spec.tile_words)
        assert LEDGER.accesses == n_tiles
        counts = LEDGER.bank_accesses
        assert sum(counts.values()) == n_tiles
        assert max(counts.values()) == -(-n_tiles // banks)   # balanced
        assert set(counts) <= {(0, k) for k in range(banks)}


def test_tiling_round_trip_analog_oracle():
    """The device-model backend (slow): one small case, still bit-exact."""
    a, b = _operands(4, 40, 7)
    pa, pb = PlanePack.pack(a, 4), PlanePack.pack(b, 4)
    spec = ArraySpec(banks=2, subarrays=1, rows=64, bitline_words=32)
    ref = cim.execute(pa, pb, ("sub", "lt"), backend="analog-oracle")
    out = dispatch.execute_tiled(pa, pb, ("sub", "lt"), spec=spec,
                                 backend="analog-oracle")
    for op in ("sub", "lt"):
        np.testing.assert_array_equal(np.array(out[op].unpack()),
                                      np.array(ref[op].unpack()))


def test_multidim_operands_tile_exactly():
    a, b = _operands(8, 2 * 13 * 5, 11)
    a, b = a.reshape(2, 13, 5), b.reshape(2, 13, 5)
    pa, pb = PlanePack.pack(a, 8), PlanePack.pack(b, 8)
    spec = ArraySpec(banks=3, subarrays=1, rows=128, bitline_words=32)
    out = dispatch.execute_tiled(pa, pb, ("add",), spec=spec,
                                 backend="jnp-boolean")
    np.testing.assert_array_equal(np.array(out["add"].unpack()),
                                  np.array(a) + np.array(b))


# ---------------------------------------------------------------------------
# geometry validation
# ---------------------------------------------------------------------------


def test_array_spec_validation_errors():
    with pytest.raises(CimOpError):
        ArraySpec(banks=0)
    with pytest.raises(CimOpError):
        ArraySpec(bitline_words=31)
    with pytest.raises(CimOpError):
        ArraySpec(bitline_words=0)
    with pytest.raises(CimOpError):
        ArraySpec().plan(0)


def test_mesh_axis_validated_at_dispatch():
    """A mesh without the requested axis must raise CimOpError from ANY
    mesh-taking entry point, not a raw KeyError deep in dispatch."""
    import jax

    mesh = jax.make_mesh((1,), ("batch",))
    a, b = _operands(8, 10, 3)
    pa, pb = PlanePack.pack(a, 8), PlanePack.pack(b, 8)
    with pytest.raises(CimOpError, match="no 'data'"):
        dispatch.execute_tiled(pa, pb, ("add",), backend="jnp-boolean",
                               mesh=mesh)


def test_rows_budget_enforced():
    """An access whose operand + output planes exceed the subarray rows must
    be refused — the geometry is a real constraint, not advice."""
    spec = ArraySpec(banks=1, subarrays=1, rows=16, bitline_words=32)
    a, b = _operands(8, 10, 3)
    pa, pb = PlanePack.pack(a, 8), PlanePack.pack(b, 8)
    with pytest.raises(CimOpError):           # 2*8 operand + 9 out > 16 rows
        dispatch.execute_tiled(pa, pb, ("add",), spec=spec,
                               backend="jnp-boolean")
    spec_ok = ArraySpec(banks=1, subarrays=1, rows=32, bitline_words=32)
    dispatch.execute_tiled(pa, pb, ("add",), spec=spec_ok,
                           backend="jnp-boolean")


# ---------------------------------------------------------------------------
# ledger: reset really clears everything; bank report is self-consistent
# ---------------------------------------------------------------------------


def test_ledger_reset_clears_every_field():
    """reset() must clear EVERY accumulator — including the per-op breakdown
    keys charge() populates and the per-bank fields charge_banked adds; a
    fresh Ledger is the reference."""
    led = Ledger()
    spec = ArraySpec(banks=2, subarrays=1, rows=64, bitline_words=32)
    led.charge(("sub", "lt"), 8, 100)
    led.charge_banked(("add",), 8, 100, spec.plan(100))
    led.charge_reduction(12.5)
    assert led.accesses and led.per_op and led.bank_accesses
    assert led.activated_words32 and led.inter_bank_words32

    led.reset()
    fresh = dataclasses.asdict(Ledger())
    assert dataclasses.asdict(led) == fresh
    # and in particular the breakdown dicts are EMPTY, not just zeroed
    assert led.per_op == {} and led.bank_accesses == {}


def test_disabled_ledger_charges_nothing():
    led = Ledger(enabled=False)
    spec = ArraySpec(banks=2, subarrays=1, rows=64, bitline_words=32)
    led.charge(("sub",), 8, 10)
    led.charge_banked(("add",), 8, 10, spec.plan(10))
    led.charge_reduction(5.0)
    assert dataclasses.asdict(led) == dataclasses.asdict(Ledger(enabled=False))


def test_bank_report_contention_and_utilization():
    spec = ArraySpec(banks=4, subarrays=1, rows=128, bitline_words=32)
    a, b = _operands(8, 5 * 32, 5)           # 5 tiles on 4 banks -> 2 waves
    pa, pb = PlanePack.pack(a, 8), PlanePack.pack(b, 8)
    LEDGER.reset()
    dispatch.execute_tiled(pa, pb, ("add",), spec=spec, backend="jnp-boolean")
    rep = LEDGER.bank_report(spec)
    assert rep["activations"] == 5
    assert rep["waves"] == 2                  # bank 0 runs tiles 0 and 4
    assert rep["ideal_waves"] == 2
    assert rep["utilization"] == pytest.approx(1.0)   # 160 words fill tiles
    assert 0 < rep["edp_decrease_pct"] < 100
    assert rep["cim_edp"] < rep["baseline_edp"]


# ---------------------------------------------------------------------------
# compiled-schedule cache
# ---------------------------------------------------------------------------


def test_schedule_cache_hits_and_misses():
    a, b = _operands(8, 100, 9)
    pa, pb = PlanePack.pack(a, 8), PlanePack.pack(b, 8)
    spec = ArraySpec(banks=2, subarrays=1, rows=128, bitline_words=32)
    dispatch.clear_schedule_cache()

    def stats_slice():
        s = dispatch.cache_stats()
        return {k: s[k] for k in ("hits", "misses", "entries")}

    dispatch.execute_tiled(pa, pb, ("add",), spec=spec, backend="jnp-boolean")
    assert stats_slice() == {"hits": 0, "misses": 1, "entries": 1}
    dispatch.execute_tiled(pa, pb, ("add",), spec=spec, backend="jnp-boolean")
    assert stats_slice() == {"hits": 1, "misses": 1, "entries": 1}

    # bank count is NOT part of the key (same tile shape -> same program)...
    dispatch.execute_tiled(pa, pb, ("add",),
                           spec=ArraySpec(banks=4, subarrays=1, rows=128,
                                          bitline_words=32),
                           backend="jnp-boolean")
    assert dispatch.cache_stats()["hits"] == 2
    # ...but ops, tile shape and backend are
    dispatch.execute_tiled(pa, pb, ("sub",), spec=spec, backend="jnp-boolean")
    dispatch.execute_tiled(pa, pb, ("add",),
                           spec=ArraySpec(banks=2, subarrays=2, rows=128,
                                          bitline_words=32),
                           backend="jnp-boolean")
    dispatch.execute_tiled(pa, pb, ("add",), spec=spec,
                           backend="pallas-interpret")
    stats = dispatch.cache_stats()
    assert stats["misses"] == 4 and stats["entries"] == 4


def test_schedule_cache_lru_bound_and_evictions():
    """The compiled-schedule cache is a bounded LRU: inserts past capacity
    evict the coldest program, hits refresh recency, and the eviction
    counter reports the churn (varied tile shapes can no longer grow the
    table without limit)."""
    a, b = _operands(8, 100, 9)
    pa, pb = PlanePack.pack(a, 8), PlanePack.pack(b, 8)
    spec = ArraySpec(banks=2, subarrays=1, rows=128, bitline_words=32)
    old_capacity = dispatch.cache_stats()["capacity"]
    dispatch.clear_schedule_cache()
    try:
        dispatch.set_schedule_cache_capacity(2)

        def run(ops):
            dispatch.execute_tiled(pa, pb, ops, spec=spec,
                                   backend="jnp-boolean")

        run(("add",))                       # miss: [add]
        run(("sub",))                       # miss: [add, sub]
        run(("xor",))                       # miss, evicts add: [sub, xor]
        s = dispatch.cache_stats()
        assert s["entries"] == 2 and s["evictions"] == 1
        run(("add",))                       # miss again (was evicted)
        s = dispatch.cache_stats()
        assert s["misses"] == 4 and s["evictions"] == 2  # [xor, add]
        run(("xor",))                       # HIT: refreshes xor -> [add, xor]
        assert dispatch.cache_stats()["hits"] == 1
        run(("or",))                        # evicts add (coldest), keeps xor
        run(("xor",))                       # still resident: recency worked
        s = dispatch.cache_stats()
        assert s["hits"] == 2 and s["entries"] == 2 and s["evictions"] == 3

        # shrinking the bound evicts immediately; degenerate bounds are errors
        dispatch.set_schedule_cache_capacity(1)
        assert dispatch.cache_stats()["entries"] == 1
        with pytest.raises(CimOpError):
            dispatch.set_schedule_cache_capacity(0)
    finally:
        dispatch.set_schedule_cache_capacity(old_capacity)
        dispatch.clear_schedule_cache()


# ---------------------------------------------------------------------------
# placement-carrying schedules + banked macros
# ---------------------------------------------------------------------------


def test_schedule_carries_placement():
    spec = ArraySpec(banks=2, subarrays=1, rows=128, bitline_words=32)
    sched = planner.plan_multiply(6, 6)
    assert sched.placement is None and sched.placed_accesses == sched.accesses
    placed = sched.placed(spec, 100)
    assert placed.placement.n_tiles == 4
    assert placed.placed_accesses == sched.accesses * 4
    # composition keeps the placement
    combined = placed + planner.plan_reduce_sum(8)
    assert combined.placement == placed.placement


def test_banked_multiply_ledger_matches_placed_schedule():
    spec = ArraySpec(banks=2, subarrays=1, rows=128, bitline_words=32)
    n_bits, n = 6, 100
    a, b = _operands(n_bits, n, 13)
    pa, pb = PlanePack.pack(a, n_bits), PlanePack.pack(b, n_bits)
    LEDGER.reset()
    prod = macro.multiply(pa, pb, backend="jnp-boolean", spec=spec)
    np.testing.assert_array_equal(np.array(prod.unpack()),
                                  np.array(a) * np.array(b))
    placed = planner.plan_multiply(n_bits, n_bits).placed(spec, n)
    assert LEDGER.accesses == placed.placed_accesses


def test_banked_matmul_charges_inter_bank_reduction():
    spec = ArraySpec(banks=2, subarrays=1, rows=128, bitline_words=32)
    rng = np.random.RandomState(17)
    A = jnp.array(rng.randint(-8, 8, (4, 7)), jnp.int32)
    B = jnp.array(rng.randint(-8, 8, (7, 3)), jnp.int32)
    LEDGER.reset()
    C = macro.matmul(A, B, n_bits=4, backend="jnp-boolean", spec=spec)
    np.testing.assert_array_equal(
        np.array(C), np.array(A, np.int64) @ np.array(B, np.int64))
    placed = planner.plan_matmul(7, 3, n_bits=4).placed(spec, 4 * 8 * 3)
    assert LEDGER.accesses == placed.placed_accesses
    # the stride-N tree reduction moves words across the 32-word tiles
    assert LEDGER.inter_bank_words32 > 0
    rep = LEDGER.bank_report(spec)
    assert rep["inter_bank_words32"] == LEDGER.inter_bank_words32


def test_kernel_ops_banked_entry_points():
    from repro.kernels import ops

    a, b = _operands(8, 90, 19)
    spec = ArraySpec(banks=3, subarrays=1, rows=128, bitline_words=32)
    LEDGER.reset()
    d, lt, eq = ops.adra_sub(a, b, n_bits=8, backend="jnp-boolean", spec=spec)
    np.testing.assert_array_equal(np.array(d), np.array(a) - np.array(b))
    np.testing.assert_array_equal(np.array(lt),
                                  (np.array(a) < np.array(b)).astype(np.int32))
    assert LEDGER.accesses == 3               # ceil(90 / 32) tiles
    s = ops.adra_add(a, b, n_bits=8, backend="jnp-boolean", spec=spec)
    np.testing.assert_array_equal(np.array(s), np.array(a) + np.array(b))
    r = ops.cim_relu(a, n_bits=8, backend="jnp-boolean", spec=spec)
    np.testing.assert_array_equal(np.array(r), np.maximum(np.array(a), 0))


def test_offload_bank_aware_access_counts():
    from repro.core.offload import analyze_hlo

    hlo = ("  %r = s8[4096] add(s8[4096] %a, s8[4096] %b)\n"
           "  %m = s8[4096] multiply(s8[4096] %a, s8[4096] %b)\n")
    base = analyze_hlo(hlo)
    assert base.banked_accesses == 0 and base.bank_waves == 0
    spec = ArraySpec(banks=4, subarrays=1, rows=1024, bitline_words=1024)
    rep = analyze_hlo(hlo, spec=spec)
    # 4096 words -> 4 tiles -> 1 wave on 4 banks; multiply plans 15 accesses
    assert rep.banked_accesses == (1 + 15) * 4
    assert rep.bank_waves == (1 + 15) * 1
    assert rep.bank_parallel_speedup == pytest.approx(4.0)


# ---------------------------------------------------------------------------
# shard_map: multi-device tiles, per-device ledgers
# ---------------------------------------------------------------------------


def test_sharded_tiles_match_and_ledgers_sum():
    """8 forced host devices: shard_map execution equals the single-device
    result, and the per-device bank ledgers sum to the single-device total
    (the substrate's conservation law)."""
    code = textwrap.dedent("""
        import os
        os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
        import numpy as np, jax.numpy as jnp
        from repro import cim
        from repro.cim import PlanePack, ArraySpec, dispatch
        from repro.launch.mesh import make_smoke_mesh

        rng = np.random.RandomState(0)
        n_bits, n = 8, 10 * 32            # 10 tiles of 32 words
        a = jnp.array(rng.randint(-100, 100, n), jnp.int32)
        b = jnp.array(rng.randint(-100, 100, n), jnp.int32)
        pa, pb = PlanePack.pack(a, n_bits), PlanePack.pack(b, n_bits)
        spec = ArraySpec(banks=2, subarrays=1, rows=64, bitline_words=32)
        mesh = make_smoke_mesh()
        n_dev = int(mesh.shape['data'])
        assert n_dev > 1, mesh

        cim.LEDGER.reset()
        ref = dispatch.execute_tiled(pa, pb, ('sub', 'lt'), spec=spec,
                                     backend='jnp-boolean')
        single_total = cim.LEDGER.accesses
        single_banks = dict(cim.LEDGER.bank_accesses)

        cim.LEDGER.reset()
        out = dispatch.execute_sharded(pa, pb, ('sub', 'lt'), mesh,
                                       spec=spec, backend='jnp-boolean')
        for op in ('sub', 'lt'):
            np.testing.assert_array_equal(np.array(out[op].unpack()),
                                          np.array(ref[op].unpack()))
        per_dev = cim.LEDGER.per_device()
        assert len(per_dev) == n_dev, per_dev
        assert sum(per_dev.values()) == single_total, (per_dev, single_total)
        assert sum(cim.LEDGER.bank_accesses.values()) == \\
            sum(single_banks.values())
        print('OK', per_dev)
    """)
    r = subprocess.run([sys.executable, "-W", "ignore", "-c", code],
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert "OK" in r.stdout

"""Batched dot_general through the CiM compiler stack.

Covers the classifier (canonical [*B,M,K] x [*B,K,N] contractions only),
`plan_batched_matmul` (per-tile access count independent of batch), the
macro executor (batch dims flattened onto the word axis, bit-exact vs
numpy), the lowering pass (bit-exact hybrid execution, resident batched
rhs), and the offload estimator's `batched_dot` category — plus the edge
shapes from the issue: batch=1 collapse, non-power-of-two K with padding,
and uint8 vs int8 operands.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cim import array, macro, planner
from repro.cim.accounting import LEDGER
from repro.cim.lower import lower
from repro.cim.trace import trace
from repro.core.offload import analyze_trace
from repro.models.layers import quantized_batched_matmul


def _canon_dims(nb):
    return (((nb + 1,), (nb,)), (tuple(range(nb)), tuple(range(nb))))


def _rand_ints(rng, shape, dtype):
    if dtype == jnp.uint8:
        return jnp.asarray(rng.randint(0, 200, shape), jnp.uint8)
    return jnp.asarray(rng.randint(-100, 100, shape), dtype)


def _multi_ops(tr):
    return [op for op in tr.ops if op.kind == "multi"]


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------


def test_plan_batched_accesses_independent_of_batch():
    base = planner.plan_batched_matmul(1, 5, 6)
    for batch in (2, 8, 64):
        sched = planner.plan_batched_matmul(batch, 5, 6)
        assert sched.accesses == base.accesses
    # and equal to the 2-D plan's schedule: batch only moves tile placement
    assert base.accesses == planner.plan_matmul(5, 6).accesses


def test_plan_batched_rejects_degenerate_shapes():
    from repro.cim import opset

    with pytest.raises(opset.CimOpError):
        planner.plan_batched_matmul(0, 5, 6)
    with pytest.raises(opset.CimOpError):
        planner.plan_batched_matmul(2, 0, 6)


def test_plan_batched_resident_rhs_flag():
    sched = planner.plan_batched_matmul(2, 5, 6, resident_rhs=True)
    assert sched.resident == ("rhs",)


# ---------------------------------------------------------------------------
# classifier
# ---------------------------------------------------------------------------


def test_classifier_batch1_collapses_to_matmul_cost():
    def bmm3(a, b):
        return jax.lax.dot_general(a, b, _canon_dims(1),
                                   preferred_element_type=jnp.int32)

    def mm2(a, b):
        return jax.lax.dot_general(a, b, _canon_dims(0),
                                   preferred_element_type=jnp.int32)

    rng = np.random.RandomState(0)
    a3 = _rand_ints(rng, (1, 4, 5), jnp.int8)
    b3 = _rand_ints(rng, (1, 5, 6), jnp.int8)
    op3, = _multi_ops(trace(bmm3, a3, b3))
    op2, = _multi_ops(trace(mm2, a3[0], b3[0]))
    assert op3.schedule.macro == "batched_matmul"
    assert op3.accesses == op2.accesses          # batch=1: identical cost
    assert op3.words == op2.words


def test_classifier_rejects_non_canonical_and_mixed_dtype():
    rng = np.random.RandomState(1)
    a = _rand_ints(rng, (1, 4, 5), jnp.int8)
    b = _rand_ints(rng, (1, 5, 6), jnp.int8)

    # jnp.matmul rewrites a singleton batch into squeeze + a non-canonical
    # contraction + transpose: every eqn must stay host, none may lower
    assert not _multi_ops(trace(lambda x, y: jnp.matmul(
        x, y, preferred_element_type=jnp.int32), a, b))

    def mixed(x, y):
        return jax.lax.dot_general(x, y.astype(jnp.int16), _canon_dims(1),
                                   preferred_element_type=jnp.int32)

    assert not _multi_ops(trace(mixed, a, b))


def test_classifier_batched_words_scale_with_batch():
    def bmm(a, b):
        return jax.lax.dot_general(a, b, _canon_dims(2),
                                   preferred_element_type=jnp.int32)

    rng = np.random.RandomState(2)
    a = _rand_ints(rng, (3, 2, 4, 5), jnp.int8)
    b = _rand_ints(rng, (3, 2, 5, 6), jnp.int8)
    op, = _multi_ops(trace(bmm, a, b))
    k_pad = 8                                    # K=5 -> next pow2
    assert op.words == 3 * 2 * 4 * k_pad * 6
    assert op.accesses == planner.plan_batched_matmul(6, 5, 6).accesses


# ---------------------------------------------------------------------------
# macro executor
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.int8, jnp.uint8])
@pytest.mark.parametrize("k", [4, 7])            # pow2 and padded K
def test_macro_batched_matmul_matches_numpy(dtype, k):
    # the standalone macro packs operands signed (like macro.matmul); uint8
    # full-range goes through lower(), where signedness comes from dtype
    rng = np.random.RandomState(3)
    if dtype == jnp.uint8:
        a = jnp.asarray(rng.randint(0, 128, (2, 3, k)), jnp.uint8)
        b = jnp.asarray(rng.randint(0, 128, (2, k, 4)), jnp.uint8)
    else:
        a = _rand_ints(rng, (2, 3, k), dtype)
        b = _rand_ints(rng, (2, k, 4), dtype)
    out = macro.batched_matmul(a, b, n_bits=8, backend="jnp-boolean")
    ref = np.einsum("bmk,bkn->bmn", np.asarray(a, np.int64),
                    np.asarray(b, np.int64))
    np.testing.assert_array_equal(np.asarray(out), ref)


def test_macro_batched_matmul_resident_pack_bit_exact():
    rng = np.random.RandomState(4)
    a = _rand_ints(rng, (2, 3, 5), jnp.int8)
    b = _rand_ints(rng, (2, 5, 4), jnp.int8)
    pack = macro.batched_matmul_rhs_pack(b, m=3, n_bits=8)
    streamed = macro.batched_matmul(a, b, n_bits=8, backend="jnp-boolean")
    pinned = macro.batched_matmul(a, n_bits=8, backend="jnp-boolean",
                                  b_pack=pack)
    np.testing.assert_array_equal(np.asarray(streamed), np.asarray(pinned))


def test_macro_batched_ledger_matches_plan():
    rng = np.random.RandomState(5)
    a = _rand_ints(rng, (4, 2, 5), jnp.int8)
    b = _rand_ints(rng, (4, 5, 3), jnp.int8)
    LEDGER.reset()
    macro.batched_matmul(a, b, n_bits=8, backend="jnp-boolean")
    assert LEDGER.accesses == planner.plan_batched_matmul(4, 5, 3).accesses


# ---------------------------------------------------------------------------
# lowering + offload
# ---------------------------------------------------------------------------


def test_lowered_batched_quantized_bit_exact():
    rng = np.random.default_rng(6)
    a = jnp.asarray(rng.normal(size=(3, 2, 4, 5)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(3, 2, 5, 6)).astype(np.float32))
    lf = lower(lambda x, y: quantized_batched_matmul(x, y, 8))
    np.testing.assert_array_equal(
        np.asarray(lf(a, b)),
        np.asarray(quantized_batched_matmul(a, b, 8)))


def test_lowered_uint8_nonpow2_k_bit_exact():
    def ubmm(x, y):
        return jax.lax.dot_general(x.astype(jnp.uint8), y.astype(jnp.uint8),
                                   _canon_dims(1),
                                   preferred_element_type=jnp.int32)

    rng = np.random.RandomState(7)
    a = jnp.asarray(rng.randint(0, 200, (2, 3, 7)), jnp.int32)
    b = jnp.asarray(rng.randint(0, 200, (2, 7, 4)), jnp.int32)
    lf = lower(ubmm)
    ref = np.einsum("bmk,bkn->bmn", np.asarray(a, np.int64),
                    np.asarray(b, np.int64))
    np.testing.assert_array_equal(np.asarray(lf(a, b)), ref)


def test_offload_reports_batched_dot_category():
    def bmm(a, b):
        return jax.lax.dot_general(a, b, _canon_dims(1),
                                   preferred_element_type=jnp.int32)

    rng = np.random.RandomState(8)
    a = _rand_ints(rng, (2, 3, 5), jnp.int8)
    b = _rand_ints(rng, (2, 5, 4), jnp.int8)
    tr = trace(bmm, a, b)
    rep = analyze_trace(tr)
    assert rep.op_histogram == {"batched_dot": 1}
    assert rep.multi_access_ops == 1
    # the rhs (KV side under attention) is pinnable: one savable load
    assert rep.resident_savable_accesses == 1
    assert rep.adra_accesses == planner.plan_batched_matmul(2, 5, 4).accesses


def test_resident_batched_rhs_pins_once_then_hits():
    rng = np.random.default_rng(9)
    a = jnp.asarray(rng.normal(size=(2, 3, 4, 8)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(2, 3, 8, 6)).astype(np.float32))
    rs = array.ResidentSet(array.ArraySpec())
    lf = lower(lambda x, y: quantized_batched_matmul(x, y, 8),
               resident_argnums=(1,), resident_set=rs)
    comp = lf.trace(a, b)
    (ra,), = [r.resident for r in comp.regions if r.resident]
    assert ra.kind == "batched_matmul_rhs"
    ref = quantized_batched_matmul(a, b, 8)
    out1 = lf(a, b)
    out2 = lf(a, b)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(ref))
    assert rs.pins == 1 and rs.hits == 1

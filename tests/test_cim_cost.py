"""Cost-model + autotuner tests (repro.cim.cost / repro.cim.autotune).

The projection/execution contract: the cost model's per-eqn access and
wave counts are built from the SAME TilePlan quantities the ledger
charges, so for any random composed graph the projected banked access
count equals the executed ledger count EXACTLY, and the projected wave
count equals the busiest bank slot's activation count. (words32 is
asserted against the shared estimator accounting, not the executed
ledger — executed reduce steps charge widened intermediate widths the
jaxpr-level projection deliberately does not model.)

Policy contract: `policy="always"` is bit-exact with the pre-cost-model
lowering including dispatch counts; the default "edp" policy demotes a
projected-losing (pad-dominated) placement to host, still bit-exact,
with the verdict visible in the OffloadReport.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cim import ArraySpec, lower
from repro.cim.accounting import LEDGER
from repro.cim.autotune import (
    DEFAULT_CANDIDATE,
    Autotuner,
    Candidate,
    steady_ms,
)
from repro.cim.cost import (
    DEFAULT_DEVICE,
    DEFAULT_POLICY,
    POLICIES,
    DeviceSpec,
    cim_wins_rows,
    normalize_policy,
    plan_offload,
)
from repro.cim.dispatch import BoundedLRU, cache_stats
from repro.cim.opset import CimOpError
from repro.cim.trace import trace
from repro.core.offload import analyze

from _hypothesis_compat import HealthCheck, given, settings, st

_PROP = dict(max_examples=10, deadline=None,
             suppress_health_check=[HealthCheck.function_scoped_fixture])

# <= 16-bit dtypes: the property spec has 128 rows, and a mul's 2n-bit
# product planes must fit them (an int32 product needs 192)
DTYPES = (jnp.int8, jnp.int16, jnp.uint8, jnp.uint16)


def _operand(dtype, n_words, seed):
    info = jnp.iinfo(dtype)
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(int(info.min), int(info.max) + 1,
                                   n_words, dtype=np.int64).astype(
                                       np.dtype(dtype.dtype
                                                if hasattr(dtype, "dtype")
                                                else dtype)))


def _assert_tree_equal(got, want):
    import jax

    got_l = jax.tree_util.tree_leaves(got)
    want_l = jax.tree_util.tree_leaves(want)
    assert len(got_l) == len(want_l)
    for g, w in zip(got_l, want_l):
        assert g.dtype == w.dtype, (g.dtype, w.dtype)
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ---------------------------------------------------------------------------
# DeviceSpec: dict/CSV round trips
# ---------------------------------------------------------------------------


def test_device_spec_dict_roundtrip():
    d = DeviceSpec(name="lab-chip", peak_flops=1e12, hbm_bw=1e11,
                   ici_bw=1e10, pj_per_flop=0.7, pj_per_byte=15.0)
    assert DeviceSpec.from_dict(d.to_dict()) == d
    assert d.key == tuple(d.to_dict().values())
    with pytest.raises(ValueError):
        DeviceSpec.from_dict({"name": "x", "warp_drive": 9000})


def test_device_spec_csv_roundtrip(tmp_path):
    path = tmp_path / "devices.csv"
    path.write_text(
        "name,peak_flops,hbm_bw,ici_bw,pj_per_flop,pj_per_byte\n"
        "tpu-v5e,197e12,819e9,50e9,0.5,20.0\n"
        "sim-a,1e12,1e11,1e10,0.9,30.0\n")
    first = DeviceSpec.from_csv(str(path))
    assert first == DEFAULT_DEVICE
    other = DeviceSpec.from_csv(str(path), name="sim-a")
    assert other.name == "sim-a" and other.pj_per_byte == 30.0
    with pytest.raises(ValueError):
        DeviceSpec.from_csv(str(path), name="nope")
    (tmp_path / "empty.csv").write_text("name\n")
    with pytest.raises(ValueError):
        DeviceSpec.from_csv(str(tmp_path / "empty.csv"))


def test_normalize_policy():
    assert normalize_policy(None) == DEFAULT_POLICY
    assert normalize_policy("cost") == "edp"
    for p in POLICIES:
        assert normalize_policy(p) == p
    with pytest.raises(ValueError):
        normalize_policy("yolo")


# ---------------------------------------------------------------------------
# projection == execution: access/wave parity on random banked graphs
# ---------------------------------------------------------------------------

_N_STEP_KINDS = 8


def _apply_step(kind, sel, vals):
    x = vals[sel % len(vals)]
    y = vals[(sel // 7) % len(vals)]
    if x.dtype != y.dtype:
        y = y.astype(x.dtype)
    k = kind % _N_STEP_KINDS
    if k == 0:
        return x + y
    if k == 1:
        return x - y
    if k == 2:
        return x * y
    if k == 3:
        return jnp.bitwise_xor(x, y)
    if k == 4:
        return jnp.minimum(x, y)
    if k == 5:
        return jnp.maximum(x, y)
    if k == 6:
        return jnp.where(x < y, x, y)
    return x + jnp.sum(x)              # k == 7: tree reduce + rebroadcast


def _random_fn(steps):
    def fn(a, b):
        vals = [a, b]
        for kind, sel in steps:
            vals.append(_apply_step(kind, sel, vals))
        return tuple(vals[-2:])
    return fn


@given(st.integers(0, 2**31 - 1), st.integers(0, len(DTYPES) - 1),
       st.integers(1, 5))
@settings(**_PROP)
def test_projected_counts_equal_executed_banked_ledger(seed, dtype_idx,
                                                       n_steps):
    """For any random graph on a banked spec, the cost model's projected
    access count (sum of per-eqn banked accesses) equals the executed
    ledger EXACTLY, and the projected critical path (sum of per-eqn waves)
    equals the busiest bank slot's activation count."""
    rng = np.random.RandomState(seed)
    dtype = DTYPES[dtype_idx]
    steps = [(int(rng.randint(0, _N_STEP_KINDS)), int(rng.randint(0, 10_000)))
             for _ in range(n_steps)]
    fn = _random_fn(steps)
    a = _operand(dtype, 96, seed)
    b = _operand(dtype, 96, seed + 1)
    spec = ArraySpec(banks=2, subarrays=1, rows=128, bitline_words=32)

    plan = plan_offload(trace(fn, a, b), spec=spec, policy="always")
    est_accesses = sum(v.banked_accesses for v in plan.verdicts)
    est_waves = sum(v.waves for v in plan.verdicts)

    lf = lower(fn, backend="jnp-boolean", spec=spec, policy="always")
    LEDGER.reset()
    _assert_tree_equal(lf(a, b), fn(a, b))
    assert LEDGER.accesses == est_accesses
    assert max(LEDGER.bank_accesses.values(), default=0) == est_waves


def test_projected_words_match_estimator_accounting():
    """The verdict's words32 is the shared estimator accounting — the same
    number analyze() reports per eqn (executed reduce ledgers differ by
    widened intermediate widths, so parity is defined at this layer)."""
    def fn(a, b):
        return (a + b) * b, jnp.sum(a)

    a = jnp.arange(-32, 32, dtype=jnp.int16)
    plan = plan_offload(trace(fn, a, a), policy="always")
    rep = analyze(fn, a, a)
    assert rep.eqn_verdicts == plan.verdicts
    assert sum(v.words32 for v in plan.verdicts) > 0
    assert rep.adra_accesses == sum(v.accesses for v in plan.verdicts)


# ---------------------------------------------------------------------------
# policy semantics through the lowering compiler
# ---------------------------------------------------------------------------

_SLIVER_SPEC = ArraySpec(banks=2, subarrays=1, rows=1024, bitline_words=32)


def _sliver_args():
    a = jnp.array([3, -9, 5, 7], jnp.int16)
    return a, 5 - a


def test_default_policy_demotes_pad_dominated_shape():
    """4 useful words on 32-word tiles (12% utilization): the default edp
    policy keeps the eqn on the host — zero accesses — and the result is
    still bit-exact via host execution."""
    def fn(a, b):
        return a + b

    a, b = _sliver_args()
    lf = lower(fn, backend="jnp-boolean", spec=_SLIVER_SPEC)
    comp = lf.trace(a, b)
    assert comp.policy == "edp"
    assert comp.accesses == 0
    assert len(comp.regions) == 0
    assert comp.offload_plan.demoted_eqns == 1
    v = comp.offload_plan.verdict_for(0)
    assert v is not None and not v.lowers and v.margin < 0
    assert "loses" in v.reason
    assert "demoted" in comp.describe()
    _assert_tree_equal(lf(a, b), fn(a, b))

    forced = lower(fn, backend="jnp-boolean", spec=_SLIVER_SPEC,
                   policy="always")
    comp_f = forced.trace(a, b)
    assert comp_f.accesses == 1 and len(comp_f.regions) == 1
    _assert_tree_equal(forced(a, b), fn(a, b))


def test_demotion_visible_in_offload_report():
    def fn(a, b):
        return a + b

    a, b = _sliver_args()
    rep = analyze(fn, a, b, spec=_SLIVER_SPEC, policy="edp")
    assert rep.policy == "edp"
    assert rep.demoted_eqns == 1
    assert rep.demoted_accesses == 1
    assert any(not v.lowers for v in rep.eqn_verdicts)
    # the report's historical default remains the un-demoted projection
    rep_always = analyze(fn, a, b, spec=_SLIVER_SPEC)
    assert rep_always.policy == "always" and rep_always.demoted_eqns == 0


def test_always_policy_bit_exact_with_default_on_winning_shapes():
    """On fully-utilized tiles the edp default demotes nothing, so default
    and policy='always' produce identical results AND identical dispatch
    counts — the acceptance bar for 'no behavior change on winners'."""
    def fn(a, b):
        t = (a + b) * b
        p = t < a
        return jnp.where(p, t, a), jnp.sum(t)

    a = jnp.arange(-64, 64, dtype=jnp.int16)
    b = 5 - a
    spec = ArraySpec(banks=2, subarrays=1, rows=128, bitline_words=32)

    counts = {}
    for policy in (None, "always"):
        lf = lower(fn, backend="jnp-boolean", spec=spec, policy=policy)
        comp = lf.trace(a, b)
        before = cache_stats()["dispatches"]
        out = lf(a, b)
        counts[policy] = (comp.accesses,
                          cache_stats()["dispatches"] - before)
        _assert_tree_equal(out, fn(a, b))
    assert counts[None] == counts["always"]
    assert counts[None][0] > 0


def test_never_policy_hosts_everything():
    def fn(a, b):
        return (a + b) ^ a

    a = jnp.arange(-16, 16, dtype=jnp.int16)
    lf = lower(fn, backend="jnp-boolean", policy="never")
    comp = lf.trace(a, a)
    assert comp.accesses == 0 and len(comp.regions) == 0
    assert comp.offload_plan.demoted_eqns == 2
    _assert_tree_equal(lf(a, a), fn(a, a))


def test_latency_policy_demotes_host_winning_sliver():
    """Physical-units policy: 4 words cannot amortize the array's access
    latency against a ~200 TFLOP/s roofline, so 'latency' hosts them."""
    def fn(a, b):
        return a + b

    a, b = _sliver_args()
    lf = lower(fn, backend="jnp-boolean", policy="latency")
    comp = lf.trace(a, b)
    assert comp.accesses == 0
    v = comp.offload_plan.verdict_for(0)
    assert not v.lowers and v.host_time_s < v.cim_time_s
    _assert_tree_equal(lf(a, b), fn(a, b))


# ---------------------------------------------------------------------------
# fusion-boundary re-evaluation: the sandwich cases
# ---------------------------------------------------------------------------


def test_interior_loser_kept_fused_when_toll_dominates():
    """win / lose / win where 2048 packed words32 cross the loser: hosting
    it would unpack+repack all of them, so the plan keeps it fused and
    marks the verdict fused=True (still lowers=False)."""
    def fn(a, s):
        t = a + a          # eqn 0: 4096 words, full tiles -> wins
        u = s * s          # eqn 1: 4 words, 12% utilized -> loses
        v = t ^ a          # eqn 2: consumes t ACROSS eqn 1 -> toll
        return u, v

    a = jnp.arange(4096, dtype=jnp.int16)
    s = jnp.array([3, -9, 5, 7], jnp.int16)
    plan = plan_offload(trace(fn, a, s), spec=_SLIVER_SPEC, policy="edp")
    assert plan.demoted_eqns == 0
    assert plan.fused_losses == 1
    v1 = plan.verdict_for(1)
    assert v1.fused and not v1.lowers

    lf = lower(fn, backend="jnp-boolean", spec=_SLIVER_SPEC)
    comp = lf.trace(a, s)
    assert len(comp.regions) == 1          # the sandwich stays one region
    assert "kept fused" in comp.describe()
    _assert_tree_equal(lf(a, s), fn(a, s))


def test_interior_loser_splits_run_when_nothing_crosses():
    """Same loser, but no value crosses it: the toll is zero, so the run
    splits around the demoted eqn and both winning halves still lower."""
    def fn(a, s):
        t = a + a          # eqn 0: wins
        u = s * s          # eqn 1: loses, nothing crosses
        v = a ^ a          # eqn 2: wins, consumes only inputs
        return t, u, v

    a = jnp.arange(4096, dtype=jnp.int16)
    s = jnp.array([3, -9, 5, 7], jnp.int16)
    plan = plan_offload(trace(fn, a, s), spec=_SLIVER_SPEC, policy="edp")
    assert 1 in plan.demoted
    assert plan.fused_losses == 0
    assert plan.verdict_for(0).lowers and plan.verdict_for(2).lowers

    lf = lower(fn, backend="jnp-boolean", spec=_SLIVER_SPEC)
    comp = lf.trace(a, s)
    assert len(comp.regions) == 2          # split around the hosted eqn
    _assert_tree_equal(lf(a, s), fn(a, s))


def test_schedule_placed_waves_is_the_cost_models_critical_path():
    """Schedule.placed_waves (planner) == accesses x TilePlan.waves — the
    latency term project_eqn charges, and the number the executed ledger's
    busiest bank slot reaches."""
    from repro.cim import planner

    spec = ArraySpec(banks=2, subarrays=1, rows=128, bitline_words=32)
    sched = planner.plan_multiply(8, 8)
    n_words = 96
    assert sched.placed_waves == len(sched.steps)          # unplaced: 1 wave
    placed = sched.placed(spec, n_words)
    assert placed.placed_waves == \
        len(sched.steps) * spec.plan(n_words).waves

    def fn(a, b):
        return a * b

    a = _operand(jnp.int8, n_words, 3)
    b = _operand(jnp.int8, n_words, 4)
    plan = plan_offload(trace(fn, a, b), spec=spec, policy="always")
    v = max(plan.verdicts, key=lambda x: x.accesses)
    assert v.waves == placed.placed_waves


def test_cim_wins_rows_shapes():
    rows = cim_wins_rows()
    assert len(rows) == 3
    assert rows[0]["lowers"] and rows[1]["lowers"]
    assert not rows[2]["lowers"]
    assert rows[2]["edp_margin_pct"] < 0 < rows[0]["edp_margin_pct"]


# ---------------------------------------------------------------------------
# BoundedLRU (the shared cache policy)
# ---------------------------------------------------------------------------


def test_bounded_lru_bound_and_counters():
    lru = BoundedLRU(capacity=2)
    lru.put("a", 1)
    lru.put("b", 2)
    assert lru.get("a") == 1           # refresh a
    lru.put("c", 3)                    # evicts b (coldest)
    assert len(lru) == 2
    assert "b" not in lru and "a" in lru and "c" in lru
    assert lru.get("b") is None
    s = lru.stats()
    assert s["evictions"] == 1 and s["hits"] == 1 and s["misses"] == 1
    assert s["capacity"] == 2 and s["entries"] == 2
    lru.clear()
    assert len(lru) == 0 and lru.stats()["hits"] == 0
    with pytest.raises(CimOpError):
        BoundedLRU(capacity=0)


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------


def _tune_fn():
    def fn(a, b):
        return (a + b) * b

    a = jnp.arange(-32, 32, dtype=jnp.int16)
    return fn, (a, 5 - a)


_SMALL_CANDIDATES = (
    Candidate(banks=2, subarrays=2, bitline_words=1024),
    Candidate(banks=4, subarrays=4, bitline_words=1024, scheme="scheme2"),
)


def test_autotune_predict_only_deterministic():
    fn, args = _tune_fn()
    tuner = Autotuner()
    r1 = tuner.tune(fn, args, candidates=_SMALL_CANDIDATES,
                    backend="jnp-boolean", measure=False)
    assert tuner.searches == 1 and not r1.from_cache
    assert repr(DEFAULT_CANDIDATE) in r1.predicted_edp
    assert r1.predicted_edp[repr(r1.winner)] <= \
        r1.predicted_edp[repr(DEFAULT_CANDIDATE)]
    assert r1.tuned_vs_default_edp_ratio >= 1.0

    r2 = Autotuner().tune(fn, args, candidates=_SMALL_CANDIDATES,
                          backend="jnp-boolean", measure=False)
    assert r2.winner == r1.winner and r2.predicted_edp == r1.predicted_edp


def test_autotune_measured_never_regresses_default():
    fn, args = _tune_fn()
    tuner = Autotuner()
    res = tuner.tune(fn, args, candidates=_SMALL_CANDIDATES,
                     backend="jnp-boolean", steady_n=1)
    assert res.default_ms is not None and res.tuned_ms is not None
    assert res.tuned_ms <= res.default_ms
    assert res.tuned_vs_default_walltime_ratio >= 1.0
    assert res.tuned_vs_default_edp_ratio >= 1.0
    assert res.measured_ms                    # at least the default measured


def test_autotune_warm_cache_skips_search():
    fn, args = _tune_fn()
    tuner = Autotuner()
    cold = tuner.tune(fn, args, candidates=_SMALL_CANDIDATES,
                      backend="jnp-boolean", measure=False)
    assert tuner.searches == 1
    warm = tuner.tune(fn, args, candidates=_SMALL_CANDIDATES,
                      backend="jnp-boolean", measure=False)
    assert warm.from_cache and warm.winner == cold.winner
    assert warm.key == cold.key
    assert tuner.searches == 1                # zero re-searches
    assert tuner.winners.stats()["hits"] == 1


def test_autotune_winners_json_roundtrip(tmp_path):
    fn, args = _tune_fn()
    tuner = Autotuner()
    cold = tuner.tune(fn, args, candidates=_SMALL_CANDIDATES,
                      backend="jnp-boolean", measure=False)
    path = str(tmp_path / "winners.json")
    tuner.save(path)

    fresh = Autotuner()
    assert fresh.load(path) == 1
    warm = fresh.tune(fn, args, candidates=_SMALL_CANDIDATES,
                      backend="jnp-boolean", measure=False)
    assert warm.from_cache and warm.winner == cold.winner
    assert fresh.searches == 0                # the whole point of the file

    other = Autotuner(device=DeviceSpec(name="not-this-chip"))
    with pytest.raises(ValueError):
        other.load(path)


def test_autotune_winners_table_is_bounded():
    tuner = Autotuner(capacity=1)
    fn1, args1 = _tune_fn()

    def fn2(a, b):
        return a - b

    tuner.tune(fn1, args1, candidates=(), backend="jnp-boolean",
               measure=False)
    tuner.tune(fn2, args1, candidates=(), backend="jnp-boolean",
               measure=False)
    assert len(tuner.winners) == 1            # first winner evicted
    assert tuner.winners.stats()["evictions"] == 1


def test_steady_ms_counts_only_steady_calls():
    calls = []
    ms = steady_ms(lambda: calls.append(1), n=3)
    assert len(calls) == 4                    # 1 warmup + 3 timed
    assert ms >= 0.0

"""Unified CiM engine: backend parity for the FULL op surface, packed-plane
chaining with zero intermediate pack/unpack, traffic accounting, registry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import cim
from repro.cim import PlanePack
from repro.core import bitplane

#: every backend that runs on a CPU host (pallas-tpu needs real hardware)
BACKENDS = ("pallas-interpret", "jnp-boolean", "analog-oracle")

RNG = np.random.RandomState(7)


def _pair(n_bits, n):
    lo, hi = -(2 ** (n_bits - 1)), 2 ** (n_bits - 1)
    a = jnp.array(RNG.randint(lo, hi, n), jnp.int32)
    b = jnp.array(RNG.randint(lo, hi, n), jnp.int32)
    return a, b


# ---------------------------------------------------------------------------
# backend parity: arithmetic + comparison
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n_bits,n", [(4, 48), (8, 70)])
def test_add_sub_compare_parity(backend, n_bits, n):
    a, b = _pair(n_bits, n)
    an, bn = np.array(a), np.array(b)
    np.testing.assert_array_equal(
        np.array(cim.add(a, b, n_bits, backend=backend)), an + bn)
    np.testing.assert_array_equal(
        np.array(cim.sub(a, b, n_bits, backend=backend)), an - bn)
    c = cim.compare(a, b, n_bits, backend=backend)
    np.testing.assert_array_equal(np.array(c.lt), (an < bn).astype(np.int32))
    np.testing.assert_array_equal(np.array(c.eq), (an == bn).astype(np.int32))
    np.testing.assert_array_equal(np.array(c.gt), (an > bn).astype(np.int32))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("fn", cim.BOOLEAN_OPS)
def test_all_16_boolean_functions_every_backend(backend, fn):
    """Every Boolean function, on every registered backend, from one access."""
    n_bits, m = 4, 15
    A = jnp.arange(16, dtype=jnp.int32)
    AA, BB = [x.ravel() for x in jnp.meshgrid(A, A, indexing="ij")]
    a, b = np.array(AA), np.array(BB)
    ref = {
        "false": np.zeros_like(a), "true": np.full_like(a, m),
        "and": a & b, "or": a | b, "xor": a ^ b,
        "nand": (~(a & b)) & m, "nor": (~(a | b)) & m, "xnor": (~(a ^ b)) & m,
        "a": a, "b": b, "not_a": (~a) & m, "not_b": (~b) & m,
        "a_and_not_b": a & ((~b) & m), "not_a_and_b": ((~a) & m) & b,
        "a_or_not_b": a | ((~b) & m), "not_a_or_b": ((~a) & m) | b,
    }[fn]
    got = np.array(cim.boolean(AA, BB, fn, n_bits, backend=backend))
    np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("backend", BACKENDS)
def test_fused_multi_op_single_access(backend):
    """Boolean + sub + compare + carries, ONE access, matches semantics."""
    a, b = _pair(8, 64)
    an, bn = np.array(a), np.array(b)
    out = cim.execute(PlanePack.pack(a, 8), PlanePack.pack(b, 8),
                      ("xor", "sub", "add", "lt", "eq", "gt",
                       "carry_add", "carry_sub"), backend=backend)
    np.testing.assert_array_equal(np.array(out["xor"].unpack()),
                                  (an & 0xFF) ^ (bn & 0xFF))
    np.testing.assert_array_equal(np.array(out["sub"].unpack()), an - bn)
    np.testing.assert_array_equal(np.array(out["add"].unpack()), an + bn)
    np.testing.assert_array_equal(np.array(out["lt"].unpack()),
                                  (an < bn).astype(np.int32))
    np.testing.assert_array_equal(np.array(out["eq"].unpack()),
                                  (an == bn).astype(np.int32))
    np.testing.assert_array_equal(np.array(out["gt"].unpack()),
                                  (an > bn).astype(np.int32))


@pytest.mark.parametrize("backend", BACKENDS)
def test_unsigned_operands_not_misread_as_negative(backend):
    """Unsigned packs with the top bit set: the engine must zero-extend before
    the two's-complement ripple, not let the overflow module sign-extend."""
    a = jnp.array([0, 255, 200, 7], jnp.int32)
    b = jnp.array([200, 1, 200, 255], jnp.int32)
    pa = PlanePack.pack(a, 8, signed=False)
    pb = PlanePack.pack(b, 8, signed=False)
    out = cim.execute(pa, pb, ("sub", "add", "lt", "eq", "gt"), backend=backend)
    an, bn = np.array(a), np.array(b)
    np.testing.assert_array_equal(np.array(out["sub"].unpack()), an - bn)
    np.testing.assert_array_equal(np.array(out["add"].unpack()), an + bn)
    np.testing.assert_array_equal(np.array(out["lt"].unpack()),
                                  (an < bn).astype(np.int32))
    np.testing.assert_array_equal(np.array(out["eq"].unpack()),
                                  (an == bn).astype(np.int32))
    np.testing.assert_array_equal(np.array(out["gt"].unpack()),
                                  (an > bn).astype(np.int32))


def test_chained_boolean_result_into_arithmetic():
    """Engine Boolean outputs are unsigned packs; chaining one into a sub
    must treat it as a magnitude, packed end to end."""
    a = jnp.array(RNG.randint(0, 256, 64), jnp.int32)
    b = jnp.array(RNG.randint(0, 256, 64), jnp.int32)
    c = jnp.array(RNG.randint(0, 256, 64), jnp.int32)
    pa = PlanePack.pack(a, 8, signed=False)
    pb = PlanePack.pack(b, 8, signed=False)
    pc = PlanePack.pack(c, 8, signed=False)
    or_ = cim.execute(pa, pb, ("or",), backend="jnp-boolean")["or"]
    assert not or_.signed
    d = cim.execute(or_, pc, ("sub",), backend="jnp-boolean")["sub"]
    np.testing.assert_array_equal(np.array(d.unpack()),
                                  (np.array(a) | np.array(b)) - np.array(c))


def test_unfused_baseline_matches_fused():
    a, b = _pair(8, 100)
    fused = cim.execute(PlanePack.pack(a, 8), PlanePack.pack(b, 8),
                        ("sub", "lt", "eq"), backend="jnp-boolean")
    unfused = cim.execute_unfused(PlanePack.pack(a, 8), PlanePack.pack(b, 8),
                                  (("sub",), ("lt", "eq")),
                                  backend="jnp-boolean")
    for op in ("sub", "lt", "eq"):
        np.testing.assert_array_equal(np.array(fused[op].unpack()),
                                      np.array(unfused[op].unpack()))


# ---------------------------------------------------------------------------
# PlanePack: chaining without repacking
# ---------------------------------------------------------------------------


def test_planepack_roundtrip_shapes():
    x = jnp.array(RNG.randint(-100, 100, (3, 5, 4)), jnp.int32)
    p = PlanePack.pack(x, 8)
    assert p.planes.dtype == jnp.uint32
    assert p.shape == (3, 5, 4) and p.n_words == 60
    np.testing.assert_array_equal(np.array(p.unpack()), np.array(x))


def test_planepack_extend_preserves_value():
    x = jnp.array([-7, 0, 5, -128, 127], jnp.int32)
    p = PlanePack.pack(x, 8).extend_to(12)
    assert p.n_bits == 12
    np.testing.assert_array_equal(np.array(p.unpack()), np.array(x))
    u = PlanePack.pack(jnp.array([3, 9], jnp.int32), 4, signed=False).extend_to(9)
    np.testing.assert_array_equal(np.array(u.unpack()), [3, 9])


def test_chained_ops_zero_intermediate_pack_unpack():
    """(a - b) - c stays in the packed-plane domain: the codec is entered
    exactly once per operand at entry and once at exit, never between ops."""
    a, b = _pair(8, 64)
    c = jnp.array(RNG.randint(-100, 100, 64), jnp.int32)
    pa, pb, pc = (PlanePack.pack(v, 8) for v in (a, b, c))

    bitplane.reset_codec_call_counts()
    d1 = cim.execute(pa, pb, ("sub",), backend="jnp-boolean")["sub"]
    d2 = cim.execute(d1, pc.extend_to(d1.n_bits), ("sub",),
                     backend="jnp-boolean")["sub"]
    assert bitplane.codec_call_counts() == {"pack": 0, "unpack": 0}
    np.testing.assert_array_equal(np.array(d2.unpack()),
                                  np.array(a) - np.array(b) - np.array(c))


def test_chained_pipeline_jaxpr_has_no_codec_ops():
    """The traced two-op pipeline contains no pack/unpack computation: the
    codecs lower to weighted reduce_sum / shift chains, neither of which may
    appear between chained engine calls."""
    a, b = _pair(8, 64)
    c = jnp.array(RNG.randint(-100, 100, 64), jnp.int32)
    pa, pb, pc = (PlanePack.pack(v, 8) for v in (a, b, c))

    def chain(pa, pb, pc):
        d1 = cim.execute(pa, pb, ("sub",), backend="jnp-boolean")["sub"]
        return cim.execute(d1, pc.extend_to(d1.n_bits), ("sub",),
                           backend="jnp-boolean")["sub"]

    text = str(jax.make_jaxpr(chain)(pa, pb, pc))
    assert "reduce_sum" not in text and "shift_right" not in text


# ---------------------------------------------------------------------------
# traffic + accounting: the one-access argument, quantified
# ---------------------------------------------------------------------------


def test_fused_traffic_ratio_exceeds_1p5():
    """Acceptance: Boolean fn + subtraction + comparison from one streamed
    pass moves > 1.5x less HBM traffic than per-function baseline passes."""
    t = cim.traffic_model_bytes(
        16, 4096, ops=("xor", "sub", "lt", "eq"),
        baseline_passes=(("xor",), ("sub",), ("lt", "eq")))
    assert t["ratio"] > 1.5, t

    a, b = _pair(16, 2048)
    m = cim.measured_traffic_bytes(
        PlanePack.pack(a, 16), PlanePack.pack(b, 16),
        ("xor", "sub", "lt", "eq"),
        baseline_passes=(("xor",), ("sub",), ("lt", "eq")),
        backend="jnp-boolean")
    assert m["ratio"] > 1.5, m


def test_legacy_traffic_model_compat():
    from repro.kernels.adra_bitplane import traffic_model_bytes
    t = traffic_model_bytes(n_bits=16, n_words32=4096)
    assert t["baseline"] > t["fused"] and t["ratio"] > 1.4


def test_energy_ledger_charges_single_access():
    led = cim.ledger()
    led.reset()
    a, b = _pair(8, 64)
    cim.execute(PlanePack.pack(a, 8), PlanePack.pack(b, 8),
                ("sub", "lt", "eq"), backend="jnp-boolean")
    assert led.accesses == 1            # fused: ONE access for three ops
    cim.execute_unfused(PlanePack.pack(a, 8), PlanePack.pack(b, 8),
                        (("sub",), ("lt", "eq")), backend="jnp-boolean")
    assert led.accesses == 3            # baseline: one per pass
    proj = led.projected()
    assert proj["energy_saved"] > 0 and proj["edp_decrease_pct"] > 60


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_backend_registry_contents_and_errors():
    names = cim.available_backends()
    for required in ("pallas-tpu", "pallas-interpret", "jnp-boolean",
                     "analog-oracle"):
        assert required in names
    with pytest.raises(KeyError):
        cim.get_backend("no-such-backend")
    with pytest.raises(ValueError):
        cim.execute(PlanePack.pack(jnp.arange(4), 4),
                    PlanePack.pack(jnp.arange(4), 4), ("bogus-op",))


def test_default_backend_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_CIM_BACKEND", "jnp-boolean")
    assert cim.default_backend_name() == "jnp-boolean"
    monkeypatch.delenv("REPRO_CIM_BACKEND")
    cim.set_default_backend("analog-oracle")
    try:
        assert cim.default_backend_name() == "analog-oracle"
    finally:
        cim.set_default_backend(None)


def test_ops_wrappers_dispatch_through_engine():
    """kernels.ops keeps its legacy contract on top of the engine."""
    from repro.kernels import ops

    a, b = _pair(8, 130)
    an, bn = np.array(a), np.array(b)
    d, lt, eq = ops.adra_sub(a, b, n_bits=8)          # registry default
    np.testing.assert_array_equal(np.array(d), an - bn)
    np.testing.assert_array_equal(np.array(lt), (an < bn).astype(np.int32))
    np.testing.assert_array_equal(np.array(eq), (an == bn).astype(np.int32))
    s = ops.adra_add(a, b, n_bits=9, interpret=True)  # pinned Pallas path
    np.testing.assert_array_equal(np.array(s), an + bn)

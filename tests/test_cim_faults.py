"""Fault layer tests: deterministic injection, SECDED ECC on resident
operands, bank failover remapping, and the shared fault-seed convention.

The chaos tests of the serve engine itself (mid-run bank kill, shedding)
live in tests/test_serve_engine.py; this file covers the substrate:
faults.py, the planepack SECDED codec, ResidentSet verify/scrub, TilePlan
dead-bank remapping, PagedKV migration, and ledger ECC accounting.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.cim import dispatch, engine, faults
from repro.cim.accounting import LEDGER
from repro.cim.array import ArraySpec, ResidentSet, resident_set
from repro.cim.opset import CimOpError
from repro.cim.planepack import (PlanePack, ecc_check_correct, ecc_encode,
                                 ecc_plane_count)

SPEC = ArraySpec(banks=2, subarrays=1, rows=64, bitline_words=32)
ECC_SPEC = ArraySpec(banks=4, subarrays=1, rows=256, bitline_words=32)


@pytest.fixture(autouse=True)
def _clean_overlay():
    faults.uninstall()
    faults.reset_fault_stats()
    yield
    faults.uninstall()
    faults.reset_fault_stats()


def _packs(n=128, bits=8):
    x = np.arange(n, dtype=np.int32) % 100
    y = np.ones(n, dtype=np.int32)
    return (x, y, PlanePack.pack(jnp.asarray(x), bits),
            PlanePack.pack(jnp.asarray(y), bits))


# ---------------------------------------------------------------------------
# SECDED codec
# ---------------------------------------------------------------------------


class TestSecded:
    def test_plane_counts(self):
        # classic Hamming r for m data bits, plus the overall parity plane
        assert ecc_plane_count(1) == 3
        assert ecc_plane_count(4) == 4
        assert ecc_plane_count(8) == 5
        assert ecc_plane_count(16) == 6

    def test_clean_roundtrip(self):
        pl = np.random.default_rng(0).integers(
            0, 2**32, size=(8, 6), dtype=np.uint32)
        par = ecc_encode(pl)
        assert par.shape == (5, 6)
        data, p2, corrected, uncorrected = ecc_check_correct(pl, par)
        assert corrected == 0 and uncorrected == 0
        assert (data == pl).all() and (p2 == par).all()

    def test_corrects_every_single_data_bit(self):
        pl = np.random.default_rng(1).integers(
            0, 2**32, size=(8, 2), dtype=np.uint32)
        par = ecc_encode(pl)
        for plane in range(8):
            for bit in (0, 13, 31, 45):
                bad = pl.copy()
                bad[plane, bit // 32] ^= np.uint32(1) << np.uint32(bit % 32)
                data, _, c, u = ecc_check_correct(bad, par)
                assert c == 1 and u == 0
                assert (data == pl).all()

    def test_corrects_single_parity_bit(self):
        pl = np.random.default_rng(2).integers(
            0, 2**32, size=(8, 2), dtype=np.uint32)
        par = ecc_encode(pl)
        for pplane in range(par.shape[0]):
            bad = par.copy()
            bad[pplane, 0] ^= np.uint32(1)
            data, fixed_par, c, u = ecc_check_correct(pl, bad)
            assert c == 1 and u == 0
            assert (data == pl).all() and (fixed_par == par).all()

    def test_detects_double_never_miscorrects(self):
        # SECDED's guarantee: two errors in one element's column are
        # DETECTED (flagged uncorrectable), never silently miscorrected
        pl = np.random.default_rng(3).integers(
            0, 2**32, size=(8, 2), dtype=np.uint32)
        par = ecc_encode(pl)
        for p1, p2 in [(0, 1), (2, 7), (0, 7), (3, 4)]:
            bad = pl.copy()
            bad[p1, 0] ^= np.uint32(1)
            bad[p2, 0] ^= np.uint32(1)
            _, _, c, u = ecc_check_correct(bad, par)
            assert u == 1 and c == 0

    def test_independent_columns(self):
        # one single-bit error in each of two different elements: both
        # corrected (the code protects each column independently)
        pl = np.random.default_rng(4).integers(
            0, 2**32, size=(8, 2), dtype=np.uint32)
        par = ecc_encode(pl)
        bad = pl.copy()
        bad[1, 0] ^= np.uint32(1 << 5)
        bad[6, 1] ^= np.uint32(1 << 20)
        data, _, c, u = ecc_check_correct(bad, par)
        assert c == 2 and u == 0 and (data == pl).all()


# ---------------------------------------------------------------------------
# deterministic injection
# ---------------------------------------------------------------------------


class TestInjection:
    def test_same_seed_same_faults(self):
        x, y, pa, pb = _packs()
        with faults.faults(faults.FaultConfig(seed=1, ber=2e-3)) as fm1:
            d1 = dispatch.execute_tiled(pa, pb, ("add",),
                                        spec=SPEC)["add"].unpack()
        with faults.faults(faults.FaultConfig(seed=1, ber=2e-3)) as fm2:
            d2 = dispatch.execute_tiled(pa, pb, ("add",),
                                        spec=SPEC)["add"].unpack()
        assert fm1.injected == fm2.injected > 0
        assert (np.asarray(d1) == np.asarray(d2)).all()

    def test_different_seed_different_faults(self):
        x, y, pa, pb = _packs()
        outs = []
        for seed in (1, 2):
            with faults.faults(faults.FaultConfig(seed=seed, ber=2e-3)):
                outs.append(np.asarray(dispatch.execute_tiled(
                    pa, pb, ("add",), spec=SPEC)["add"].unpack()))
        assert not (outs[0] == outs[1]).all()

    def test_no_model_no_change(self):
        x, y, pa, pb = _packs()
        out = dispatch.execute_tiled(pa, pb, ("add",), spec=SPEC)
        assert (np.asarray(out["add"].unpack()) == x + y).all()
        assert faults.fault_stats()["fault_injected"] == 0

    def test_engine_path_injects(self):
        x, y, pa, pb = _packs()
        with faults.faults(faults.FaultConfig(seed=2, ber=5e-3)) as fm:
            engine.execute(pa, pb, ("add",))
        assert fm.injected > 0
        assert dispatch.cache_stats()["fault_injected"] == fm.injected

    def test_stuck_rows_hit_only_their_bank(self):
        x, y, pa, pb = _packs()
        clean = np.asarray(dispatch.execute_tiled(
            pa, pb, ("add",), spec=SPEC)["add"].unpack())
        with faults.faults(faults.FaultConfig(seed=0, stuck=((1, 0, 1),))):
            st = np.asarray(dispatch.execute_tiled(
                pa, pb, ("add",), spec=SPEC)["add"].unpack())
        diff = st != clean
        # bank 1 owns tiles 1 and 3 of the 4-tile placement: words 32..63
        # and 96..127; bank 0's words must be untouched
        assert not diff[:32].any() and not diff[64:96].any()
        assert diff[32:64].any() or diff[96:128].any()

    def test_config_from_env(self, monkeypatch):
        monkeypatch.setenv(faults.ENV_SEED, "42")
        monkeypatch.setenv(faults.ENV_RESIDENT_BER, "1e-4")
        cfg = faults.FaultConfig.from_env()
        assert cfg.seed == 42 and cfg.resident_ber == 1e-4
        assert faults.fault_seed() == 42
        monkeypatch.setenv(faults.ENV_SEED, "not-an-int")
        assert faults.fault_seed(default=7) == 7

    def test_kill_bank_schedule(self):
        fm = faults.FaultModel(faults.FaultConfig(kill_bank_at=(3, 1)))
        fm.on_step(0)
        fm.on_step(2)
        assert fm.dead_banks == ()
        fm.on_step(3)
        assert fm.dead_banks == (1,) and fm.bank_kills == 1
        fm.on_step(4)                              # idempotent
        assert fm.bank_kills == 1


# ---------------------------------------------------------------------------
# ECC-protected resident operands
# ---------------------------------------------------------------------------


def _ecc_set():
    return ResidentSet(ECC_SPEC, reserve_rows=64, ecc=True)


class TestResidentEcc:
    def test_pin_stores_parity_and_charges_ecc(self):
        rs = _ecc_set()
        _, _, pack, _ = _packs()
        LEDGER.reset()
        e = rs.pin(("w",), pack, fingerprint=(1,))
        assert e.ecc_parity is not None
        assert e.ecc_parity.shape[0] == ecc_plane_count(pack.n_bits)
        # 13 rows/bank = 8 data + 5 parity planes per tile
        assert all(r == 13 for r in e.rows_by_bank.values())
        n_tiles = ECC_SPEC.plan(pack.n_words).n_tiles
        assert LEDGER.ecc_accesses == n_tiles
        assert LEDGER.ecc_words32 == pytest.approx(
            pack.n_words * ecc_plane_count(pack.n_bits) / 32.0)
        # the comparable load charge is UNCHANGED by protection
        assert LEDGER.load_accesses == n_tiles

    def test_get_corrects_single_bit_faults(self):
        rs = _ecc_set()
        x, _, pack, _ = _packs()
        rs.pin(("w",), pack, fingerprint=(1,))
        with faults.faults(faults.FaultConfig(seed=3,
                                              resident_ber=2e-4)) as fm:
            for _ in range(20):
                got = rs.get(("w",), fingerprint=(1,))
                assert got is not None
                assert (np.asarray(got.pack.unpack()) == x).all()
        assert fm.injected > 0
        assert rs.ecc_corrected == fm.injected
        assert rs.ecc_uncorrected == 0

    def test_uncorrectable_invalidates_and_misses(self):
        rs = _ecc_set()
        _, _, pack, _ = _packs()
        rs.pin(("w",), pack, fingerprint=(1,))
        cfg = faults.FaultConfig(seed=0, uncorrectable_at_verify=(0,))
        with faults.faults(cfg) as fm:
            assert rs.get(("w",), fingerprint=(1,)) is None
        assert fm.uncorrected == 1
        assert rs.invalidations == 1
        assert rs.get(("w",), fingerprint=(1,)) is None     # really gone

    def test_uncorrectable_raises_when_failstop(self):
        rs = _ecc_set()
        _, _, pack, _ = _packs()
        rs.pin(("w",), pack, fingerprint=(1,))
        cfg = faults.FaultConfig(seed=0, uncorrectable_at_verify=(0,),
                                 raise_on_uncorrectable=True)
        with faults.faults(cfg):
            with pytest.raises(faults.UncorrectableFaultError):
                rs.get(("w",), fingerprint=(1,))
        # the entry was invalidated before raising: a re-pin recovers
        e = rs.pin(("w",), pack, fingerprint=(1,))
        assert rs.get(("w",), fingerprint=(1,)) is e

    def test_scrub_integrates_retention_decay(self):
        rs = _ecc_set()
        x, _, pack, _ = _packs()
        clk = [0.0]
        fm = faults.FaultModel(
            faults.FaultConfig(seed=5, retention_per_s=2.0),
            clock=lambda: clk[0])
        with faults.faults(fm):
            e = rs.pin(("w",), pack, fingerprint=(1,))
            assert e.scrubbed_s == 0.0
            clk[0] = 2.0
            r = rs.scrub()
            assert r["scanned"] == 1
            assert e.scrubbed_s == 2.0      # decay window reset
            got = rs.get(("w",), fingerprint=(1,))
            if got is not None:             # survived (or repaired)
                assert (np.asarray(got.pack.unpack()) == x).all()

    def test_unprotected_set_never_verifies(self):
        rs = ResidentSet(ECC_SPEC, reserve_rows=64, ecc=False)
        _, _, pack, _ = _packs()
        e = rs.pin(("w",), pack)
        assert e.ecc_parity is None
        with faults.faults(faults.FaultConfig(seed=1, resident_ber=1e-3)):
            rs.get(("w",))
        assert rs.ecc_verifies == 0

    def test_registry_default_ecc_toggle(self):
        from repro.cim.array import (clear_resident, resident_ecc_default,
                                     set_resident_ecc)
        clear_resident()
        assert set_resident_ecc(True) is False
        try:
            assert resident_ecc_default()
            assert resident_set(ECC_SPEC).ecc
        finally:
            set_resident_ecc(False)
            clear_resident()

    def test_ledger_fault_counters_and_reset(self):
        LEDGER.reset()
        rs = _ecc_set()
        _, _, pack, _ = _packs()
        rs.pin(("w",), pack, fingerprint=(1,))
        with faults.faults(faults.FaultConfig(
                seed=0, uncorrectable_at_verify=(0,))):
            rs.get(("w",), fingerprint=(1,))
        assert LEDGER.fault_injected >= 2
        assert LEDGER.fault_detected >= 1
        assert LEDGER.fault_uncorrected == 1
        assert LEDGER.ecc_accesses > 0
        LEDGER.reset()
        assert LEDGER.fault_injected == 0 and LEDGER.ecc_accesses == 0
        assert LEDGER.fault_uncorrected == 0 and LEDGER.ecc_words32 == 0


# ---------------------------------------------------------------------------
# resident invalidation counter (fingerprint mismatch)
# ---------------------------------------------------------------------------


def test_fingerprint_mismatch_counts_invalidation():
    rs = ResidentSet(SPEC)
    _, _, pack, _ = _packs(n=32)
    rs.pin(("w",), pack, fingerprint=(1,))
    assert rs.get(("w",), fingerprint=(2,)) is None
    st = rs.stats()
    assert st["invalidations"] == 1 and st["misses"] == 1
    from repro.cim.array import resident_stats
    assert resident_stats()["resident_invalidations"] >= 1
    # the counter also surfaces through the one-stop cache_stats()
    assert "resident_invalidations" in dispatch.cache_stats()


# ---------------------------------------------------------------------------
# bank failover: dead-bank remapping
# ---------------------------------------------------------------------------


class TestFailover:
    def test_disable_bank_validation(self):
        spec = ArraySpec(banks=2, subarrays=1, rows=64, bitline_words=32)
        deg = spec.disable_bank(0)
        assert deg.enabled_banks == (1,) and deg.n_enabled == 1
        with pytest.raises(CimOpError):
            deg.disable_bank(1)                 # nothing left to remap to
        with pytest.raises(CimOpError):
            ArraySpec(banks=2, subarrays=1, rows=64, bitline_words=32,
                      disabled_banks=(5,))

    def test_degraded_plan_skips_dead_banks(self):
        deg = ArraySpec(banks=4, subarrays=1, rows=64, bitline_words=32,
                        disabled_banks=(1, 2))
        plan = deg.plan(4 * 32)
        assert plan.live_banks == (0, 3)
        assert all(plan.bank_of(t) in (0, 3) for t in range(plan.n_tiles))
        assert plan.waves == 2                  # 4 tiles over 2 live banks
        counts = plan.bank_counts(1)
        assert set(b for (_d, b) in counts) == {0, 3}

    def test_remap_is_bit_exact(self):
        x, y, pa, pb = _packs()
        healthy = np.asarray(dispatch.execute_tiled(
            pa, pb, ("add", "lt"), spec=SPEC)["add"].unpack())
        deg = SPEC.disable_bank(0)
        remapped = np.asarray(dispatch.execute_tiled(
            pa, pb, ("add", "lt"), spec=deg)["add"].unpack())
        assert (healthy == remapped).all()
        assert (healthy == x + y).all()

    def test_degraded_spec_is_distinct_cache_key(self):
        deg = SPEC.disable_bank(1)
        assert deg != SPEC and hash(deg) != hash(SPEC) or deg != SPEC
        assert resident_set(SPEC) is not resident_set(deg)

    def test_spec_override_routes_layers(self):
        from repro.cim.array import (current_spec, set_current_spec,
                                     spec_override, DEFAULT_SPEC)
        assert spec_override() is None
        assert current_spec() == DEFAULT_SPEC
        deg = SPEC.disable_bank(0)
        try:
            assert set_current_spec(deg) is None
            assert spec_override() == deg and current_spec() == deg
        finally:
            set_current_spec(None)
        assert spec_override() is None

    def test_paged_kv_migrates_off_dead_bank(self):
        from repro.launch.paged_kv import PagedKV
        rs = ResidentSet(SPEC)
        kv = PagedKV(spec=SPEC, n_blocks=4, block_tokens=4, kv_bits=8,
                     resident_set=rs)
        assert kv.alloc(0, 16)                  # all 4 blocks, banks 0+1
        assert set(rs.rows_per_bank()) == {0, 1}
        deg = SPEC.disable_bank(0)
        rs2 = ResidentSet(deg)
        moved = kv.migrate(deg, rs2)
        assert moved == 4
        assert set(rs2.rows_per_bank()) == {1}  # everything off bank 0
        assert len(rs) == 0                     # old claims released
        assert kv.spec == deg
        kv.free(0)
        assert len(rs2) == 0                    # lifecycle follows the move

    def test_paged_kv_migrate_rolls_back_on_failure(self):
        from repro.launch.paged_kv import PagedKV
        rs = ResidentSet(SPEC)
        kv = PagedKV(spec=SPEC, n_blocks=4, block_tokens=4, kv_bits=8,
                     resident_set=rs)
        assert kv.alloc(0, 16)
        deg = SPEC.disable_bank(0)
        # target set too small: 4 blocks x 8 rows on ONE live bank = 32
        # rows, but only 24 fit — the migration must fail atomically
        rs_small = ResidentSet(deg, reserve_rows=40)
        with pytest.raises(CimOpError):
            kv.migrate(deg, rs_small)
        assert len(rs_small) == 0               # staged claims rolled back
        assert len(rs) == 4 and kv.spec == SPEC  # table untouched

    def test_check_fits_respects_degraded_budget(self):
        deg = ArraySpec(banks=2, subarrays=1, rows=64, bitline_words=32,
                        disabled_banks=(0,))
        assert deg.parallel_words == 32         # one live bank
        plan = deg.plan(64)
        assert plan.n_tiles == 2 and plan.waves == 2


# ---------------------------------------------------------------------------
# the shared seed convention with the training supervisor
# ---------------------------------------------------------------------------


class TestHostFailureHook:
    def test_fires_at_scheduled_steps_once(self):
        from repro.runtime.supervisor import SimulatedHostFailure
        hook = faults.host_failure_hook(fail_steps=(2,))
        hook(0)
        hook(1)
        with pytest.raises(SimulatedHostFailure):
            hook(2)
        hook(2)                                 # replay after restart: clean
        hook(3)

    def test_probabilistic_fires_deterministically(self):
        from repro.runtime.supervisor import SimulatedHostFailure
        failed = []
        hook = faults.host_failure_hook(p_fail=0.5, seed=123)
        for step in range(20):
            try:
                hook(step)
            except SimulatedHostFailure:
                failed.append(step)
        assert failed                           # p=0.5 over 20 steps
        # an identical campaign fails at exactly the same steps
        failed2 = []
        hook2 = faults.host_failure_hook(p_fail=0.5, seed=123)
        for step in range(20):
            try:
                hook2(step)
            except SimulatedHostFailure:
                failed2.append(step)
        assert failed == failed2

    def test_seed_env_convention(self, monkeypatch):
        monkeypatch.setenv(faults.ENV_SEED, "99")
        from repro.runtime.supervisor import SimulatedHostFailure
        hook = faults.host_failure_hook(p_fail=1.0)
        with pytest.raises(SimulatedHostFailure, match="seed 99"):
            hook(0)

    def test_supervisor_recovers_from_hook(self, tmp_path):
        """End-to-end: a Supervisor driven by the shared-seed hook restarts
        through the injected failure and finishes the run — the hook fires
        once, so the restart replay of the same step is clean."""
        from repro.checkpoint import CheckpointManager
        from repro.runtime.supervisor import Supervisor, SupervisorConfig

        def step_fn(st, batch):
            return {"step": st["step"] + 1,
                    "value": st["value"] + batch}, {"loss": jnp.float32(1.0)}

        hook = faults.host_failure_hook(fail_steps=(3,), seed=7)
        sup = Supervisor(step_fn, lambda s: jnp.float32(1.0),
                         CheckpointManager(str(tmp_path), keep=2),
                         SupervisorConfig(ckpt_every=2, max_restarts=4),
                         fault_hook=hook)
        state0 = {"step": jnp.int32(0), "value": jnp.float32(0.0)}
        final, _ = sup.run(state0, 6)
        assert len(sup.events) == 1
        assert int(final["step"]) == 6


# ---------------------------------------------------------------------------
# cost model: ECC overhead weighed by the offload policy
# ---------------------------------------------------------------------------


def test_ecc_overhead_ratio_scales_load_cost():
    from repro.cim import cost
    from repro.cim.trace import trace

    def f(a, b):
        return a + b

    tr = trace(f, np.zeros(64, np.int16), np.ones(64, np.int16))
    op = next(o for o in tr.ops if o.eligible and o.accesses > 0)
    res = __import__("repro.cim.accounting",
                     fromlist=["_SCHEMES"])._SCHEMES["current"](1024)
    plain = cost.project_eqn(op, 0, None, res, cost.DEFAULT_DEVICE, "edp")
    prot = cost.project_eqn(op, 0, None, res, cost.DEFAULT_DEVICE, "edp",
                            ecc_overhead_ratio=cost.ecc_overhead(op.n_bits))
    assert prot.load_words32 > plain.load_words32
    assert prot.cim_energy > plain.cim_energy
    assert cost.ecc_overhead(8) == pytest.approx(5 / 8)
    assert cost.ecc_overhead(16) == pytest.approx(6 / 16)

"""Lowering-parity tests: `lower(fn)` must be bit-exact with plain `fn`.

The differential contract of the jaxpr->CiM compiler (repro.cim.lower):
for any composition of eligible ops — including mixed eligible/ineligible
graphs, INT_MIN / -1 / 0 edges, unsigned wrap-around and dtype converts —
the hybrid callable returns exactly what the un-lowered function returns,
on every CPU backend. Fusion is asserted structurally (region counts,
concatenated schedules) and physically (codec counters prove zero
pack/unpack between chained ops; the ledger proves accesses == plan).

The estimator/executor agreement is asserted too: repro.core.offload's
jaxpr-sourced access counts equal the executed ledger counts, unbanked and
banked.

Runs under real hypothesis when installed and under the seeded-numpy
fallback otherwise (tests/_hypothesis_compat.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cim import ArraySpec, lower
from repro.cim.accounting import LEDGER
from repro.core.bitplane import codec_call_counts, reset_codec_call_counts
from repro.core.offload import analyze

from _hypothesis_compat import HealthCheck, given, settings, st

PORTABLE = ("jnp-boolean", "pallas-interpret")

_PROP = dict(max_examples=20, deadline=None,
             suppress_health_check=[HealthCheck.function_scoped_fixture])

DTYPES = (jnp.int8, jnp.int16, jnp.int32, jnp.uint8, jnp.uint16)


def _edge_operand(dtype, n_words, seed):
    """Random operand with INT_MIN / -1 / 0 / 1 / MAX edges forced in."""
    info = jnp.iinfo(dtype)
    rng = np.random.RandomState(seed)
    edges = np.array([info.min, info.max, 0, 1,
                      info.min + 1, info.max - 1], np.int64)
    n_rand = max(0, n_words - len(edges))
    vals = np.concatenate([
        edges, rng.randint(int(info.min), int(info.max) + 1,
                           n_rand, dtype=np.int64)])[:n_words]
    rng.shuffle(vals)
    return jnp.asarray(vals.astype(np.dtype(dtype.dtype
                                            if hasattr(dtype, "dtype")
                                            else dtype)))


def _assert_tree_equal(got, want):
    got_l = jax.tree_util.tree_leaves(got)
    want_l = jax.tree_util.tree_leaves(want)
    assert len(got_l) == len(want_l)
    for g, w in zip(got_l, want_l):
        assert g.dtype == w.dtype, (g.dtype, w.dtype)
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ---------------------------------------------------------------------------
# randomly composed eligible-op graphs (the property suite)
# ---------------------------------------------------------------------------

_N_STEP_KINDS = 15


def _apply_step(kind, sel, vals):
    """One random graph step over the value pool (pure jnp — the reference
    semantics ARE whatever jnp does, including promotions and wrap)."""
    x = vals[sel % len(vals)]
    y = vals[(sel // 7) % len(vals)]
    if x.dtype != y.dtype:            # keep binops explicit about promotion
        y = y.astype(x.dtype)
    k = kind % _N_STEP_KINDS
    if k == 0:
        return x + y
    if k == 1:
        return x - y
    if k == 2:
        return x * y
    if k == 3:
        return jnp.bitwise_and(x, y)
    if k == 4:
        return jnp.bitwise_or(x, y)
    if k == 5:
        return jnp.bitwise_xor(x, y)
    if k == 6:
        return jnp.minimum(x, y)
    if k == 7:
        return jnp.maximum(x, y)
    if k == 8:
        return -x
    if k == 9:
        return ~x
    if k == 10:                        # compare + select (free peripheral)
        cmp = (x < y, x <= y, x > y, x >= y, x == y, x != y)[sel % 6]
        return jnp.where(cmp, x, y)
    if k == 11:                        # int->int convert round trip
        return x.astype(jnp.int8).astype(x.dtype)
    if k == 12:                        # ineligible float island (host)
        return jnp.floor(x.astype(jnp.float32) / 3.0).astype(x.dtype)
    if k == 13:                        # full tree reduction, re-broadcast
        return x + jnp.sum(x)
    return jnp.abs(x)                  # k == 14


def _random_fn(steps):
    def fn(a, b, c):
        vals = [a, b, c]
        for kind, sel in steps:
            vals.append(_apply_step(kind, sel, vals))
        return tuple(vals[-3:])
    return fn


@given(st.integers(0, 2**31 - 1), st.integers(0, len(DTYPES) - 1),
       st.integers(2, 8))
@settings(**_PROP)
def test_random_composed_graphs_bit_exact(seed, dtype_idx, n_steps):
    rng = np.random.RandomState(seed)
    dtype = DTYPES[dtype_idx]
    steps = [(int(rng.randint(0, _N_STEP_KINDS)), int(rng.randint(0, 10_000)))
             for _ in range(n_steps)]
    fn = _random_fn(steps)
    a = _edge_operand(dtype, 12, seed)
    b = _edge_operand(dtype, 12, seed + 1)
    c = _edge_operand(dtype, 12, seed + 2)
    ref = fn(a, b, c)
    for backend in PORTABLE:
        _assert_tree_equal(lower(fn, backend=backend)(a, b, c), ref)


@given(st.integers(0, 2**31 - 1), st.integers(0, len(DTYPES) - 1))
@settings(**_PROP)
def test_lowered_ledger_always_equals_plan(seed, dtype_idx):
    """For any random graph, one execution charges the ledger EXACTLY the
    planned access count — the cursor guarantee lifted to whole programs —
    and the jaxpr-sourced offload estimate reports the same number."""
    rng = np.random.RandomState(seed)
    dtype = DTYPES[dtype_idx]
    steps = [(int(rng.randint(0, _N_STEP_KINDS)), int(rng.randint(0, 10_000)))
             for _ in range(4)]
    fn = _random_fn(steps)
    args = [_edge_operand(dtype, 12, seed + i) for i in range(3)]
    lf = lower(fn, backend="jnp-boolean")
    comp = lf.trace(*args)
    LEDGER.reset()
    lf(*args)
    assert LEDGER.accesses == comp.accesses
    assert analyze(fn, *args).adra_accesses == LEDGER.accesses


# ---------------------------------------------------------------------------
# fusion structure: one schedule, zero intermediate repacks
# ---------------------------------------------------------------------------


def test_chain_fuses_into_single_schedule_zero_repacks():
    """>= 2 adjacent eligible eqns fuse into ONE region Schedule, and the
    codec counters prove the only pack/unpack are the region's boundary:
    three entry packs, one exit unpack, NOTHING between chained ops."""
    def fn(a, b, c):
        return ((a + b) - c) ^ a

    a = jnp.arange(-16, 16, dtype=jnp.int16)
    b, c = a + 3, a - 7
    lf = lower(fn, backend="jnp-boolean")
    comp = lf.trace(a, b, c)
    assert len(comp.regions) == 1
    region = comp.regions[0]
    assert len(region.ops) == 3 and region.accesses == 3
    assert region.schedule.segments == (("add", 1), ("sub", 1), ("xor", 1))

    reset_codec_call_counts()
    LEDGER.reset()
    out = lf(a, b, c)
    counts = codec_call_counts()
    assert counts == {"pack": 3, "unpack": 1}, counts
    assert LEDGER.accesses == 3
    np.testing.assert_array_equal(np.asarray(out), np.asarray(fn(a, b, c)))


def test_compare_select_chain_is_one_access():
    """lt + both selects of a tournament level fuse to a single access:
    the selects are zero-access peripheral writebacks."""
    def fn(a, b, ia, ib):
        take_b = a < b
        return jnp.where(take_b, b, a), jnp.where(take_b, ib, ia)

    a = jnp.array([3, -9, 5, 7], jnp.int16)
    b = jnp.array([3, 4, -5, 9], jnp.int16)
    ia = jnp.arange(4, dtype=jnp.int32)
    ib = ia + 4
    lf = lower(fn, backend="jnp-boolean")
    comp = lf.trace(a, b, ia, ib)
    assert len(comp.regions) == 1 and comp.accesses == 1
    LEDGER.reset()
    _assert_tree_equal(lf(a, b, ia, ib), fn(a, b, ia, ib))
    assert LEDGER.accesses == 1


def test_mixed_graph_splits_regions_at_host_ops():
    """An ineligible float island splits the graph into two fused regions;
    the hybrid result stays bit-exact."""
    def fn(a, b):
        t = (a + b) * b                        # region 0
        f = jnp.sin(t.astype(jnp.float32))     # host
        q = jnp.round(f * 100.0).astype(jnp.int32)
        return (q - a) ^ b                     # region 1

    a = jnp.arange(-8, 8, dtype=jnp.int32)
    b = 3 - a
    lf = lower(fn, backend="jnp-boolean")
    comp = lf.trace(a, b)
    assert len(comp.regions) == 2
    assert comp.host_eqns >= 3
    np.testing.assert_array_equal(np.asarray(lf(a, b)), np.asarray(fn(a, b)))


def test_nested_jit_output_reused_inside_inlines_correctly():
    """pjit inlining must rename INTERNAL consumers of a nested output too:
    a jitted subfunction whose returned intermediate also feeds another eqn
    inside it lowers (and fuses) instead of crashing on a dangling var."""
    @jax.jit
    def g(x):
        t = x + 1
        return t, t * 2

    def fn(x):
        a, b = g(x)
        return a - b

    x = jnp.arange(-8, 8, dtype=jnp.int16)
    lf = lower(fn, backend="jnp-boolean")
    comp = lf.trace(x)
    assert len(comp.regions) == 1          # add, mul, sub all fuse
    np.testing.assert_array_equal(np.asarray(lf(x)), np.asarray(fn(x)))


def test_closed_over_constant_as_output():
    """A captured constant returned verbatim must round-trip through the
    hybrid executor (constvars seed the env)."""
    c = jnp.arange(3, dtype=jnp.int16)

    def fn(x):
        return x + 1, c

    x = jnp.arange(3, dtype=jnp.int16)
    _assert_tree_equal(lower(fn, backend="jnp-boolean")(x), fn(x))


def test_purely_free_runs_execute_on_host():
    """A run of only zero-access eqns (converts/reshapes) does no array
    work and must not open a region."""
    def fn(a):
        return a.astype(jnp.int16).reshape(4, 2).astype(jnp.int32)

    a = jnp.arange(8, dtype=jnp.int32)
    lf = lower(fn, backend="jnp-boolean")
    comp = lf.trace(a)
    assert len(comp.regions) == 0 and comp.accesses == 0
    LEDGER.reset()
    np.testing.assert_array_equal(np.asarray(lf(a)), np.asarray(fn(a)))
    assert LEDGER.accesses == 0


# ---------------------------------------------------------------------------
# contractions and the full single-access surface through lower()
# ---------------------------------------------------------------------------


def test_dot_general_lowered_exact_and_fused_with_elementwise():
    def fn(x, w, bias):
        y = jnp.matmul(x, w, preferred_element_type=jnp.int32)
        return y + bias

    x = jnp.array(np.random.RandomState(0).randint(-128, 128, (4, 6)),
                  jnp.int8)
    w = jnp.array(np.random.RandomState(1).randint(-128, 128, (6, 3)),
                  jnp.int8)
    bias = jnp.arange(3, dtype=jnp.int32)
    lf = lower(fn, backend="jnp-boolean")
    comp = lf.trace(x, w, bias)
    assert len(comp.regions) == 1          # dot and bias-add share a cursor
    LEDGER.reset()
    np.testing.assert_array_equal(np.asarray(lf(x, w, bias)),
                                  np.asarray(fn(x, w, bias)))
    assert LEDGER.accesses == comp.accesses


def test_int8_wrap_and_unsigned_semantics():
    def fn(s, u):
        return s * s, s + s, u + u, -u, u * u

    s = jnp.array([-128, -1, 127, 100, -100, 0, 1, 64], jnp.int8)
    u = jnp.array([0, 255, 128, 200, 1, 99, 250, 7], jnp.uint8)
    for backend in PORTABLE:
        _assert_tree_equal(lower(fn, backend=backend)(s, u), fn(s, u))


def test_bool_predicates_and_logic_stay_packed():
    def fn(a, b):
        p = a != b
        q = a >= b
        return jnp.logical_and(p, q), jnp.logical_xor(p, q), p

    a = jnp.array([-5, 0, 3, 3, 9, -1], jnp.int16)
    b = jnp.array([-5, 1, -3, 3, 2, -1], jnp.int16)
    lf = lower(fn, backend="jnp-boolean")
    comp = lf.trace(a, b)
    assert len(comp.regions) == 1
    _assert_tree_equal(lf(a, b), fn(a, b))


def test_analog_oracle_backend_tiny_chain():
    """The device-model backend IS the paper; one small fused chain must
    agree bit-for-bit with it too."""
    def fn(a, b, c):
        return (a + b) - c

    a = jnp.array([-8, -1, 0, 3], jnp.int8)
    b = jnp.array([7, 1, -2, 3], jnp.int8)
    c = jnp.array([1, -1, 5, -6], jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(lower(fn, backend="analog-oracle")(a, b, c)),
        np.asarray(fn(a, b, c)))


# ---------------------------------------------------------------------------
# estimator == executor (the shared-eligibility contract), banked included
# ---------------------------------------------------------------------------


def test_offload_jaxpr_counts_equal_executed_ledger_banked():
    def fn(a, b):
        t = (a + b) * b
        p = t < a
        return jnp.where(p, t, a), jnp.sum(t)

    a = jnp.arange(-64, 64, dtype=jnp.int16)
    b = 5 - a
    spec = ArraySpec(banks=2, subarrays=1, rows=128, bitline_words=32)

    rep = analyze(fn, a, b)
    lf = lower(fn, backend="jnp-boolean")
    LEDGER.reset()
    _assert_tree_equal(lf(a, b), fn(a, b))
    assert LEDGER.accesses == rep.adra_accesses

    rep_banked = analyze(fn, a, b, spec=spec)
    assert rep_banked.banked_accesses > rep_banked.adra_accesses  # >1 tile
    lfb = lower(fn, backend="jnp-boolean", spec=spec)
    LEDGER.reset()
    _assert_tree_equal(lfb(a, b), fn(a, b))
    assert LEDGER.accesses == rep_banked.banked_accesses


def test_offload_hlo_source_still_available():
    def fn(a, b):
        return (a + b) * b

    a = jnp.arange(16, dtype=jnp.int16)
    rep = analyze(fn, a, a, source="hlo")
    assert rep.source == "hlo"
    assert rep.op_histogram.get("add") == 1
    assert rep.op_histogram.get("multiply") == 1
    with pytest.raises(ValueError):
        analyze(fn, a, a, source="nope")


def test_offload_s4_bit_accounting_rounds_once():
    """4-bit dtypes must contribute exact bit counts, rounded to bytes once
    at the end — no fractional bytes in the totals."""
    from repro.core.offload import analyze_hlo

    r = analyze_hlo("%x = s4[101]{0} add(s4[101] %a, s4[101] %b)\n")
    # 3 * 101 * 4 bits = 1212 bits -> ceil = 152 bytes (not int(151.5))
    assert r.eligible_bytes == 152
    assert isinstance(r.eligible_bytes, int)
    assert r.total_bytes_estimate >= r.eligible_bytes


# ---------------------------------------------------------------------------
# rewired callers
# ---------------------------------------------------------------------------


def test_mlp_cim_is_a_lowered_application():
    from repro.models import layers

    key = jax.random.PRNGKey(0)
    p = layers.mlp_init(key, 8, 16, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 8), jnp.float32)
    lf = layers._lowered_mlp("swiglu", 8, "jnp-boolean", None, None)
    comp = lf.trace(p, x)
    assert len(comp.regions) == 3          # one fused region per matmul
    LEDGER.reset()
    out = layers.mlp_cim(p, x, "swiglu", n_bits=8, backend="jnp-boolean")
    assert LEDGER.accesses == comp.accesses
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(layers._mlp_quantized(p, x, "swiglu", 8)))


def test_adra_sample_levels_lower_to_single_access():
    from repro.train.step import adra_sample

    rng = np.random.RandomState(3)
    logits = jnp.asarray(rng.randn(4, 33).astype(np.float32))
    # padded-vocab columns masked to -inf must never win
    logits = logits.at[:, -3:].set(-1e30)
    got = adra_sample(logits)
    want = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernels_ops_cim_lower_entry_point():
    from repro.kernels import ops

    def fn(a, b):
        return jnp.maximum(a - b, 0)

    a = jnp.array([5, -3, 9, 0], jnp.int16)
    b = jnp.array([1, 2, 30, 0], jnp.int16)
    lf = ops.cim_lower(fn, backend="jnp-boolean")
    np.testing.assert_array_equal(np.asarray(lf(a, b)), np.asarray(fn(a, b)))

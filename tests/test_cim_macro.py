"""Macro-op planner + executors: correctness across backends, ledger access
counts equal to schedule lengths, schedule traffic model, error paths."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import cim
from repro.cim import PlanePack, macro, planner
from repro.cim.accounting import LEDGER
from repro.cim.opset import CimOpError

BACKENDS = ("pallas-interpret", "jnp-boolean", "analog-oracle")

RNG = np.random.RandomState(11)


def _ints(lo, hi, n):
    return jnp.array(RNG.randint(lo, hi, n), jnp.int32)


# ---------------------------------------------------------------------------
# multiply
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_multiply_signed_parity(backend):
    a = _ints(-8, 8, 40)
    b = _ints(-8, 8, 40)
    p = macro.multiply(PlanePack.pack(a, 4), PlanePack.pack(b, 4),
                       backend=backend)
    assert p.n_bits == 8 and p.signed
    np.testing.assert_array_equal(np.array(p.unpack()),
                                  np.array(a) * np.array(b))


@pytest.mark.parametrize("backend", BACKENDS)
def test_multiply_unsigned_parity(backend):
    a = _ints(0, 16, 40)
    b = _ints(0, 16, 40)
    p = macro.multiply(PlanePack.pack(a, 4, signed=False),
                       PlanePack.pack(b, 4, signed=False), backend=backend)
    assert not p.signed
    np.testing.assert_array_equal(np.array(p.unpack()),
                                  np.array(a) * np.array(b))


def test_multiply_int_min_edge():
    """INT_MIN x INT_MIN needs the full 2n-bit product width."""
    a = jnp.array([-128, -128, -1, 127], jnp.int32)
    b = jnp.array([-128, 127, -1, 127], jnp.int32)
    p = macro.multiply(PlanePack.pack(a, 8), PlanePack.pack(b, 8),
                       backend="jnp-boolean")
    np.testing.assert_array_equal(np.array(p.unpack()),
                                  np.array(a) * np.array(b))


def test_multiply_mixed_widths():
    a = _ints(-64, 64, 30)
    b = _ints(-4, 4, 30)
    p = macro.multiply(PlanePack.pack(a, 7), PlanePack.pack(b, 3),
                       backend="jnp-boolean")
    assert p.n_bits == 10
    np.testing.assert_array_equal(np.array(p.unpack()),
                                  np.array(a) * np.array(b))


def test_multiply_charges_exactly_planned_accesses():
    for wa, wb, signed in [(8, 8, True), (8, 8, False), (5, 3, True),
                           (4, 1, True), (4, 1, False)]:
        a = PlanePack.pack(_ints(0, 2 ** (wa - 1), 16), wa, signed=signed)
        b = PlanePack.pack(_ints(0, 2 ** (wb - 1) or 1, 16), wb, signed=signed)
        sched = planner.plan_multiply(wa, wb, signed_b=signed)
        LEDGER.reset()
        macro.multiply(a, b, backend="jnp-boolean")
        assert LEDGER.accesses == sched.accesses, (wa, wb, signed)


# ---------------------------------------------------------------------------
# select-based macros
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_abs_relu_min_max_parity(backend):
    x = jnp.array([-128, -127, -1, 0, 1, 126, 127, -55], jnp.int32)
    y = jnp.array([127, -128, 0, -1, 1, -126, 127, 55], jnp.int32)
    xn, yn = np.array(x), np.array(y)
    px, py = PlanePack.pack(x, 8), PlanePack.pack(y, 8)
    np.testing.assert_array_equal(
        np.array(macro.abs_(px, backend=backend).unpack()), np.abs(xn))
    np.testing.assert_array_equal(
        np.array(macro.relu(px, backend=backend).unpack()),
        np.maximum(xn, 0))
    np.testing.assert_array_equal(
        np.array(macro.minimum(px, py, backend=backend).unpack()),
        np.minimum(xn, yn))
    np.testing.assert_array_equal(
        np.array(macro.maximum(px, py, backend=backend).unpack()),
        np.maximum(xn, yn))


def test_select_macros_are_single_access():
    x = PlanePack.pack(_ints(-100, 100, 32), 8)
    y = PlanePack.pack(_ints(-100, 100, 32), 8)
    for fn, sched in [
        (lambda: macro.abs_(x, backend="jnp-boolean"), planner.plan_abs(8)),
        (lambda: macro.relu(x, backend="jnp-boolean"), planner.plan_relu(8)),
        (lambda: macro.minimum(x, y, backend="jnp-boolean"),
         planner.plan_minimum(8)),
        (lambda: macro.maximum(x, y, backend="jnp-boolean"),
         planner.plan_maximum(8)),
    ]:
        LEDGER.reset()
        fn()
        assert LEDGER.accesses == sched.accesses == 1


def test_abs_int_min_is_exact():
    """abs(INT_MIN) does not overflow: the result pack is (n+1)-plane."""
    x = jnp.array([-128], jnp.int32)
    out = macro.abs_(PlanePack.pack(x, 8), backend="jnp-boolean")
    assert out.n_bits == 9
    assert int(out.unpack()[0]) == 128


# ---------------------------------------------------------------------------
# popcount / reduce
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n_bits", [1, 3, 8])
def test_popcount_parity(backend, n_bits):
    x = _ints(-(2 ** (n_bits - 1)), 2 ** (n_bits - 1), 33)
    out = macro.popcount(PlanePack.pack(x, n_bits), backend=backend)
    mask = (1 << n_bits) - 1
    want = np.array([bin(int(v) & mask).count("1") for v in np.array(x)])
    np.testing.assert_array_equal(np.array(out.unpack()), want)


def test_popcount_charges_n_minus_1():
    for n_bits in (1, 2, 5, 16):
        x = PlanePack.pack(_ints(0, 2, 8), n_bits)
        LEDGER.reset()
        macro.popcount(x, backend="jnp-boolean")
        assert LEDGER.accesses == n_bits - 1
        assert planner.plan_popcount(n_bits).accesses == n_bits - 1


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("n", [1, 2, 31, 64, 100])
def test_reduce_sum_parity(backend, n):
    if backend != "jnp-boolean" and n > 31:
        pytest.skip("large reductions only on the fast portable backend")
    x = _ints(-100, 100, n)
    out = macro.reduce_sum(PlanePack.pack(x, 8), backend=backend)
    assert out.shape == ()
    assert int(out.unpack()) == int(np.array(x).sum())


def test_reduce_sum_charges_log2_accesses():
    for n, want in [(1, 0), (2, 1), (3, 2), (64, 6), (100, 7)]:
        x = PlanePack.pack(_ints(-5, 5, n), 8)
        LEDGER.reset()
        macro.reduce_sum(x, backend="jnp-boolean")
        assert LEDGER.accesses == want
        assert planner.plan_reduce_sum(n).accesses == want


# ---------------------------------------------------------------------------
# dot / matmul — the acceptance criteria
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_int8_matmul_matches_jnp_matmul_all_backends(backend):
    """ACCEPTANCE: exact int8 x int8 -> int32 on every CPU backend, and the
    ledger access count equals the planner's schedule length."""
    if backend == "jnp-boolean":
        m, k, n = 7, 9, 6
    else:                       # per-bit oracle / interpreter: keep it small
        m, k, n = 3, 4, 2
    A = _ints(-128, 128, (m, k)).reshape(m, k)
    B = _ints(-128, 128, (k, n)).reshape(k, n)
    sched = planner.plan_matmul(k, n, n_bits=8)
    LEDGER.reset()
    C = cim.matmul(A, B, n_bits=8, backend=backend)
    assert LEDGER.accesses == sched.accesses
    assert C.dtype == jnp.int32
    want = jnp.matmul(A.astype(jnp.int32), B.astype(jnp.int32))
    np.testing.assert_array_equal(np.array(C), np.array(want))


def test_matmul_access_count_independent_of_m_n():
    k = 8
    a1 = planner.plan_matmul(k, 1, n_bits=8).accesses
    a2 = planner.plan_matmul(k, 64, n_bits=8).accesses
    assert a1 == a2 == (2 * 8 - 1) + 3


@pytest.mark.parametrize("k", [1, 2, 5, 16])
def test_dot_parity_and_accesses(k):
    a = _ints(-128, 128, k)
    b = _ints(-128, 128, k)
    LEDGER.reset()
    got = cim.dot(a, b, n_bits=8, backend="jnp-boolean")
    assert LEDGER.accesses == planner.plan_dot(k, n_bits=8).accesses
    assert int(got) == int(np.array(a, np.int64) @ np.array(b, np.int64))


def test_matmul_rejects_bad_shapes():
    with pytest.raises(CimOpError):
        cim.matmul(jnp.ones((2, 3), jnp.int32), jnp.ones((4, 2), jnp.int32))


# ---------------------------------------------------------------------------
# schedules: structure + traffic model
# ---------------------------------------------------------------------------


def test_multiply_schedule_structure():
    s = planner.plan_multiply(8, 8, signed_b=True)
    assert s.accesses == 15 and s.out_bits == 16
    assert [st.ops[0] for st in s.steps][:4] == ["and", "and", "add", "and"]
    assert s.steps[-1].ops == ("sub",)          # MSB weight is -2^(n-1)
    u = planner.plan_multiply(8, 8, signed_b=False)
    assert all(st.ops[0] != "sub" for st in u.steps)
    one = planner.plan_multiply(4, 1, signed_b=True)
    assert [st.ops[0] for st in one.steps] == ["and", "sub"]


def test_schedule_concat_and_matmul_plan():
    s = planner.plan_matmul(5, 3, n_bits=8)
    assert s.accesses == 15 + 3                 # K_pad = 8 -> 3 tree levels
    assert {st.role for st in s.steps} == {"pp", "acc", "reduce"}
    assert [st.stride for st in s.steps if st.role == "reduce"] == [3, 6, 12]


def test_schedule_traffic_fused_vs_unfused_ratio():
    """ACCEPTANCE: a multiply schedule moves > 1.5x less traffic fused
    (intermediates in-array) than unfused (re-streamed per access)."""
    t = planner.schedule_traffic_bytes(planner.plan_multiply(8, 8), 8, 4096)
    assert t["ratio"] > 1.5, t
    assert t["baseline"] > t["fused"]


def test_kernel_bench_json_reports_multiply_ratio(tmp_path, capsys):
    """ACCEPTANCE: the benchmark's --json artifact carries the multiply
    schedule's fused-vs-unfused traffic ratio, > 1.5."""
    import importlib
    import json
    import pathlib
    import sys

    root = pathlib.Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(root / "benchmarks"))
    try:
        bench = importlib.import_module("kernel_bench")
    finally:
        sys.path.pop(0)
    out = tmp_path / "BENCH_kernel.json"
    bench.main(["--json", str(out)])
    capsys.readouterr()                          # swallow the CSV lines
    d = json.loads(out.read_text())
    assert d["macro_multiply"]["traffic"]["ratio"] > 1.5
    assert (d["macro_multiply"]["ledger_accesses"]
            == d["macro_multiply"]["accesses"])


# ---------------------------------------------------------------------------
# cursor honesty + accounting
# ---------------------------------------------------------------------------


def test_cursor_rejects_unplanned_access():
    sched = planner.plan_relu(8)
    cur = macro.ScheduleCursor(sched, "jnp-boolean")
    a = PlanePack.pack(_ints(-10, 10, 8), 8)
    with pytest.raises(CimOpError):
        cur.execute(a, a, ("add",))             # plan says ("gt",)


def test_cursor_rejects_extra_access():
    sched = planner.plan_relu(8)
    cur = macro.ScheduleCursor(sched, "jnp-boolean")
    a = PlanePack.pack(_ints(-10, 10, 8), 8)
    z = PlanePack.zeros_like(a)
    cur.execute(a, z, ("gt",))
    with pytest.raises(CimOpError):
        cur.execute(a, z, ("gt",))


def test_cursor_finish_flags_underrun():
    cur = macro.ScheduleCursor(planner.plan_multiply(4, 4), "jnp-boolean")
    with pytest.raises(CimOpError):
        cur.finish()


def test_measured_traffic_charges_zero_accesses():
    """measured_traffic_bytes abstractly evaluates the backend: no charge."""
    a = PlanePack.pack(_ints(-100, 100, 64), 8)
    b = PlanePack.pack(_ints(-100, 100, 64), 8)
    LEDGER.reset()
    cim.measured_traffic_bytes(a, b, ("xor", "sub"), backend="jnp-boolean")
    assert LEDGER.accesses == 0 and LEDGER.words32 == 0


def test_ledger_autouse_fixture_isolates_tests():
    """The conftest fixture resets the ledger before each test."""
    assert LEDGER.accesses == 0
    cim.add(_ints(0, 4, 4), _ints(0, 4, 4), 4, backend="jnp-boolean")
    assert LEDGER.accesses == 1                  # next test starts at 0 again


# ---------------------------------------------------------------------------
# error paths: CimOpError everywhere an op request can be malformed
# ---------------------------------------------------------------------------


def test_engine_boolean_unknown_function_raises_cim_op_error():
    a = _ints(0, 4, 4)
    with pytest.raises(CimOpError, match="unknown Boolean function"):
        cim.boolean(a, a, "xorish", n_bits=4)


def test_validate_ops_empty_raises_cim_op_error():
    with pytest.raises(CimOpError, match="empty op request"):
        cim.execute(PlanePack.pack(_ints(0, 4, 4), 4),
                    PlanePack.pack(_ints(0, 4, 4), 4), ())


def test_validate_ops_duplicate_raises_cim_op_error():
    with pytest.raises(CimOpError, match="duplicate"):
        cim.execute(PlanePack.pack(_ints(0, 4, 4), 4),
                    PlanePack.pack(_ints(0, 4, 4), 4), ("sub", "sub"))


def test_validate_ops_unknown_raises_cim_op_error():
    with pytest.raises(CimOpError, match="unknown CiM op"):
        cim.execute(PlanePack.pack(_ints(0, 4, 4), 4),
                    PlanePack.pack(_ints(0, 4, 4), 4), ("mystery",))


def test_cim_op_error_is_a_value_error():
    """Back-compat: pre-existing callers catching ValueError still work."""
    assert issubclass(CimOpError, ValueError)


# ---------------------------------------------------------------------------
# outward wiring: kernels.ops entry points, quantized linear, offload
# ---------------------------------------------------------------------------


def test_kernels_ops_cim_matmul_and_relu():
    from repro.kernels import ops

    A = _ints(-128, 128, (4, 5)).reshape(4, 5)
    B = _ints(-128, 128, (5, 3)).reshape(5, 3)
    C = ops.cim_matmul(A, B, backend="jnp-boolean")
    np.testing.assert_array_equal(
        np.array(C), np.array(A, np.int64) @ np.array(B, np.int64))
    x = _ints(-100, 100, (2, 6)).reshape(2, 6)
    np.testing.assert_array_equal(
        np.array(ops.cim_relu(x, n_bits=8, backend="jnp-boolean")),
        np.maximum(np.array(x), 0))


def test_cim_quantized_linear_close_to_float():
    import jax

    from repro.models.layers import cim_linear, quantize_symmetric

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (3, 8), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 4), jnp.float32)
    y = cim_linear(x, w, n_bits=8, backend="jnp-boolean")
    ref = x @ w
    # int8 symmetric fake-quant of both operands: modest relative error
    err = float(jnp.max(jnp.abs(y - ref)) / (jnp.max(jnp.abs(ref)) + 1e-9))
    assert err < 0.05, err
    # and the CiM contraction itself is EXACT on the quantized integers
    xq, sx = quantize_symmetric(x, 8)
    wq, sw = quantize_symmetric(w, 8)
    got = cim.matmul(xq, wq, n_bits=8, backend="jnp-boolean")
    np.testing.assert_array_equal(
        np.array(got), np.array(xq, np.int64) @ np.array(wq, np.int64))


def test_offload_counts_multiply_and_dot_with_planner_accesses():
    from repro.cim.planner import plan_matmul, plan_multiply
    from repro.core.offload import analyze_hlo

    hlo = """
      %m = s8[64,128]{1,0} multiply(s8[64,128]{1,0} %a, s8[64,128]{1,0} %b)
      %d = s32[64,16]{1,0} dot(s8[64,32]{1,0} %x, s8[32,16]{1,0} %y), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %s = s8[64,128]{1,0} add(s8[64,128]{1,0} %a, s8[64,128]{1,0} %b)
    """
    r = analyze_hlo(hlo)
    assert r.op_histogram == {"multiply": 1, "dot": 1, "add": 1}
    assert r.multi_access_ops == 2
    want = plan_multiply(8, 8).accesses + plan_matmul(32, 1, n_bits=8).accesses
    assert r.planner_accesses == want
    assert r.eligible_ops == 3 and r.edp_decrease_pct > 0

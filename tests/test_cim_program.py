"""Whole-schedule compiled execution: one jitted XLA dispatch per macro /
fused region, with ledger charges replayed from the plan.

The contract under test: compiling a schedule into a single XLA program
changes the COST of execution (dispatch count, walltime), never its
semantics or its accounting — results are bit-exact with the eager cursor,
and every field of the ledger (accesses, words32, per-op histogram,
per-bank slots, activated/inter-bank words) is identical to what the eager
per-access charging produced, unbanked and banked, cold cache and warm.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import cim
from repro.cim import PlanePack, dispatch, macro, planner
from repro.cim.accounting import LEDGER, Ledger, PlannedCharges

RNG = np.random.RandomState(5)

#: a small banked geometry: 70-word operands place 3 tiles over 2 banks
SPEC = cim.ArraySpec(banks=2, subarrays=1, rows=256, bitline_words=32)


def _ints(lo, hi, shape):
    return jnp.array(RNG.randint(lo, hi, shape), jnp.int32)


def _ledger_state():
    """Deep snapshot of every ledger counter (dicts copied)."""
    out = {}
    for f in dataclasses.fields(LEDGER):
        if f.name == "enabled":
            continue
        v = getattr(LEDGER, f.name)
        out[f.name] = dict(v) if isinstance(v, dict) else v
    return out


# ---------------------------------------------------------------------------
# dispatch counts: one program per schedule, warm calls hit
# ---------------------------------------------------------------------------


def test_macro_matmul_is_exactly_one_dispatch():
    A = _ints(-128, 128, (8, 16))
    B = _ints(-128, 128, (16, 4))
    C1 = cim.matmul(A, B, n_bits=8, backend="jnp-boolean")  # compile if cold
    mid = dispatch.cache_stats()
    C2 = cim.matmul(A, B, n_bits=8, backend="jnp-boolean")
    after = dispatch.cache_stats()
    assert after["dispatches"] - mid["dispatches"] == 1
    assert after["misses"] == mid["misses"]           # zero retrace warm
    assert after["hits"] >= mid["hits"] + 1
    want = np.array(A, np.int64) @ np.array(B, np.int64)
    np.testing.assert_array_equal(np.array(C1), want)
    np.testing.assert_array_equal(np.array(C2), want)


def test_warm_macro_ledger_and_results_identical_to_cold():
    x = _ints(-100, 100, 66)
    y = _ints(-100, 100, 66)
    pa, pb = PlanePack.pack(x, 8), PlanePack.pack(y, 8)
    LEDGER.reset()
    cold = macro.multiply(pa, pb, backend="jnp-boolean")
    cold_led = _ledger_state()
    LEDGER.reset()
    warm = macro.multiply(pa, pb, backend="jnp-boolean")
    assert _ledger_state() == cold_led
    np.testing.assert_array_equal(np.array(cold.unpack()),
                                  np.array(warm.unpack()))


def test_charges_replay_on_every_invocation():
    x = _ints(-100, 100, 48)
    pa = PlanePack.pack(x, 8)
    plan = planner.plan_popcount(8)
    LEDGER.reset()
    for _ in range(3):
        macro.popcount(pa, backend="jnp-boolean")
    assert LEDGER.accesses == 3 * plan.accesses


# ---------------------------------------------------------------------------
# ledger parity: compiled program vs eager cursor, full field set
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", [None, SPEC], ids=["unbanked", "banked"])
def test_multiply_ledger_matches_eager_cursor(spec):
    x = _ints(-100, 100, 70)
    y = _ints(-100, 100, 70)
    pa, pb = PlanePack.pack(x, 8), PlanePack.pack(y, 8)
    sched = planner.plan_multiply(8, 8)
    if spec is not None:
        sched = sched.placed(spec, pa.n_words)

    LEDGER.reset()
    cur = macro.ScheduleCursor(sched, "jnp-boolean", spec=spec)
    ref = macro._multiply_with(cur, pa, pb)
    cur.finish()
    eager = _ledger_state()

    LEDGER.reset()
    out = cim.multiply(pa, pb, backend="jnp-boolean", spec=spec)
    assert _ledger_state() == eager
    np.testing.assert_array_equal(np.array(out.unpack()),
                                  np.array(ref.unpack()))
    np.testing.assert_array_equal(np.array(out.unpack()),
                                  np.array(x) * np.array(y))


def test_banked_reduce_inter_bank_traffic_matches_eager_cursor():
    """The stride charges of a cross-tile reduction are recorded at trace
    time and replayed — including the fractional inter-bank words."""
    x = _ints(-50, 50, 70)
    pa = PlanePack.pack(x, 8)
    sched = planner.plan_reduce_sum(pa.n_words, stride=1,
                                    n_bits=8).placed(SPEC, pa.n_words)

    LEDGER.reset()
    cur = macro.ScheduleCursor(sched, "jnp-boolean", spec=SPEC)
    ref = macro._reduce_sum_body(cur, pa)
    cur.finish()
    eager = _ledger_state()
    assert eager["inter_bank_words32"] > 0      # strides cross tiles here

    LEDGER.reset()
    out = cim.reduce_sum(pa, backend="jnp-boolean", spec=SPEC)
    assert _ledger_state() == eager
    assert int(out.unpack()) == int(ref.unpack()) == int(np.array(x).sum())


@pytest.mark.parametrize("spec", [None, SPEC], ids=["unbanked", "banked"])
def test_every_macro_charges_exactly_its_plan(spec):
    x = _ints(-100, 100, 70)
    y = _ints(-100, 100, 70)
    pa, pb = PlanePack.pack(x, 8), PlanePack.pack(y, 8)
    cases = [
        (lambda: macro.abs_(pa, backend="jnp-boolean", spec=spec),
         planner.plan_abs(8)),
        (lambda: macro.relu(pa, backend="jnp-boolean", spec=spec),
         planner.plan_relu(8)),
        (lambda: macro.minimum(pa, pb, backend="jnp-boolean", spec=spec),
         planner.plan_minimum(8)),
        (lambda: macro.maximum(pa, pb, backend="jnp-boolean", spec=spec),
         planner.plan_maximum(8)),
        (lambda: macro.popcount(pa, backend="jnp-boolean", spec=spec),
         planner.plan_popcount(8)),
        (lambda: macro.multiply(pa, pb, backend="jnp-boolean", spec=spec),
         planner.plan_multiply(8, 8)),
        (lambda: macro.reduce_sum(pa, backend="jnp-boolean", spec=spec),
         planner.plan_reduce_sum(70, n_bits=8)),
    ]
    for fn, plan in cases:
        if spec is not None:
            plan = plan.placed(spec, 70)
        LEDGER.reset()
        fn()
        assert LEDGER.accesses == plan.placed_accesses, plan.macro


# ---------------------------------------------------------------------------
# lowered regions: one dispatch per region, cold/warm parity, sharing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", [None, SPEC], ids=["unbanked", "banked"])
def test_lowered_region_one_dispatch_cold_warm_parity(spec):
    def fn(a, b):
        return ((a + b) * b) - a

    a = _ints(-60, 60, 70).astype(jnp.int16)
    b = _ints(-60, 60, 70).astype(jnp.int16)
    lf = cim.lower(fn, backend="jnp-boolean", spec=spec)
    comp = lf.trace(a, b)
    assert len(comp.regions) == 1

    LEDGER.reset()
    out1 = lf(a, b)                              # cold: trace + compile
    cold_led = _ledger_state()
    mid = dispatch.cache_stats()
    LEDGER.reset()
    out2 = lf(a, b)                              # warm: cache hit
    after = dispatch.cache_stats()

    assert _ledger_state() == cold_led           # counters move identically
    assert after["dispatches"] - mid["dispatches"] == len(comp.regions) == 1
    assert after["misses"] == mid["misses"]
    np.testing.assert_array_equal(np.array(out1), np.array(fn(a, b)))
    np.testing.assert_array_equal(np.array(out1), np.array(out2))


def test_structurally_identical_regions_share_one_program():
    """Two separate lower() applications of the same function structure
    resolve to the SAME cached region program (structural key): the second
    one's execution is hit-only."""
    def make():
        return cim.lower(lambda a, b: (a + b) ^ a, backend="jnp-boolean")

    a = _ints(-40, 40, 34).astype(jnp.int16)
    b = _ints(-40, 40, 34).astype(jnp.int16)
    lf1 = make()
    want = np.array((a + b) ^ a)
    np.testing.assert_array_equal(np.array(lf1(a, b)), want)
    before = dispatch.cache_stats()
    lf2 = make()                                 # fresh trace, same structure
    np.testing.assert_array_equal(np.array(lf2(a, b)), want)
    after = dispatch.cache_stats()
    assert after["misses"] == before["misses"]
    assert after["hits"] > before["hits"]


def test_identical_regions_within_one_trace_compile_once():
    """Repeated identical regions in a SINGLE lowered function (the
    repeated-layer pattern) share one program too: the region schedule's
    macro name is not positional, so the structural key is the whole key."""
    def fn(a, b):
        t = (a + b) ^ a                          # region, structure S
        f = jnp.floor(t.astype(jnp.float32) / 2.0)   # host island
        q = f.astype(jnp.int16)
        return (q + b) ^ q                       # region, same structure S

    a = _ints(-40, 40, 38).astype(jnp.int16)
    b = _ints(-40, 40, 38).astype(jnp.int16)
    lf = cim.lower(fn, backend="jnp-boolean")
    comp = lf.trace(a, b)
    assert len(comp.regions) == 2
    assert comp.regions[0].key == comp.regions[1].key
    before = dispatch.cache_stats()
    out = lf(a, b)                               # compiles ONE program
    after = dispatch.cache_stats()
    assert after["misses"] - before["misses"] == 1
    assert after["dispatches"] - before["dispatches"] == 2
    np.testing.assert_array_equal(np.array(out), np.array(fn(a, b)))


def test_mesh_macro_compiles_through_shard_map():
    """The shard_map path stays inside the step program: one dispatch, same
    results, per-device ledger intact (single-device mesh smoke)."""
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices("cpu")[:1]), ("data",))
    x = _ints(-100, 100, 70)
    y = _ints(-100, 100, 70)
    pa, pb = PlanePack.pack(x, 8), PlanePack.pack(y, 8)
    LEDGER.reset()
    out = cim.multiply(pa, pb, backend="jnp-boolean", spec=SPEC, mesh=mesh)
    plan = planner.plan_multiply(8, 8).placed(SPEC, 70)
    assert LEDGER.accesses == plan.placed_accesses
    np.testing.assert_array_equal(np.array(out.unpack()),
                                  np.array(x) * np.array(y))


def test_donation_excludes_caller_and_alias_shared_buffers():
    """Region buffer donation may only name dead intermediates: never the
    caller's arrays, and never vars touching a pjit-inlining `_alias` (the
    alias outvar holds the SAME jax.Array as its source, so donating either
    side would delete a buffer the other may still need)."""
    @jax.jit
    def g(x):
        t = x + 1
        return t, t                          # duplicated output -> _alias

    def fn(x):
        a, b = g(x)
        return a * 2, b                      # region eats a; b lives on

    x = jnp.arange(-8, 8, dtype=jnp.int16)
    comp = cim.lower(fn, backend="jnp-boolean").trace(x)
    assert any(op.name == "_alias" for op in comp.trace.ops)
    add_region, mul_region = comp.regions
    # mul's input is the add result whose buffer the alias outvar shares:
    # dead after the region by liveness, yet it must NOT be donated
    assert mul_region.donatable == ()
    assert add_region.donatable == ()        # consumes caller's x directly
    np.testing.assert_array_equal(
        np.array(cim.lower(fn, backend="jnp-boolean")(x)[0]),
        np.array(fn(x)[0]))


def test_donation_marks_dead_host_intermediates():
    """Positive control: a host-produced intermediate consumed only by the
    region IS donatable (the accumulator-chain reuse case)."""
    def fn(x):
        h = jnp.sin(x.astype(jnp.float32))           # host island
        q = jnp.round(h * 7.0).astype(jnp.int16)     # dead after region
        return q * 2

    x = jnp.arange(-8, 8, dtype=jnp.int16)
    comp = cim.lower(fn, backend="jnp-boolean").trace(x)
    (region,) = comp.regions
    assert len(region.donatable) == 1


def test_failed_invocation_charges_nothing():
    """A program whose execution raises must leave the ledger and the
    dispatch counter untouched — accounting follows execution, not intent."""
    pc = PlannedCharges((("access", ("add",), 8, 16),))

    def boom(*_):
        raise RuntimeError("device lost")

    prog = macro.CompiledSchedule(boom, pc)
    LEDGER.reset()
    before = dispatch.cache_stats()["dispatches"]
    with pytest.raises(RuntimeError):
        prog()
    assert LEDGER.accesses == 0
    assert dispatch.cache_stats()["dispatches"] == before


# ---------------------------------------------------------------------------
# PlannedCharges unit behavior
# ---------------------------------------------------------------------------


def test_planned_charges_replays_into_ledger():
    pc = PlannedCharges((
        ("access", ("add",), 8, 16),
        ("banked", ("sub",), 8, 64, SPEC.plan(64), 1),
        ("reduction", 2.5),
    ))
    led = Ledger()
    pc.replay(led)
    assert pc.accesses == 2
    assert led.accesses == 1 + SPEC.plan(64).n_tiles
    assert led.per_op == {"add": 1, "sub": 1}
    assert led.inter_bank_words32 == 2.5
    assert led.words32 == 16 * 8 / 32.0 + 64 * 8 / 32.0


def test_planned_charges_respects_disabled_ledger():
    led = Ledger(enabled=False)
    PlannedCharges((("access", ("add",), 8, 16),)).replay(led)
    assert led.accesses == 0


def test_compiled_program_rejects_unknown_charge_kind():
    with pytest.raises(ValueError):
        PlannedCharges((("bogus", 1),)).replay(Ledger())

"""Property-based differential tests: the CiM engine and every macro op vs
a numpy oracle, over random bit-widths 2-32, signed and unsigned operands,
and forced INT_MIN / -1 / 0 / MAX edge cases, across the CPU backends.

Runs under real hypothesis when installed and under the seeded-numpy
fallback otherwise (tests/_hypothesis_compat.py).
"""
import jax.numpy as jnp
import numpy as np

from repro import cim
from repro.cim import PlanePack, macro, planner
from repro.cim.accounting import LEDGER

from _hypothesis_compat import HealthCheck, given, settings, st

PORTABLE = ("jnp-boolean", "pallas-interpret")

_PROP = dict(max_examples=25, deadline=None,
             suppress_health_check=[HealthCheck.function_scoped_fixture])


def _wrap32(v):
    """What unpack() returns for any plane width: int32 two's complement."""
    return ((np.asarray(v, np.int64) + (1 << 31)) % (1 << 32)) - (1 << 31)


def _operands(n_bits, signed, seed, n_words=12):
    """int64 operand pair with INT_MIN / -1 / 0 / 1 / MAX edges forced in."""
    rng = np.random.RandomState(seed)
    if signed:
        lo, hi = -(1 << (n_bits - 1)), 1 << (n_bits - 1)
        edges = np.array([lo, -1, 0, 1, hi - 1], np.int64)
    else:
        lo, hi = 0, 1 << n_bits
        edges = np.array([0, 1, hi - 1, hi >> 1], np.int64)
    n_rand = max(0, n_words - len(edges))
    a = np.concatenate([edges, rng.randint(lo, hi, n_rand, dtype=np.int64)])
    b = np.concatenate([edges[::-1], rng.randint(lo, hi, n_rand, dtype=np.int64)])
    return a, b


def _pack64(v, n_bits, signed):
    """Pack an int64 value array (bit patterns) as a PlanePack."""
    pattern = (v & 0xFFFFFFFF).astype(np.uint32).view(np.int32)
    return PlanePack.pack(jnp.array(pattern), n_bits, signed=signed)


# ---------------------------------------------------------------------------
# single-access op surface
# ---------------------------------------------------------------------------


def _check_single_access(backend, n_bits, signed, seed):
    a, b = _operands(n_bits, signed, seed)
    mask = (1 << n_bits) - 1
    pa, pb = _pack64(a, n_bits, signed), _pack64(b, n_bits, signed)

    arith_ops = ["add", "sub", "lt", "eq", "gt"]
    if signed:                      # no width extension -> carries are n-bit
        arith_ops += ["carry_add", "carry_sub"]
    out = cim.execute(pa, pb, tuple(arith_ops), backend=backend)
    got = {op: np.asarray(out[op].unpack(), np.int64) for op in arith_ops}
    np.testing.assert_array_equal(got["add"], _wrap32(a + b), err_msg="add")
    np.testing.assert_array_equal(got["sub"], _wrap32(a - b), err_msg="sub")
    np.testing.assert_array_equal(got["lt"], (a < b).astype(np.int64))
    np.testing.assert_array_equal(got["eq"], (a == b).astype(np.int64))
    np.testing.assert_array_equal(got["gt"], (a > b).astype(np.int64))
    if signed:
        pat_a, pat_b = a & mask, b & mask
        np.testing.assert_array_equal(
            got["carry_add"], (pat_a + pat_b) >> n_bits, err_msg="carry_add")
        np.testing.assert_array_equal(
            got["carry_sub"], (pat_a + (~b & mask) + 1) >> n_bits,
            err_msg="carry_sub")

    # all 16 Boolean functions in one (extension-free) access
    out = cim.execute(pa, pb, cim.BOOLEAN_OPS, backend=backend)
    pat_a, pat_b = a & mask, b & mask
    ref = {
        "false": np.zeros_like(pat_a), "true": np.full_like(pat_a, mask),
        "and": pat_a & pat_b, "or": pat_a | pat_b, "xor": pat_a ^ pat_b,
        "nand": ~(pat_a & pat_b) & mask, "nor": ~(pat_a | pat_b) & mask,
        "xnor": ~(pat_a ^ pat_b) & mask, "a": pat_a, "b": pat_b,
        "not_a": ~pat_a & mask, "not_b": ~pat_b & mask,
        "a_and_not_b": pat_a & ~pat_b & mask,
        "not_a_and_b": ~pat_a & mask & pat_b,
        "a_or_not_b": (pat_a | (~pat_b & mask)) & mask,
        "not_a_or_b": ((~pat_a & mask) | pat_b) & mask,
    }
    for fn in cim.BOOLEAN_OPS:
        np.testing.assert_array_equal(
            np.asarray(out[fn].unpack(), np.int64), _wrap32(ref[fn]),
            err_msg=fn)


@settings(**_PROP)
@given(st.integers(2, 32), st.booleans(), st.integers(0, 2**31 - 1))
def test_property_single_access_portable(n_bits, signed, seed):
    for backend in PORTABLE:
        _check_single_access(backend, n_bits, signed, seed)


@settings(**_PROP)
@given(st.integers(2, 8), st.booleans(), st.integers(0, 2**31 - 1))
def test_property_single_access_analog(n_bits, signed, seed):
    _check_single_access("analog-oracle", n_bits, signed, seed)


# ---------------------------------------------------------------------------
# macro ops
# ---------------------------------------------------------------------------


@settings(**_PROP)
@given(st.integers(2, 16), st.integers(2, 16), st.booleans(),
       st.integers(0, 2**31 - 1))
def test_property_multiply(wa, wb, signed, seed):
    a, _ = _operands(wa, signed, seed, n_words=10)
    _, b = _operands(wb, signed, seed + 1, n_words=10)
    LEDGER.reset()
    p = macro.multiply(_pack64(a, wa, signed), _pack64(b, wb, signed),
                       backend="jnp-boolean")
    assert LEDGER.accesses == planner.plan_multiply(wa, wb, signed).accesses
    np.testing.assert_array_equal(np.asarray(p.unpack(), np.int64),
                                  _wrap32(a * b))


@settings(**_PROP)
@given(st.integers(2, 32), st.integers(0, 2**31 - 1))
def test_property_select_macros_and_popcount(n_bits, seed):
    a, b = _operands(n_bits, True, seed)
    mask = (1 << n_bits) - 1
    pa, pb = _pack64(a, n_bits, True), _pack64(b, n_bits, True)
    for backend in PORTABLE:
        LEDGER.reset()
        np.testing.assert_array_equal(
            np.asarray(macro.abs_(pa, backend=backend).unpack(), np.int64),
            _wrap32(np.abs(a)), err_msg="abs")
        np.testing.assert_array_equal(
            np.asarray(macro.relu(pa, backend=backend).unpack(), np.int64),
            _wrap32(np.maximum(a, 0)), err_msg="relu")
        np.testing.assert_array_equal(
            np.asarray(macro.minimum(pa, pb, backend=backend).unpack(),
                       np.int64), _wrap32(np.minimum(a, b)), err_msg="min")
        np.testing.assert_array_equal(
            np.asarray(macro.maximum(pa, pb, backend=backend).unpack(),
                       np.int64), _wrap32(np.maximum(a, b)), err_msg="max")
        assert LEDGER.accesses == 4              # one access per select macro
    # popcount is n-1 accesses: property-check it on the fast backend only
    pc = macro.popcount(pa, backend="jnp-boolean").unpack()
    want = [bin(int(v) & mask).count("1") for v in a]
    np.testing.assert_array_equal(np.asarray(pc, np.int64), want,
                                  err_msg="popcount")


@settings(**_PROP)
@given(st.integers(2, 12), st.booleans(), st.integers(1, 64),
       st.integers(0, 2**31 - 1))
def test_property_reduce_sum(n_bits, signed, n, seed):
    rng = np.random.RandomState(seed)
    lo, hi = ((-(1 << (n_bits - 1)), 1 << (n_bits - 1)) if signed
              else (0, 1 << n_bits))
    x = rng.randint(lo, hi, n, dtype=np.int64)
    x[:1] = lo                                   # force the extreme value in
    LEDGER.reset()
    out = macro.reduce_sum(_pack64(x, n_bits, signed), backend="jnp-boolean")
    assert LEDGER.accesses == planner.plan_reduce_sum(n).accesses
    assert int(out.unpack()) == int(x.sum())


@settings(**_PROP)
@given(st.integers(1, 9), st.integers(0, 2**31 - 1))
def test_property_int8_dot(k, seed):
    rng = np.random.RandomState(seed)
    a = rng.randint(-128, 128, k).astype(np.int32)
    b = rng.randint(-128, 128, k).astype(np.int32)
    a[:1], b[:1] = -128, -128                    # INT8_MIN edge
    LEDGER.reset()
    got = cim.dot(jnp.array(a), jnp.array(b), n_bits=8, backend="jnp-boolean")
    assert LEDGER.accesses == planner.plan_dot(k, n_bits=8).accesses
    assert int(got) == int(a.astype(np.int64) @ b.astype(np.int64))


@settings(**_PROP)
@given(st.integers(2, 4), st.booleans(), st.integers(0, 2**31 - 1))
def test_property_macro_analog_oracle(n_bits, signed, seed):
    """The device-model backend agrees with the oracle on macro schedules."""
    a, b = _operands(n_bits, signed, seed, n_words=6)
    pa, pb = _pack64(a, n_bits, signed), _pack64(b, n_bits, signed)
    p = macro.multiply(pa, pb, backend="analog-oracle")
    np.testing.assert_array_equal(np.asarray(p.unpack(), np.int64),
                                  _wrap32(a * b))
    if signed:
        np.testing.assert_array_equal(
            np.asarray(macro.relu(pa, backend="analog-oracle").unpack(),
                       np.int64), _wrap32(np.maximum(a, 0)))

"""Resident-operand contract tests (repro.cim.array.ResidentSet + the
lowering compiler's resident mode).

The contract under test:

  * pin / get / evict lifecycle — LRU eviction under row pressure,
    fingerprint invalidation, non-evictable reservations, counters;
  * the combined row budget — `ArraySpec.check_fits` charges resident
    occupancy against the same rows the access planes need;
  * the charge model — residency removes ONLY the streamed-operand load
    charges; compute `accesses` match the plan exactly as without it;
  * bit-exactness — resident and per-call-repacked executions return the
    SAME arrays on every portable CPU backend;
  * program-cache separation — streamed and resident executions of one
    region never share a compiled program.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cim import CimOpError, PlanePack, clear_resident, macro
from repro.cim import dispatch
from repro.cim.accounting import LEDGER
from repro.cim.array import ArraySpec, ResidentSet, resident_set
from repro.cim.lower import lower
from repro.models import layers

PORTABLE = ("jnp-boolean", "pallas-interpret")

SPEC = ArraySpec(banks=2, subarrays=1, rows=64, bitline_words=32)


def _pack(n_words: int, n_bits: int = 8, seed: int = 0) -> PlanePack:
    rng = np.random.default_rng(seed)
    a = rng.integers(-100, 100, size=(n_words,), dtype=np.int32)
    return PlanePack.pack(jnp.asarray(a), n_bits, signed=True)


# ---------------------------------------------------------------------------
# ResidentSet lifecycle
# ---------------------------------------------------------------------------


class TestResidentSet:
    def test_pin_get_hit(self):
        rs = ResidentSet(SPEC)
        p = _pack(8)
        rs.pin("w", p, fingerprint=(1,))
        e = rs.get("w", fingerprint=(1,))
        assert e is not None and e.pack is p
        assert rs.hits == 1 and rs.misses == 0

    def test_get_miss_counts(self):
        rs = ResidentSet(SPEC)
        assert rs.get("absent") is None
        assert rs.misses == 1

    def test_peek_counts_nothing(self):
        rs = ResidentSet(SPEC)
        rs.pin("w", _pack(8), fingerprint=(1,))
        assert rs.peek("w", (1,))
        assert not rs.peek("w", (2,))
        assert not rs.peek("absent")
        assert rs.hits == 0 and rs.misses == 0

    def test_fingerprint_mismatch_invalidates(self):
        rs = ResidentSet(SPEC)
        rs.pin("w", _pack(8), fingerprint=(1,))
        assert rs.get("w", fingerprint=(2,)) is None
        assert rs.invalidations == 1
        assert len(rs) == 0                      # stale rows released

    def test_lru_eviction_under_pressure(self):
        # 8-bit two-tile packs land 8 plane rows on EACH bank, so the
        # 64-row banks hold 8 pins; further pins evict in LRU order
        rs = ResidentSet(SPEC)
        n_fit = SPEC.rows // 8
        for i in range(n_fit + 2):
            rs.pin(("w", i), _pack(2 * SPEC.tile_words, seed=i))
        assert rs.evictions >= 2
        assert rs.get(("w", 0)) is None          # oldest went first
        assert rs.get(("w", n_fit + 1)) is not None

    def test_oversize_pin_raises_with_occupancy(self):
        rs = ResidentSet(SPEC, reserve_rows=32)
        with pytest.raises(CimOpError, match="resident budget"):
            rs.pin("big", _pack(64 * SPEC.tile_words, n_bits=8))

    def test_reserve_is_not_evictable(self):
        rs = ResidentSet(SPEC)
        per_bank = SPEC.rows                     # fill bank 0 exactly
        rs.reserve(("kv", 0), per_bank, bank=0)
        with pytest.raises(CimOpError, match="reservation"):
            # a same-bank pin cannot evict the reservation
            rs.pin("w", _pack(SPEC.tile_words))
        assert rs.evictions == 0

    def test_release_and_clear(self):
        rs = ResidentSet(SPEC)
        rs.pin("w", _pack(8))
        assert rs.release("w") and not rs.release("w")
        rs.pin("v", _pack(8))
        rs.clear()
        assert len(rs) == 0 and rs.resident_rows == 0

    def test_repin_replaces(self):
        rs = ResidentSet(SPEC)
        rs.pin("w", _pack(8, seed=0), fingerprint=(1,))
        p2 = _pack(8, seed=1)
        rs.pin("w", p2, fingerprint=(2,))
        assert len(rs) == 1
        assert rs.get("w", fingerprint=(2,)).pack is p2

    def test_pin_charges_load_once(self):
        LEDGER.reset()
        rs = ResidentSet(SPEC)
        p = _pack(8)
        rs.pin("w", p)
        assert LEDGER.load_accesses == SPEC.plan(p.n_words).n_tiles
        rs.get("w")
        assert LEDGER.load_accesses == SPEC.plan(p.n_words).n_tiles


# ---------------------------------------------------------------------------
# combined row budget
# ---------------------------------------------------------------------------


class TestCheckFits:
    def test_resident_occupancy_in_budget(self):
        spec = ArraySpec(rows=64)
        spec.check_fits(8, ("add",), resident_rows=30)  # 16+9+30 <= 64
        with pytest.raises(CimOpError, match="resident"):
            spec.check_fits(8, ("add",), resident_rows=48)

    def test_registry_occupancy_reaches_dispatch(self):
        clear_resident()
        rs = resident_set(SPEC)
        rs.reserve(("kv", 0), 40, bank=0)
        with pytest.raises(CimOpError, match="resident"):
            # a 16-bit add needs 2*16+17 = 49 rows — fine on an empty
            # array, impossible beside the 40 reserved rows
            dispatch.execute_tiled(
                _pack(SPEC.tile_words, n_bits=16),
                _pack(SPEC.tile_words, n_bits=16, seed=1),
                ("add",), spec=SPEC)
        clear_resident()


# ---------------------------------------------------------------------------
# charge model: residency removes loads ONLY
# ---------------------------------------------------------------------------


def _ledger_delta(fn):
    a0, l0, r0 = LEDGER.accesses, LEDGER.load_accesses, LEDGER.resident_reuses
    out = fn()
    return out, (LEDGER.accesses - a0, LEDGER.load_accesses - l0,
                 LEDGER.resident_reuses - r0)


class TestResidentCharges:
    def test_macro_matmul_resident_rhs(self):
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.integers(-50, 50, (4, 16), dtype=np.int32))
        b = jnp.asarray(rng.integers(-50, 50, (16, 8), dtype=np.int32))
        ref = np.asarray(a) @ np.asarray(b)

        plain, d_plain = _ledger_delta(lambda: macro.matmul(a, b, 8))
        bp = macro.matmul_rhs_pack(b, a.shape[0], 8)
        res, d_res = _ledger_delta(lambda: macro.matmul(a, b_pack=bp, n_bits=8))

        np.testing.assert_array_equal(np.asarray(plain), ref)
        np.testing.assert_array_equal(np.asarray(res), ref)
        assert d_plain[0] == d_res[0]            # identical compute accesses
        assert d_plain[1] == 2 and d_res[1] == 1  # rhs load gone
        assert d_plain[2] == 0 and d_res[2] == 1  # one reuse charged

    def test_lowered_warm_call_drops_loads_only(self):
        clear_resident()
        dispatch.clear_schedule_cache()
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 32))
        w = jax.random.normal(jax.random.PRNGKey(1), (32, 16))

        # streamed per-call baseline
        layers.cim_linear(x, w, n_bits=8)        # trace+first call
        _, d_stream = _ledger_delta(lambda: layers.cim_linear(x, w, n_bits=8))

        # resident: cold pins, then warm
        layers.cim_linear(x, w, n_bits=8, resident=True)
        _, d_warm = _ledger_delta(
            lambda: layers.cim_linear(x, w, n_bits=8, resident=True))
        assert d_warm[0] == d_stream[0]          # plan accesses untouched
        assert d_warm[1] < d_stream[1]           # strictly fewer loads
        assert d_warm[2] >= 1
        clear_resident()

    def test_schedule_resident_names(self):
        from repro.cim import planner
        s = planner.plan_matmul(16, 8, resident_rhs=True)
        assert s.operands == ("lhs", "rhs") and s.resident == ("rhs",)
        with pytest.raises(CimOpError):
            planner.plan_matmul(16, 8).with_resident("nope")


# ---------------------------------------------------------------------------
# bit-exactness + program-cache separation
# ---------------------------------------------------------------------------


def _quant_linear(x, w):
    # same shape as layers._quantized_linear: float quantize on the host,
    # the EXACT int8 contraction is the CiM-eligible eqn
    scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-9)
    wq = jnp.clip(jnp.round(w / scale * 127), -127, 127).astype(jnp.int8)
    xq = jnp.clip(jnp.round(x * 8), -127, 127).astype(jnp.int8)
    y = jax.lax.dot_general(xq, wq, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.int32)
    return y.astype(jnp.float32) * scale


class TestLoweredResident:
    @pytest.mark.parametrize("backend", PORTABLE)
    def test_bit_exact_resident_vs_repack(self, backend):
        clear_resident()
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 16))
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
        ref = np.asarray(lower(_quant_linear, backend=backend)(x, w))
        lf = lower(_quant_linear, backend=backend, resident_argnums=(1,))
        cold = np.asarray(lf(x, w))
        warm = np.asarray(lf(x, w))
        np.testing.assert_array_equal(cold, ref)
        np.testing.assert_array_equal(warm, ref)
        clear_resident()

    def test_residency_planning_classifies_rhs(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 16))
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
        lf = lower(_quant_linear, resident_argnums=(1,))
        lf(x, w)                                  # trace + cold pin
        comp = lf.trace(x, w)
        kinds = [(ra.ai, ra.kind) for r in comp.regions for ra in r.resident]
        assert kinds, "weight-derived region input must be resident-planned"
        assert all(k == "matmul_rhs" for _, k in kinds)
        # host eqns that only quantize the pinned weights skip when warm
        assert comp._warm_skip

    def test_program_cache_keys_differ(self):
        clear_resident()
        dispatch.clear_schedule_cache()
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 16))
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
        lower(_quant_linear)(x, w)
        m_streamed = dispatch.cache_stats()["misses"]
        lf = lower(_quant_linear, resident_argnums=(1,))
        lf(x, w)
        m_resident = dispatch.cache_stats()["misses"]
        assert m_resident > m_streamed, \
            "resident region must compile its own program"
        lf(x, w)                                  # warm: no new programs
        assert dispatch.cache_stats()["misses"] == m_resident
        clear_resident()

    def test_tracer_leaves_fall_back_to_streamed(self):
        clear_resident()
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 16))
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
        lf = lower(_quant_linear, resident_argnums=(1,))
        ref = np.asarray(_quant_linear(x, w))
        out = jax.jit(lambda xx, ww: lf(xx, ww))(x, w)
        np.testing.assert_array_equal(np.asarray(out), ref)
        from repro.cim.array import resident_stats
        assert resident_stats()["resident_pins"] == 0
        clear_resident()

"""Energy/EDP model vs the paper's reported numbers (Sec. IV, Figs 4-7)."""
import numpy as np
import pytest

from repro.core import energy


def test_current_sensing_anchor_1024():
    r = energy.current_sensing(1024)
    # paper: 1.94x speedup, 41.18% energy decrease, 69.04% EDP decrease
    assert r.speedup == pytest.approx(1.94, abs=0.01)
    assert r.energy_decrease_pct == pytest.approx(41.18, abs=0.2)
    assert r.edp_decrease_pct == pytest.approx(69.04, abs=1.2)  # paper rounding
    # CiM op costs 1.24x a standard read
    assert r.cim.energy / r.read.energy == pytest.approx(1.24, abs=0.01)
    # RBL charging dominates: 91% of read, 74% of CiM energy (Fig 4a)
    assert r.read.breakdown["bitline"] / r.read.energy == pytest.approx(0.91, abs=0.01)
    assert r.cim.breakdown["bitline"] / r.cim.energy == pytest.approx(0.74, abs=0.01)


def test_current_sensing_benefits_grow_with_array_size():
    sw = energy.sweep("current")
    sizes = sorted(sw)
    ed = [sw[s].energy_decrease_pct for s in sizes]
    sp = [sw[s].speedup for s in sizes]
    edp = [sw[s].edp_decrease_pct for s in sizes]
    assert all(np.diff(ed) > 0) and all(np.diff(sp) > 0) and all(np.diff(edp) > 0)
    assert all(s < 2.0 for s in sp)  # bounded by the 2-access baseline


def test_scheme1_anchor_1024():
    r = energy.voltage_scheme1(1024)
    # paper: +20-23% energy, 1.57-1.73x speedup, 23.26-28.81% EDP decrease
    assert -23.0 <= r.energy_decrease_pct <= -20.0
    assert 1.57 <= r.speedup <= 1.73
    assert 23.26 <= r.edp_decrease_pct <= 28.81 + 0.3
    # ADRA discharges 6*Delta vs 2*Delta -> 3x bitline energy (1.5x vs baseline)
    assert r.cim.breakdown["bitline"] / r.read.breakdown["bitline"] == pytest.approx(3.0)


def test_scheme2_anchor_1024():
    r = energy.voltage_scheme2(1024)
    # paper: 1.945-1.983x speedup, 35.5-45.8% less energy, 66.83-72.6% EDP dec.
    assert 1.945 <= r.speedup <= 1.983
    assert 35.5 <= r.energy_decrease_pct <= 45.8
    assert 66.83 <= r.edp_decrease_pct <= 72.6
    # scheme 2: bitline energy identical for read and CiM
    assert r.cim.breakdown["bitline"] == pytest.approx(r.read.breakdown["bitline"])


def test_frequency_crossover_7p53_mhz():
    f = energy.frequency_crossover_hz()
    assert f == pytest.approx(7.53e6, rel=0.01)
    # below f*: scheme 2 wins; above: scheme 1 wins
    lo = energy.scheme_energies_vs_frequency(1e6)
    hi = energy.scheme_energies_vs_frequency(50e6)
    assert lo["scheme2"] < lo["scheme1"]
    assert hi["scheme1"] < hi["scheme2"]


def test_parallelism_crossover_42pct():
    p = energy.parallelism_crossover()
    assert p == pytest.approx(0.42, abs=0.02)  # paper: ~42%
    lo = energy.scheme_energies_vs_parallelism(0.2)
    hi = energy.scheme_energies_vs_parallelism(0.9)
    assert lo["scheme2"] < lo["scheme1"]   # low parallelism: scheme 2 wins
    assert hi["scheme1"] < hi["scheme2"]   # high parallelism: scheme 1 wins


def test_sense_margin_consistent_with_bitline_budget():
    # 6*Delta swing must stay below VDD and above 50 mV margins
    assert energy.CIM_SWING < energy.V_DD
    assert energy.DELTA_SENSE > 0.05


def test_edp_summary_all_schemes_positive():
    s = energy.edp_summary()
    for scheme, row in s.items():
        assert row["edp_decrease_pct"] > 20.0, scheme  # paper headline: 23.2-72.6%
        assert 23.2 - 0.3 <= row["edp_decrease_pct"] <= 72.6 + 0.3

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.bitplane import pack_bitplanes, unpack_bitplanes
from repro.kernels import ops, ref
from repro.kernels.adra_bitplane import (
    adra_bitplane_op,
    traffic_model_bytes,
)

RNG = np.random.RandomState(42)


# ---------------------------------------------------------------------------
# adra_bitplane
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_bits", [4, 8, 16, 32])
@pytest.mark.parametrize("n", [32, 100, 1000])
@pytest.mark.parametrize("select", [0, 1])
def test_adra_bitplane_matches_plane_oracle(n_bits, n, select):
    lo, hi = -(2 ** (n_bits - 1)), 2 ** (n_bits - 1) - 1
    a = jnp.array(RNG.randint(lo, hi, n), jnp.int32)
    b = jnp.array(RNG.randint(lo, hi, n), jnp.int32)
    ap, bp = pack_bitplanes(a, n_bits), pack_bitplanes(b, n_bits)
    got = adra_bitplane_op(ap, bp, select=select, interpret=True)
    want = ref.adra_bitplane_ref(ap, bp, select=select)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.array(g), np.array(w))


@pytest.mark.parametrize("n_bits", [8, 16])
def test_adra_bitplane_int_semantics(n_bits):
    lo, hi = -(2 ** (n_bits - 1)), 2 ** (n_bits - 1) - 1
    a = jnp.array(RNG.randint(lo, hi, 500), jnp.int32)
    b = jnp.array(RNG.randint(lo, hi, 500), jnp.int32)
    d, lt, eq = ops.adra_sub(a, b, n_bits=n_bits, interpret=True)
    np.testing.assert_array_equal(np.array(d), np.array(a) - np.array(b))
    np.testing.assert_array_equal(np.array(lt), (np.array(a) < np.array(b)).astype(np.int32))
    np.testing.assert_array_equal(np.array(eq), (np.array(a) == np.array(b)).astype(np.int32))
    s = ops.adra_add(a, b, n_bits=n_bits + 1, interpret=True)
    np.testing.assert_array_equal(np.array(s), np.array(a) + np.array(b))


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 12), st.integers(1, 200), st.booleans())
def test_adra_bitplane_property(n_bits, n, sub):
    lo, hi = -(2 ** (n_bits - 1)), 2 ** (n_bits - 1) - 1
    rng = np.random.RandomState(n_bits * 1000 + n)
    a = jnp.array(rng.randint(lo, hi + 1, n), jnp.int32)
    b = jnp.array(rng.randint(lo, hi + 1, n), jnp.int32)
    if sub:
        d, lt, eq = ops.adra_sub(a, b, n_bits=n_bits, interpret=True)
        np.testing.assert_array_equal(np.array(d), np.array(a) - np.array(b))
    else:
        s = ops.adra_add(a, b, n_bits=n_bits, interpret=True)
        np.testing.assert_array_equal(np.array(s), np.array(a) + np.array(b))


def test_baseline_two_pass_matches_fused():
    a = jnp.array(RNG.randint(-1000, 1000, 300), jnp.int32)
    b = jnp.array(RNG.randint(-1000, 1000, 300), jnp.int32)
    d1, l1, e1 = ops.adra_sub(a, b, n_bits=16, interpret=True)
    d2, l2, e2 = ops.baseline_sub_then_cmp(a, b, n_bits=16, interpret=True)
    np.testing.assert_array_equal(np.array(d1), np.array(d2))
    np.testing.assert_array_equal(np.array(l1), np.array(l2))
    np.testing.assert_array_equal(np.array(e1), np.array(e2))


def test_traffic_model_single_vs_two_pass():
    """The TPU analogue of the paper's 1-vs-2 access claim: the fused kernel
    moves ~0.6x the bytes of the per-function baseline."""
    t = traffic_model_bytes(n_bits=16, n_words32=4096)
    assert t["baseline"] > t["fused"]
    assert t["ratio"] > 1.4


def test_bitplane_roundtrip_dtypes():
    for n_bits in (8, 16, 32):
        v = RNG.randint(-2 ** (n_bits - 1), 2 ** (n_bits - 1), 257).astype(np.int32)
        planes = pack_bitplanes(jnp.array(v), n_bits)
        assert planes.dtype == jnp.uint32
        back = np.array(unpack_bitplanes(planes, 257))
        np.testing.assert_array_equal(back, v)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [
    (1, 128, 128, 4, 4, 64),     # MHA
    (2, 128, 128, 4, 2, 64),     # GQA 2:1
    (1, 256, 256, 8, 1, 64),     # MQA
    (1, 64, 192, 4, 2, 32),      # cross lengths (kv longer)
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(shape, causal, dtype):
    b, tq, tk, hq, hkv, d = shape
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (b, tq, hq, d), dtype)
    k = jax.random.normal(k2, (b, tk, hkv, d), dtype)
    v = jax.random.normal(k3, (b, tk, hkv, d), dtype)
    out = ops.attention(q, k, v, causal=causal, use_pallas=True, interpret=True)
    want = ref.mha_ref(q, k, v, causal=causal)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.array(out, np.float32),
                               np.array(want, np.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(1, 128, 128), (2, 256, 256), (3, 128, 384)])
def test_rglru_vs_ref(shape):
    b, t, d = shape
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    x = jax.random.normal(ks[0], (b, t, d))
    r = jax.random.normal(ks[1], (b, t, d))
    i = jax.random.normal(ks[2], (b, t, d))
    ll = jax.random.normal(ks[3], (d,))
    y, h = ops.rglru_scan(x, r, i, ll, use_pallas=True, interpret=True)
    ye, he = ref.rglru_ref(x, r, i, ll)
    np.testing.assert_allclose(np.array(y), np.array(ye), atol=1e-5)
    np.testing.assert_allclose(np.array(h), np.array(he), atol=1e-5)


def test_rglru_state_carry_chunked_equals_monolithic():
    """Chunking time across sequential grid steps must be exact (VMEM state
    carry), including a nonzero initial state."""
    b, t, d = 2, 256, 128
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x, r, i = (jax.random.normal(ks[j], (b, t, d)) for j in range(3))
    ll = jax.random.normal(ks[3], (d,))
    h0 = jax.random.normal(ks[4], (b, d))
    y1, hl1 = ops.rglru_scan(x, r, i, ll, h0=h0, use_pallas=True, interpret=True)
    y2, hl2 = ref.rglru_ref(x, r, i, ll, h0=h0)
    np.testing.assert_allclose(np.array(y1), np.array(y2), atol=1e-5)
    np.testing.assert_allclose(np.array(hl1), np.array(hl2), atol=1e-5)


# ---------------------------------------------------------------------------
# sLSTM kernel (VMEM-resident recurrent weights)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(3, 32, 64), (5, 64, 128), (2, 48, 256)])
def test_slstm_kernel_vs_oracle(shape):
    from repro.kernels.slstm import slstm_scan

    b, t, d = shape
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    wx = jax.random.normal(ks[0], (b, t, 4, d))
    r = jax.random.normal(ks[1], (d, 4, d)) * 0.2
    bg = jax.random.normal(ks[2], (4, d)) * 0.1
    h0 = jnp.zeros((b, d)); c0 = jnp.zeros((b, d))
    n0 = jnp.ones((b, d)); m0 = jnp.zeros((b, d))

    def step(carry, wx_t):
        h, c, n, m = carry
        pre = wx_t + jnp.einsum("bd,dge->bge", h, r) + bg[None]
        z = jnp.tanh(pre[:, 0]); i_t = pre[:, 1]
        f_t = jax.nn.log_sigmoid(pre[:, 2]); o = jax.nn.sigmoid(pre[:, 3])
        m_new = jnp.maximum(f_t + m, i_t)
        i_eff = jnp.exp(i_t - m_new); f_eff = jnp.exp(f_t + m - m_new)
        c = f_eff * c + i_eff * z; n = f_eff * n + i_eff
        h = o * c / jnp.maximum(n, 1e-6)
        return (h, c, n, m_new), h

    (hf, cf, nf, mf), ys = jax.lax.scan(step, (h0, c0, n0, m0), wx.swapaxes(0, 1))
    y2, (h2, c2, n2, m2) = slstm_scan(wx, r, bg, h0, c0, n0, m0,
                                      block_b=4, interpret=True)
    np.testing.assert_allclose(np.array(ys.swapaxes(0, 1)), np.array(y2), atol=1e-5)
    for a, b_ in [(hf, h2), (cf, c2), (nf, n2), (mf, m2)]:
        np.testing.assert_allclose(np.array(a), np.array(b_), atol=1e-5)

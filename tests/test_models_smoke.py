"""Per-architecture smoke tests: REDUCED same-family configs, one forward +
one train step on CPU, asserting output shapes and finiteness (assignment:
'instantiate a reduced config of the same family and run one forward/train
step on CPU asserting output shapes + no NaNs')."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build
from repro.optim import AdamWConfig
from repro.train import init_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    if cfg.embed_stub:
        return {
            "embeds": jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32) * 0.02,
            "targets": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
        }
    return {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    B, S = batch["targets"].shape

    logits, aux = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), "NaN/inf in logits"

    opt = AdamWConfig(lr=1e-3)
    state = init_state(model, KEY, opt)
    step = jax.jit(make_train_step(model, opt))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", ["llama3.2-1b", "recurrentgemma-9b", "xlstm-125m",
                                  "deepseek-v2-lite-16b", "musicgen-large"])
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = build(cfg)
    params = model.init(KEY)
    B, S = 2, 12
    batch = _batch(cfg, B, S)
    inputs = ({"embeds": batch["embeds"]} if cfg.embed_stub
              else {"tokens": batch["tokens"]})
    caches, logits = model.prefill(params, inputs, max_len=S + 4)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    step_in = (
        {"embeds": batch["embeds"][:, :1]} if cfg.embed_stub
        else {"tokens": batch["tokens"][:, :1]})
    step_in["positions"] = jnp.full((B,), S, jnp.int32)
    caches, logits = model.decode_step(params, caches, step_in)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen3-14b", "gemma-2b",
                                  "recurrentgemma-9b", "xlstm-125m"])
def test_decode_matches_teacher_forcing(arch):
    """prefill + step-by-step decode must equal the full forward pass."""
    cfg = get_config(arch).reduced()
    model = build(cfg)
    params = model.init(KEY)
    B, S = 2, 10
    toks = jax.random.randint(jax.random.PRNGKey(7), (B, S), 0, cfg.vocab_size)
    full, _ = model.forward(params, {"tokens": toks})
    Sp = S - 3
    caches, last = model.prefill(params, {"tokens": toks[:, :Sp]}, max_len=S)
    np.testing.assert_allclose(np.array(last), np.array(full[:, Sp - 1]),
                               atol=2e-4, rtol=2e-4)
    for t in range(Sp, S):
        caches, lg = model.decode_step(
            params, caches,
            {"tokens": toks[:, t:t + 1], "positions": jnp.full((B,), t, jnp.int32)})
        np.testing.assert_allclose(np.array(lg), np.array(full[:, t]),
                                   atol=2e-4, rtol=2e-4)


def test_full_configs_have_exact_published_dims():
    """The FULL configs carry the exact assigned hyperparameters."""
    rows = {
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
    }
    for arch, (L, d, h, kv, ff, v) in rows.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab_size) == (L, d, h, kv, ff, v), arch
    # MoE / MLA structure
    g = get_config("grok-1-314b")
    assert g.moe.n_experts == 8 and g.moe.top_k == 2
    ds = get_config("deepseek-v2-lite-16b")
    assert ds.moe.n_experts == 64 and ds.moe.top_k == 6 and ds.moe.n_shared == 2
    assert ds.mla.kv_lora_rank == 512
    assert get_config("gemma-2b").head_dim == 256
    assert get_config("qwen3-14b").qk_norm
    assert get_config("recurrentgemma-9b").block_pattern == ("rec", "rec", "local")
    assert get_config("xlstm-125m").sub_quadratic


def test_long_context_state_is_o1_for_subquadratic_archs():
    """long_500k viability: cache bytes must not scale with context length."""
    for arch in ("recurrentgemma-9b", "xlstm-125m"):
        cfg = get_config(arch).reduced()
        model = build(cfg)
        small = model.init_caches(1, 64)
        big = model.init_caches(1, 4096)
        b_small = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(small))
        b_big = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(big))
        assert b_small == b_big, arch  # ring buffer / recurrent state only

"""The beyond-paper perf substrate must be bit-faithful to the naive forms:
blockwise custom-VJP attention, chunkwise-parallel mLSTM, chunked scans,
microbatched gradient accumulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.xlstm as xl
from repro.models.blockwise_attention import blockwise_attention
from repro.models.scan_utils import chunked_scan, pick_chunk

KEY = jax.random.PRNGKey(0)


def _dense_ref(q, k, v, causal, window, scale=None):
    b, tq, hq, d = q.shape
    _, tk, hkv, _ = k.shape
    g = hq // hkv
    scale = scale or 1.0 / d ** 0.5
    kf = jnp.repeat(k.astype(jnp.float32), g, 2)
    vf = jnp.repeat(v.astype(jnp.float32), g, 2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale, kf)
    qpos = jnp.arange(tq)[:, None] + (tk - tq)
    kpos = jnp.arange(tk)[None, :]
    m = jnp.ones((tq, tk), bool)
    if causal:
        m = m & (qpos >= kpos)
    if window:
        m = m & (qpos - kpos < window)
    logits = jnp.where(m[None, None], logits, -1e30)
    return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(logits, -1), vf)


@pytest.mark.parametrize("cfg", [
    dict(b=2, tq=128, tk=128, hq=4, hkv=2, d=32, causal=True, window=0),
    dict(b=1, tq=256, tk=256, hq=8, hkv=1, d=16, causal=True, window=0),
    dict(b=2, tq=128, tk=128, hq=4, hkv=4, d=32, causal=True, window=40),
    dict(b=1, tq=96, tk=160, hq=4, hkv=2, d=32, causal=True, window=0),
    dict(b=2, tq=128, tk=128, hq=4, hkv=2, d=32, causal=False, window=0),
])
def test_blockwise_attention_fwd_and_grad(cfg):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (cfg["b"], cfg["tq"], cfg["hq"], cfg["d"]))
    k = jax.random.normal(ks[1], (cfg["b"], cfg["tk"], cfg["hkv"], cfg["d"]))
    v = jax.random.normal(ks[2], (cfg["b"], cfg["tk"], cfg["hkv"], cfg["d"]))
    out = blockwise_attention(q, k, v, cfg["causal"], None, cfg["window"], 64)
    want = _dense_ref(q, k, v, cfg["causal"], cfg["window"])
    np.testing.assert_allclose(np.array(out), np.array(want), atol=3e-6)

    def loss_bw(q, k, v):
        return jnp.sum(jnp.sin(blockwise_attention(q, k, v, cfg["causal"],
                                                   None, cfg["window"], 64)))

    def loss_ref(q, k, v):
        return jnp.sum(jnp.sin(_dense_ref(q, k, v, cfg["causal"], cfg["window"])))

    g1 = jax.grad(loss_bw, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.array(a), np.array(b), atol=5e-5)


def test_blockwise_mla_latent_shapes():
    """dv != dk path (MLA latent attention)."""
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 8, 48))
    k = jax.random.normal(ks[1], (1, 128, 1, 48))
    v = jax.random.normal(ks[2], (1, 128, 1, 24))
    out = blockwise_attention(q, k, v, True, None, 0, 32)
    assert out.shape == (1, 128, 8, 24)
    want = _dense_ref(q, k, v, True, 0)
    np.testing.assert_allclose(np.array(out), np.array(want), atol=3e-6)


def test_chunkwise_mlstm_equals_sequential():
    b, t, h, dh = 2, 128, 3, 16
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (b, t, h, dh))
    k = jax.random.normal(ks[1], (b, t, h, dh))
    v = jax.random.normal(ks[2], (b, t, h, dh))
    i_pre = jax.random.normal(ks[3], (b, t, h)) * 2
    f_pre = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, t, h)) * 2 + 2)

    hs_seq, st_seq = xl._mlstm_cell(q, k, v, i_pre, f_pre, None)
    init = (jnp.zeros((b, h, dh, dh)), jnp.zeros((b, h, dh)),
            jnp.full((b, h), -jnp.inf))
    for chunk in (16, 32, 128):
        hs_ch, st_ch = xl._mlstm_chunkwise(q, k, v, i_pre, f_pre, init, chunk=chunk)
        np.testing.assert_allclose(np.array(hs_seq), np.array(hs_ch), atol=3e-5)
        for a, b_ in zip(st_seq, st_ch):
            np.testing.assert_allclose(np.array(a), np.array(b_), atol=3e-5)
    # continuation from a nonzero state (prefill -> decode handoff)
    hs1, _ = xl._mlstm_cell(q, k, v, i_pre, f_pre, st_seq)
    hs2, _ = xl._mlstm_chunkwise(q, k, v, i_pre, f_pre, st_ch, chunk=32)
    np.testing.assert_allclose(np.array(hs1), np.array(hs2), atol=3e-5)


def test_chunkwise_mlstm_grads_flow():
    b, t, h, dh = 1, 64, 2, 8
    ks = jax.random.split(KEY, 5)
    args = [jax.random.normal(ks[j], (b, t, h, dh)) for j in range(3)]
    i_pre = jax.random.normal(ks[3], (b, t, h))
    f_pre = jax.nn.log_sigmoid(jax.random.normal(ks[4], (b, t, h)))
    init = (jnp.zeros((b, h, dh, dh)), jnp.zeros((b, h, dh)),
            jnp.full((b, h), -jnp.inf))

    def loss(q, k, v):
        hs, _ = xl._mlstm_chunkwise(q, k, v, i_pre, f_pre, init, chunk=16)
        return jnp.sum(hs ** 2)

    g = jax.grad(loss, argnums=(0, 1, 2))(*args)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in g)
    assert any(float(jnp.max(jnp.abs(x))) > 0 for x in g)


def test_chunked_scan_exactness():
    def body(c, x):
        c = 0.9 * c + x
        return c, c * 2.0

    xs = jax.random.normal(KEY, (96, 4))
    c1, y1 = jax.lax.scan(body, jnp.zeros((4,)), xs)
    c2, y2 = chunked_scan(body, jnp.zeros((4,)), xs, chunk=pick_chunk(96, 32))
    np.testing.assert_allclose(np.array(c1), np.array(c2), rtol=1e-6)
    np.testing.assert_allclose(np.array(y1), np.array(y2), rtol=1e-6)


def test_pick_chunk_divides():
    for t in (96, 100, 4096, 7, 524288):
        c = pick_chunk(t, 256)
        assert t % c == 0 and 1 <= c <= 256


def test_interleaved_rope_preserves_norm_and_relativity():
    from repro.models.layers import apply_rope
    x = jax.random.normal(KEY, (1, 8, 2, 16))
    pos = jnp.arange(8)[None].astype(jnp.int32)
    y = apply_rope(x, pos, 10_000.0)
    # rotations preserve the per-pair norm
    np.testing.assert_allclose(
        np.array(jnp.linalg.norm(x, axis=-1)),
        np.array(jnp.linalg.norm(y, axis=-1)), rtol=1e-5)
    # relative property: <R(p)q, R(p+k)v> depends only on k
    q = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 1, 16))
    v = jax.random.normal(jax.random.PRNGKey(4), (1, 1, 1, 16))
    dots = []
    for p in (0, 5, 11):
        rq = apply_rope(q, jnp.array([[p]]), 10_000.0)
        rv = apply_rope(v, jnp.array([[p + 3]]), 10_000.0)
        dots.append(float(jnp.sum(rq * rv)))
    assert abs(dots[0] - dots[1]) < 1e-4 and abs(dots[1] - dots[2]) < 1e-4

"""Roofline methodology: HLO collective parser, analytic models, terms."""
import jax
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.launch import roofline as rl

HLO = """
HloModule test
ENTRY %main {
  %p0 = bf16[128,256]{1,0} parameter(0)
  %p1 = f32[64]{0} parameter(1)
  %ag = bf16[2048,256]{1,0} all-gather(%p0), replica_groups={}
  %ar.1 = f32[64]{0} all-reduce(%p1), to_apply=%add
  %rs = bf16[8,256]{1,0} reduce-scatter(%p0), to_apply=%add
  %a2a = bf16[128,256]{1,0} all-to-all(%p0)
  %cp = f32[64]{0} collective-permute(%p1)
  %ars = f32[64]{0} all-reduce-start(%p1)
  %ard = f32[64]{0} all-reduce-done(%ars)
  ROOT %t = (bf16[128,256]{1,0}) tuple(%a2a)
}
"""


def test_collective_parser_sums_operand_bytes():
    st = rl.collective_bytes(HLO)
    p0 = 128 * 256 * 2
    p1 = 64 * 4
    assert st.bytes_by_op["all-gather"] == p0
    # plain all-reduce + all-reduce-start counted, -done deduped
    assert st.bytes_by_op["all-reduce"] == 2 * p1
    assert st.count_by_op["all-reduce"] == 2
    assert st.bytes_by_op["reduce-scatter"] == p0
    assert st.bytes_by_op["all-to-all"] == p0
    assert st.bytes_by_op["collective-permute"] == p1


def test_collective_parser_tuple_shapes():
    hlo = "%x = (bf16[4,4]{1,0}, f32[2]{0}) all-reduce(%a, %b)\n%a = bf16[4,4]{1,0} add(%x, %x)\n%b = f32[2]{0} add(%x, %x)\n"
    st = rl.collective_bytes(hlo)
    assert st.bytes_by_op["all-reduce"] == 4 * 4 * 2 + 2 * 4


def test_shape_bytes_subbyte_dtypes_round_once():
    """4-bit dtypes contribute exact bit totals, rounded up to bytes ONCE
    per instruction — s4[7] is 4 bytes, never a fractional 3.5."""
    assert rl._shape_bytes("s4[7]") == 4           # 28 bits -> ceil 4
    assert rl._shape_bytes("u4[8]") == 4           # exact 32 bits
    assert rl._shape_bytes("s4[101]") == 51        # 404 bits -> ceil 51
    # tuples accumulate bits BEFORE the single round-up
    assert rl._shape_bytes("(s4[1], s4[1])") == 1  # 8 bits, not 1+1
    assert rl._shape_bytes("(s4[3], u4[3])") == 3  # 24 bits, not 2+2
    assert rl._shape_bytes("bf16[4,4]") == 32
    assert rl._shape_bytes("token[]") == 0


def test_collective_parser_s4_operands():
    hlo = ("%q = s4[101]{0} parameter(0)\n"
           "%ag = s4[101]{0} all-gather(%q), replica_groups={}\n")
    st = rl.collective_bytes(hlo)
    assert st.bytes_by_op["all-gather"] == 51      # ceil(101*4/8)


def test_roofline_terms_accept_device_spec_override():
    from repro.cim.cost import DeviceSpec

    slow = DeviceSpec(name="half-speed", peak_flops=rl.PEAK_FLOPS / 2,
                      hbm_bw=rl.HBM_BW / 2, ici_bw=rl.ICI_BW)
    base = rl.RooflineTerms(flops_global=197e12, bytes_global=819e9,
                            collective_bytes_per_chip=0.0, n_chips=1,
                            model_flops=197e12)
    over = rl.RooflineTerms(flops_global=197e12, bytes_global=819e9,
                            collective_bytes_per_chip=0.0, n_chips=1,
                            model_flops=197e12, device=slow)
    assert over.t_compute == pytest.approx(2 * base.t_compute)
    assert over.t_memory == pytest.approx(2 * base.t_memory)
    assert base.to_dict()["device"] == "tpu-v5e"
    assert over.to_dict()["device"] == "half-speed"


def test_module_constants_come_from_default_device():
    from repro.cim.cost import DEFAULT_DEVICE

    assert rl.PEAK_FLOPS == DEFAULT_DEVICE.peak_flops
    assert rl.HBM_BW == DEFAULT_DEVICE.hbm_bw
    assert rl.ICI_BW == DEFAULT_DEVICE.ici_bw


def test_roofline_terms_and_bottleneck():
    t = rl.RooflineTerms(flops_global=197e12 * 256, bytes_global=819e9,
                         collective_bytes_per_chip=50e9, n_chips=256,
                         model_flops=197e12 * 128)
    assert t.t_compute == pytest.approx(1.0)
    assert t.t_memory == pytest.approx(1.0 / 256)
    assert t.t_collective == pytest.approx(1.0)
    assert t.bottleneck in ("compute", "collective")
    assert t.useful_flops_ratio == pytest.approx(0.5)
    assert t.roofline_fraction == pytest.approx(0.5)


def test_analytic_flops_scales_sanely():
    cfg = get_config("llama3.2-1b")
    train = rl.analytic_flops(cfg, SHAPES["train_4k"])
    prefill = rl.analytic_flops(cfg, SHAPES["prefill_32k"])
    decode = rl.analytic_flops(cfg, SHAPES["decode_32k"])
    # train is fwd x4 over ~1M tokens; decode is 1 token/seq
    assert train > prefill > decode > 0
    # vs 6*N*D: same order of magnitude (attention + remat inflate)
    n = 1.10e9  # non-embedding params
    d = 256 * 4096
    assert 0.5 < train / (6 * n * d * 4 / 3) < 3.0


def test_analytic_flops_moe_counts_capacity_not_all_experts():
    ds = get_config("deepseek-v2-lite-16b")
    fl = rl.analytic_flops(ds, SHAPES["train_4k"])
    # dense-equivalent (all 64 experts) would be ~8x the top-6 routed figure
    import dataclasses
    dense_like = dataclasses.replace(
        ds, moe=dataclasses.replace(ds.moe, top_k=ds.moe.n_experts,
                                    capacity_factor=1.0))
    fl_dense = rl.analytic_flops(dense_like, SHAPES["train_4k"])
    assert fl_dense > 3 * fl


def test_active_param_count_scales_moe():
    cfg = get_config("grok-1-314b")
    from repro.models import build
    params = jax.eval_shape(build(cfg).init, jax.random.PRNGKey(0))
    total = sum(np.prod(l.shape) for l in jax.tree.leaves(params))
    active = rl.active_param_count(cfg, params)
    assert total > 3.0e11            # ~314 B params materialized
    assert active < 0.45 * total     # top-2 of 8 experts dominate the count

"""Roofline methodology: HLO collective parser, analytic models, terms."""
import jax
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.launch import roofline as rl

HLO = """
HloModule test
ENTRY %main {
  %p0 = bf16[128,256]{1,0} parameter(0)
  %p1 = f32[64]{0} parameter(1)
  %ag = bf16[2048,256]{1,0} all-gather(%p0), replica_groups={}
  %ar.1 = f32[64]{0} all-reduce(%p1), to_apply=%add
  %rs = bf16[8,256]{1,0} reduce-scatter(%p0), to_apply=%add
  %a2a = bf16[128,256]{1,0} all-to-all(%p0)
  %cp = f32[64]{0} collective-permute(%p1)
  %ars = f32[64]{0} all-reduce-start(%p1)
  %ard = f32[64]{0} all-reduce-done(%ars)
  ROOT %t = (bf16[128,256]{1,0}) tuple(%a2a)
}
"""


def test_collective_parser_sums_operand_bytes():
    st = rl.collective_bytes(HLO)
    p0 = 128 * 256 * 2
    p1 = 64 * 4
    assert st.bytes_by_op["all-gather"] == p0
    # plain all-reduce + all-reduce-start counted, -done deduped
    assert st.bytes_by_op["all-reduce"] == 2 * p1
    assert st.count_by_op["all-reduce"] == 2
    assert st.bytes_by_op["reduce-scatter"] == p0
    assert st.bytes_by_op["all-to-all"] == p0
    assert st.bytes_by_op["collective-permute"] == p1


def test_collective_parser_tuple_shapes():
    hlo = "%x = (bf16[4,4]{1,0}, f32[2]{0}) all-reduce(%a, %b)\n%a = bf16[4,4]{1,0} add(%x, %x)\n%b = f32[2]{0} add(%x, %x)\n"
    st = rl.collective_bytes(hlo)
    assert st.bytes_by_op["all-reduce"] == 4 * 4 * 2 + 2 * 4


def test_roofline_terms_and_bottleneck():
    t = rl.RooflineTerms(flops_global=197e12 * 256, bytes_global=819e9,
                         collective_bytes_per_chip=50e9, n_chips=256,
                         model_flops=197e12 * 128)
    assert t.t_compute == pytest.approx(1.0)
    assert t.t_memory == pytest.approx(1.0 / 256)
    assert t.t_collective == pytest.approx(1.0)
    assert t.bottleneck in ("compute", "collective")
    assert t.useful_flops_ratio == pytest.approx(0.5)
    assert t.roofline_fraction == pytest.approx(0.5)


def test_analytic_flops_scales_sanely():
    cfg = get_config("llama3.2-1b")
    train = rl.analytic_flops(cfg, SHAPES["train_4k"])
    prefill = rl.analytic_flops(cfg, SHAPES["prefill_32k"])
    decode = rl.analytic_flops(cfg, SHAPES["decode_32k"])
    # train is fwd x4 over ~1M tokens; decode is 1 token/seq
    assert train > prefill > decode > 0
    # vs 6*N*D: same order of magnitude (attention + remat inflate)
    n = 1.10e9  # non-embedding params
    d = 256 * 4096
    assert 0.5 < train / (6 * n * d * 4 / 3) < 3.0


def test_analytic_flops_moe_counts_capacity_not_all_experts():
    ds = get_config("deepseek-v2-lite-16b")
    fl = rl.analytic_flops(ds, SHAPES["train_4k"])
    # dense-equivalent (all 64 experts) would be ~8x the top-6 routed figure
    import dataclasses
    dense_like = dataclasses.replace(
        ds, moe=dataclasses.replace(ds.moe, top_k=ds.moe.n_experts,
                                    capacity_factor=1.0))
    fl_dense = rl.analytic_flops(dense_like, SHAPES["train_4k"])
    assert fl_dense > 3 * fl


def test_active_param_count_scales_moe():
    cfg = get_config("grok-1-314b")
    from repro.models import build
    params = jax.eval_shape(build(cfg).init, jax.random.PRNGKey(0))
    total = sum(np.prod(l.shape) for l in jax.tree.leaves(params))
    active = rl.active_param_count(cfg, params)
    assert total > 3.0e11            # ~314 B params materialized
    assert active < 0.45 * total     # top-2 of 8 experts dominate the count

"""Fault tolerance, checkpointing, data determinism, optimizer, compression."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, synthetic_batch
from repro.models import build
from repro.optim import AdamWConfig, compression
from repro.runtime import SimulatedHostFailure, StragglerDetector, Supervisor, SupervisorConfig
from repro.train import init_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _setup(tmp_path, compress=False):
    cfg = get_config("llama3.2-1b").reduced()
    model = build(cfg)
    opt = AdamWConfig(lr=1e-3)
    state = init_state(model, KEY, opt, compress_grads=compress)
    step = jax.jit(make_train_step(model, opt, compress_grads=compress))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, batch=2, seq_len=16)
    mb = lambda s: {k: jnp.asarray(v) for k, v in synthetic_batch(s, dcfg).items()}
    ckpt = CheckpointManager(str(tmp_path), keep=3)
    return cfg, model, state, step, mb, ckpt


# ---------------------------------------------------------------------------
# checkpoint save / restore / atomicity
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    _, _, state, step, mb, ckpt = _setup(tmp_path)
    state, _ = step(state, mb(0))
    ckpt.save(1, state, blocking=True)
    assert ckpt.latest_step() == 1
    restored = ckpt.restore(1, jax.tree.map(np.zeros_like, state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.array(a), np.array(b))


def test_checkpoint_async_and_gc(tmp_path):
    _, _, state, step, mb, ckpt = _setup(tmp_path)
    for s in range(1, 6):
        ckpt.save(s, state, blocking=False)
    ckpt.wait()
    assert ckpt.latest_step() == 5
    assert len(ckpt.all_steps()) <= 3  # keep=3


# ---------------------------------------------------------------------------
# supervisor: failure recovery is bit-exact (restart-exact data pipeline)
# ---------------------------------------------------------------------------


def test_supervisor_recovers_from_injected_failure(tmp_path):
    _, _, state0, step, mb, ckpt = _setup(tmp_path)

    # uninterrupted reference run
    ref_state = state0
    for s in range(6):
        ref_state, _ = step(ref_state, mb(s))

    # failing run: dies at step 4 (after ckpt at 2), supervisor restores
    fails = {"left": 1}

    def fault_hook(step_num):
        if step_num == 4 and fails["left"]:
            fails["left"] -= 1
            raise SimulatedHostFailure("node lost")

    sup = Supervisor(step, mb, CheckpointManager(str(tmp_path / "f"), keep=3),
                     SupervisorConfig(ckpt_every=2), fault_hook=fault_hook)
    state, _ = sup.run(state0, 6)
    assert len(sup.events) == 1

    for a, b in zip(jax.tree.leaves(ref_state), jax.tree.leaves(state)):
        np.testing.assert_allclose(np.array(a, np.float32), np.array(b, np.float32),
                                   atol=0, rtol=0)


def test_supervisor_nan_sentinel(tmp_path):
    cfg, model, state0, _, mb, _ = _setup(tmp_path)
    calls = {"n": 0}

    def poisoned_step(state, batch):
        calls["n"] += 1
        opt = AdamWConfig(lr=1e-3)
        real = jax.jit(make_train_step(model, opt))
        new_state, m = real(state, batch)
        if calls["n"] == 3:   # poison exactly one step
            m = dict(m)
            m["loss"] = jnp.float32(jnp.nan)
        return new_state, m

    ckpt = CheckpointManager(str(tmp_path / "nan"), keep=2)
    sup = Supervisor(poisoned_step, mb, ckpt, SupervisorConfig(ckpt_every=1))
    state, metrics = sup.run(state0, 5)
    assert len(sup.events) == 1 and "non-finite" in sup.events[0]["error"]
    assert np.isfinite(float(metrics["loss"]))


def test_straggler_detector_flags_slow_host():
    det = StragglerDetector(4, SupervisorConfig(straggler_factor=2.0, ewma_alpha=1.0))
    flagged = det.update(np.array([0.1, 0.1, 0.1, 0.5]))
    assert flagged == [3]
    flagged = det.update(np.array([0.1, 0.1, 0.1, 0.1]))
    assert flagged == []  # recovered


# ---------------------------------------------------------------------------
# data pipeline determinism
# ---------------------------------------------------------------------------


def test_data_restart_exactness():
    dcfg = DataConfig(seed=3, vocab_size=1000, batch=4, seq_len=32)
    a = synthetic_batch(17, dcfg)
    b = synthetic_batch(17, dcfg)   # same step -> identical bits
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synthetic_batch(18, dcfg)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # targets are inputs shifted by one (next-token packing)
    full = synthetic_batch(0, dcfg)
    assert full["tokens"].shape == (4, 32) and full["targets"].shape == (4, 32)


def test_data_in_vocab_range():
    dcfg = DataConfig(vocab_size=77, batch=8, seq_len=64)
    b = synthetic_batch(0, dcfg)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 77


# ---------------------------------------------------------------------------
# gradient compression numerics
# ---------------------------------------------------------------------------


def test_int8_compression_error_feedback_converges():
    """Error feedback keeps the long-run mean of q/dq equal to the signal."""
    rng = np.random.RandomState(0)
    g = jnp.array(rng.randn(256) * 1e-3, jnp.float32)
    res = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    n = 64
    for _ in range(n):
        q, s, res = compression.compress(g, res)
        acc = acc + compression.decompress(q, s)
    # accumulated dequantized sum ~= n * g  (bias -> 0 thanks to residuals)
    np.testing.assert_allclose(np.array(acc) / n, np.array(g), atol=2e-5)


def test_compression_tree_structure_preserved():
    tree = {"a": jnp.ones((4, 4)), "b": (jnp.zeros((3,)), jnp.ones((2, 2)))}
    res = compression.init_residuals(tree)
    dq, new_res = compression.compress_tree(tree, res)
    assert jax.tree.structure(dq) == jax.tree.structure(tree)
    assert jax.tree.structure(new_res) == jax.tree.structure(tree)


def test_compressed_training_still_learns():
    cfg = get_config("llama3.2-1b").reduced()
    model = build(cfg)
    opt = AdamWConfig(lr=5e-3)
    state = init_state(model, KEY, opt, compress_grads=True)
    step = jax.jit(make_train_step(model, opt, compress_grads=True))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, batch=2, seq_len=16)
    batch = {k: jnp.asarray(v) for k, v in synthetic_batch(0, dcfg).items()}
    first = None
    for i in range(10):
        state, m = step(state, batch)
        first = first if first is not None else float(m["loss"])
    assert float(m["loss"]) < first

"""Serve-engine tests: paged KV block table + continuous batching.

`PagedKV` is pure bookkeeping (block pool + ResidentSet reservations) and
is tested exhaustively; the `ServeEngine` tests run a real reduced model
through the queue and assert the request lifecycle invariants — completion,
monotone timestamps, per-request attribution, slot/block recycling — not
wall-clock numbers, which are machine-dependent and belong to the gated
serve bench.
"""
import jax
import pytest

from repro.cim import CimOpError
from repro.cim.array import ArraySpec, ResidentSet
from repro.configs import get_config
from repro.launch.paged_kv import PagedKV
from repro.launch.serve import ServeEngine, ServeRequest, _percentile
from repro.models import build

SPEC = ArraySpec(banks=2, subarrays=1, rows=64, bitline_words=32)


# ---------------------------------------------------------------------------
# paged KV block table
# ---------------------------------------------------------------------------


class TestPagedKV:
    def test_alloc_extend_free(self):
        kv = PagedKV(spec=SPEC, n_blocks=4, block_tokens=4)
        assert kv.alloc(0, 6)                    # 6 tokens -> 2 blocks
        assert kv.blocks_in_use == 2
        assert kv.extend(0, 2)                   # fills block 2, no claim
        assert kv.blocks_in_use == 2
        assert kv.extend(0, 1)                   # 9th token -> 3rd block
        assert kv.blocks_in_use == 3
        kv.free(0)
        assert kv.blocks_in_use == 0
        assert kv.stats().peak_blocks == 3

    def test_alloc_is_all_or_nothing(self):
        kv = PagedKV(spec=SPEC, n_blocks=2, block_tokens=4)
        assert not kv.alloc(0, 12)               # needs 3 of 2 blocks
        assert kv.blocks_in_use == 0             # partial claim rolled back
        assert kv.stats().failed_allocs == 1
        assert kv.alloc(0, 8)                    # pool still usable

    def test_double_alloc_rejected(self):
        kv = PagedKV(spec=SPEC, n_blocks=4, block_tokens=4)
        kv.alloc(0, 4)
        with pytest.raises(ValueError):
            kv.alloc(0, 4)
        with pytest.raises(ValueError):
            kv.extend(99)

    def test_bank_alignment(self):
        kv = PagedKV(spec=SPEC, n_blocks=8, block_tokens=4)
        assert [kv.bank_of_block(b) for b in range(4)] == [0, 1, 0, 1]

    def test_reservations_drive_resident_rows(self):
        rs = ResidentSet(SPEC)
        kv = PagedKV(spec=SPEC, n_blocks=4, block_tokens=4, kv_bits=16,
                     resident_set=rs)
        assert kv.alloc(0, 8)                    # blocks 0,1 -> banks 0,1
        assert rs.rows_per_bank() == {0: 16, 1: 16}
        kv.free(0)
        assert rs.resident_rows == 0             # reservations released

    def test_failed_reservation_rolls_back_block(self):
        # 3 rows of reserve budget: the 16-row KV reservation cannot fit
        rs = ResidentSet(SPEC, reserve_rows=61)
        kv = PagedKV(spec=SPEC, n_blocks=4, block_tokens=4, kv_bits=16,
                     resident_set=rs)
        assert not kv.alloc(0, 4)
        assert kv.blocks_in_use == 0 and len(rs) == 0
        assert kv.stats().failed_allocs == 1

    def test_reservations_are_not_evictable_by_pins(self):
        from repro.cim import PlanePack
        import jax.numpy as jnp
        rs = ResidentSet(SPEC)
        kv = PagedKV(spec=SPEC, n_blocks=8, block_tokens=4, kv_bits=16,
                     resident_set=rs)
        assert kv.alloc(0, 32)                   # 8 blocks: 64 rows/bank
        with pytest.raises(CimOpError, match="reservation"):
            rs.pin("w", PlanePack.pack(jnp.arange(8), 8, signed=False))
        assert kv.blocks_in_use == 8             # KV untouched

    def test_for_model_sizing(self):
        cfg = get_config("llama3.2-1b").reduced()
        kv = PagedKV.for_model(cfg, spec=SPEC, slots=3, max_len=16)
        words_per_token = 2 * cfg.kv_dim * cfg.n_layers
        expect_bt = max(1, SPEC.tile_words // words_per_token)
        assert kv.block_tokens == expect_bt
        assert kv.n_blocks == 3 * (-(-16 // expect_bt))
        # the pool holds exactly slots * max_len tokens
        assert kv.n_blocks * kv.block_tokens >= 3 * 16


def test_percentile():
    assert _percentile([], 50) == 0.0
    assert _percentile([7.0], 99) == 7.0
    xs = [float(i) for i in range(101)]      # 0..100: index == percentile
    assert _percentile(xs, 50) == 50.0
    assert _percentile(xs, 99) == 99.0
    assert _percentile(xs, 0) == 0.0
    assert _percentile(list(reversed(xs)), 100) == 100.0


# ---------------------------------------------------------------------------
# the engine, end to end on a real reduced model
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("llama3.2-1b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _run(model, params, *, slots, reqs, prompt_len=4, gen=3, paged=None,
         warmup_steps=0):
    engine = ServeEngine(model, params, slots=slots,
                         max_len=prompt_len + gen, paged=paged,
                         warmup_steps=warmup_steps)
    requests = [ServeRequest(rid=i, prompt_len=prompt_len, gen=gen)
                for i in range(reqs)]
    return engine.run(requests), requests


def test_engine_completes_all_requests(small_model):
    model, params = small_model
    rep, requests = _run(model, params, slots=2, reqs=3, gen=3)
    assert rep["requests"] == 3 and rep["total_tokens"] == 9
    assert rep["decode_tokens"] == 6          # first token of each: prefill
    for r in requests:
        assert r.done and len(r.tokens) == r.gen
        assert r.first_token_s >= r.arrival_s
        assert r.done_s >= r.first_token_s
        assert r.prefill_ms > 0.0
        assert len(r.token_latencies_ms) == r.gen - 1
    # 3 requests through 2 slots: the third waited for a retirement
    assert {r.slot for r in requests} == {0, 1}


def test_engine_recycles_slots_and_blocks(small_model):
    model, params = small_model
    cfg = model.cfg
    paged = PagedKV.for_model(cfg, slots=2, max_len=7)
    rep, _ = _run(model, params, slots=2, reqs=4, paged=paged)
    assert rep["kv"]["failed_allocs"] == 0
    assert paged.blocks_in_use == 0           # every retirement freed blocks
    assert rep["kv"]["peak_blocks"] <= paged.n_blocks
    assert rep["requests"] == 4


def test_engine_report_shape(small_model):
    model, params = small_model
    rep, _ = _run(model, params, slots=2, reqs=2)
    for key in ("tok_s_steady", "p50_ms", "p99_ms", "prefill_ms_mean",
                "decode_steps", "wall_s", "per_request"):
        assert key in rep
    assert len(rep["per_request"]) == 2
    for pr in rep["per_request"]:
        assert pr["tokens"] == 3
    assert rep["p99_ms"] >= rep["p50_ms"] >= 0.0


def test_engine_single_token_requests(small_model):
    # gen == 1: the prefill token completes the request, no decode steps
    model, params = small_model
    rep, requests = _run(model, params, slots=2, reqs=2, gen=1)
    assert all(r.done and len(r.tokens) == 1 for r in requests)
    assert rep["decode_tokens"] == 0 and rep["decode_steps"] == 0


def _cim_test_model(name="serve-chaos-test", resident=True):
    from repro.configs.base import ArchConfig

    cfg = ArchConfig(name=name, family="dense", n_layers=1,
                     d_model=16, n_heads=4, n_kv_heads=2, head_dim=8,
                     d_ff=32, vocab_size=64, dtype="float32",
                     tensor_parallel=False, cim_mlp_bits=8,
                     cim_attention_bits=8, cim_unroll_groups=True,
                     cim_resident=resident)
    model = build(cfg)
    return model, model.init(jax.random.PRNGKey(1))


def _fresh_cim():
    from repro.cim import clear_schedule_cache
    from repro.cim import cost as cost_mod
    from repro.cim import faults, ledger
    from repro.cim.array import clear_resident, set_current_spec
    ledger().reset()
    clear_resident()
    clear_schedule_cache()
    cost_mod.reset_plan_stats()
    set_current_spec(None)
    faults.uninstall()
    faults.reset_fault_stats()


def _serve_cim(model, params, *, reqs=2, gen=4, spec=None, **kw):
    from repro.cim.array import DEFAULT_SPEC, resident_set
    spec = spec or DEFAULT_SPEC
    rs = resident_set(spec)
    paged = PagedKV.for_model(model.cfg, spec=spec, slots=2,
                              max_len=4 + gen, resident_set=rs)
    engine = ServeEngine(model, params, slots=2, max_len=4 + gen,
                         cim_lower=True, paged=paged, warmup_steps=0,
                         spec=spec, **kw)
    requests = [ServeRequest(rid=i, prompt_len=4, gen=gen)
                for i in range(reqs)]
    return engine.run(requests), requests, engine


def test_engine_report_surfaces_offload_plan_stats():
    """With cim_lower the report carries the cost model's offload decision
    counters (repro.cim.cost.PLAN_STATS): plans were cut for the lowered
    decode, every eligible eqn of the unbanked paths wins under the
    default edp policy, and the counters mirror the module state."""
    from repro.cim import cost as cost_mod
    from repro.configs.base import ArchConfig

    cfg = ArchConfig(name="serve-offload-test", family="dense", n_layers=1,
                     d_model=16, n_heads=4, n_kv_heads=2, head_dim=8,
                     d_ff=32, vocab_size=64, dtype="float32",
                     tensor_parallel=False, cim_mlp_bits=8,
                     cim_attention_bits=8, cim_unroll_groups=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    cost_mod.reset_plan_stats()
    engine = ServeEngine(model, params, slots=1, max_len=4, cim_lower=True,
                         warmup_steps=0)
    rep = engine.run([ServeRequest(rid=0, prompt_len=2, gen=2)])
    off = rep["offload"]
    assert off == cost_mod.PLAN_STATS
    assert off["plans"] > 0
    assert off["eqns_lowered"] > 0
    # unbanked placements always win the edp comparison: nothing demoted
    assert off["eqns_demoted"] == 0 and off["demoted_accesses"] == 0


# ---------------------------------------------------------------------------
# chaos: the self-healing serve loop under injected faults
# ---------------------------------------------------------------------------


class TestChaos:
    def test_bit_exact_under_single_bit_resident_faults(self):
        """Single-bit faults on ECC-protected resident planes: the served
        tokens are bit-identical to the fault-free run, every error
        corrected, zero uncorrected (the tentpole acceptance). BER is set
        high enough that this tiny model's resident footprint sees flips."""
        from repro.cim import faults
        from repro.cim.array import set_resident_ecc

        model, params = _cim_test_model()
        _fresh_cim()
        clean, _, _ = _serve_cim(model, params)
        clean_ids = [r["token_ids"] for r in clean["per_request"]]

        _fresh_cim()
        set_resident_ecc(True)
        try:
            with faults.faults(faults.FaultConfig(
                    seed=11, resident_ber=1e-3,
                    raise_on_uncorrectable=True)) as fm:
                chaos, _, _ = _serve_cim(model, params)
        finally:
            set_resident_ecc(False)
            _fresh_cim()
        assert [r["token_ids"] for r in chaos["per_request"]] == clean_ids
        assert fm.injected > 0 and fm.corrected == fm.injected
        assert fm.uncorrected == 0
        assert chaos["faults"]["corrected"] > 0
        assert chaos["faults"]["uncorrected"] == 0
        assert chaos["faults"]["ecc_uncorrected"] == 0

    def test_uncorrectable_triggers_repair_and_retry(self):
        """A forced double-bit error raises mid-decode; the engine counts
        a repair, re-pins from the host weights, retries the step, and the
        output is STILL bit-identical to the fault-free run."""
        from repro.cim import faults
        from repro.cim.array import set_resident_ecc

        model, params = _cim_test_model()
        _fresh_cim()
        clean, _, _ = _serve_cim(model, params)
        clean_ids = [r["token_ids"] for r in clean["per_request"]]

        _fresh_cim()
        set_resident_ecc(True)
        try:
            with faults.faults(faults.FaultConfig(
                    seed=0, uncorrectable_at_verify=(2,),
                    raise_on_uncorrectable=True)) as fm:
                chaos, _, engine = _serve_cim(model, params)
        finally:
            set_resident_ecc(False)
            _fresh_cim()
        assert engine.repairs >= 1
        assert chaos["faults"]["repairs"] >= 1
        assert fm.uncorrected >= 1              # detected, then repaired
        assert [r["token_ids"] for r in chaos["per_request"]] == clean_ids

    def test_retry_budget_exhaustion_raises(self):
        from repro.cim import faults
        from repro.cim.array import set_resident_ecc

        model, params = _cim_test_model()
        _fresh_cim()
        set_resident_ecc(True)
        try:
            # every verify uncorrectable: the budget cannot save the step
            with faults.faults(faults.FaultConfig(
                    seed=0, uncorrectable_at_verify=tuple(range(200)),
                    raise_on_uncorrectable=True)):
                with pytest.raises(Exception):
                    _serve_cim(model, params, retry_budget=1)
        finally:
            set_resident_ecc(False)
            _fresh_cim()

    def test_mid_run_bank_kill_completes_all_requests(self):
        """One bank killed mid-run: the engine fails over (degraded spec,
        paged KV migrated, weights re-pinned), every admitted request
        completes, and the report shows the failover + zero uncorrected."""
        from repro.cim import faults
        from repro.cim.array import DEFAULT_SPEC, spec_override

        model, params = _cim_test_model()
        _fresh_cim()
        try:
            with faults.faults(faults.FaultConfig(
                    seed=5, kill_bank_at=(2, 1))) as fm:
                rep, requests, engine = _serve_cim(model, params, gen=6)
        finally:
            _fresh_cim()
        assert fm.bank_kills == 1
        assert engine.failovers == 1
        assert engine.spec.disabled_banks == (1,)
        assert engine.spec != DEFAULT_SPEC
        assert spec_override() is None          # _fresh_cim restored it
        for r in requests:
            assert r.done and len(r.tokens) == r.gen
        assert rep["completed"] == len(requests)
        assert rep["shed"] == 0
        assert rep["faults"]["failovers"] == 1
        assert rep["faults"]["uncorrected"] == 0
        assert rep["faults"]["ecc_uncorrected"] == 0
        # KV reservations all live on surviving banks
        assert 1 not in engine.paged.rs.rows_per_bank()

    def test_bank_kill_tokens_match_healthy_run(self):
        """Failover is value-transparent: the degraded-geometry run emits
        the same tokens (remap is bit-exact; host demotion is bit-exact)."""
        from repro.cim import faults

        model, params = _cim_test_model()
        _fresh_cim()
        clean, _, _ = _serve_cim(model, params, gen=6)
        clean_ids = [r["token_ids"] for r in clean["per_request"]]
        _fresh_cim()
        try:
            with faults.faults(faults.FaultConfig(
                    seed=5, kill_bank_at=(2, 0))):
                chaos, _, _ = _serve_cim(model, params, gen=6)
        finally:
            _fresh_cim()
        assert [r["token_ids"] for r in chaos["per_request"]] == clean_ids


class TestAdmissionControl:
    def test_timeout_sheds_stale_requests(self, small_model):
        model, params = small_model
        engine = ServeEngine(model, params, slots=1, max_len=7,
                             warmup_steps=0, timeout_s=0.0)
        # the second request is due immediately but can never be admitted
        # within a 0-second wait while the first owns the only slot
        reqs = [ServeRequest(rid=0, prompt_len=4, gen=3),
                ServeRequest(rid=1, prompt_len=4, gen=3)]
        rep = engine.run(reqs)
        assert rep["shed"] == 1 and engine.shed_count == 1
        assert reqs[1].shed and not reqs[1].tokens
        assert reqs[0].done
        assert rep["completed"] == 1
        shed_reports = [r for r in rep["per_request"] if r["shed"]]
        assert len(shed_reports) == 1 and shed_reports[0]["rid"] == 1

    def test_queue_limit_sheds_excess_from_tail(self, small_model):
        model, params = small_model
        engine = ServeEngine(model, params, slots=1, max_len=7,
                             warmup_steps=0, queue_limit=1)
        reqs = [ServeRequest(rid=i, prompt_len=4, gen=3) for i in range(4)]
        rep = engine.run(reqs)
        # 1 admitted immediately + 1 queued; the rest shed from the tail
        assert rep["shed"] == 2
        assert sum(1 for r in reqs if r.done) == 2
        assert reqs[3].shed                     # tail shed first

    def test_all_shed_report_is_safe(self, small_model):
        """Every request shed: the report builds without crashing, with
        empty-sample percentiles at 0.0 (the _percentile guard end-to-end)
        and decode_tokens pinned at 0, not negative. slots=0 models a
        fully-failed engine draining its queue: nothing can ever be
        admitted, so the 0-second timeout sheds every due request."""
        model, params = small_model
        engine = ServeEngine(model, params, slots=0, max_len=7,
                             warmup_steps=0, queue_limit=0, timeout_s=0.0)
        reqs = [ServeRequest(rid=i, prompt_len=4, gen=3) for i in range(3)]
        rep = engine.run(reqs)
        assert rep["shed"] == 3 and rep["completed"] == 0
        assert rep["total_tokens"] == 0 and rep["decode_tokens"] == 0
        assert rep["p50_ms"] == 0.0 and rep["p99_ms"] == 0.0
        assert rep["tok_s_steady"] == 0.0
        assert all(r["shed"] for r in rep["per_request"])

"""Serve-engine tests: paged KV block table + continuous batching.

`PagedKV` is pure bookkeeping (block pool + ResidentSet reservations) and
is tested exhaustively; the `ServeEngine` tests run a real reduced model
through the queue and assert the request lifecycle invariants — completion,
monotone timestamps, per-request attribution, slot/block recycling — not
wall-clock numbers, which are machine-dependent and belong to the gated
serve bench.
"""
import jax
import pytest

from repro.cim import CimOpError
from repro.cim.array import ArraySpec, ResidentSet
from repro.configs import get_config
from repro.launch.paged_kv import PagedKV
from repro.launch.serve import ServeEngine, ServeRequest, _percentile
from repro.models import build

SPEC = ArraySpec(banks=2, subarrays=1, rows=64, bitline_words=32)


# ---------------------------------------------------------------------------
# paged KV block table
# ---------------------------------------------------------------------------


class TestPagedKV:
    def test_alloc_extend_free(self):
        kv = PagedKV(spec=SPEC, n_blocks=4, block_tokens=4)
        assert kv.alloc(0, 6)                    # 6 tokens -> 2 blocks
        assert kv.blocks_in_use == 2
        assert kv.extend(0, 2)                   # fills block 2, no claim
        assert kv.blocks_in_use == 2
        assert kv.extend(0, 1)                   # 9th token -> 3rd block
        assert kv.blocks_in_use == 3
        kv.free(0)
        assert kv.blocks_in_use == 0
        assert kv.stats().peak_blocks == 3

    def test_alloc_is_all_or_nothing(self):
        kv = PagedKV(spec=SPEC, n_blocks=2, block_tokens=4)
        assert not kv.alloc(0, 12)               # needs 3 of 2 blocks
        assert kv.blocks_in_use == 0             # partial claim rolled back
        assert kv.stats().failed_allocs == 1
        assert kv.alloc(0, 8)                    # pool still usable

    def test_double_alloc_rejected(self):
        kv = PagedKV(spec=SPEC, n_blocks=4, block_tokens=4)
        kv.alloc(0, 4)
        with pytest.raises(ValueError):
            kv.alloc(0, 4)
        with pytest.raises(ValueError):
            kv.extend(99)

    def test_bank_alignment(self):
        kv = PagedKV(spec=SPEC, n_blocks=8, block_tokens=4)
        assert [kv.bank_of_block(b) for b in range(4)] == [0, 1, 0, 1]

    def test_reservations_drive_resident_rows(self):
        rs = ResidentSet(SPEC)
        kv = PagedKV(spec=SPEC, n_blocks=4, block_tokens=4, kv_bits=16,
                     resident_set=rs)
        assert kv.alloc(0, 8)                    # blocks 0,1 -> banks 0,1
        assert rs.rows_per_bank() == {0: 16, 1: 16}
        kv.free(0)
        assert rs.resident_rows == 0             # reservations released

    def test_failed_reservation_rolls_back_block(self):
        # 3 rows of reserve budget: the 16-row KV reservation cannot fit
        rs = ResidentSet(SPEC, reserve_rows=61)
        kv = PagedKV(spec=SPEC, n_blocks=4, block_tokens=4, kv_bits=16,
                     resident_set=rs)
        assert not kv.alloc(0, 4)
        assert kv.blocks_in_use == 0 and len(rs) == 0
        assert kv.stats().failed_allocs == 1

    def test_reservations_are_not_evictable_by_pins(self):
        from repro.cim import PlanePack
        import jax.numpy as jnp
        rs = ResidentSet(SPEC)
        kv = PagedKV(spec=SPEC, n_blocks=8, block_tokens=4, kv_bits=16,
                     resident_set=rs)
        assert kv.alloc(0, 32)                   # 8 blocks: 64 rows/bank
        with pytest.raises(CimOpError, match="reservation"):
            rs.pin("w", PlanePack.pack(jnp.arange(8), 8, signed=False))
        assert kv.blocks_in_use == 8             # KV untouched

    def test_for_model_sizing(self):
        cfg = get_config("llama3.2-1b").reduced()
        kv = PagedKV.for_model(cfg, spec=SPEC, slots=3, max_len=16)
        words_per_token = 2 * cfg.kv_dim * cfg.n_layers
        expect_bt = max(1, SPEC.tile_words // words_per_token)
        assert kv.block_tokens == expect_bt
        assert kv.n_blocks == 3 * (-(-16 // expect_bt))
        # the pool holds exactly slots * max_len tokens
        assert kv.n_blocks * kv.block_tokens >= 3 * 16


def test_percentile():
    assert _percentile([], 50) == 0.0
    assert _percentile([7.0], 99) == 7.0
    xs = [float(i) for i in range(101)]      # 0..100: index == percentile
    assert _percentile(xs, 50) == 50.0
    assert _percentile(xs, 99) == 99.0
    assert _percentile(xs, 0) == 0.0
    assert _percentile(list(reversed(xs)), 100) == 100.0


# ---------------------------------------------------------------------------
# the engine, end to end on a real reduced model
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("llama3.2-1b").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _run(model, params, *, slots, reqs, prompt_len=4, gen=3, paged=None,
         warmup_steps=0):
    engine = ServeEngine(model, params, slots=slots,
                         max_len=prompt_len + gen, paged=paged,
                         warmup_steps=warmup_steps)
    requests = [ServeRequest(rid=i, prompt_len=prompt_len, gen=gen)
                for i in range(reqs)]
    return engine.run(requests), requests


def test_engine_completes_all_requests(small_model):
    model, params = small_model
    rep, requests = _run(model, params, slots=2, reqs=3, gen=3)
    assert rep["requests"] == 3 and rep["total_tokens"] == 9
    assert rep["decode_tokens"] == 6          # first token of each: prefill
    for r in requests:
        assert r.done and len(r.tokens) == r.gen
        assert r.first_token_s >= r.arrival_s
        assert r.done_s >= r.first_token_s
        assert r.prefill_ms > 0.0
        assert len(r.token_latencies_ms) == r.gen - 1
    # 3 requests through 2 slots: the third waited for a retirement
    assert {r.slot for r in requests} == {0, 1}


def test_engine_recycles_slots_and_blocks(small_model):
    model, params = small_model
    cfg = model.cfg
    paged = PagedKV.for_model(cfg, slots=2, max_len=7)
    rep, _ = _run(model, params, slots=2, reqs=4, paged=paged)
    assert rep["kv"]["failed_allocs"] == 0
    assert paged.blocks_in_use == 0           # every retirement freed blocks
    assert rep["kv"]["peak_blocks"] <= paged.n_blocks
    assert rep["requests"] == 4


def test_engine_report_shape(small_model):
    model, params = small_model
    rep, _ = _run(model, params, slots=2, reqs=2)
    for key in ("tok_s_steady", "p50_ms", "p99_ms", "prefill_ms_mean",
                "decode_steps", "wall_s", "per_request"):
        assert key in rep
    assert len(rep["per_request"]) == 2
    for pr in rep["per_request"]:
        assert pr["tokens"] == 3
    assert rep["p99_ms"] >= rep["p50_ms"] >= 0.0


def test_engine_single_token_requests(small_model):
    # gen == 1: the prefill token completes the request, no decode steps
    model, params = small_model
    rep, requests = _run(model, params, slots=2, reqs=2, gen=1)
    assert all(r.done and len(r.tokens) == 1 for r in requests)
    assert rep["decode_tokens"] == 0 and rep["decode_steps"] == 0


def test_engine_report_surfaces_offload_plan_stats():
    """With cim_lower the report carries the cost model's offload decision
    counters (repro.cim.cost.PLAN_STATS): plans were cut for the lowered
    decode, every eligible eqn of the unbanked paths wins under the
    default edp policy, and the counters mirror the module state."""
    from repro.cim import cost as cost_mod
    from repro.configs.base import ArchConfig

    cfg = ArchConfig(name="serve-offload-test", family="dense", n_layers=1,
                     d_model=16, n_heads=4, n_kv_heads=2, head_dim=8,
                     d_ff=32, vocab_size=64, dtype="float32",
                     tensor_parallel=False, cim_mlp_bits=8,
                     cim_attention_bits=8, cim_unroll_groups=True)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    cost_mod.reset_plan_stats()
    engine = ServeEngine(model, params, slots=1, max_len=4, cim_lower=True,
                         warmup_steps=0)
    rep = engine.run([ServeRequest(rid=0, prompt_len=2, gen=2)])
    off = rep["offload"]
    assert off == cost_mod.PLAN_STATS
    assert off["plans"] > 0
    assert off["eqns_lowered"] > 0
    # unbanked placements always win the edp comparison: nothing demoted
    assert off["eqns_demoted"] == 0 and off["demoted_accesses"] == 0

"""Sharding rules + elastic resharding. Multi-device cases run in a
subprocess with a forced 8-device host platform (the device count must be
set before jax initializes, so it cannot run in the main pytest process)."""
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import build
from repro.sharding import param_specs
from repro.launch.mesh import elastic_mesh_shape


def _run_subprocess(body: str):
    code = "import os\nos.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n" + \
        textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-W", "ignore", "-c", code],
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_param_specs_divisible_everywhere():
    """Every spec must divide its dim by the mesh axis size — for all archs
    (this is what jax enforces at jit time on the production mesh)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))  # structure-only mesh

    class Fake:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    for arch in ("llama3.2-1b", "qwen3-14b", "gemma-2b", "grok-1-314b",
                 "deepseek-v2-lite-16b", "granite-3-8b", "internvl2-26b",
                 "recurrentgemma-9b", "xlstm-125m", "musicgen-large"):
        cfg = get_config(arch)
        model = build(cfg)
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        specs = param_specs(cfg, params, Fake())
        leaves = jax.tree_util.tree_flatten_with_path(params)[0]
        spec_leaves = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
        assert len(leaves) == len(spec_leaves)
        for (path, leaf), spec in zip(leaves, spec_leaves):
            for dim, entry in zip(leaf.shape, tuple(spec)):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                size = int(np.prod([Fake.shape[a] for a in axes]))
                assert dim % size == 0, (arch, path, leaf.shape, spec)


def test_param_sharding_covers_big_tensors():
    """No >=2-D weight tensor may be fully replicated on the production mesh
    (param memory at 314B depends on it) — norms/scalars excepted."""
    class Fake:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    for arch in ("grok-1-314b", "qwen3-14b", "deepseek-v2-lite-16b"):
        cfg = get_config(arch)
        model = build(cfg)
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        specs = param_specs(cfg, params, Fake())
        flat = jax.tree_util.tree_flatten_with_path(params)[0]
        spec_leaves = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
        for (path, leaf), spec in zip(flat, spec_leaves):
            n = int(np.prod(leaf.shape))
            if n >= 1_000_000:   # every big tensor must shard somewhere
                assert any(e is not None for e in tuple(spec)), (arch, path, spec)


def test_elastic_mesh_planner():
    assert elastic_mesh_shape(256) == (16, 16)
    assert elastic_mesh_shape(240) == (15, 16)   # one host of 16 lost
    assert elastic_mesh_shape(192) == (12, 16)
    assert elastic_mesh_shape(8, prefer_model=16) == (1, 8)
    assert elastic_mesh_shape(7) == (1, 7)


@pytest.mark.slow
def test_sharded_train_step_runs_on_8_devices():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import build
        from repro.optim import AdamWConfig
        from repro.sharding import batch_specs, state_specs, to_named
        from repro.train import init_state, make_train_step

        cfg = get_config("llama3.2-1b").reduced()
        model = build(cfg)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        opt = AdamWConfig(lr=1e-3)
        state = init_state(model, jax.random.PRNGKey(0), opt)
        st = to_named(mesh, state_specs(cfg, state, mesh))
        state = jax.device_put(state, st)
        batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
                 "targets": jnp.zeros((8, 16), jnp.int32)}
        bs = to_named(mesh, batch_specs(cfg, batch, mesh))
        batch = jax.device_put(batch, bs)
        step = jax.jit(make_train_step(model, opt), in_shardings=(st, bs),
                       out_shardings=(st, None), donate_argnums=(0,))
        with mesh:
            state, m = step(state, batch)
        assert np.isfinite(float(m["loss"]))
        print("OK", float(m["loss"]))
    """)
    assert "OK" in out


@pytest.mark.slow
def test_elastic_restore_across_meshes():
    """Save on a 4x2 mesh, restore onto 2x4 and 8x1 — bit-identical params."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.checkpoint import CheckpointManager
        from repro.configs import get_config
        from repro.models import build
        from repro.optim import AdamWConfig
        from repro.runtime import restore_on_mesh
        from repro.sharding import state_specs, to_named
        from repro.train import init_state

        cfg = get_config("llama3.2-1b").reduced()
        model = build(cfg)
        opt = AdamWConfig()
        state = init_state(model, jax.random.PRNGKey(3), opt)

        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        state_a = jax.device_put(state, to_named(mesh_a, state_specs(cfg, state, mesh_a)))
        d = tempfile.mkdtemp()
        ckpt = CheckpointManager(d)
        ckpt.save(7, state_a, blocking=True)

        for shape in ((2, 4), (8, 1)):
            mesh_b = jax.make_mesh(shape, ("data", "model"))
            abstract = jax.tree.map(np.zeros_like, state)
            restored = restore_on_mesh(ckpt, 7, abstract, cfg, mesh_b)
            for x, y in zip(jax.tree.leaves(state_a), jax.tree.leaves(restored)):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_moe_ep_matches_reference():
    """shard_map expert parallelism == single-device MoE in the no-drop
    regime (8 devices, experts sharded 4-way, one psum per layer)."""
    out = _run_subprocess("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import moe as moe_lib
        from repro.models.moe_ep import moe_apply_ep

        cfg = get_config("grok-1-314b").reduced()
        cfg = dataclasses.replace(
            cfg, d_model=64,
            moe=dataclasses.replace(cfg.moe, n_experts=8, top_k=2,
                                    d_ff_expert=32, n_shared=0,
                                    capacity_factor=8.0))
        p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))

        y_ref, _aux = moe_lib.moe_apply(p, cfg, x)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
            y_ep = jax.jit(lambda p_, x_: moe_apply_ep(p_, cfg, x_, mesh))(p, x)
        err = float(jnp.max(jnp.abs(y_ref - y_ep)))
        assert err < 2e-5, err
        print("OK", err)
    """)
    assert "OK" in out
